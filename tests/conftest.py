import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: do NOT set XLA_FLAGS / device-count here — smoke tests must see the
# real single CPU device. Multi-device paths are tested via subprocesses
# (tests/test_multidevice.py) so they never pollute this process's backend.
