import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401
except ImportError:
    # Property tests degrade to a deterministic fixed-seed sweep when the
    # real hypothesis isn't installed (tier-1 containers can't pip install).
    import warnings
    warnings.warn("hypothesis not installed: property tests run the "
                  "deterministic fallback sweep (tests/_hypothesis_fallback.py)"
                  " — no shrinking or edge-case search", stacklevel=1)
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_fallback import install as _install_hypothesis
    _install_hypothesis(sys.modules)

# NOTE: do NOT set XLA_FLAGS / device-count here — smoke tests must see the
# real single CPU device. Multi-device paths are tested via subprocesses
# (tests/test_multidevice.py) so they never pollute this process's backend.
