import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Global determinism pin: every seeded sweep in the suite (harness
# scenario sampling, the hypothesis fallback's RNG) derives from
# REPRO_SEED, so any CI failure is replayable locally by exporting the
# seed printed in the pytest header below.
REPRO_SEED = int(os.environ.get("REPRO_SEED", "0"))

try:
    import hypothesis  # noqa: F401
except ImportError:
    # Property tests degrade to a deterministic fixed-seed sweep when the
    # real hypothesis isn't installed (tier-1 containers can't pip install).
    import warnings
    warnings.warn("hypothesis not installed: property tests run the "
                  "deterministic fallback sweep (tests/_hypothesis_fallback.py)"
                  " — no shrinking or edge-case search", stacklevel=1)
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_fallback import install as _install_hypothesis
    _install_hypothesis(sys.modules)


def pytest_report_header(config):
    return (f"repro: REPRO_SEED={REPRO_SEED} (harness scenario sampling and "
            f"the hypothesis-fallback sweep derive from it; export "
            f"REPRO_SEED=<n> to replay a failure, or replay one scenario "
            f"with `python -m repro.harness replay --seed <n>`)")

# NOTE: do NOT set XLA_FLAGS / device-count here — smoke tests must see the
# real single CPU device. Multi-device paths are tested via subprocesses
# (tests/test_multidevice.py) so they never pollute this process's backend.
