"""Event-driven fabric simulator (docs/netsim.md): exactly-once capture on
arbitrary topologies, legacy-model counter regression, Fig 10 sweeps at
512 ranks / 2 DP groups, PFC propagation, loss + retransmission, and the
mid-iteration link-failure -> `core.recovery` bit-identical resume path."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.net.pfc import PfcConfig
from repro.net.simulator import (FailureSpec, _legacy_simulate_allgather,
                                 simulate_allgather_replication,
                                 simulate_fabric, sweep_replication)


# -- exactly-once capture, any topology -------------------------------------

@given(st.integers(2, 8), st.integers(1, 3), st.integers(1, 3),
       st.integers(1, 3),
       st.sampled_from(["single", "rail", "leaf-spine"]))
@settings(max_examples=15, deadline=None)
def test_exactly_once_any_topology(rpg, groups, shadow, rf, topo):
    """Every (group, channel, chunk, replica) is captured exactly once,
    with zero drops, on every topology flavor."""
    r = simulate_fabric(groups, rpg, rpg * 8192, topology=topo,
                        n_shadow_nodes=shadow, replication_factor=rf,
                        ranks_per_leaf=4, n_spines=2)
    assert r.ring_completed
    assert r.reassembled_ok
    assert r.drops == 0
    assert r.duplicate_mirror_bytes == 0   # exactly once, not at-least-once
    assert r.missing_captures == 0
    assert sum(r.shadow_bytes.values()) == r.grad_bytes_per_group * groups * rf


def test_multi_channel_streams():
    """Per-channel shadow streams (§4.1.2) still cover the payload exactly
    once when chunks are striped over channels."""
    r = simulate_fabric(2, 6, 6 * 30000, n_channels=3, n_shadow_nodes=2,
                        ranks_per_leaf=4)
    assert r.reassembled_ok
    assert sum(r.shadow_bytes.values()) == 2 * (6 * 30000 // 6) * 6


def test_frame_coalescing_exact_counters():
    """Coalesced macro-frames keep wire-exact frame counters and byte
    totals (quantum only changes event granularity)."""
    kw = dict(n_shadow_nodes=2, replication_factor=3, topology="single")
    a = simulate_fabric(1, 4, 4 << 20, frame_quantum=1, **kw)
    b = simulate_fabric(1, 4, 4 << 20, frame_quantum=16, **kw)
    assert a.rx_frames == b.rx_frames
    assert a.tx_frames == b.tx_frames
    assert a.mirrored_frames == b.mirrored_frames
    assert a.shadow_bytes == b.shadow_bytes
    assert a.reassembled_ok and b.reassembled_ok


# -- compatibility wrapper vs the legacy per-round model ---------------------

@pytest.mark.parametrize("n_ranks", [2, 3, 5, 8])
@pytest.mark.parametrize("rf", [1, 4])
def test_wrapper_matches_legacy_counters(n_ranks, rf):
    """The event engine behind `simulate_allgather_replication` reproduces
    the legacy simulator's tx/rx ratio and reassembly verdict on the seed
    parameter grid (the regression the ISSUE pins)."""
    grad = n_ranks * 64 * 1024
    new = simulate_allgather_replication(n_ranks, grad, replication_factor=rf)
    old = _legacy_simulate_allgather(n_ranks, grad, replication_factor=rf)
    assert new.rx_frames == old.rx_frames
    assert new.tx_frames == old.tx_frames
    assert new.tx_over_rx == old.tx_over_rx
    assert new.reassembled_ok == old.reassembled_ok is True
    assert sum(new.shadow_bytes.values()) == sum(old.shadow_bytes.values())


# -- Fig 10 shape at scale ---------------------------------------------------

def test_fig10_sweep_512_ranks_two_groups():
    """Acceptance: >=512 ranks across >=2 DP groups on the rail fabric —
    TX/RX ratio grows monotonically (and sub-linearly) with the
    replication factor, capture stays exactly-once."""
    rs = sweep_replication(
        (1, 2, 4), n_dp_groups=2, ranks_per_group=256,
        grad_bytes_per_group=256 * 2048, topology="rail",
        n_shadow_nodes=2, ranks_per_leaf=32)
    ratios = [r.tx_over_rx for r in rs]
    assert all(r.reassembled_ok and r.drops == 0 for r in rs)
    assert all(r.n_ranks == 512 and r.n_dp_groups == 2 for r in rs)
    assert ratios == sorted(ratios) and ratios[0] < ratios[-1]
    # only tagged packets replicate: far below linear growth (Fig 10)
    assert ratios[-1] < 1.1
    # both rings finished and shared the fabric concurrently
    assert all(len(r.group_done_s) == 2 for r in rs)


def test_topology_sweep_1024_ranks():
    """1024 ranks across 4 DP groups on every topology flavor — the scale
    the fast path exists for.  The calendar-queue engine (fast=True) keeps
    the sweep affordable in CI while the differential suite
    (tests/test_fabric_fastpath.py) pins it bit-identical to the oracle,
    so the Fig 10 claims transfer."""
    from repro.net.simulator import sweep_topology
    rs = sweep_topology(
        ("rail", "leaf-spine"), n_dp_groups=4, ranks_per_group=256,
        grad_bytes_per_group=256 * 1024, n_shadow_nodes=4,
        replication_factor=2, ranks_per_leaf=32, fast=True)
    for name, r in rs.items():
        assert r.n_ranks == 1024 and r.n_dp_groups == 4, name
        assert r.ring_completed and r.reassembled_ok, name
        assert r.drops == 0 and r.missing_captures == 0, name
        assert r.duplicate_mirror_bytes == 0, name              # exactly once
        assert sum(r.shadow_bytes.values()) == \
            r.grad_bytes_per_group * 4 * 2, name
        assert len(r.group_done_s) == 4, name
        assert 1.0 <= r.tx_over_rx < 1.1, name                  # Fig 10 shape


# -- resource semantics ------------------------------------------------------

def test_pfc_pause_propagates_and_stays_lossless():
    """Shadow-drain incast (1 NIC, two round-0 taggers) backpressures the
    fabric via PAUSE instead of dropping (§4.3.3)."""
    r = simulate_fabric(1, 4, 4 * (2 << 20), topology="single",
                        shadow_nics=1, n_shadow_nodes=1)
    assert r.pfc_pauses > 0
    assert r.pfc_resumes > 0
    assert r.drops == 0
    assert r.reassembled_ok


def test_lossy_class_drops_and_retransmits():
    """With PFC off and tiny buffers the fabric drops: ring (training)
    frames are retransmitted by their sources and the AllGather still
    completes; mirror copies are not retransmitted (the switch keeps no
    state, §4.3.2), so the capture is marked incomplete."""
    r = simulate_fabric(1, 8, 8 * (1 << 20), topology="leaf-spine",
                        ranks_per_leaf=2, n_spines=1, spine_gbps=100.0,
                        pfc=PfcConfig(enabled=False, capacity_bytes=64 * 1024),
                        max_retx=200, max_time_s=5.0)
    assert r.drops > 0
    assert r.retransmits > 0
    assert r.ring_completed            # TCP keeps training traffic alive
    assert r.mirror_lost_frames > 0
    assert not r.reassembled_ok        # which is why the paper needs PFC


def test_frame_timestamps():
    r = simulate_fabric(2, 8, 8 * 65536, n_shadow_nodes=2, ranks_per_leaf=4)
    ring_n, ring_mean, ring_max = r.latency["ring"]
    mir_n, mir_mean, mir_max = r.latency["mirror"]
    assert ring_n > 0 and mir_n > 0
    assert 0 < ring_mean <= ring_max
    assert 0 < mir_mean <= mir_max
    assert r.duration_s >= ring_max


# -- fabric-level failure injection ------------------------------------------

MIDRUN = dict(n_dp_groups=2, ranks_per_group=64,
              grad_bytes_per_group=64 * 8192, topology="rail",
              n_shadow_nodes=2, ranks_per_leaf=16)


def _midpoint():
    return simulate_fabric(**MIDRUN).duration_s / 2


def test_spine_kill_reroutes_and_completes():
    """Killing a whole spine mid-iteration: ECMP fails over, the ring and
    the capture both still complete exactly-once."""
    r = simulate_fabric(**MIDRUN,
                        failures=[FailureSpec(_midpoint(), "switch",
                                              "spine0")])
    assert r.rerouted > 0
    assert r.ring_completed
    assert r.reassembled_ok


def test_shadow_nic_kill_loses_capture_not_training():
    """Killing a shadow access link mid-iteration: training traffic is
    untouched (zero overhead either way) but that iteration's capture is
    incomplete — the recovery trigger."""
    r = simulate_fabric(**MIDRUN,
                        failures=[FailureSpec(_midpoint(), "shadow_nic",
                                              "s0")])
    assert r.ring_completed
    assert not r.reassembled_ok
    assert r.missing_captures > 0
    assert r.mirror_lost_frames > 0


# -- failure -> core.recovery: bit-identical resume --------------------------

def test_link_failure_recovers_bit_identical():
    """End-to-end acceptance scenario, driven through the chaos harness
    (`repro.harness`): the PacketizedChannel's fabric loses iteration
    LOST's capture to a mid-iteration shadow-NIC failure, so its delivery
    arrives gated and the shadow cluster skips that apply; when the
    training node then fails, `core.recovery` consolidates at LOST-1 and
    the resumed run converges bit-identically to an uninterrupted one —
    no manual lost-step plumbing anywhere. The harness's invariants
    (exactly-once, contiguity, zero-overhead, resume-bit-identity) check
    every step; the original drill's explicit assertions are kept."""
    from repro.harness import (ChannelSpec, FabricFailure, FailureSchedule,
                               Scenario, run_scenario)

    LOST = 4                     # iteration whose capture the fabric loses
    sc = Scenario(
        name="fabric-gated-recovery", level="full", seed=11,
        steps=6, batch=2, seq=16,
        channel=ChannelSpec(kind="packetized", topology="rail-optimized",
                            n_dp_groups=2, ranks_per_group=4),
        schedule=FailureSchedule(
            train_fail_steps=(LOST + 1,),
            fabric=(FabricFailure(step=LOST, kind="capture"),)))
    res = run_scenario(sc)
    assert res.passed, res.violations
    ck, stats = res.trace.checkpointer, res.trace.stats
    # the fabric gated LOST, so recovery lands one step earlier
    assert ck.skipped_steps == [LOST]
    assert ck.skipped_captures == 1
    # gated capture not counted; the post-recovery rerun of LOST is
    assert ck.n_checkpoints == stats.steps - 1 == sc.steps
    assert stats.recoveries == 1
    assert stats.recovered_at == [LOST - 1]
    for k in res.trace.ref_final["params"]:
        assert np.array_equal(res.trace.final["params"][k],
                              res.trace.ref_final["params"][k]), k
