"""Multi-device paths (shard_map PP, RS/AG capture, mini dry-run) —
run in SUBPROCESSES with forced host device counts so this process's
single-device backend stays untouched."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int, timeout=600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_pipeline_parallel_4stage():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.dist.pipeline import make_pp_mesh, pipeline_apply, \\
            gpipe_utilization
        mesh = make_pp_mesh(n_stages=4, n_data=1)
        S, M, mb, d = 4, 6, 2, 8
        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.standard_normal((S, d, d)), jnp.float32) * 0.3
        xs = jnp.asarray(rng.standard_normal((M, mb, d)), jnp.float32)
        out = pipeline_apply(lambda w, x: jnp.tanh(x @ w), ws, xs, mesh)
        ref = xs
        for i in range(S):
            ref = jnp.tanh(ref @ ws[i])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)
        assert abs(gpipe_utilization(6, 4) - 6/9) < 1e-9
        print("PP_OK")
    """, devices=4)
    assert "PP_OK" in out


def test_rs_ag_capture_semantics():
    """ReduceScatter shard concatenation == AllReduce result (exactly-once
    coverage of the reduced gradients, docs/ARCHITECTURE.md)."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.dist.collectives import ring_all_reduce_rs_ag
        mesh = jax.make_mesh((4,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        x = jnp.arange(32, dtype=jnp.float32)
        with mesh:
            full, shard = jax.jit(
                lambda t: ring_all_reduce_rs_ag(t, mesh, "data"))(x)
        # each device contributed the same x (replicated input) -> sum = 4x
        np.testing.assert_allclose(np.asarray(full), np.asarray(x) * 4)
        # the gathered shards ARE the full result: exactly-once coverage
        assert full.shape == x.shape
        print("RSAG_OK")
    """, devices=4)
    assert "RSAG_OK" in out


def test_mini_dryrun_8dev():
    """A miniature production mesh (4 data x 2 model) lower+compiles the
    real train step for a reduced arch, and the HLO analyzer finds
    collectives."""
    out = run_sub("""
        import jax, jax.numpy as jnp, json
        import repro.configs as C
        from repro.dist.sharding import ShardingRules
        from repro.launch.hlo_analysis import analyze_compiled
        from repro.models import registry
        from repro.optim import OptimizerConfig
        from repro.train.step import (abstract_train_state, build_train_step)
        from dataclasses import replace
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        cfg = replace(C.get("tinyllama-1.1b").reduced(), microbatches=2,
                      d_model=64, num_heads=4, num_kv_heads=2, head_dim=16)
        rules = ShardingRules(mesh)
        step = build_train_step(cfg, mesh, rules, OptimizerConfig(),
                                lambda s: 1e-3)
        state = abstract_train_state(cfg, rules)
        inputs = {
            "tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32,
                sharding=rules.sharding("batch", None, dims=(8, 32))),
            "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32,
                sharding=rules.sharding("batch", None, dims=(8, 32))),
        }
        with mesh:
            compiled = jax.jit(step, donate_argnums=(0,)).lower(
                state, inputs).compile()
        s = analyze_compiled(compiled)
        assert s["flops_per_device"] > 0
        assert s["collective_bytes_per_device"] > 0
        assert s["memory"]["temp_bytes"] > 0
        print("DRYRUN_OK", json.dumps(s["per_collective"]))
    """, devices=8)
    assert "DRYRUN_OK" in out


def test_production_mesh_shapes():
    out = run_sub("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert dict(m1.shape) == {"data": 16, "model": 16}
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        assert m2.devices.size == 512
        print("MESH_OK")
    """, devices=512)
    assert "MESH_OK" in out
