"""THE paper's core claim, verified structurally: capturing the reduced
gradients for Checkmate adds ZERO collectives/FLOPs-of-note to the compiled
training step (the payload is the reduce-scatter output the step already
produces). Subprocess with 8 host devices so the SPMD program is real."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_capture_adds_no_collectives():
    code = """
        import os
        import jax, jax.numpy as jnp
        from dataclasses import replace
        import repro.configs as C
        from repro.dist.sharding import ShardingRules
        from repro.launch.hlo_analysis import analyze_compiled
        from repro.optim import OptimizerConfig
        from repro.train.step import abstract_train_state, build_train_step

        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        cfg = replace(C.get("tinyllama-1.1b").reduced(), microbatches=2)
        rules = ShardingRules(mesh)
        state = abstract_train_state(cfg, rules)
        inputs = {
            "tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32,
                sharding=rules.sharding("batch", None, dims=(8, 32))),
            "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32,
                sharding=rules.sharding("batch", None, dims=(8, 32))),
        }
        out = {}
        for rg in (False, True):
            step = build_train_step(cfg, mesh, rules, OptimizerConfig(),
                                    lambda s: 1e-3, return_grads=rg)
            with mesh:
                c = jax.jit(step, donate_argnums=(0,)).lower(
                    state, inputs).compile()
            s = analyze_compiled(c)
            out[rg] = s
        assert out[True]["collective_bytes_per_device"] == \\
            out[False]["collective_bytes_per_device"], out
        extra_flops = (out[True]["flops_per_device"]
                       - out[False]["flops_per_device"])
        assert extra_flops / out[False]["flops_per_device"] < 0.001
        print("ZERO_OVERHEAD_OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ZERO_OVERHEAD_OK" in out.stdout
