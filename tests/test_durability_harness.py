"""Durability through the chaos harness (docs/harness.md).

The golden corpus carries five durability scenarios; this file pins the
acceptance drill on top of the parametrized golden pass in
test_harness.py: a scenario that kills the ENTIRE shadow plane mid-run
recovers via `restore_from_tiers()` to the newest flushed step,
bit-identical to the reference trainer, and the zero-flush-stall
invariant holds everywhere flushing is on.
"""
import dataclasses
import json

import pytest

from repro.harness import (GOLDEN, REGISTRY, DurabilitySpec, Scenario,
                           ShadowPlaneLoss, TierFailure, run_scenario,
                           sample_scenario)


def test_durability_invariants_registered():
    for name in ("zero-flush-stall", "tier-restore", "torn-delta"):
        assert name in REGISTRY, name


def test_golden_corpus_has_durability_coverage():
    dur = [n for n, s in GOLDEN.items() if s.durability.enabled]
    assert set(dur) >= {"durability-clean", "shadow-plane-loss",
                        "flush-lag", "tier-failure-fallback",
                        "compressed-flush"}
    assert any(s.schedule.plane_loss for s in GOLDEN.values())
    assert any(s.schedule.tier_fail for s in GOLDEN.values())


def test_shadow_plane_loss_recovers_from_tiers():
    """Acceptance drill: every channel + shadow node dies at step 4; the
    run survives on `restore_from_tiers()` alone and the restored replica
    is bit-identical to the reference trainer at the flushed step."""
    sc = GOLDEN["shadow-plane-loss"]
    res = run_scenario(sc)
    assert res.passed, res.violations
    (pl,) = res.trace.plane_losses
    assert pl["total"] is True
    assert pl["step"] == 4
    assert pl["durable_hint"] == ("local-disk", 4)
    assert pl["restored_step"] == 4           # every_steps=1: zero lag
    assert sorted(pl["dead_nodes"]) == list(range(sc.shadow_nodes))
    # the run CONTINUED past the loss: later steps exist and replayed
    assert res.trace.records[-1].step == sc.steps


def test_flush_lag_bounds_the_restore_point():
    """every_steps=2 with the plane lost at step 5: the tier can only
    hold step 4, and that is exactly where restore lands."""
    sc = GOLDEN["flush-lag"]
    res = run_scenario(sc)
    assert res.passed, res.violations
    (pl,) = res.trace.plane_losses
    assert pl["step"] == 5 and pl["restored_step"] == 4


def test_tier_failure_falls_back_across_tiers():
    sc = GOLDEN["tier-failure-fallback"]
    assert any(tf.tier == "local-disk" for tf in sc.schedule.tier_fail)
    res = run_scenario(sc)
    assert res.passed, res.violations


def test_sampled_plane_loss_scenario_passes():
    sc = sample_scenario(1057)
    assert sc.durability.enabled and sc.schedule.plane_loss
    res = run_scenario(sc)
    assert res.passed, res.violations


def test_scenario_json_round_trips_durability_fields():
    sc = GOLDEN["tier-failure-fallback"]
    back = Scenario.from_json(json.loads(json.dumps(sc.to_json())))
    assert back == sc
    assert back.durability.object_store
    assert back.schedule.tier_fail == sc.schedule.tier_fail
    sc2 = GOLDEN["shadow-plane-loss"]
    back2 = Scenario.from_json(json.loads(json.dumps(sc2.to_json())))
    assert back2 == sc2 and back2.schedule.plane_loss


def _reject(sc, match):
    with pytest.raises(ValueError, match=match):
        sc.validate()


def test_validation_rejects_incoherent_durability_specs():
    base = GOLDEN["shadow-plane-loss"]
    # plane loss without a durability plane: nothing to restore from
    _reject(dataclasses.replace(base, durability=DurabilitySpec()),
            "durability")
    # plane loss with compressed flushing: restore is lossy, the
    # bit-identity invariant cannot apply
    _reject(dataclasses.replace(
        base, durability=dataclasses.replace(base.durability,
                                             compress=True)), "compress")
    # plane loss out of step range
    _reject(dataclasses.replace(
        base, schedule=dataclasses.replace(
            base.schedule, plane_loss=(ShadowPlaneLoss(step=99),))), "step")
    # tier failure naming a tier the scenario doesn't run
    clean = GOLDEN["durability-clean"]
    _reject(dataclasses.replace(
        clean, schedule=dataclasses.replace(
            clean.schedule,
            tier_fail=(TierFailure(step=2, tier="object-store"),))),
        "object")
