"""Elastic scaling: a shadow-consolidated checkpoint restores onto a
DIFFERENT mesh (changed DP width) and training continues identically —
the restart path a 1000+-node deployment needs after losing a slice.
Subprocess: multi-device meshes."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code, devices, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_elastic_restore_across_meshes():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        import repro.configs as C
        from repro.core.buckets import layout_for_tree
        from repro.core.recovery import state_from_checkpoint
        from repro.core.shadow import ShadowCluster
        from repro.data.synthetic import SyntheticStream, device_batch
        from repro.dist.sharding import ShardingRules
        from repro.optim import OptimizerConfig
        from repro.train.step import build_train_step, make_train_state

        cfg = C.get("tinyllama-1.1b").reduced()
        opt = OptimizerConfig(lr=1e-3)

        def mesh_of(dp, tp):
            return jax.make_mesh((dp, tp), ("data", "model"),
                devices=jax.devices()[:dp*tp],
                axis_types=(jax.sharding.AxisType.Auto,) * 2)

        # phase 1: train 3 steps on a (4 data, 2 model) mesh w/ shadow
        mesh_a = mesh_of(4, 2)
        rules_a = ShardingRules(mesh_a)
        state = make_train_state(jax.random.PRNGKey(0), cfg, rules_a)
        shadow = ShadowCluster(layout_for_tree(state.params), opt, n_nodes=2)
        shadow.bootstrap(state.params, state.mu, state.nu, 0)
        step_a = jax.jit(build_train_step(cfg, mesh_a, rules_a, opt,
                                          lambda s: 1e-3))
        stream = SyntheticStream(cfg, 8, 32, seed=0)
        with mesh_a:
            for t in range(3):
                batch = device_batch(stream.batch_at(t), rules_a)
                state, m, g = step_a(state, batch)
                shadow.on_gradients(t + 1, 1e-3,
                                    {k: np.asarray(v) for k, v in g.items()})

        # phase 2: "pod lost" -> restore onto (2 data, 4 model), keep going
        ckpt = shadow.consolidate()
        assert ckpt["step"] == 3
        mesh_b = mesh_of(2, 4)
        rules_b = ShardingRules(mesh_b)
        state_b = state_from_checkpoint(ckpt, cfg, rules_b)
        # SPMD-vs-CPU-replay agreement: <= 1 ULP f32 (the paper's own
        # "8th decimal place" criterion, §6.5); bitwise equality holds for
        # identical compile contexts (test_shadow/test_recovery).
        for k in state_b.params:
            np.testing.assert_allclose(np.asarray(state_b.params[k]),
                                       np.asarray(state.params[k]),
                                       rtol=1e-6, atol=1e-7)
        step_b = jax.jit(build_train_step(cfg, mesh_b, rules_b, opt,
                                          lambda s: 1e-3))
        with mesh_b:
            batch = device_batch(stream.batch_at(3), rules_b)
            state_b, m_b, _ = step_b(state_b, batch)

        # reference: continue on the original mesh with the same batch
        with mesh_a:
            batch = device_batch(stream.batch_at(3), rules_a)
            state_a, m_a, _ = step_a(state, batch)
        # continuing on a DIFFERENT mesh changes bf16 reduction orders, so
        # the comparison is loss-level, not elementwise (resharding changes
        # numerics slightly in any framework).
        assert abs(float(m_a["loss"]) - float(m_b["loss"])) < 5e-3
        assert int(state_b.step) == 4
        print("ELASTIC_OK", float(m_a["loss"]), float(m_b["loss"]))
    """, devices=8)
    assert "ELASTIC_OK" in out


def test_fsdp_zero1_capture_compiles():
    """FSDP + ZeRO-1 (the paper's §8 'future work' combo) lowers with the
    gradient capture on a multi-device mesh."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from dataclasses import replace
        import repro.configs as C
        from repro.dist.sharding import ShardingRules
        from repro.launch.hlo_analysis import analyze_compiled
        from repro.optim import OptimizerConfig
        from repro.train.step import abstract_train_state, build_train_step

        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        cfg = replace(C.get("granite-34b").reduced(), microbatches=2,
                      d_model=128, d_ff=256, num_heads=4, num_kv_heads=1,
                      head_dim=32, fsdp=True)
        rules = ShardingRules(mesh, fsdp=True)
        step = build_train_step(cfg, mesh, rules, OptimizerConfig(),
                                lambda s: 1e-3)
        state = abstract_train_state(cfg, rules)
        inputs = {
            "tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32,
                sharding=rules.sharding("batch", None, dims=(8, 32))),
            "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32,
                sharding=rules.sharding("batch", None, dims=(8, 32))),
        }
        with mesh:
            c = jax.jit(step, donate_argnums=(0,)).lower(state,
                                                         inputs).compile()
        s = analyze_compiled(c)
        assert s["flops_per_device"] > 0
        assert s["per_collective"].get("all-gather", 0) > 0   # FSDP gathers
        print("FSDP_OK")
    """, devices=8)
    assert "FSDP_OK" in out
