"""Elastic recovery: a shadow-consolidated checkpoint restores onto a
DIFFERENT mesh (changed DP width, FSDP flip) and training continues — the
restart path a 1000+-node deployment needs after losing a slice with no
hot spare. The mesh is chosen by `repro.core.costmodel.plan_elastic_mesh`
and realized by `repro.core.elastic`; captures flow through the
first-class `CheckmateCheckpointer.on_step` path. Subprocess cases cover
multi-device meshes; the tier case runs on the smoke mesh."""
import os
import subprocess
import sys
import textwrap

import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code, devices, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_elastic_restore_across_meshes():
    """DP 4 x TP 2 -> lose ranks 4..7 -> replan DP 2 x TP 2 on the
    survivors, restore through `recover(new_rules=...)`, keep training."""
    out = run_sub("""
        import numpy as np, jax
        import repro.configs as C
        from repro.core.buckets import layout_for_tree
        from repro.core.channel import InProcessChannel, StepEvent
        from repro.core.checkpoint import CheckmateCheckpointer
        from repro.core.costmodel import ElasticMeshBudget, plan_elastic_mesh
        from repro.core.elastic import rules_from_plan
        from repro.core.recovery import recover
        from repro.core.shadow import ShadowCluster
        from repro.data.synthetic import SyntheticStream, device_batch
        from repro.optim import OptimizerConfig
        from repro.train.step import build_train_step, make_train_state

        cfg = C.get("tinyllama-1.1b").reduced()
        opt = OptimizerConfig(lr=1e-3)
        budget = ElasticMeshBudget(model_parallel=2)

        # phase 1: the healthy world — 8 ranks as (4 data, 2 model),
        # captures through the first-class checkpointer path
        plan_a = plan_elastic_mesh(8, budget)
        assert plan_a.mesh_shape == (4, 2) and not plan_a.dropped
        rules_a = rules_from_plan(plan_a)
        mesh_a = rules_a.mesh
        state = make_train_state(jax.random.PRNGKey(0), cfg, rules_a)
        shadow = ShadowCluster(layout_for_tree(state.params), opt,
                               n_nodes=2)
        shadow.bootstrap(state.params, state.mu, state.nu, 0)
        ck = CheckmateCheckpointer(shadow, channel=InProcessChannel())
        step_a = jax.jit(build_train_step(cfg, mesh_a, rules_a, opt,
                                          lambda s: 1e-3))
        stream = SyntheticStream(cfg, 8, 32, seed=0)
        with mesh_a:
            for t in range(3):
                batch = device_batch(stream.batch_at(t), rules_a)
                state, m, g = step_a(state, batch)
                ck.on_step(StepEvent(
                    step=t + 1, lr=1e-3,
                    grads={k: np.asarray(v) for k, v in g.items()}))
        assert ck.n_checkpoints == 3

        # phase 2: ranks 4..7 lost -> replan on the survivors and land
        # the consolidated checkpoint on the shrunken mesh
        plan_b = plan_elastic_mesh(range(4), budget)
        assert plan_b.dp == 2 and plan_b.mesh_shape == (2, 2)
        rules_b = rules_from_plan(plan_b)
        state_b, resume = recover(ck.shadow, cfg, rules_a,
                                  new_rules=rules_b)
        assert resume == 3 and int(state_b.step) == 3
        # SPMD-vs-CPU-replay agreement: <= 1 ULP f32 (the paper's own
        # "8th decimal place" criterion, par.6.5); bitwise equality holds
        # for identical compile contexts (test_shadow/test_recovery).
        for k in state_b.params:
            np.testing.assert_allclose(np.asarray(state_b.params[k]),
                                       np.asarray(state.params[k]),
                                       rtol=1e-6, atol=1e-7)
        step_b = jax.jit(build_train_step(cfg, rules_b.mesh, rules_b, opt,
                                          lambda s: 1e-3))
        with rules_b.mesh:
            batch = device_batch(stream.batch_at(3), rules_b)
            state_b, m_b, _ = step_b(state_b, batch)

        # reference: continue on the original mesh with the same batch
        with mesh_a:
            batch = device_batch(stream.batch_at(3), rules_a)
            state_a, m_a, _ = step_a(state, batch)
        # continuing on a DIFFERENT mesh changes bf16 reduction orders, so
        # the comparison is loss-level, not elementwise (resharding changes
        # numerics slightly in any framework).
        assert abs(float(m_a["loss"]) - float(m_b["loss"])) < 5e-3
        assert int(state_b.step) == 4
        print("ELASTIC_OK", float(m_a["loss"]), float(m_b["loss"]))
    """, devices=8)
    assert "ELASTIC_OK" in out


def test_fsdp_to_pure_dp_restore():
    """An FSDP-sharded run restores onto a smaller pure-DP (replicated)
    mesh: the planner flips the split, the consolidated host tree lands
    exactly, and the next step compiles and runs."""
    out = run_sub("""
        import numpy as np, jax
        import repro.configs as C
        from repro.core.buckets import layout_for_tree
        from repro.core.channel import InProcessChannel, StepEvent
        from repro.core.checkpoint import CheckmateCheckpointer
        from repro.core.costmodel import ElasticMeshBudget, plan_elastic_mesh
        from repro.core.elastic import rules_from_plan
        from repro.core.recovery import recover
        from repro.core.shadow import ShadowCluster
        from repro.data.synthetic import SyntheticStream, device_batch
        from repro.optim import OptimizerConfig
        from repro.train.step import build_train_step, make_train_state

        cfg = C.get("tinyllama-1.1b").reduced()
        opt = OptimizerConfig(lr=1e-3)

        plan_a = plan_elastic_mesh(4, ElasticMeshBudget(), fsdp=True)
        assert plan_a.fsdp and plan_a.dp == 4
        rules_a = rules_from_plan(plan_a)
        state = make_train_state(jax.random.PRNGKey(1), cfg, rules_a)
        shadow = ShadowCluster(layout_for_tree(state.params), opt,
                               n_nodes=2)
        shadow.bootstrap(state.params, state.mu, state.nu, 0)
        ck = CheckmateCheckpointer(shadow, channel=InProcessChannel())
        step_a = jax.jit(build_train_step(cfg, rules_a.mesh, rules_a, opt,
                                          lambda s: 1e-3))
        stream = SyntheticStream(cfg, 8, 32, seed=1)
        with rules_a.mesh:
            for t in range(2):
                batch = device_batch(stream.batch_at(t), rules_a)
                state, m, g = step_a(state, batch)
                ck.on_step(StepEvent(
                    step=t + 1, lr=1e-3,
                    grads={k: np.asarray(v) for k, v in g.items()}))

        # the shrunken world drops FSDP: 2 survivors, fully replicated
        plan_b = plan_elastic_mesh(2, ElasticMeshBudget())
        assert not plan_b.fsdp and plan_b.dp == 2
        rules_b = rules_from_plan(plan_b)
        state_b, resume = recover(ck.shadow, cfg, rules_a,
                                  new_rules=rules_b)
        assert resume == 2
        for k in state_b.params:
            np.testing.assert_allclose(np.asarray(state_b.params[k]),
                                       np.asarray(state.params[k]),
                                       rtol=1e-6, atol=1e-7)
        step_b = jax.jit(build_train_step(cfg, rules_b.mesh, rules_b, opt,
                                          lambda s: 1e-3))
        with rules_b.mesh:
            batch = device_batch(stream.batch_at(2), rules_b)
            state_b, m_b, _ = step_b(state_b, batch)
        assert int(state_b.step) == 3
        print("FSDP_DP_OK")
    """, devices=4)
    assert "FSDP_DP_OK" in out


def test_recover_from_tiers_onto_reconfigured_mesh(tmp_path):
    """Total plane loss + elastic mesh change in ONE recovery: the tiers
    are read with the OLD capture layout (they wrote those records) and
    only the final device_put targets the new rules — the smoke mesh's
    FSDP flip, the layout change a single device can express."""
    import jax

    import repro.configs as C
    from repro.core.buckets import layout_for_tree
    from repro.core.channel import InProcessChannel, StepEvent
    from repro.core.checkpoint import CheckmateCheckpointer
    from repro.core.recovery import recover
    from repro.core.shadow import ShadowCluster
    from repro.data.synthetic import SyntheticStream, device_batch
    from repro.dist.sharding import ShardingRules, make_smoke_mesh
    from repro.durability import DurableShadow, LocalDiskTier
    from repro.optim import OptimizerConfig
    from repro.train.step import build_train_step, make_train_state

    cfg = C.get("tinyllama-1.1b").reduced()
    opt = OptimizerConfig(lr=1e-3)
    rules_a = ShardingRules(make_smoke_mesh())
    state = make_train_state(jax.random.PRNGKey(0), cfg, rules_a)
    shadow = ShadowCluster(layout_for_tree(state.params), opt, n_nodes=2)
    dur = DurableShadow([LocalDiskTier(tmp_path)]).attach(shadow)
    shadow.bootstrap(state.params, state.mu, state.nu, 0)
    ck = CheckmateCheckpointer(shadow, channel=InProcessChannel())
    step_fn = jax.jit(build_train_step(cfg, rules_a.mesh, rules_a, opt,
                                       lambda s: 1e-3))
    stream = SyntheticStream(cfg, 4, 16, seed=0)
    try:
        with rules_a.mesh:
            for t in range(3):
                batch = device_batch(stream.batch_at(t), rules_a)
                state, m, g = step_fn(state, batch)
                ck.on_step(StepEvent(
                    step=t + 1, lr=1e-3,
                    grads={k: np.asarray(v) for k, v in g.items()}))
        dur.drain()
        for n in list(shadow.nodes):        # the WHOLE plane dies
            shadow.kill_node(n.node_id)

        rules_b = ShardingRules(make_smoke_mesh(), fsdp=True)
        state_b, resume = recover(shadow, cfg, rules_a,
                                  tiers=dur.tiers, new_rules=rules_b)
        assert resume == 3
        for k in state_b.params:
            assert np.array_equal(np.asarray(state_b.params[k]),
                                  np.asarray(state.params[k])), k
        for k in state_b.mu:
            assert np.array_equal(np.asarray(state_b.mu[k]),
                                  np.asarray(state.mu[k])), k
        step_b = jax.jit(build_train_step(cfg, rules_b.mesh, rules_b, opt,
                                          lambda s: 1e-3))
        with rules_b.mesh:
            batch = device_batch(stream.batch_at(3), rules_b)
            state_b, m2, _ = step_b(state_b, batch)
        assert int(state_b.step) == 4
    finally:
        shadow.shutdown()


def test_fsdp_zero1_capture_compiles():
    """FSDP + ZeRO-1 (the paper's §8 'future work' combo) lowers with the
    gradient capture on a multi-device mesh."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from dataclasses import replace
        import repro.configs as C
        from repro.dist.sharding import ShardingRules
        from repro.launch.hlo_analysis import analyze_compiled
        from repro.optim import OptimizerConfig
        from repro.train.step import abstract_train_state, build_train_step

        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        cfg = replace(C.get("granite-34b").reduced(), microbatches=2,
                      d_model=128, d_ff=256, num_heads=4, num_kv_heads=1,
                      head_dim=32, fsdp=True)
        rules = ShardingRules(mesh, fsdp=True)
        step = build_train_step(cfg, mesh, rules, OptimizerConfig(),
                                lambda s: 1e-3)
        state = abstract_train_state(cfg, rules)
        inputs = {
            "tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32,
                sharding=rules.sharding("batch", None, dims=(8, 32))),
            "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32,
                sharding=rules.sharding("batch", None, dims=(8, 32))),
        }
        with mesh:
            c = jax.jit(step, donate_argnums=(0,)).lower(state,
                                                         inputs).compile()
        s = analyze_compiled(c)
        assert s["flops_per_device"] > 0
        assert s["per_collective"].get("all-gather", 0) > 0   # FSDP gathers
        print("FSDP_OK")
    """, devices=8)
    assert "FSDP_OK" in out
