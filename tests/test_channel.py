"""GradientChannel delivery API: in-process vs packetized equivalence
(bit-identical shadow state over random layouts/topologies), compressed
bounded divergence (error-feedback invariant), gated-delivery semantics,
capture accounting, consolidation timeouts, and the deprecation shims."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.buckets import layout_for_tree
from repro.core.channel import (CompressedChannel, InProcessChannel,
                                PacketizedChannel, StepEvent)
from repro.core.checkpoint import SyncCheckpointer
from repro.core.shadow import ShadowCluster
from repro.dist.compression import compress_tree, init_error_feedback
from repro.optim import OptimizerConfig, apply_updates, init_state


def _tree(n_leaves: int, seed: int) -> dict:
    rng = np.random.default_rng(seed)
    return {f"leaf{k}": rng.standard_normal((6 + 2 * k, 5))
            .astype(np.float32) for k in range(n_leaves)}


def _drive(channel, layout, params, grad_steps, opt=None, n_nodes=2):
    """Push ``grad_steps`` through ``channel`` into a fresh shadow cluster;
    returns the consolidated checkpoint."""
    opt = opt or OptimizerConfig(lr=1e-3)
    shadow = ShadowCluster(layout, opt, n_nodes=n_nodes)
    zeros = {k: np.zeros_like(v) for k, v in params.items()}
    shadow.bootstrap(params, zeros, zeros, 0)
    channel.open(layout)
    for step, grads in enumerate(grad_steps, start=1):
        channel.send(StepEvent(step=step, grads=grads, lr=1e-3))
        for d in channel.poll():
            assert d.complete
            shadow.on_delivery(d)
    channel.close()
    return shadow.consolidate()


# -- equivalence: the transport must not change the checkpoint ---------------

@given(st.integers(1, 4), st.sampled_from([1024, 4096, 1 << 16]),
       st.integers(1, 3), st.sampled_from([1, 2]), st.sampled_from([2, 4]),
       st.sampled_from(["single", "rail-optimized", "leaf-spine"]))
@settings(max_examples=6, deadline=None)
def test_inprocess_packetized_bit_identical(n_leaves, cap, n_nodes,
                                            n_groups, rpg, topo):
    """InProcessChannel and PacketizedChannel (loss-free fabric) produce
    bit-identical ShadowCluster.consolidate() output over random bucket
    layouts, DP-group counts, and topologies."""
    params = _tree(n_leaves, seed=n_leaves * 7 + cap % 97)
    layout = layout_for_tree(params, cap_bytes=cap)
    rng = np.random.default_rng(42)
    grad_steps = [{k: rng.standard_normal(v.shape).astype(np.float32) * 0.01
                   for k, v in params.items()} for _ in range(2)]

    a = _drive(InProcessChannel(), layout, params, grad_steps,
               n_nodes=n_nodes)
    b = _drive(PacketizedChannel(topology=topo, n_dp_groups=n_groups,
                                 ranks_per_group=rpg, ranks_per_leaf=4),
               layout, params, grad_steps, n_nodes=n_nodes)
    assert a["step"] == b["step"] == 2
    for k in a["params"]:
        assert np.array_equal(a["params"][k], b["params"][k]), k
        assert np.array_equal(a["mu"][k], b["mu"][k]), k
        assert np.array_equal(a["nu"][k], b["nu"][k]), k


def test_packetized_gated_delivery():
    """A fabric failure surfaces as a gated (complete=False) delivery that
    the shadow refuses; the next step is clean again (one-shot failure)."""
    params = _tree(3, seed=0)
    layout = layout_for_tree(params, cap_bytes=4096)
    chan = PacketizedChannel(ranks_per_group=4, failures_at={2: "capture"})
    chan.open(layout)
    shadow = ShadowCluster(layout, OptimizerConfig(lr=1e-3), n_nodes=2)
    zeros = {k: np.zeros_like(v) for k, v in params.items()}
    shadow.bootstrap(params, zeros, zeros, 0)
    for step in (1, 2, 3):
        chan.send(StepEvent(step=step, grads=params, lr=1e-3))
    ds = chan.poll()
    assert [d.complete for d in ds] == [True, False, True]
    assert ds[1].grads is None and ds[1].missing_captures > 0
    assert ds[1].fabric.ring_completed      # training was NOT affected
    with pytest.raises(ValueError, match="gated"):
        shadow.on_delivery(ds[1])


# -- compressed channel: EF bit-identity + bounded divergence ----------------

def test_compressed_channel_matches_reference_stream():
    """The channel's internal compressor is bit-identical to the reference
    compress_tree chain: a training state applying the reference dequantized
    stream equals the shadow state fed through CompressedChannel."""
    params = _tree(3, seed=1)
    layout = layout_for_tree(params, cap_bytes=4096)
    opt = OptimizerConfig(lr=1e-3)
    rng = np.random.default_rng(5)
    raw_steps = [{k: rng.standard_normal(v.shape).astype(np.float32) * 0.01
                  for k, v in params.items()} for _ in range(3)]

    state = init_state({k: jnp.asarray(v) for k, v in params.items()})
    apply_fn = jax.jit(lambda s, g: apply_updates(s, g, opt, 1e-3))
    ef = init_error_feedback(params)
    for raw in raw_steps:
        deq, ef, _ = compress_tree(raw, ef)
        state = apply_fn(state, deq)

    ckpt = _drive(CompressedChannel(InProcessChannel()), layout, params,
                  raw_steps, opt=opt)
    for k in params:
        assert np.array_equal(np.asarray(state.params[k]),
                              ckpt["params"][k]), k


def test_compressed_channel_error_feedback_divergence_bound():
    """With momentum-free SGD the EF invariant is sharp: the shadow (which
    consumed the compressed stream) diverges from raw-gradient training by
    exactly lr * residual — bounded by one quantization step, not by the
    number of iterations."""
    lr = 0.1
    opt = OptimizerConfig(name="sgd", momentum=0.0, lr=lr, weight_decay=0.0)
    params = _tree(2, seed=2)
    layout = layout_for_tree(params, cap_bytes=4096)
    rng = np.random.default_rng(9)
    raw_steps = [{k: rng.standard_normal(v.shape).astype(np.float32)
                  for k, v in params.items()} for _ in range(4)]

    chan = CompressedChannel(InProcessChannel())
    shadow = ShadowCluster(layout, opt, n_nodes=2)
    zeros = {k: np.zeros_like(v) for k, v in params.items()}
    shadow.bootstrap(params, zeros, zeros, 0)
    chan.open(layout)
    for step, grads in enumerate(raw_steps, start=1):
        chan.send(StepEvent(step=step, grads=grads, lr=lr))
        for d in chan.poll():
            shadow.on_delivery(d)
    ckpt = shadow.consolidate()

    raw = {k: v.copy() for k, v in params.items()}       # p -= lr * g, f32
    for grads in raw_steps:
        for k in raw:
            raw[k] = (raw[k] - np.float32(lr) * grads[k]).astype(np.float32)

    ef = {k: np.asarray(v) for k, v in chan.compressor.ef.items()}
    for k in params:
        div = ckpt["params"][k] - raw[k]
        # p_shadow - p_raw == lr * ef_T (the un-applied residual mass)
        np.testing.assert_allclose(div, lr * ef[k], atol=5e-6)
        assert np.max(np.abs(div)) <= lr * np.max(np.abs(ef[k])) + 5e-6
    assert chan.compressor.ratio > 3.5               # it really compressed
    assert any(np.any(ckpt["params"][k] != raw[k]) for k in params)


# -- capture accounting (failure drills run through the chaos harness) -------

def test_gated_capture_accounting():
    """A gated capture produces NO checkpoint (neither n_checkpoints nor
    the stall accounting moves; skipped_captures/skipped_steps record it)
    AND desynchronizes the stream: without a resync the shadow refuses
    later applies, staying frozen at the last fully-captured step instead
    of manufacturing a state that skipped the lost gradient. Driven by
    the harness (`resync=False` = events without state_fn); the
    stall-accounting and contiguity invariants check every step."""
    from repro.harness import (ChannelSpec, FabricFailure, FailureSchedule,
                               Scenario, run_scenario)
    sc = Scenario(
        name="gated-capture-frozen", seed=3, steps=3, n_leaves=2,
        shadow_nodes=1, resync=False,
        channel=ChannelSpec(kind="packetized"),
        schedule=FailureSchedule(fabric=(
            FabricFailure(step=2, kind="capture"),)))
    res = run_scenario(sc)
    assert res.passed, res.violations
    ck = res.trace.checkpointer
    assert ck.n_checkpoints == 1
    assert ck.skipped_captures == 2          # the gap AND the refused step 3
    assert ck.skipped_steps == [2, 3]
    # frozen: contiguity preserved at the last fully-captured step
    assert res.trace.final_shadow["step"] == 1
    assert all(r.stall == 0.0 for r in res.trace.records if r.gated)
    assert ck.stall_total == res.trace.records[0].stall  # gated adds none


def test_gated_capture_resyncs_from_state_fn():
    """When the next StepEvent carries state_fn (as the training loop's
    always do — harness `resync=True`), the checkpointer heals the gap
    with a full-state copy: the resync counts as that step's checkpoint
    and the stream resumes."""
    from repro.harness import (ChannelSpec, FabricFailure, FailureSchedule,
                               Scenario, run_scenario)
    sc = Scenario(
        name="gated-capture-resync", seed=3, steps=4, n_leaves=2,
        shadow_nodes=1, resync=True,
        channel=ChannelSpec(kind="packetized"),
        schedule=FailureSchedule(fabric=(
            FabricFailure(step=2, kind="capture"),)))
    res = run_scenario(sc)
    assert res.passed, res.violations
    ck = res.trace.checkpointer
    assert ck.n_checkpoints == 3                 # steps 1, 3 (copy), 4
    assert ck.skipped_captures == 1
    assert ck.skipped_steps == [2]
    assert ck.resyncs == [3]
    assert res.trace.final_shadow["step"] == 4

    # restore() clears the desync too: recovery rewinds training onto the
    # shadow state, so the resumed stream is contiguous by construction
    sc2 = Scenario(
        name="gated-restore-clears-desync", seed=4, steps=2, n_leaves=2,
        shadow_nodes=1, resync=False,
        channel=ChannelSpec(kind="packetized"),
        schedule=FailureSchedule(
            train_fail_steps=(2,),
            fabric=(FabricFailure(step=1, kind="capture"),)))
    res2 = run_scenario(sc2)
    assert res2.passed, res2.violations
    ck2 = res2.trace.checkpointer
    # gated step 1, failure at 2 -> restore() rewound to the bootstrap
    # state (step 0) and both steps replayed cleanly
    replayed = [r for r in res2.trace.records if not r.first_seen]
    assert replayed and replayed[0].restored_step == 0
    assert ck2.n_checkpoints == 2
    assert res2.trace.final_shadow["step"] == 2


# -- consolidation timeout ---------------------------------------------------

def test_consolidate_timeout_reports_laggards():
    """A wedged shadow worker can no longer hang recovery: consolidate
    honors its deadline end-to-end and reports the lagging node ids. The
    harness's wedge drill installs the wedge before the final step's
    delivery; the consolidate-timeout invariant checks deadline, laggard
    ids, and the post-release retry."""
    from repro.harness import FailureSchedule, Scenario, run_scenario
    sc = Scenario(
        name="wedge-timeout-laggards", seed=4, steps=2, n_leaves=2,
        shadow_nodes=2, shadow_async=True,
        schedule=FailureSchedule(wedge_node=0, wedge_release_s=1.5))
    res = run_scenario(sc)
    assert res.passed, res.violations
    w = res.trace.wedge
    assert w["raised"]
    assert w["lagging"] == [0]
    assert w["partial_step"] == 1            # min across nodes: stale
    assert w["final_step"] == 2              # worker released: completes


# -- deprecation shims -------------------------------------------------------

def test_deprecated_on_gradients_still_works_and_warns():
    params = _tree(2, seed=6)
    layout = layout_for_tree(params, cap_bytes=4096)
    zeros = {k: np.zeros_like(v) for k, v in params.items()}

    old = ShadowCluster(layout, OptimizerConfig(lr=1e-3), n_nodes=2)
    old.bootstrap(params, zeros, zeros, 0)
    with pytest.warns(DeprecationWarning, match="on_gradients"):
        old.on_gradients(1, 1e-3, params)

    new = ShadowCluster(layout, OptimizerConfig(lr=1e-3), n_nodes=2)
    new.bootstrap(params, zeros, zeros, 0)
    chan = InProcessChannel()
    chan.open(layout)
    chan.send(StepEvent(step=1, grads=params, lr=1e-3))
    for d in chan.poll():
        new.on_delivery(d)

    a, b = old.consolidate(), new.consolidate()
    for k in params:
        assert np.array_equal(a["params"][k], b["params"][k]), k


def test_deprecated_kwarg_on_step_still_works_and_warns():
    st_tree = {"params": {"w": np.ones(64, np.float32)},
               "mu": {"w": np.zeros(64, np.float32)},
               "nu": {"w": np.zeros(64, np.float32)}, "step": 1}
    ck = SyncCheckpointer(freq=1)
    with pytest.warns(DeprecationWarning, match="StepEvent"):
        stall = ck.on_step(1, state_fn=lambda: st_tree, grads=None,
                           lr=1e-3, iter_time=0.01)
    assert stall >= 0.0 and ck.n_checkpoints == 1

    ck2 = SyncCheckpointer(freq=1)
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)   # new API must be clean
        ck2.on_step(StepEvent(step=1, state_fn=lambda: st_tree, lr=1e-3))
    assert ck2.n_checkpoints == 1

    with pytest.raises(TypeError):                     # no mixing
        ck2.on_step(StepEvent(step=2, state_fn=lambda: st_tree), lr=1e-3)
