"""Flat-state shadow plane: the wire layout as the native state format.

Pins the invariants the flat hot path rests on:

* flat fused apply is BIT-identical to the seed per-leaf path for every
  optimizer in UPDATE_FNS, across multi-bucket layouts, node counts, and
  sync/async mode (property test);
* ``Delivery.grads`` is a lazy zero-copy leaf view — no element is ever
  copied, for in-process and packetized transports alike;
* ``ShadowNode.apply_times`` is bounded while ``stats()`` stays exact;
* the flat one-pass compressor path is bit-identical to the leaf path.
"""
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.buckets import (FlatTreeView, alloc_flat, layout_for_tree,
                                pack_all)
from repro.core.channel import (CompressedChannel, InProcessChannel,
                                PacketizedChannel, StepEvent)
from repro.core.shadow import ShadowCluster
from repro.dist.compression import Compressor
from repro.optim import OptimizerConfig, UPDATE_FNS


def _tree(n_leaves: int, seed: int) -> dict:
    rng = np.random.default_rng(seed)
    return {f"leaf{k}": rng.standard_normal((6 + 2 * k, 5))
            .astype(np.float32) for k in range(n_leaves)}


def _drive(layout, params, grad_steps, *, flat, opt, n_nodes=2,
           async_mode=False, grad_scale=1.0, assignment=None,
           max_lag_steps=None, apply_delay_s=0.0):
    shadow = ShadowCluster(layout, opt, n_nodes=n_nodes, flat=flat,
                           async_mode=async_mode, assignment=assignment,
                           max_lag_steps=max_lag_steps)
    if apply_delay_s:
        for node in shadow.nodes:       # throttle the fused apply itself so
            orig = node._apply          # batched replays pay it per step
            node._apply = (lambda *a, _o=orig:
                           (time.sleep(apply_delay_s), _o(*a))[1])
    zeros = {k: np.zeros_like(v) for k, v in params.items()}
    shadow.bootstrap(params, zeros, zeros, 0)
    chan = InProcessChannel()
    chan.open(layout)
    for step, grads in enumerate(grad_steps, start=1):
        chan.send(StepEvent(step=step, grads=grads, lr=1e-3,
                            grad_scale=grad_scale))
        for d in chan.poll():
            shadow.on_delivery(d)
    chan.close()
    ckpt = shadow.consolidate(timeout=60)
    ckpt["shadow_stats"] = shadow.stats()
    shadow.shutdown()
    return ckpt


# -- flat == per-leaf, bitwise, everywhere ------------------------------------

@given(st.sampled_from(sorted(UPDATE_FNS)),
       st.sampled_from([256, 600, 1 << 20]),
       st.sampled_from([1, 3]), st.sampled_from([False, True]))
@settings(max_examples=8, deadline=None)
def test_flat_apply_bit_identical_to_per_leaf(opt_name, cap, n_nodes,
                                              async_mode):
    """The flat fused per-bucket apply produces the SAME bits as the seed
    per-leaf path for every functional optimizer, across bucket layouts
    (cap 256/600 give multi-bucket, 1 MiB collapses to one bucket),
    partitionings, and sync/async delivery."""
    opt = OptimizerConfig(name=opt_name, lr=1e-3)
    params = _tree(4, seed=cap % 13)
    layout = layout_for_tree(params, cap_bytes=cap)
    rng = np.random.default_rng(99)
    grad_steps = [{k: rng.standard_normal(v.shape).astype(np.float32) * 0.01
                   for k, v in params.items()} for _ in range(3)]

    a = _drive(layout, params, grad_steps, flat=True, opt=opt,
               n_nodes=n_nodes, async_mode=async_mode, grad_scale=0.7)
    b = _drive(layout, params, grad_steps, flat=False, opt=opt,
               n_nodes=n_nodes, grad_scale=0.7)
    assert a["step"] == b["step"] == 3
    for k in params:
        assert np.array_equal(a["params"][k], b["params"][k]), k
        assert np.array_equal(a["mu"][k], b["mu"][k]), k
        assert np.array_equal(a["nu"][k], b["nu"][k]), k


# -- Delivery.grads never copies ----------------------------------------------

def test_inprocess_delivery_grads_views_alias_flats():
    params = _tree(3, seed=1)
    layout = layout_for_tree(params, cap_bytes=600)
    chan = InProcessChannel()
    chan.open(layout)
    chan.send(StepEvent(step=1, grads=params, lr=1e-3))
    (d,) = chan.poll()
    assert d.flats is not None and isinstance(d.grads, FlatTreeView)
    index = layout.leaf_index()
    for name, v in params.items():
        bid, slot = index[name]
        view = d.grads[name]
        assert view.shape == v.shape
        assert np.array_equal(view, v)
        # the view aliases the flat buffer: zero copies either way
        assert np.shares_memory(view, d.flats[bid])
        d.flats[bid][slot.offset] = 123.0
        assert view.flat[0] == 123.0


def test_packetized_delivery_grads_views_alias_rx_buffer():
    """The packetized delivery's leaf views alias the fabric rx buffer
    itself — reassembly is the last time gradient bytes are touched."""
    params = _tree(3, seed=2)
    layout = layout_for_tree(params, cap_bytes=600)
    chan = PacketizedChannel(ranks_per_group=4)
    chan.open(layout)
    chan.send(StepEvent(step=1, grads=params, lr=1e-3))
    (d,) = chan.poll()
    assert d.complete
    bases = set()
    for name, v in params.items():
        bid, _ = layout.leaf_index()[name]
        view = d.grads[name]
        assert np.array_equal(view, v)          # loss-free fabric: exact bytes
        assert np.shares_memory(view, d.flats[bid])
        base = view
        while base.base is not None:
            base = base.base
        bases.add(id(base))
    assert len(bases) == 1                      # one rx buffer behind them all
    chan.close()


def test_packetized_send_reuses_wire_buffer():
    """open() hoists the topology/meta/buffer work; per-send the tx wire
    buffer is reused, not reallocated."""
    params = _tree(2, seed=3)
    layout = layout_for_tree(params, cap_bytes=600)
    chan = PacketizedChannel(ranks_per_group=4)
    chan.open(layout)
    src_before = chan._src_buf
    metas_before = chan._metas
    for step in (1, 2, 3):
        chan.send(StepEvent(step=step, grads=params, lr=1e-3))
    assert chan._src_buf is src_before
    assert chan._metas is metas_before
    ds = chan.poll()
    # rx buffers must NOT be shared across deliveries (consumers hold them)
    assert not np.shares_memory(ds[0].flats[0], ds[1].flats[0])
    chan.close()


# -- bounded apply_times, exact stats -----------------------------------------

def test_apply_times_bounded_stats_exact():
    params = _tree(2, seed=4)
    layout = layout_for_tree(params, cap_bytes=600)
    shadow = ShadowCluster(layout, OptimizerConfig(lr=1e-3), n_nodes=1,
                           apply_times_maxlen=4)
    zeros = {k: np.zeros_like(v) for k, v in params.items()}
    shadow.bootstrap(params, zeros, zeros, 0)
    chan = InProcessChannel()
    chan.open(layout)
    n_steps = 7
    for step in range(1, n_steps + 1):
        chan.send(StepEvent(step=step, grads=params, lr=1e-3))
        for d in chan.poll():
            shadow.on_delivery(d)
    node = shadow.nodes[0]
    assert len(node.apply_times) == 4           # bounded window
    assert node.apply_count == n_steps          # exact counters keep going
    st_ = shadow.stats()
    assert st_.mean_apply_s == pytest.approx(
        node.apply_total_s / node.apply_count)
    assert st_.max_apply_s == node.apply_max_s
    assert st_.max_apply_s >= max(node.apply_times)


# -- flat compressor path == leaf compressor path -----------------------------

def test_compress_flats_bit_identical_to_leaf_path():
    params = _tree(3, seed=5)
    layout = layout_for_tree(params, cap_bytes=600)
    rng = np.random.default_rng(11)
    steps = [{k: rng.standard_normal(v.shape).astype(np.float32)
              for k, v in params.items()} for _ in range(3)]

    leaf_c, flat_c = Compressor(), Compressor()
    for tree in steps:
        deq_leaf = {k: np.asarray(v) for k, v in leaf_c.compress(tree).items()}
        deq_flat = flat_c.compress_flats(layout, pack_all(layout, tree))
        view = FlatTreeView(layout, deq_flat)
        for k in params:
            assert np.array_equal(deq_leaf[k], view[k]), k
    assert leaf_c.wire_bytes_total == flat_c.wire_bytes_total
    assert leaf_c.raw_bytes_total == flat_c.raw_bytes_total
    for k in params:                            # residuals identical too
        assert np.array_equal(np.asarray(leaf_c.ef[k]), flat_c.ef[k]), k


def test_mixed_dtype_trees_bucket_per_dtype_and_stay_bit_identical():
    """Buckets never mix dtypes (a shared wire buffer would silently
    promote the narrower leaves), so flat state keeps each leaf's dtype —
    and its per-step rounding — exactly like the per-leaf path."""
    import jax.numpy as jnp
    rng = np.random.default_rng(6)
    params = {
        "a.w": rng.standard_normal((8, 4)).astype(np.float32),
        "b.w": jnp.asarray(rng.standard_normal((8, 4)), jnp.bfloat16),
        "c.w": rng.standard_normal((8, 4)).astype(np.float32),
    }
    layout = layout_for_tree(params)
    for b in layout.buckets:
        assert len({s.dtype for s in b.slots}) == 1, b
    grad_steps = [{k: rng.standard_normal((8, 4)).astype(np.float32) * 0.01
                   for k in params} for _ in range(3)]
    opt = OptimizerConfig(lr=1e-3)
    a = _drive(layout, params, grad_steps, flat=True, opt=opt)
    b = _drive(layout, params, grad_steps, flat=False, opt=opt)
    for k in params:
        assert a["params"][k].dtype == np.asarray(params[k]).dtype, k
        assert np.array_equal(a["params"][k], b["params"][k]), k
        assert np.array_equal(a["mu"][k], b["mu"][k]), k


def test_compressed_over_packetized_keeps_f32_stream_on_narrow_layout():
    """The dequantized f32 stand-in must ride the packetized wire as f32
    even when the param layout is bf16 — the wire adapts to the payload
    dtype instead of silently downcasting, so the two transports stay
    bit-identical and the EF residuals track what was actually delivered."""
    import jax.numpy as jnp
    rng = np.random.default_rng(8)
    params = {f"w{i}": jnp.asarray(rng.standard_normal((8, 4)), jnp.bfloat16)
              for i in range(3)}
    layout = layout_for_tree(params, cap_bytes=600)
    grads = {k: rng.standard_normal((8, 4)).astype(np.float32)
             for k in params}

    def deliver(chan):
        chan.open(layout)
        chan.send(StepEvent(step=1, grads=grads, lr=1e-3))
        (d,) = chan.poll()
        chan.close()
        return d

    a = deliver(CompressedChannel(InProcessChannel()))
    b = deliver(CompressedChannel(PacketizedChannel(ranks_per_group=4)))
    for bid in a.flats:
        assert a.flats[bid].dtype == np.float32
        assert b.flats[bid].dtype == np.float32
        assert np.array_equal(a.flats[bid], b.flats[bid])


def test_alloc_flat_is_xla_aligned():
    for n in (1, 7, 127, 4096):
        buf = alloc_flat(n, np.float32)
        assert buf.size == n and buf.dtype == np.float32
        assert buf.ctypes.data % 64 == 0


# -- batched K-step apply == K sequential applies, bitwise ---------------------

@given(st.sampled_from(sorted(UPDATE_FNS)),
       st.integers(1, 4),                     # lag depth K
       st.sampled_from([False, True]),        # reference: sync / async
       st.integers(0, 63))                    # sharded-assignment shuffle
@settings(max_examples=8, deadline=None)
def test_lagged_batched_apply_bit_identical(opt_name, k, ref_async, aseed):
    """A bounded-lag shadow whose workers drain K-deep backlogs in batched
    replays consolidates to the SAME bits as the unlagged path — across
    optimizers, sync/async references, random sharded assignments, and lag
    depths 1..4.  Sequential-replay semantics (not gradient summing) is the
    acceptance bar: the optimizer's moment trajectory must be untouched."""
    opt = OptimizerConfig(name=opt_name, lr=1e-3)
    params = _tree(4, seed=7)
    layout = layout_for_tree(params, cap_bytes=600)
    n_nodes = 3
    arng = np.random.default_rng(aseed)
    assignment = {b.bucket_id: int(arng.integers(0, n_nodes))
                  for b in layout.buckets}
    grng = np.random.default_rng(17)
    grad_steps = [{n: grng.standard_normal(v.shape).astype(np.float32) * 0.01
                   for n, v in params.items()} for _ in range(5)]

    lagged = _drive(layout, params, grad_steps, flat=True, opt=opt,
                    n_nodes=n_nodes, async_mode=True, grad_scale=0.7,
                    assignment=assignment, max_lag_steps=k,
                    apply_delay_s=0.004)
    ref = _drive(layout, params, grad_steps, flat=True, opt=opt,
                 n_nodes=n_nodes, async_mode=ref_async, grad_scale=0.7,
                 assignment=assignment)
    assert lagged["step"] == ref["step"] == 5
    st_ = lagged["shadow_stats"]
    assert st_.max_queue_depth <= k             # the bound held
    assert st_.max_batch <= max(k, 1)
    for name in params:
        assert np.array_equal(lagged["params"][name], ref["params"][name]), \
            name
        assert np.array_equal(lagged["mu"][name], ref["mu"][name]), name
        assert np.array_equal(lagged["nu"][name], ref["nu"][name]), name


def test_lagged_apply_exercises_batching_and_blocks_at_bound():
    """With a deliberately slow applier and bound 3, the machinery must
    actually engage: the trainer blocks at the bound (lag_waits > 0) and at
    least one multi-step batched catch-up replay runs — while staying
    bit-identical to the unthrottled reference."""
    opt = OptimizerConfig(name="adamw", lr=1e-3)
    params = _tree(3, seed=9)
    layout = layout_for_tree(params, cap_bytes=600)
    rng = np.random.default_rng(23)
    grad_steps = [{n: rng.standard_normal(v.shape).astype(np.float32) * 0.01
                   for n, v in params.items()} for _ in range(7)]

    lagged = _drive(layout, params, grad_steps, flat=True, opt=opt,
                    n_nodes=2, async_mode=True, max_lag_steps=3,
                    apply_delay_s=0.02)
    ref = _drive(layout, params, grad_steps, flat=True, opt=opt, n_nodes=2)
    st_ = lagged["shadow_stats"]
    assert st_.lag_waits > 0 and st_.lag_wait_s > 0.0
    assert st_.batched_applies > 0 and st_.max_batch >= 2
    assert st_.max_queue_depth <= 3
    assert lagged["step"] == ref["step"] == 7
    for name in params:
        assert np.array_equal(lagged["params"][name], ref["params"][name]), \
            name


def test_max_lag_requires_async_and_positive_bound():
    params = _tree(2, seed=10)
    layout = layout_for_tree(params, cap_bytes=600)
    opt = OptimizerConfig(lr=1e-3)
    with pytest.raises(ValueError, match="async"):
        ShadowCluster(layout, opt, async_mode=False, max_lag_steps=2)
    with pytest.raises(ValueError, match=">= 1"):
        ShadowCluster(layout, opt, async_mode=True, max_lag_steps=0)
