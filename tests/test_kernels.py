"""Pallas kernels vs ref.py oracles — shape/dtype sweeps (interpret mode)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("shape", [(128,), (1000,), (257, 129), (4, 33, 7),
                                   (128 * 256,), (3, 128, 128)])
@pytest.mark.parametrize("pdtype", [jnp.float32, jnp.bfloat16])
def test_fused_adamw_matches_ref(shape, pdtype):
    p = jnp.asarray(RNG.standard_normal(shape), pdtype)
    g = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    m = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    v = jnp.asarray(np.abs(RNG.standard_normal(shape)), jnp.float32)
    po, mo, vo = ops.fused_adamw(p, g, m, v, 5.0, 3e-4)
    pr, mr, vr = ref.adamw_ref(p, g, m, v, 5.0, 3e-4)
    np.testing.assert_allclose(np.asarray(po, np.float32),
                               np.asarray(pr, np.float32),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(mo, mr, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(vo, vr, rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("hyp", [dict(b1=0.9, b2=0.999, eps=1e-8, wd=0.0),
                                 dict(b1=0.8, b2=0.95, eps=1e-6, wd=0.2)])
def test_fused_adamw_hyperparams(hyp):
    shape = (515,)
    p = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    g = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    m = jnp.zeros(shape, jnp.float32)
    v = jnp.zeros(shape, jnp.float32)
    po, mo, vo = ops.fused_adamw(p, g, m, v, 1.0, 1e-3, **hyp)
    pr, mr, vr = ref.adamw_ref(p, g, m, v, 1.0, 1e-3, **hyp)
    np.testing.assert_allclose(po, pr, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("b,s,h,d", [(2, 128, 2, 16), (1, 256, 4, 32),
                                     (2, 64, 2, 8), (1, 64, 1, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(b, s, h, d, causal):
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32) * 0.3
    k = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32) * 0.3
    v = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    o = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    orf = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(o, orf, rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    b, s, h, d = 1, 128, 2, 32
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.bfloat16) * 0.3
    k = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.bfloat16) * 0.3
    v = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.bfloat16)
    o = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    orf = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32),
                               rtol=0.05, atol=0.05)


def test_flash_attention_uneven_blocks():
    """q and kv block sizes differ."""
    b, s, h, d = 1, 128, 1, 16
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32) * 0.5
    k = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32) * 0.5
    v = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    o = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=32)
    orf = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(o, orf, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n", [128, 1000, 12345, 128 * 300])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_packed_copy(n, dtype):
    if dtype == jnp.int32:
        x = jnp.asarray(RNG.integers(-100, 100, n), dtype)
    else:
        x = jnp.asarray(RNG.standard_normal(n), dtype)
    np.testing.assert_array_equal(np.asarray(ops.packed_copy(x)),
                                  np.asarray(x))


def test_bucket_pack_matches_ref():
    leaves = [jnp.asarray(RNG.standard_normal(s), jnp.float32)
              for s in [(3, 4), (7,), (2, 2, 2)]]
    total = sum(x.size for x in leaves)
    flat_ref = ref.bucket_pack_ref(leaves, total)
    from repro.kernels.bucket_pack import pack_leaves
    padded_total = total + ((-total) % 128)
    flat = pack_leaves(leaves, padded_total)
    np.testing.assert_array_equal(np.asarray(flat[:total]),
                                  np.asarray(flat_ref))
    back = ref.bucket_unpack_ref(flat[:total], [x.shape for x in leaves])
    for a, b in zip(back, leaves):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
