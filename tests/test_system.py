"""End-to-end system behaviour: the full Checkmate pipeline (train ->
capture -> bucket -> shadow -> consolidate -> recover) plus data pipeline
determinism and the async timeliness invariant."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.core.buckets import layout_for_tree
from repro.core.checkpoint import CheckmateCheckpointer
from repro.core.recovery import FailurePlan
from repro.core.shadow import ShadowCluster
from repro.data.synthetic import SyntheticStream
from repro.dist.sharding import ShardingRules, make_smoke_mesh
from repro.optim import OptimizerConfig
from repro.train.loop import train
from repro.train.step import make_train_state


@pytest.fixture(scope="module")
def env():
    mesh = make_smoke_mesh()
    cfg = C.get("llama3.2-3b").reduced()
    return cfg, ShardingRules(mesh), OptimizerConfig(lr=1e-3)


def test_end_to_end_checkmate_async(env):
    """Async shadow plane keeps per-iteration checkpoints bit-identical and
    keeps up with training (the §6.3 timeliness condition)."""
    cfg, rules, opt = env
    s0 = make_train_state(jax.random.PRNGKey(0), cfg, rules)
    shadow = ShadowCluster(layout_for_tree(s0.params), opt, n_nodes=2,
                           async_mode=True)
    shadow.bootstrap(s0.params, s0.mu, s0.nu, 0)
    state, stats = train(cfg, rules, steps=8, batch=4, seq=32, opt=opt,
                         state=s0, checkpointer=CheckmateCheckpointer(shadow))
    ckpt = shadow.consolidate(timeout=60)
    assert ckpt["step"] == 8
    for k in state.params:
        assert np.array_equal(np.asarray(state.params[k]),
                              ckpt["params"][k]), k
    s = shadow.stats()
    assert s.lag == 0
    assert s.mean_apply_s < max(stats.mean_iter, 1e-3) * 10
    shadow.shutdown()


def test_loss_decreases(env):
    cfg, rules, opt = env
    _, stats = train(cfg, rules, steps=12, batch=8, seq=32, opt=opt, seed=5)
    assert np.mean(stats.losses[-3:]) < np.mean(stats.losses[:3])


def test_data_determinism_and_seek():
    cfg = C.get("tinyllama-1.1b").reduced()
    a = SyntheticStream(cfg, 4, 32, seed=9)
    b = SyntheticStream(cfg, 4, 32, seed=9).seek(3)
    batches_a = [a.batch_at(i) for i in range(5)]
    np.testing.assert_array_equal(batches_a[3]["tokens"],
                                  next(b)["tokens"])
    # different steps differ
    assert not np.array_equal(batches_a[0]["tokens"],
                              batches_a[1]["tokens"])


def test_failure_without_checkpointer_raises(env):
    cfg, rules, opt = env
    with pytest.raises(RuntimeError):
        train(cfg, rules, steps=6, batch=4, seq=32, opt=opt,
              failure_plan=FailurePlan((3,)))


def test_straggler_flagging(env):
    """The loop's EMA straggler detector flags nothing on a uniform run."""
    cfg, rules, opt = env
    _, stats = train(cfg, rules, steps=8, batch=4, seq=32, opt=opt,
                     straggler_factor=50.0)
    assert stats.straggler_flags == []


def test_grads_cover_all_params(env):
    """The capture payload (grads out of train_step) covers every leaf —
    Checkmate's correctness precondition."""
    cfg, rules, opt = env
    from repro.models import registry
    from repro.train.step import build_train_step
    state = make_train_state(jax.random.PRNGKey(0), cfg, rules)
    step = jax.jit(build_train_step(cfg, rules.mesh, rules, opt,
                                    lambda s: 1e-3))
    batch = {"tokens": jnp.ones((4, 32), jnp.int32),
             "labels": jnp.ones((4, 32), jnp.int32)}
    _, _, grads = step(state, batch)
    assert set(grads) == set(registry.param_specs(cfg))
