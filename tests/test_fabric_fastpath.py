"""Differential suite for the fabric fast path (`simulate_fabric(fast=True)`).

The calendar-queue engine must be *bit-identical* to the per-frame oracle —
not statistically close: every counter, verdict, timestamp, and PFC pause
account in `FabricResult` has to match exactly, because `ChannelSpec.fast`
is serialized into scenario/bundle JSON and a violation replayed on the
other engine must reproduce the same trace.  A property sweep drives random
topologies x DP-group shapes x failure specs through both engines and
compares `dataclasses.asdict` of the results wholesale; any mismatch writes
a harness-style repro bundle (config + seed + differing fields) so the case
is replayable without re-running the sweep.
"""
import dataclasses
import json
import os
import tempfile
from pathlib import Path

import pytest
from hypothesis import given, settings, strategies as st

from repro.net.pfc import PfcConfig
from repro.net.simulator import FailureSpec, simulate_fabric

TOPOLOGIES = ("single", "rail", "leaf-spine")
FAILURE_KINDS = (None, "link", "switch", "shadow_nic")

# counters the ISSUE pins by name (the wholesale asdict comparison subsumes
# these, but a targeted list gives a readable first-divergence report)
_PINNED = ("rx_frames", "tx_frames", "mirrored_frames", "drops",
           "retransmits", "rerouted", "missing_captures",
           "duplicate_mirror_bytes", "mirror_lost_frames", "reassembled_ok",
           "ring_completed", "duration_s", "group_done_s", "pfc_pauses",
           "pfc_resumes", "pfc_pause_s", "link_pfc", "events")


def _failures(kind, topo, at_s):
    """A valid one-shot `FailureSpec` for the drawn topology (planner
    naming: single -> sw0; rail/leaf-spine -> leaf{i}/spine{i}; shadow
    hosts -> s{i})."""
    if kind is None:
        return ()
    if kind == "shadow_nic":
        target = "s0"
    elif kind == "switch":
        target = "sw0" if topo == "single" else "spine0"
    else:  # link: cut the shadow access link (single) or a leaf uplink
        target = ("s0", "sw0") if topo == "single" else ("leaf0", "spine0")
    return (FailureSpec(at_s=at_s, kind=kind, target=target),)


def _bundle(config: dict, diffs: list[str]) -> Path:
    """Write a harness-style repro bundle for a fast-vs-oracle divergence."""
    bundle_dir = Path(os.environ.get(
        "REPRO_BUNDLE_DIR",
        Path(tempfile.gettempdir()) / "repro-fastpath-bundles"))
    bundle_dir.mkdir(parents=True, exist_ok=True)
    cfg = dict(config)
    cfg["failures"] = [dataclasses.asdict(f) for f in cfg.get("failures", ())]
    cfg["pfc"] = dataclasses.asdict(cfg["pfc"]) if "pfc" in cfg else None
    payload = {
        "seed": int(os.environ.get("REPRO_SEED", "0")),
        "scenario": {"kind": "fabric-fastpath-differential", "config": cfg},
        "failing_step": None,
        "violations": [f"fast-path divergence: {d}" for d in diffs],
    }
    path = bundle_dir / "fastpath-divergence.json"
    path.write_text(json.dumps(payload, indent=2, default=str))
    return path


def _assert_identical(**config):
    oracle = simulate_fabric(fast=False, **config)
    fast = simulate_fabric(fast=True, **config)
    a, b = dataclasses.asdict(oracle), dataclasses.asdict(fast)
    if a == b:
        return oracle
    diffs = [f"{k}: oracle={a[k]!r} fast={b[k]!r}"
             for k in a if a[k] != b[k]]
    pinned = [d for d in diffs if d.split(":")[0] in _PINNED] or diffs
    path = _bundle(config, diffs)
    pytest.fail(f"fast engine diverged from the per-frame oracle on "
                f"{len(diffs)} field(s) (repro bundle: {path}):\n  "
                + "\n  ".join(pinned[:8]))


# -- the property: random shapes x failures, full-result equality ------------

@given(st.integers(1, 3),                    # DP groups
       st.integers(2, 8),                    # ranks per group
       st.integers(1, 3),                    # shadow nodes
       st.integers(1, 4),                    # replication factor
       st.sampled_from(TOPOLOGIES),
       st.sampled_from(FAILURE_KINDS),
       st.integers(10, 300))                 # failure time, microseconds
@settings(max_examples=24, deadline=None)
def test_fast_matches_oracle_everywhere(groups, rpg, shadow, rf, topo,
                                        fail, at_us):
    """Bit-exact frame counters, delivery-completeness verdicts, and
    identical timestamps / PFC pause accounting on every drawn config."""
    _assert_identical(
        n_dp_groups=groups, ranks_per_group=rpg,
        grad_bytes_per_group=rpg * 8192, topology=topo,
        n_shadow_nodes=shadow, replication_factor=rf,
        ranks_per_leaf=4, n_spines=2,
        failures=_failures(fail, topo, at_us * 1e-6))


# -- targeted corners the sweep may not hit every run -------------------------

def test_fast_matches_oracle_pfc_heavy():
    """Tiny switch buffers force PAUSE/RESUME storms; the per-link pause
    ledger (durations included) must match to the bit."""
    r = _assert_identical(
        n_dp_groups=2, ranks_per_group=6, grad_bytes_per_group=6 * 65536,
        topology="leaf-spine", n_shadow_nodes=2, replication_factor=2,
        ranks_per_leaf=4, n_spines=2,
        pfc=PfcConfig(capacity_bytes=32768, xoff_frac=0.5, xon_frac=0.3))
    assert r.pfc_pauses > 0          # the corner actually fired
    assert r.pfc_pause_s > 0.0


def test_fast_matches_oracle_lossy_retransmit():
    """PFC off -> drops + retransmissions; retry timing must line up."""
    r = _assert_identical(
        n_dp_groups=1, ranks_per_group=8, grad_bytes_per_group=8 * (1 << 20),
        topology="leaf-spine", ranks_per_leaf=2, n_spines=1,
        spine_gbps=100.0, max_retx=200, max_time_s=5.0,
        pfc=PfcConfig(enabled=False, capacity_bytes=64 * 1024))
    assert r.drops > 0 and r.retransmits > 0
    assert r.ring_completed            # TCP keeps training traffic alive
    assert not r.reassembled_ok        # mirrors are not retransmitted


def test_fast_matches_oracle_coalesced_frames():
    """Macro-frame quantum changes event granularity, not outcomes — and
    both engines must agree at every quantum."""
    for quantum in (1, 4, 16):
        _assert_identical(
            n_dp_groups=1, ranks_per_group=4, grad_bytes_per_group=4 << 18,
            topology="single", n_shadow_nodes=2, replication_factor=3,
            frame_quantum=quantum)


def test_fast_matches_oracle_multi_channel():
    """Chunks striped over channels: per-channel capture streams must
    reassemble identically on both engines."""
    _assert_identical(
        n_dp_groups=2, ranks_per_group=6, grad_bytes_per_group=6 * 30000,
        topology="rail", n_channels=3, n_shadow_nodes=2, ranks_per_leaf=4)


def test_divergence_writes_repro_bundle(tmp_path, monkeypatch):
    """The mismatch path itself: a synthetic divergence emits a replayable
    harness-style bundle naming the differing fields."""
    monkeypatch.setenv("REPRO_BUNDLE_DIR", str(tmp_path))
    cfg = dict(n_dp_groups=1, ranks_per_group=2, grad_bytes_per_group=16384,
               topology="single",
               failures=(FailureSpec(at_s=1e-4, kind="shadow_nic",
                                     target="s0"),))
    path = _bundle(cfg, ["rx_frames: oracle=10 fast=11"])
    stored = json.loads(path.read_text())
    assert stored["scenario"]["kind"] == "fabric-fastpath-differential"
    assert stored["scenario"]["config"]["failures"][0]["kind"] == "shadow_nic"
    assert stored["violations"] == [
        "fast-path divergence: rx_frames: oracle=10 fast=11"]
