"""Shadow cluster: bit-exact replication, partitioning, async timeliness
(paper §4.2, §6.5)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.core.buckets import layout_for_tree
from repro.core.shadow import ShadowCluster, plan_shadow_nodes
from repro.dist.sharding import ShardingRules, make_smoke_mesh
from repro.optim import OptimizerConfig, apply_updates, init_state
from repro.train.step import make_train_state


@pytest.fixture(scope="module")
def setup():
    mesh = make_smoke_mesh()
    cfg = C.get("tinyllama-1.1b").reduced()
    rules = ShardingRules(mesh)
    state = make_train_state(jax.random.PRNGKey(0), cfg, rules)
    return cfg, rules, state


def _random_grads(params, seed):
    rng = np.random.default_rng(seed)
    return {k: rng.standard_normal(v.shape).astype(np.float32) * 0.01
            for k, v in params.items()}


@pytest.mark.parametrize("n_nodes", [1, 3])
@pytest.mark.parametrize("opt_name", ["adamw", "adam", "sgd"])
def test_bit_exact_replication(setup, n_nodes, opt_name):
    """Shadow replay == training update, bitwise, for every optimizer the
    paper names as functional (SGD/Adam/AdamW, §4.2.4)."""
    cfg, rules, state0 = setup
    opt = OptimizerConfig(name=opt_name, lr=1e-3)
    layout = layout_for_tree(state0.params, cap_bytes=32 * 1024)
    shadow = ShadowCluster(layout, opt, n_nodes=n_nodes)
    shadow.bootstrap(state0.params, state0.mu, state0.nu, 0)

    state = state0
    apply_fn = jax.jit(lambda s, g: apply_updates(s, g, opt, 1e-3))
    for step in range(1, 4):
        grads = _random_grads(state0.params, step)
        state = apply_fn(state, {k: jnp.asarray(v) for k, v in grads.items()})
        shadow.on_gradients(step, 1e-3, grads)

    ckpt = shadow.consolidate()
    assert ckpt["step"] == 3
    for k in state.params:
        assert np.array_equal(np.asarray(state.params[k]), ckpt["params"][k]), k
        assert np.array_equal(np.asarray(state.mu[k]), ckpt["mu"][k]), k
        assert np.array_equal(np.asarray(state.nu[k]), ckpt["nu"][k]), k


def test_partition_is_disjoint_and_total(setup):
    cfg, rules, state0 = setup
    layout = layout_for_tree(state0.params, cap_bytes=32 * 1024)
    shadow = ShadowCluster(layout, OptimizerConfig(), n_nodes=4)
    all_leaves = [l for n in shadow.nodes for l in n._leaves]
    assert sorted(all_leaves) == sorted(state0.params)   # total, disjoint


def test_async_mode_and_stats(setup):
    cfg, rules, state0 = setup
    layout = layout_for_tree(state0.params)
    shadow = ShadowCluster(layout, OptimizerConfig(), n_nodes=2,
                           async_mode=True)
    shadow.bootstrap(state0.params, state0.mu, state0.nu, 0)
    for step in range(1, 6):
        shadow.on_gradients(step, 1e-3, _random_grads(state0.params, step))
    ckpt = shadow.consolidate(timeout=30)
    assert ckpt["step"] == 5
    s = shadow.stats()
    assert s.lag == 0
    assert s.mean_apply_s > 0
    shadow.shutdown()


def test_grad_scale_matches_clipped_training(setup):
    """Global-norm clipping: shadow applies the scale computed on the
    training side (metadata), staying bit-identical."""
    cfg, rules, state0 = setup
    opt = OptimizerConfig(lr=1e-3, grad_clip=0.5)
    layout = layout_for_tree(state0.params)
    shadow = ShadowCluster(layout, OptimizerConfig(lr=1e-3), n_nodes=2)
    shadow.bootstrap(state0.params, state0.mu, state0.nu, 0)

    grads = _random_grads(state0.params, 0)
    gn = float(np.sqrt(sum((g ** 2).sum() for g in grads.values())))
    scale = min(1.0, 0.5 / (gn + 1e-9))
    state = jax.jit(lambda s, g: apply_updates(s, g, opt, 1e-3))(
        state0, {k: jnp.asarray(v) for k, v in grads.items()})
    shadow.on_gradients(1, 1e-3, grads, grad_scale=scale)
    ckpt = shadow.consolidate()
    for k in state.params:
        np.testing.assert_allclose(np.asarray(state.params[k]),
                                   ckpt["params"][k], rtol=1e-6, atol=1e-7)


def test_plan_shadow_nodes(setup):
    """§4.2.4 profiling: returns a node count that fits the iteration."""
    cfg, rules, state0 = setup
    layout = layout_for_tree(state0.params)
    tree = {k: np.asarray(v) for k, v in state0.params.items()}
    n, t = plan_shadow_nodes(layout, OptimizerConfig(), iter_time_s=10.0,
                             trial_tree=tree)
    assert n == 1                      # 10s budget >> tiny model apply time
    n2, _ = plan_shadow_nodes(layout, OptimizerConfig(),
                              iter_time_s=max(t / 4, 1e-6), trial_tree=tree)
    assert n2 >= n
