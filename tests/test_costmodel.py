"""Appendix A/B cost model vs the paper's published numbers."""
import math

import pytest

from repro.core import costmodel as cm


def test_llama3_iteration_time():
    """Paper App. A: 4.58 s at 400 TFLOP/s achieved on 16384 GPUs."""
    t = cm.iteration_time(cm.LLAMA3_405B, 400e12, 16384)
    assert abs(t - 4.58) < 0.05


def test_checkpoint_time():
    """Paper App. A: 405B checkpoint over 2 TB/s ~ 1.2 s."""
    assert abs(cm.checkpoint_time(405e9) - 1.2) < 0.05


def test_thirty_minute_interval_waste():
    """Fig 1: Meta's 30-min interval wastes ~1.7M GPU-hours."""
    p = cm.CostParams()
    f30 = 30 * 60 / p.iter_time_s
    w = cm.wasted_gpu_hours_sota(f30, p)
    assert 1.5e6 < w < 2.0e6


def test_optimal_frequency_band():
    """Fig 1: best frequency ~ every 32 iterations (we get ~35)."""
    f = cm.optimal_frequency(cm.CostParams())
    assert 24 <= f <= 48


def test_sota_minimum_waste():
    """Paper: 'even at the best checkpoint frequency ... still wastes over
    300,000 GPU hours'."""
    w = cm.wasted_gpu_hours_sota_min(cm.CostParams())
    assert 3.0e5 < w < 3.6e5


def test_checkmate_waste_and_cut():
    """Paper §1: Checkmate cuts GPU waste by over 98% (4,367 GPU-hours)."""
    p = cm.CostParams()
    w = cm.wasted_gpu_hours_checkmate(p)
    assert 4.0e3 < w < 5.0e3
    cut = 1 - w / cm.wasted_gpu_hours_sota_min(p)
    assert cut > 0.98


def test_cpu_node_hours():
    """Paper App. B: 166K CPU-node hours for the shadow cluster."""
    assert abs(cm.cpu_node_hours(cm.CostParams()) - 166_000) < 1_000


def test_fig11_low_overhead_point():
    """Fig 11: at 10 ms overhead and 16,384 GPUs, ~448 GPU-hours/day."""
    p = cm.CostParams(ckpt_stall_s=0.01)
    assert abs(cm.gpu_hours_saved_per_day(p) - 448) < 30


def test_fig11_low_failure_rate():
    """§6.7: at 0.5% of Meta's failure rate, ~70,000 GPU-hours saved over
    54 days."""
    p = cm.CostParams(failure_rate=1e-6)
    total = cm.gpu_hours_saved_per_day(p) * 54
    assert 5.5e4 < total < 9e4


def test_savings_positive_and_bounded():
    p = cm.CostParams()
    assert cm.cost_checkmate(p) < cm.cost_sota_min(p)
    assert 2e6 < cm.savings_usd(p) < 4e6       # paper: ~$2.6M


def test_scaling_with_cluster_size():
    """§6.7: 'quadratic increase in wasted work with system scale'.

    At a FIXED checkpoint frequency, waste is quadratic in N -> 16x from
    4K to 16K GPUs (the paper's headline). Against an optimally *re-tuned*
    baseline (f* ~ 1/sqrt(N)), the net saving grows as N^1.5 -> 8x; both
    regimes hold in the model.
    """
    fixed_f = 512
    w4 = cm.wasted_gpu_hours_sota(fixed_f, cm.CostParams(n_gpus=4096)) \
        - cm.wasted_gpu_hours_checkmate(cm.CostParams(n_gpus=4096))
    w16 = cm.wasted_gpu_hours_sota(fixed_f, cm.CostParams(n_gpus=16384)) \
        - cm.wasted_gpu_hours_checkmate(cm.CostParams(n_gpus=16384))
    assert 14 < w16 / w4 < 18                  # ~quadratic (paper: 16x)
    s4 = cm.gpu_hours_saved_per_day(cm.CostParams(n_gpus=4096))
    s16 = cm.gpu_hours_saved_per_day(cm.CostParams(n_gpus=16384))
    assert 6.5 < s16 / s4 < 9.5                # N^1.5 vs tuned baseline
