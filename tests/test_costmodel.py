"""Appendix A/B cost model vs the paper's published numbers."""
import math

import pytest

from repro.core import costmodel as cm


def test_llama3_iteration_time():
    """Paper App. A: 4.58 s at 400 TFLOP/s achieved on 16384 GPUs."""
    t = cm.iteration_time(cm.LLAMA3_405B, 400e12, 16384)
    assert abs(t - 4.58) < 0.05


def test_checkpoint_time():
    """Paper App. A: 405B checkpoint over 2 TB/s ~ 1.2 s."""
    assert abs(cm.checkpoint_time(405e9) - 1.2) < 0.05


def test_thirty_minute_interval_waste():
    """Fig 1: Meta's 30-min interval wastes ~1.7M GPU-hours."""
    p = cm.CostParams()
    f30 = 30 * 60 / p.iter_time_s
    w = cm.wasted_gpu_hours_sota(f30, p)
    assert 1.5e6 < w < 2.0e6


def test_optimal_frequency_band():
    """Fig 1: best frequency ~ every 32 iterations (we get ~35)."""
    f = cm.optimal_frequency(cm.CostParams())
    assert 24 <= f <= 48


def test_sota_minimum_waste():
    """Paper: 'even at the best checkpoint frequency ... still wastes over
    300,000 GPU hours'."""
    w = cm.wasted_gpu_hours_sota_min(cm.CostParams())
    assert 3.0e5 < w < 3.6e5


def test_checkmate_waste_and_cut():
    """Paper §1: Checkmate cuts GPU waste by over 98% (4,367 GPU-hours)."""
    p = cm.CostParams()
    w = cm.wasted_gpu_hours_checkmate(p)
    assert 4.0e3 < w < 5.0e3
    cut = 1 - w / cm.wasted_gpu_hours_sota_min(p)
    assert cut > 0.98


def test_cpu_node_hours():
    """Paper App. B: 166K CPU-node hours for the shadow cluster."""
    assert abs(cm.cpu_node_hours(cm.CostParams()) - 166_000) < 1_000


def test_fig11_low_overhead_point():
    """Fig 11: at 10 ms overhead and 16,384 GPUs, ~448 GPU-hours/day."""
    p = cm.CostParams(ckpt_stall_s=0.01)
    assert abs(cm.gpu_hours_saved_per_day(p) - 448) < 30


def test_fig11_low_failure_rate():
    """§6.7: at 0.5% of Meta's failure rate, ~70,000 GPU-hours saved over
    54 days."""
    p = cm.CostParams(failure_rate=1e-6)
    total = cm.gpu_hours_saved_per_day(p) * 54
    assert 5.5e4 < total < 9e4


def test_savings_positive_and_bounded():
    p = cm.CostParams()
    assert cm.cost_checkmate(p) < cm.cost_sota_min(p)
    assert 2e6 < cm.savings_usd(p) < 4e6       # paper: ~$2.6M


def test_scaling_with_cluster_size():
    """§6.7: 'quadratic increase in wasted work with system scale'.

    At a FIXED checkpoint frequency, waste is quadratic in N -> 16x from
    4K to 16K GPUs (the paper's headline). Against an optimally *re-tuned*
    baseline (f* ~ 1/sqrt(N)), the net saving grows as N^1.5 -> 8x; both
    regimes hold in the model.
    """
    fixed_f = 512
    w4 = cm.wasted_gpu_hours_sota(fixed_f, cm.CostParams(n_gpus=4096)) \
        - cm.wasted_gpu_hours_checkmate(cm.CostParams(n_gpus=4096))
    w16 = cm.wasted_gpu_hours_sota(fixed_f, cm.CostParams(n_gpus=16384)) \
        - cm.wasted_gpu_hours_checkmate(cm.CostParams(n_gpus=16384))
    assert 14 < w16 / w4 < 18                  # ~quadratic (paper: 16x)
    s4 = cm.gpu_hours_saved_per_day(cm.CostParams(n_gpus=4096))
    s16 = cm.gpu_hours_saved_per_day(cm.CostParams(n_gpus=16384))
    assert 6.5 < s16 / s4 < 9.5                # N^1.5 vs tuned baseline


# -- shadow fleet planning (§4.2.4): budgets, feasibility, refusal -----------

def _layout(n_leaves=6, elems=64, cap=4):
    """Tiny metadata-only layout: ``n_leaves`` float32 leaves, ``cap``
    leaves' bytes per bucket."""
    from repro.core.buckets import build_buckets
    return build_buckets([(f"w{i}", (elems,), "float32")
                          for i in range(n_leaves)],
                         cap_bytes=cap * elems * 4)


def test_plan_shadow_nodes_minimal_when_roomy():
    plan = cm.plan_shadow_nodes(_layout())
    assert plan.n_nodes == 1
    assert plan.ram_bound == plan.nic_bound == 1
    assert plan.bytes_per_node_max <= cm.ShadowBudget().usable_ram
    assert plan.n_buckets == len(_layout().buckets)


def test_plan_shadow_nodes_ram_bound_scales_fleet():
    """Shrink per-node RAM until the aggregate state needs several nodes;
    the plan must honor the bound AND the indivisible-bucket granularity."""
    lo = _layout(n_leaves=8, elems=1024, cap=2)
    state = sum(b.size * (4 + cm.MOMENT_BYTES_PER_ELEM) for b in lo.buckets)
    budget = cm.ShadowBudget(ram_bytes_per_node=state / 3 / 0.9,
                             nic_gbps_per_node=1e6)
    plan = cm.plan_shadow_nodes(lo, budget=budget)
    assert plan.n_nodes >= plan.ram_bound >= 3
    assert plan.bytes_per_node_max <= budget.usable_ram


def test_plan_shadow_nodes_nic_bound_scales_fleet():
    lo = _layout(n_leaves=8, elems=1024, cap=2)
    # NIC absorbs ~1/3 of the wire bytes per iteration -> >= 3 nodes
    gbps = lo.total_bytes * 8.0 / 4.58 / 1e9 / 3
    plan = cm.plan_shadow_nodes(
        lo, budget=cm.ShadowBudget(nic_gbps_per_node=gbps * 1.01))
    assert plan.n_nodes >= plan.nic_bound >= 3
    assert plan.gbps_per_node_max <= gbps * 1.01 + 1e-9


def test_plan_refuses_indivisible_bucket_loudly():
    lo = _layout(n_leaves=2, elems=1024, cap=2)      # one fat bucket
    tiny = cm.ShadowBudget(ram_bytes_per_node=1024)  # < one bucket's state
    with pytest.raises(cm.ShadowPlanError, match="rebucket"):
        cm.plan_shadow_nodes(lo, budget=tiny)


def test_plan_refuses_exhausted_fleet_loudly():
    lo = _layout(n_leaves=8, elems=1024, cap=1)
    per_bucket = lo.buckets[0].size * (4 + cm.MOMENT_BYTES_PER_ELEM)
    budget = cm.ShadowBudget(ram_bytes_per_node=per_bucket / 0.9 * 1.1,
                             max_nodes=3)            # needs 8 single-bucket nodes
    with pytest.raises(cm.ShadowPlanError, match="max_nodes"):
        cm.plan_shadow_nodes(lo, budget=budget)


def test_every_config_is_shadowable_within_default_budget():
    """Acceptance: EVERY architecture in repro.configs — including
    arctic_480b and dbrx_132b — gets a feasible plan from the default
    paper-hardware budget, and the headline frontier config needs a
    genuinely sharded fleet (>= 8 nodes)."""
    import repro.configs as C
    plans = {}
    for name in C.all_archs():
        plans[name] = cm.shadow_plan_for_config(C.get(name))
        assert 1 <= plans[name].n_nodes <= cm.ShadowBudget().max_nodes, name
    assert plans["arctic-480b"].n_nodes >= 8
    assert plans["dbrx-132b"].n_nodes >= 2
    # the plan's per-node RSS proxy respects the budget everywhere
    for name, p in plans.items():
        assert p.bytes_per_node_max <= cm.ShadowBudget().usable_ram, name


# -- elastic mesh planning ----------------------------------------------------

def test_elastic_plan_widest_feasible_dp():
    plan = cm.plan_elastic_mesh(8)
    assert plan.dp == 8 and plan.n_ranks == 8 and not plan.fsdp
    assert plan.survivors == tuple(range(8)) and plan.dropped == ()
    assert plan.mesh_shape == (8, 1)
    assert plan.axis_names == ("data", "model")


def test_elastic_plan_respects_batch_divisibility():
    """7 survivors with global_batch=8: dp 7, 6, 5 don't divide the batch,
    so the plan drops to dp 4 and names the 3 idled ranks."""
    plan = cm.plan_elastic_mesh(7, cm.ElasticMeshBudget(global_batch=8))
    assert plan.dp == 4
    assert plan.survivors == (0, 1, 2, 3) and plan.dropped == (4, 5, 6)


def test_elastic_plan_flips_fsdp_under_memory_pressure():
    """State too big for one replicated rank: the planner flips to FSDP,
    dividing per-rank state by the DP width."""
    budget = cm.ElasticMeshBudget(hbm_bytes_per_rank=100.0)
    plan = cm.plan_elastic_mesh(4, budget, state_bytes=300.0)
    assert plan.fsdp and plan.dp == 4
    assert plan.state_bytes_per_rank <= budget.usable_hbm


def test_elastic_plan_model_parallel_groups():
    plan = cm.plan_elastic_mesh(8, cm.ElasticMeshBudget(model_parallel=2))
    assert plan.mesh_shape == (4, 2)
    assert plan.axis_names == ("data", "model")
    # losing two ranks leaves 6 = 3 complete TP groups
    plan = cm.plan_elastic_mesh(range(6),
                                cm.ElasticMeshBudget(model_parallel=2))
    assert plan.dp == 3 and plan.n_ranks == 6


def test_elastic_plan_refuses_loudly():
    with pytest.raises(cm.ElasticPlanError, match="min_dp"):
        cm.plan_elastic_mesh(1, cm.ElasticMeshBudget(model_parallel=2))
    with pytest.raises(cm.ElasticPlanError, match="global_batch"):
        cm.plan_elastic_mesh(3, cm.ElasticMeshBudget(global_batch=7,
                                                     min_dp=2))
    with pytest.raises(cm.ElasticPlanError):
        cm.plan_elastic_mesh(2, cm.ElasticMeshBudget(
            hbm_bytes_per_rank=10.0, allow_fsdp=False), state_bytes=1e4)
