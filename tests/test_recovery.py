"""Recovery correctness (paper §6.5 / Fig 9): interrupted-and-recovered
training is indistinguishable from uninterrupted training.

The failure drills run through the chaos harness (`repro.harness`): a
declarative Scenario drives train loop -> checkpointer -> recovery, the
invariant registry (resume-bit-identity, replay-determinism, contiguity,
stall accounting) checks every step, and the explicit assertions the
original hand-rolled drills made are kept on top of the result."""
import numpy as np
import pytest

import jax

import repro.configs as C
from repro.core.buckets import layout_for_tree
from repro.core.checkpoint import CheckmateCheckpointer
from repro.core.recovery import recover
from repro.core.shadow import ShadowCluster
from repro.durability import DurableShadow, FlushPolicy, LocalDiskTier
from repro.dist.sharding import ShardingRules, make_smoke_mesh
from repro.harness import FailureSchedule, Scenario, run_scenario
from repro.optim import OptimizerConfig
from repro.train.loop import train
from repro.train.step import make_train_state

STEPS, BATCH, SEQ, SEED = 10, 4, 32, 3


@pytest.fixture(scope="module")
def baseline():
    mesh = make_smoke_mesh()
    cfg = C.get("tinyllama-1.1b").reduced()
    rules = ShardingRules(mesh)
    opt = OptimizerConfig(lr=1e-3)
    state, stats = train(cfg, rules, steps=STEPS, batch=BATCH, seq=SEQ,
                         opt=opt, seed=SEED)
    return cfg, rules, opt, state, stats


def test_checkmate_recovery_bitwise_identical():
    """Two injected failures, recovered from the per-iteration shadow
    checkpoint, converge bit-identically to the uninterrupted reference
    (which the harness runs internally)."""
    sc = Scenario(name="recovery-bitwise", level="full", seed=SEED,
                  steps=STEPS, batch=BATCH, seq=SEQ,
                  schedule=FailureSchedule(train_fail_steps=(4, 8)))
    res = run_scenario(sc)
    assert res.passed, res.violations
    stats = res.trace.stats
    assert stats.recoveries == 2
    # per-iteration checkpointing -> recovery resumes at the failed step
    assert stats.recovered_at == [3, 7]
    for k in res.trace.ref_final["params"]:
        assert np.array_equal(res.trace.final["params"][k],
                              res.trace.ref_final["params"][k]), k
    assert stats.losses == res.trace.ref_losses


def test_repeated_work_vs_frequency():
    """A freq-5 baseline checkpointer loses work on failure (repeated
    steps), quantifying the paper's repeated-work argument."""
    sc = Scenario(name="repeated-work-sync-freq5", level="full", seed=SEED,
                  steps=STEPS, batch=BATCH, seq=SEQ,
                  checkpointer="sync", ckpt_freq=5,
                  schedule=FailureSchedule(train_fail_steps=(8,)))
    res = run_scenario(sc)
    assert res.passed, res.violations
    stats = res.trace.stats
    # failed at 8, last checkpoint at 5 -> recomputes steps 6,7 (repeated)
    assert stats.recovered_at == [5]
    assert stats.steps == STEPS + 2          # 2 repeated iterations
    for k in res.trace.ref_final["params"]:
        assert np.array_equal(res.trace.final["params"][k],
                              res.trace.ref_final["params"][k]), k


def test_elastic_restore_changes_shadow_partitioning(baseline):
    """Consolidated checkpoints restore regardless of shadow node count
    (elastic shadow plane)."""
    cfg, rules, opt, state_a, _ = baseline
    for nodes in (1, 3):
        # fresh state per run: train() donates the input state's buffers
        s0 = make_train_state(jax.random.PRNGKey(SEED), cfg, rules)
        shadow = ShadowCluster(layout_for_tree(s0.params), opt, n_nodes=nodes)
        shadow.bootstrap(s0.params, s0.mu, s0.nu, 0)
        _, stats = train(cfg, rules, steps=4, batch=BATCH, seq=SEQ, opt=opt,
                         seed=SEED, state=s0,
                         checkpointer=CheckmateCheckpointer(shadow))
        ckpt = shadow.consolidate()
        assert ckpt["step"] == 4
        assert set(ckpt["params"]) == set(s0.params)


def test_recover_falls_back_to_tiers(baseline, tmp_path):
    """`recover(tiers=...)`: a partial shadow loss merges the dead owners'
    shards from the durable tier; a TOTAL plane loss rebuilds the whole
    checkpoint from the tier — both land at the trainer's step with the
    trainer's exact values."""
    cfg, rules, opt, _, _ = baseline
    s0 = make_train_state(jax.random.PRNGKey(SEED), cfg, rules)
    shadow = ShadowCluster(layout_for_tree(s0.params), opt, n_nodes=3)
    dur = DurableShadow([LocalDiskTier(tmp_path)],
                        FlushPolicy()).attach(shadow)
    shadow.bootstrap(s0.params, s0.mu, s0.nu, 0)
    state, _ = train(cfg, rules, steps=3, batch=BATCH, seq=SEQ, opt=opt,
                     seed=SEED, state=s0,
                     checkpointer=CheckmateCheckpointer(shadow))
    dur.drain()
    ref = {k: np.asarray(v) for k, v in state.params.items()}

    shadow.kill_node(0)                       # partial: merge from tier
    st, step = recover(shadow, cfg, rules, tiers=dur.tiers)
    assert step == 3
    for k in ref:
        assert np.array_equal(np.asarray(st.params[k]), ref[k]), k

    shadow.kill_node(1)                       # total: whole plane gone
    shadow.kill_node(2)
    st, step = recover(shadow, cfg, rules, tiers=dur.tiers)
    assert step == 3
    for k in ref:
        assert np.array_equal(np.asarray(st.params[k]), ref[k]), k
    shadow.shutdown()
