"""Recovery correctness (paper §6.5 / Fig 9): interrupted-and-recovered
training is indistinguishable from uninterrupted training."""
import numpy as np
import pytest

import jax

import repro.configs as C
from repro.core.buckets import layout_for_tree
from repro.core.checkpoint import CheckmateCheckpointer, SyncCheckpointer
from repro.core.recovery import FailurePlan
from repro.core.shadow import ShadowCluster
from repro.dist.sharding import ShardingRules, make_smoke_mesh
from repro.optim import OptimizerConfig
from repro.train.loop import train
from repro.train.step import make_train_state

STEPS, BATCH, SEQ, SEED = 10, 4, 32, 3


@pytest.fixture(scope="module")
def baseline():
    mesh = make_smoke_mesh()
    cfg = C.get("tinyllama-1.1b").reduced()
    rules = ShardingRules(mesh)
    opt = OptimizerConfig(lr=1e-3)
    state, stats = train(cfg, rules, steps=STEPS, batch=BATCH, seq=SEQ,
                         opt=opt, seed=SEED)
    return cfg, rules, opt, state, stats


def test_checkmate_recovery_bitwise_identical(baseline):
    cfg, rules, opt, state_a, stats_a = baseline
    s0 = make_train_state(jax.random.PRNGKey(SEED), cfg, rules)
    shadow = ShadowCluster(layout_for_tree(s0.params), opt, n_nodes=2)
    shadow.bootstrap(s0.params, s0.mu, s0.nu, 0)
    state_b, stats_b = train(
        cfg, rules, steps=STEPS, batch=BATCH, seq=SEQ, opt=opt, seed=SEED,
        state=s0, checkpointer=CheckmateCheckpointer(shadow),
        failure_plan=FailurePlan((4, 8)))
    assert stats_b.recoveries == 2
    # per-iteration checkpointing -> recovery resumes at the failed step
    assert stats_b.recovered_at == [3, 7]
    for k in state_a.params:
        assert np.array_equal(np.asarray(state_a.params[k]),
                              np.asarray(state_b.params[k])), k
    assert stats_a.losses == stats_b.losses


def test_repeated_work_vs_frequency(baseline):
    """A freq-5 baseline checkpointer loses work on failure (repeated
    steps), quantifying the paper's repeated-work argument."""
    cfg, rules, opt, state_a, stats_a = baseline
    s0 = make_train_state(jax.random.PRNGKey(SEED), cfg, rules)
    ck = SyncCheckpointer(freq=5)
    state_b, stats_b = train(
        cfg, rules, steps=STEPS, batch=BATCH, seq=SEQ, opt=opt, seed=SEED,
        state=s0, checkpointer=ck, failure_plan=FailurePlan((8,)))
    # failed at 8, last checkpoint at 5 -> recomputes steps 6,7 (repeated)
    assert stats_b.recovered_at == [5]
    assert stats_b.steps == STEPS + 2          # 2 repeated iterations
    for k in state_a.params:
        assert np.array_equal(np.asarray(state_a.params[k]),
                              np.asarray(state_b.params[k])), k


def test_elastic_restore_changes_shadow_partitioning(baseline):
    """Consolidated checkpoints restore regardless of shadow node count
    (elastic shadow plane)."""
    cfg, rules, opt, state_a, _ = baseline
    for nodes in (1, 3):
        # fresh state per run: train() donates the input state's buffers
        s0 = make_train_state(jax.random.PRNGKey(SEED), cfg, rules)
        shadow = ShadowCluster(layout_for_tree(s0.params), opt, n_nodes=nodes)
        shadow.bootstrap(s0.params, s0.mu, s0.nu, 0)
        _, stats = train(cfg, rules, steps=4, batch=BATCH, seq=SEQ, opt=opt,
                         seed=SEED, state=s0,
                         checkpointer=CheckmateCheckpointer(shadow))
        ckpt = shadow.consolidate()
        assert ckpt["step"] == 4
        assert set(ckpt["params"]) == set(s0.params)
