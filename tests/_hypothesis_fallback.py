"""Minimal deterministic stand-in for `hypothesis` (used only when the real
package is not installed — see conftest.py).

The container that runs tier-1 cannot always install dev dependencies, so
property tests fall back to a fixed-seed random sweep over the same strategy
shapes: each `@given` case runs `max_examples` times with boundary values
first, then seeded-random draws. This keeps the *property* assertions
exercised everywhere, while real hypothesis (when present, e.g. in CI after
`pip install -e .[dev]`) still owns shrinking and edge-case search.

Supported surface (what this repo's tests use): `given`, `settings`,
`strategies.integers/floats/lists/tuples/sampled_from` and `Strategy.map`.
"""
from __future__ import annotations

import random
import types


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random, i: int):
        return self._draw(rng, i)

    def map(self, fn):
        return Strategy(lambda rng, i: fn(self._draw(rng, i)))


def _bounded(lo, hi, pick):
    # boundary values first, then random draws
    def draw(rng, i):
        if i == 0:
            return lo
        if i == 1:
            return hi
        return pick(rng)
    return draw


def integers(min_value=None, max_value=None):
    lo = -(2 ** 31) if min_value is None else min_value
    hi = 2 ** 31 if max_value is None else max_value
    return Strategy(_bounded(lo, hi, lambda rng: rng.randint(lo, hi)))


def floats(min_value=None, max_value=None, **_):
    lo = -1e9 if min_value is None else min_value
    hi = 1e9 if max_value is None else max_value
    return Strategy(_bounded(lo, hi, lambda rng: rng.uniform(lo, hi)))


def lists(elements: Strategy, min_size=0, max_size=10):
    def draw(rng, i):
        size = min_size if i == 0 else rng.randint(min_size, max_size)
        # first element follows the outer example index so element boundary
        # values (i == 0/1) are exercised deliberately, not just by luck
        return [elements.example(rng, i if k == 0 else 2 + rng.randint(0, 7))
                for k in range(size)]
    return Strategy(draw)


def tuples(*strats: Strategy):
    return Strategy(lambda rng, i: tuple(s.example(rng, i) for s in strats))


def sampled_from(seq):
    seq = list(seq)
    return Strategy(lambda rng, i: seq[i % len(seq)] if i < len(seq)
                    else rng.choice(seq))


strategies = types.SimpleNamespace(
    integers=integers, floats=floats, lists=lists, tuples=tuples,
    sampled_from=sampled_from)

_DEFAULT_MAX_EXAMPLES = 20


def settings(**kwargs):
    def deco(fn):
        fn._fallback_settings = kwargs
        return fn
    return deco


def given(*strats: Strategy):
    def deco(fn):
        conf = getattr(fn, "_fallback_settings", {})
        n = conf.get("max_examples", _DEFAULT_MAX_EXAMPLES)

        # no functools.wraps: pytest must see a zero-arg signature, not the
        # original one (strategy params would look like missing fixtures)
        def wrapper():
            # REPRO_SEED pins the sweep (printed in the pytest header by
            # conftest.py) so any failure is locally replayable
            import os
            rng = random.Random(int(os.environ.get("REPRO_SEED", "0")))
            for i in range(n):
                fn(*(s.example(rng, i) for s in strats))
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


def install(sys_modules: dict):
    """Register this module as `hypothesis` in sys.modules."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.__doc__ = __doc__
    sys_modules["hypothesis"] = mod
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "lists", "tuples", "sampled_from"):
        setattr(st_mod, name, getattr(strategies, name))
    sys_modules["hypothesis.strategies"] = st_mod
