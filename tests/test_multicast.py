"""Switch control plane + shadow routing (paper §4.3.1, §4.2.4)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.buckets import build_buckets
from repro.core.multicast import SwitchControlPlane, assign_buckets


def test_two_streams_per_dp_group():
    cp = SwitchControlPlane(n_dp_groups=128, ranks_per_group=128,
                            n_shadow_nodes=4).setup()
    assert cp.multicast_streams == 256          # paper §4.4: LLaMA3 number
    assert cp.extra_switch_ports() == 256


def test_lookup_boundary_ranks_only():
    cp = SwitchControlPlane(n_dp_groups=2, ranks_per_group=4,
                            n_shadow_nodes=1).setup()
    assert cp.lookup(0, 0) is not None
    assert cp.lookup(0, 3) is not None
    assert cp.lookup(0, 1) is None
    assert cp.lookup(1, 4) is not None          # first rank of group 1
    g = cp.lookup(0, 3)
    assert g.next_rank == 0                     # ring wraps


@given(st.integers(1, 16), st.lists(st.integers(1, 10**6), min_size=1,
                                    max_size=60))
@settings(max_examples=50, deadline=None)
def test_assignment_balanced_and_deterministic(n_nodes, sizes):
    leaves = [(f"l{i}", (s,), "float32") for i, s in enumerate(sizes)]
    layout = build_buckets(leaves, cap_bytes=1 << 20)
    a1 = assign_buckets(layout, n_nodes)
    a2 = assign_buckets(layout, n_nodes)
    assert a1 == a2                              # deterministic (recovery!)
    assert set(a1) == {b.bucket_id for b in layout.buckets}
    loads = [0] * n_nodes
    for b in layout.buckets:
        loads[a1[b.bucket_id]] += b.nbytes
    # greedy bound: max load <= mean + max bucket
    biggest = max(b.nbytes for b in layout.buckets)
    assert max(loads) <= sum(loads) / n_nodes + biggest


@given(st.integers(1, 16), st.lists(st.integers(1, 10**6), min_size=1,
                                    max_size=60))
@settings(max_examples=50, deadline=None)
def test_assignment_spread_bounded_and_shared_across_call_sites(n_nodes, sizes):
    """Byte balance: max/min node load differ by at most the largest bucket
    (greedy invariant: the heaviest node was lightest when it last received
    a bucket, and the min only grows). Every call site — training nodes,
    switch control plane, ShadowCluster — must derive the SAME mapping, or
    recovery consolidates the wrong partitions."""
    from repro.core.shadow import ShadowCluster
    from repro.optim.functional import OptimizerConfig

    leaves = [(f"l{i}", (s,), "float32") for i, s in enumerate(sizes)]
    layout = build_buckets(leaves, cap_bytes=1 << 20)
    a = assign_buckets(layout, n_nodes)
    loads = [0] * n_nodes
    for b in layout.buckets:
        loads[a[b.bucket_id]] += b.nbytes
    biggest = max(b.nbytes for b in layout.buckets)
    assert max(loads) - min(loads) <= biggest
    # independent call site (the shadow plane) derives the identical mapping
    cluster = ShadowCluster(layout, OptimizerConfig(), n_nodes=n_nodes)
    assert cluster.assignment == a
