"""Docs stay executable: every ``python`` fence in docs/*.md and README.md
runs, and relative markdown links resolve (tools/check_docs.py — the same
check the CI docs job runs)."""
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_docs  # noqa: E402


def test_docs_exist_and_linked():
    assert (ROOT / "docs" / "ARCHITECTURE.md").exists()
    assert (ROOT / "docs" / "netsim.md").exists()
    readme = (ROOT / "README.md").read_text()
    assert "docs/ARCHITECTURE.md" in readme
    assert "docs/netsim.md" in readme


def test_doc_code_blocks_execute_and_links_resolve():
    assert check_docs.main([]) == 0
