"""Chaos co-simulation harness (docs/harness.md): golden corpus passes
every invariant, random scenarios sampled from one integer pass and
replay deterministically, violation bundles reproduce bit-identically
(and replay as pytest cases), and scenario specs round-trip JSON."""
import json
import os
from pathlib import Path

import pytest
from hypothesis import given, settings

from repro.harness import (GOLDEN, ChannelSpec, FailureSchedule, Scenario,
                           replay_bundle, repro_seed, run_scenario,
                           sample_scenario, scenario_strategy)

CHANNEL_GOLDEN = sorted(n for n, s in GOLDEN.items() if s.level == "channel")


# -- golden corpus -----------------------------------------------------------

@pytest.mark.parametrize("name", CHANNEL_GOLDEN)
def test_golden_channel_scenarios_pass(name):
    """Every channel-level golden scenario passes every applicable
    invariant (the full corpus, including full-level, is the CI chaos
    job: `python -m repro.harness run --corpus golden`)."""
    result = run_scenario(GOLDEN[name])
    assert result.passed, (name, result.violations)


def test_golden_corpus_spans_the_scenario_space():
    """The corpus covers all three topologies, all three channel stacks,
    and every failure class the schedule can express."""
    scs = list(GOLDEN.values())
    assert len(scs) >= 20
    assert {s.channel.kind for s in scs} == {"inprocess", "packetized",
                                            "compressed"}
    assert {s.channel.topology for s in scs if s.channel.has_fabric} >= {
        "single", "rail-optimized", "leaf-spine"}
    kinds = {f.kind for s in scs for f in s.schedule.fabric}
    assert kinds == {"capture", "link", "switch", "shadow_nic"}
    assert any(s.schedule.train_fail_steps for s in scs)
    assert any(s.schedule.wedge_node is not None for s in scs)
    assert any(s.level == "full" for s in scs)


# -- random scenarios from one integer ---------------------------------------

def test_sample_scenario_deterministic():
    base = repro_seed()
    for seed in (base + 5, base + 81, base + 1009):
        assert sample_scenario(seed) == sample_scenario(seed)


@given(scenario_strategy(level="channel"))
@settings(max_examples=5, deadline=None)
def test_sampled_scenarios_pass_all_invariants(sc):
    """Any scenario the sampler can produce must pass — a violation here
    is a real bug, replayable from the scenario's single seed."""
    result = run_scenario(sc)
    assert result.passed, (sc.name, result.violations)


def test_sampled_run_replays_bit_identically():
    """`replay --seed N` semantics: two runs of the same sampled scenario
    produce byte-identical outcome bundles."""
    seed = repro_seed() + 333
    a = run_scenario(sample_scenario(seed, level="channel")).bundle()
    b = run_scenario(sample_scenario(seed, level="channel")).bundle()
    assert a == b


# -- violation bundles -------------------------------------------------------

def _forced_violation_scenario():
    """A scenario that deterministically violates: bit-identity is forced
    onto a compressed stream (whose shadow intentionally diverges)."""
    return Scenario(name="forced-bit-identity-on-compressed", seed=5,
                    steps=3, channel=ChannelSpec(kind="compressed"),
                    invariants=("shadow-bit-identity",))


def test_violation_emits_minimal_bundle_that_replays(tmp_path):
    result = run_scenario(_forced_violation_scenario(), bundle_dir=tmp_path)
    assert not result.passed
    assert result.failing_step == 1
    d = json.loads(result.bundle_path.read_text())
    # minimal repro: seed + scenario JSON + failing step (+ what failed),
    # plus the trailing trace window for triage (on-disk only — the
    # in-memory bundle() stays wall-clock-free for bit-identical replays)
    assert set(d) == {"seed", "scenario", "failing_step", "violations",
                      "trace_tail"}
    assert d["trace_tail"] and all("ph" in e for e in d["trace_tail"])
    assert result.bundle_path.with_suffix(".trace.json").exists() or \
        (result.bundle_path.parent
         / f"{result.scenario.name}.trace.json").exists()
    assert d["failing_step"] == 1
    assert Scenario.from_dict(d["scenario"]) == result.scenario
    _, identical = replay_bundle(result.bundle_path)
    assert identical


_BUNDLE_DIRS = [Path(__file__).parent / "bundles"]
if os.environ.get("REPRO_BUNDLE_DIR"):
    _BUNDLE_DIRS.append(Path(os.environ["REPRO_BUNDLE_DIR"]))
_BUNDLES = sorted(p for d in _BUNDLE_DIRS if d.is_dir()
                  for p in d.glob("*.json"))


@pytest.mark.parametrize("path", _BUNDLES or [None],
                         ids=[p.name for p in _BUNDLES] or ["none"])
def test_repro_bundles_replay_as_pytest_cases(path):
    """Any bundle dropped in tests/bundles/ (or $REPRO_BUNDLE_DIR, e.g. a
    CI chaos artifact) replays here bit-identically."""
    if path is None:
        pytest.skip("no repro bundles to replay")
    result, identical = replay_bundle(path)
    assert identical, (path, result.violations)


# -- scenario spec round trip ------------------------------------------------

def test_scenario_json_roundtrip():
    for seed in (repro_seed() + 2, repro_seed() + 77):
        sc = sample_scenario(seed)
        assert Scenario.from_json(sc.to_json()) == sc
    wedge = GOLDEN["wedge-consolidate"]
    assert Scenario.from_json(wedge.to_json()) == wedge
    multi = GOLDEN["multi-failure-sequence"]       # tuple targets survive
    assert Scenario.from_json(multi.to_json()) == multi


def test_fabric_mode_recorded_and_round_trips():
    """Every scenario/bundle JSON pins the fabric engine it ran on
    (`channel.fast`): a violation found on the calendar-queue fast path must
    replay on that exact engine, not silently fall back to the oracle."""
    fast = GOLDEN["slow-apply-clean"]               # fast=True golden
    assert fast.channel.fast is True
    d = fast.to_dict()
    assert d["channel"]["fast"] is True
    back = Scenario.from_dict(d)
    assert back == fast and back.channel.fast is True
    # the default stays the per-frame oracle, and it round-trips too
    oracle = GOLDEN["slow-apply-with-link-burst"]
    assert oracle.channel.fast is False
    assert oracle.to_dict()["channel"]["fast"] is False
    assert Scenario.from_dict(oracle.to_dict()).channel.fast is False
    # build() hands the flag to the transport, which hands it to the engine
    chan = fast.channel.build({}, fast.shadow_nodes)
    assert chan.fast is True
    assert oracle.channel.build({}, oracle.shadow_nodes).fast is False
    # new lagged-apply knobs survive the same round trip
    assert Scenario.from_dict(fast.to_dict()).max_lag_steps == \
        fast.max_lag_steps
    assert Scenario.from_dict(fast.to_dict()).apply_delay_s == \
        fast.apply_delay_s


def test_fast_engine_bundle_replays_on_fast_engine(tmp_path):
    """A bundle produced under fast=True replays bit-identically — and the
    replayed scenario still carries fast=True through the JSON."""
    sc = Scenario(name="forced-bit-identity-on-fast-fabric", seed=6, steps=3,
                  channel=ChannelSpec(kind="compressed", inner="packetized",
                                      fast=True),
                  invariants=("shadow-bit-identity",))
    result = run_scenario(sc, bundle_dir=tmp_path)
    assert not result.passed and result.bundle_path is not None
    stored = json.loads(result.bundle_path.read_text())
    assert stored["scenario"]["channel"]["fast"] is True
    replayed, identical = replay_bundle(result.bundle_path)
    assert identical
    assert replayed.scenario.channel.fast is True


def test_scenario_validation_rejects_inconsistent_specs():
    from repro.harness import FabricFailure
    with pytest.raises(ValueError, match="fabric"):
        Scenario(name="x", schedule=FailureSchedule(
            fabric=(FabricFailure(step=1, kind="capture"),))).validate()
    with pytest.raises(ValueError, match="async"):
        Scenario(name="x", schedule=FailureSchedule(
            wedge_node=0)).validate()
    with pytest.raises(ValueError, match="outside"):
        Scenario(name="x", steps=3,
                 channel=ChannelSpec(kind="packetized"),
                 schedule=FailureSchedule(fabric=(
                     FabricFailure(step=9, kind="capture"),))).validate()


def test_repro_seed_env(monkeypatch):
    monkeypatch.setenv("REPRO_SEED", "4242")
    assert repro_seed() == 4242
    monkeypatch.delenv("REPRO_SEED")
    assert repro_seed() == 0
    assert repro_seed(7) == 7
