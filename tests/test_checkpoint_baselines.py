"""Copy-persist baselines (§2.2/§6.2): ordering of stalls, restores,
CheckFreq tuning."""
import time

import numpy as np
import pytest

from repro.core.checkpoint import (AsyncCheckpointer, CheckFreqCheckpointer,
                                   GeminiLikeCheckpointer, NoCheckpointer,
                                   ShardedAsyncCheckpointer, SyncCheckpointer)


def _state(nbytes=8 << 20):
    n = nbytes // 4
    return {"params": {"w": np.random.default_rng(0)
                       .standard_normal(n).astype(np.float32)},
            "mu": {"w": np.zeros(n, np.float32)},
            "nu": {"w": np.zeros(n, np.float32)},
            "step": 1}


def _drive(ck, steps=6, state=None):
    state = state or _state()
    for step in range(1, steps + 1):
        st = dict(state, step=step)
        ck.on_step(step, state_fn=lambda: st, grads=None, lr=1e-3,
                   iter_time=0.01)
    ck.finalize()
    return ck


def test_no_checkpointer_zero_stall():
    ck = _drive(NoCheckpointer())
    assert ck.stall_total == 0.0
    assert ck.n_checkpoints == 0
    assert ck.restore() is None


def test_sync_stalls_most():
    state = _state()
    sync = _drive(SyncCheckpointer(freq=1), state=state)
    async_ = _drive(AsyncCheckpointer(freq=1), state=state)
    sharded = _drive(ShardedAsyncCheckpointer(freq=1, n_shards=8), state=state)
    assert sync.n_checkpoints == 6
    # per-checkpoint stall ordering (paper Fig 2): sync >= async >= sharded
    assert sync.stall_total >= async_.stall_total * 0.8
    assert async_.stall_total >= sharded.stall_total * 0.5
    assert sync.restore()["step"] == 6


def test_frequency_trades_stall():
    # share one state so both drives copy warm pages — a fresh state's
    # first copy pays the page faults, which would dominate the sparse
    # checkpointer's single checkpoint and invert the comparison
    state = _state()
    every = _drive(SyncCheckpointer(freq=1), state=state)
    sparse = _drive(SyncCheckpointer(freq=5), state=state)
    assert sparse.n_checkpoints < every.n_checkpoints
    assert sparse.stall_total < every.stall_total


def test_gemini_overlap_model():
    # long iterations -> transfer hides; short iterations -> residual stall
    # (slow network + small state so the modelled residual >> copy noise)
    ck = GeminiLikeCheckpointer(freq=1, network_gbps=0.5)
    st = _state(8 << 20)
    s_long = ck.on_step(1, state_fn=lambda: st, iter_time=2.0)
    s_short = ck.on_step(2, state_fn=lambda: st, iter_time=0.0001)
    assert s_short >= s_long + 0.05


def test_checkfreq_tunes_frequency():
    ck = CheckFreqCheckpointer(target_overhead=0.05, profile_steps=2)
    st = _state()
    for step in range(1, 10):
        ck.on_step(step, state_fn=lambda: st, iter_time=0.005)
    assert ck.tuned_freq is not None and ck.tuned_freq >= 1
