"""Property tests for heartbeat tagging (paper §4.1, Fig 4)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.tagging import (chunk_at, incast_per_round, is_tagged,
                                tag_schedule, tagged_chunks_per_rank,
                                verify_exactly_once)


@given(st.integers(min_value=1, max_value=128))
@settings(max_examples=60, deadline=None)
def test_exactly_once(n):
    """Every chunk tagged exactly once per iteration — the §4.1 invariant."""
    assert verify_exactly_once(n)


@given(st.integers(min_value=2, max_value=128))
@settings(max_examples=60, deadline=None)
def test_incast_bound(n):
    """At most TWO simultaneous taggers per round (why shadow nodes get two
    NICs, §4.1.1); round 0 has exactly two, later rounds one."""
    inc = incast_per_round(n)
    assert inc[0] == 2 or n == 2
    assert all(v <= 2 for v in inc.values())
    for rnd in range(1, n - 1):
        assert inc[rnd] == 1


@given(st.integers(min_value=2, max_value=64))
@settings(max_examples=40, deadline=None)
def test_boundary_ranks_only(n):
    """Only rank 0 (round 0) and rank n-1 tag."""
    per_rank = tagged_chunks_per_rank(n)
    assert set(per_rank) <= {0, n - 1}
    assert per_rank[0] == [chunk_at(0, 0, n)]
    assert len(per_rank[n - 1]) == n - 1


def test_figure4_example():
    """Paper Fig 4b: 4 GPUs — rank 0 tags C1 in round 0; rank 3 tags
    C0, C3, C2 across rounds."""
    per_rank = tagged_chunks_per_rank(4)
    assert per_rank[0] == [1]
    assert per_rank[3] == [0, 3, 2]


@given(st.integers(min_value=2, max_value=32),
       st.integers(min_value=1, max_value=4),
       st.integers(min_value=1, max_value=4))
@settings(max_examples=40, deadline=None)
def test_schedule_sequence_numbers(n, channels, nodes):
    """Per-channel shadow-stream sequence numbers are dense + monotone
    (§4.1.2) and every (channel, chunk) appears exactly once."""
    evs = tag_schedule(n, n_channels=channels, n_shadow_nodes=nodes)
    per_ch = {}
    for ev in evs:
        per_ch.setdefault(ev.channel, []).append(ev)
    assert set(per_ch) == set(range(channels))
    for ch, lst in per_ch.items():
        seqs = [e.seq for e in lst]
        assert seqs == list(range(len(lst)))
        chunks = [e.chunk for e in lst]
        assert sorted(chunks) == list(range(n))
        assert all(0 <= e.shadow_node < nodes for e in lst)
