"""Gradient compression x Checkmate consistency: when training applies
int8+EF-compressed gradients, the shadow cluster receiving the SAME
dequantized gradients stays bit-identical (docs/ARCHITECTURE.md, shadow plane)."""
import numpy as np

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.core.buckets import layout_for_tree
from repro.core.shadow import ShadowCluster
from repro.dist.compression import compress_tree, init_error_feedback
from repro.dist.sharding import ShardingRules, make_smoke_mesh
from repro.optim import OptimizerConfig, apply_updates
from repro.train.step import make_train_state


def test_shadow_consistent_under_compression():
    mesh = make_smoke_mesh()
    cfg = C.get("tinyllama-1.1b").reduced()
    rules = ShardingRules(mesh)
    opt = OptimizerConfig(lr=1e-3)
    state = make_train_state(jax.random.PRNGKey(0), cfg, rules)

    layout = layout_for_tree(state.params)
    shadow = ShadowCluster(layout, opt, n_nodes=2)
    shadow.bootstrap(state.params, state.mu, state.nu, 0)

    ef = init_error_feedback(state.params)
    apply_fn = jax.jit(lambda s, g: apply_updates(s, g, opt, 1e-3))
    rng = np.random.default_rng(0)
    for step in range(1, 4):
        raw = {k: jnp.asarray(rng.standard_normal(v.shape), jnp.float32) * 0.01
               for k, v in state.params.items()}
        # compress BEFORE the (simulated) reduction; training consumes the
        # dequantized grads, shadow receives the identical dequantized grads
        deq, ef, wire = compress_tree(raw, ef)
        state = apply_fn(state, deq)
        shadow.on_gradients(step, 1e-3, {k: np.asarray(v)
                                         for k, v in deq.items()})

    ckpt = shadow.consolidate()
    for k in state.params:
        assert np.array_equal(np.asarray(state.params[k]),
                              ckpt["params"][k]), k
    assert ckpt["step"] == 3
