"""HLO cost extractor: exact on known programs (incl. while trip counts)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import HloModule, analyze_hlo_text


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_single_matmul_flops_exact():
    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    c = _compile(lambda a, b: a @ b, x, w)
    cost = analyze_hlo_text(c.as_text())
    assert cost.flops == 2 * 256 * 512 * 128


def test_scan_multiplies_trip_count():
    def f(x, w):
        def body(x, _):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, None, length=8)
        return x
    x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    cost = analyze_hlo_text(_compile(f, x, w).as_text())
    matmul = 2 * 512 ** 3
    assert abs(cost.flops - 8 * (matmul + 512 * 512)) / (8 * matmul) < 0.01
    # XLA's own analysis counts the body once — ours must be ~8x larger
    xla = _compile(f, x, w).cost_analysis()
    if isinstance(xla, (list, tuple)):      # jax 0.4.x: list of one dict
        xla = xla[0]
    assert cost.flops > 7 * xla["flops"]


def test_nested_scan():
    def f(x, w):
        def outer(x, _):
            def inner(y, _):
                return jnp.tanh(y @ w), None
            y, _ = jax.lax.scan(inner, x, None, length=3)
            return y, None
        x, _ = jax.lax.scan(outer, x, None, length=4)
        return x
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    cost = analyze_hlo_text(_compile(f, x, w).as_text())
    matmul = 2 * 128 ** 3
    assert abs(cost.flops - 12 * (matmul + 128 * 128)) / (12 * matmul) < 0.02


def test_batched_dot_flops():
    x = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    c = _compile(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), x, w)
    cost = analyze_hlo_text(c.as_text())
    assert cost.flops == 2 * 4 * 64 * 32 * 16


def test_bytes_nonzero_and_bounded():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _compile(lambda a: (a * 2 + 1).sum(), x)
    cost = analyze_hlo_text(c.as_text())
    nbytes = 1024 * 1024 * 4
    assert nbytes <= cost.bytes <= 6 * nbytes


def test_tuple_types_with_index_comments_parse():
    """Regression: (a, b, ..., /*index=5*/ c, ...) tuple types must parse."""
    txt = """
HloModule test, is_scheduled=true

%body (p: (s32[], f32[8,8], f32[8,8], f32[8,8], f32[8,8], /*index=5*/f32[8,8])) -> (s32[], f32[8,8], f32[8,8], f32[8,8], f32[8,8], /*index=5*/f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}, f32[8,8]{1,0}, f32[8,8]{1,0}, f32[8,8]{1,0}, /*index=5*/f32[8,8]{1,0}) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %g2 = f32[8,8]{1,0} get-tuple-element(%p), index=2
  %d = f32[8,8]{1,0} dot(%g1, %g2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]{1,0}, f32[8,8]{1,0}, f32[8,8]{1,0}, f32[8,8]{1,0}, /*index=5*/f32[8,8]{1,0}) tuple(%g0, %d, %g2, %g2, %g2, %g2)
}

%cond (p2: (s32[], f32[8,8], f32[8,8], f32[8,8], f32[8,8], /*index=5*/f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]{1,0}, f32[8,8]{1,0}, f32[8,8]{1,0}, f32[8,8]{1,0}, /*index=5*/f32[8,8]{1,0}) parameter(0)
  %c = s32[] constant(5)
  %i = s32[] get-tuple-element(%p2), index=0
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: (s32[], f32[8,8], f32[8,8], f32[8,8], f32[8,8], /*index=5*/f32[8,8])) -> (s32[], f32[8,8], f32[8,8], f32[8,8], f32[8,8], /*index=5*/f32[8,8]) {
  %a = (s32[], f32[8,8]{1,0}, f32[8,8]{1,0}, f32[8,8]{1,0}, f32[8,8]{1,0}, /*index=5*/f32[8,8]{1,0}) parameter(0)
  ROOT %w = (s32[], f32[8,8]{1,0}, f32[8,8]{1,0}, f32[8,8]{1,0}, f32[8,8]{1,0}, /*index=5*/f32[8,8]{1,0}) while(%a), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"},"known_init_step":{"init":"0","step":"1"}}
}
"""
    cost = analyze_hlo_text(txt)
    assert cost.flops == 5 * 2 * 8 * 8 * 8


def test_collective_parse():
    txt = """
HloModule t, is_scheduled=true

ENTRY %main (x: f32[64,128]) -> f32[64,128] {
  %x = f32[64,128]{1,0} parameter(0)
  ROOT %ar = f32[64,128]{1,0} all-reduce(%x), channel_id=1, replica_groups=[1,4]<=[4], to_apply=%add
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
"""
    cost = analyze_hlo_text(txt)
    assert cost.collective_bytes == 64 * 128 * 4
    assert cost.per_collective == {"all-reduce": 64 * 128 * 4}
