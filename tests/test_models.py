"""Model substrate: attention paths agree, SSD matches the naive recurrence,
decode is consistent with teacher-forced forward."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.dist.sharding import ShardingRules, make_smoke_mesh
from repro.models import layers as L
from repro.models import registry
from repro.models import ssm as M

RNG = np.random.default_rng(1)


@pytest.fixture(scope="module")
def rules():
    return ShardingRules(make_smoke_mesh())


# -- attention ----------------------------------------------------------------

def _naive_attention(q, k, v, causal):
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(q.shape[-1])
    if causal:
        mask = jnp.tril(jnp.ones((q.shape[1], k.shape[1]), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("q_chunk", [16, 64, 128])
def test_attention_qchunk(causal, q_chunk, rules):
    b, s, h, d = 2, 128, 2, 16
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32) * 0.4
    k = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32) * 0.4
    v = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    out = L.attention_qchunk(q, k, v, causal=causal, q_chunk=q_chunk)
    np.testing.assert_allclose(out, _naive_attention(q, k, v, causal),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("chunk", [16, 32])
def test_attention_tri(chunk, rules):
    b, s, h, d = 1, 128, 2, 16
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32) * 0.4
    k = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32) * 0.4
    v = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    out = L.attention_tri(q, k, v, q_chunk=chunk, kv_chunk=chunk)
    np.testing.assert_allclose(out, _naive_attention(q, k, v, True),
                               rtol=2e-5, atol=2e-5)


def test_attention_decode_matches_full(rules):
    b, s, h, d = 2, 33, 2, 16
    q = jnp.asarray(RNG.standard_normal((b, 1, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    out = L.attention_decode(q, k, v, length=s)
    ref = _naive_attention(q, k, v, causal=False)   # full visibility @ len s
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_expand_kv():
    k = jnp.arange(2 * 4 * 2 * 3, dtype=jnp.float32).reshape(2, 4, 2, 3)
    e = L.expand_kv(k, 6)
    assert e.shape == (2, 4, 6, 3)
    np.testing.assert_array_equal(e[:, :, 0], e[:, :, 1])
    np.testing.assert_array_equal(e[:, :, 0], k[:, :, 0])


# -- SSD ----------------------------------------------------------------------

def _ssd_naive(x, dt, A, B, Cm):
    """Token-by-token reference recurrence."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    S = np.zeros((b, h, n, p), np.float32)
    ys = []
    for t in range(s):
        dA = np.exp(dt[:, t] * A)                       # (b,h)
        S = S * dA[..., None, None] + np.einsum(
            "bn,bh,bhp->bhnp", B[:, t], dt[:, t], x[:, t])
        ys.append(np.einsum("bn,bhnp->bhp", Cm[:, t], S))
    return np.stack(ys, axis=1), S


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_ssd_chunked_matches_recurrence(chunk):
    b, s, h, p, n = 2, 32, 3, 4, 5
    x = RNG.standard_normal((b, s, h, p)).astype(np.float32)
    dt = np.abs(RNG.standard_normal((b, s, h))).astype(np.float32) * 0.5
    A = -np.abs(RNG.standard_normal(h)).astype(np.float32)
    B = RNG.standard_normal((b, s, n)).astype(np.float32)
    Cm = RNG.standard_normal((b, s, n)).astype(np.float32)
    y, S = M.ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                         jnp.asarray(B), jnp.asarray(Cm), chunk)
    y_ref, S_ref = _ssd_naive(x, dt, A, B, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S), S_ref, rtol=1e-4, atol=1e-4)


def test_ssd_decode_continues_prefill():
    """state from ssd_chunked + decode step == running the recurrence one
    token further."""
    b, s, h, p, n = 1, 16, 2, 4, 3
    x = RNG.standard_normal((b, s + 1, h, p)).astype(np.float32)
    dt = np.abs(RNG.standard_normal((b, s + 1, h))).astype(np.float32) * 0.5
    A = -np.abs(RNG.standard_normal(h)).astype(np.float32)
    B = RNG.standard_normal((b, s + 1, n)).astype(np.float32)
    Cm = RNG.standard_normal((b, s + 1, n)).astype(np.float32)
    _, S = M.ssd_chunked(jnp.asarray(x[:, :s]), jnp.asarray(dt[:, :s]),
                         jnp.asarray(A), jnp.asarray(B[:, :s]),
                         jnp.asarray(Cm[:, :s]), 8)
    y1, S1 = M.ssd_decode_step(jnp.asarray(x[:, s]), jnp.asarray(dt[:, s]),
                               jnp.asarray(A), jnp.asarray(B[:, s]),
                               jnp.asarray(Cm[:, s]), S)
    y_ref, S_ref = _ssd_naive(x, dt, A, B, Cm)
    np.testing.assert_allclose(np.asarray(y1), y_ref[:, s], rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(S1), S_ref, rtol=1e-4, atol=1e-4)


def test_causal_conv_matches_manual():
    b, s, c, w = 2, 10, 3, 4
    x = RNG.standard_normal((b, s, c)).astype(np.float32)
    kern = RNG.standard_normal((w, c)).astype(np.float32)
    out = np.asarray(M.causal_conv(jnp.asarray(x), jnp.asarray(kern)))
    for t in range(s):
        ref = np.zeros((b, c), np.float32)
        for tap in range(w):
            src = t - (w - 1 - tap)
            if src >= 0:
                ref += x[:, src] * kern[tap]
        np.testing.assert_allclose(out[:, t], ref, rtol=1e-5, atol=1e-5)


def test_conv_step_matches_causal_conv():
    b, s, c, w = 1, 8, 2, 4
    x = RNG.standard_normal((b, s, c)).astype(np.float32)
    kern = RNG.standard_normal((w, c)).astype(np.float32)
    full = np.asarray(M.causal_conv(jnp.asarray(x), jnp.asarray(kern)))
    cache = jnp.zeros((b, w - 1, c))
    for t in range(s):
        y, cache = M.conv_step(jnp.asarray(x[:, t]), cache, jnp.asarray(kern))
        np.testing.assert_allclose(np.asarray(y), full[:, t], rtol=1e-5,
                                   atol=1e-5)


# -- decode/teacher-forcing consistency ---------------------------------------

@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-2.7b",
                                  "zamba2-1.2b"])
def test_decode_matches_forward(arch, rules):
    """prefill(t) + decode(token t) logits == full forward at position t.

    Run in f32 so the check is algorithmic, not bf16-rounding-order noise.
    """
    from dataclasses import replace
    cfg = replace(C.get(arch).reduced(), compute_dtype="float32")
    params = registry.init_params(jax.random.PRNGKey(0), cfg, rules)
    b, s = 2, 16
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s + 1)), jnp.int32)

    mod = registry.family_module(cfg)
    full_logits = mod.forward(params, cfg, rules, toks)

    cache, logits_p = registry.prefill(params, cfg, rules, toks[:, :s],
                                       max_seq=s + 4)
    np.testing.assert_allclose(np.asarray(logits_p[:, -1], np.float32),
                               np.asarray(full_logits[:, s - 1], np.float32),
                               rtol=2e-2, atol=2e-2)
    logits_d, cache = registry.decode_step(params, cfg, rules, cache,
                                           toks[:, s:s + 1])
    np.testing.assert_allclose(np.asarray(logits_d[:, -1], np.float32),
                               np.asarray(full_logits[:, s], np.float32),
                               rtol=2e-2, atol=2e-2)
