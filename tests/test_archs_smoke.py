"""Per-architecture smoke tests (deliverable f): REDUCED same-family config,
one train step on CPU, assert output shapes + finite values."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.dist.sharding import ShardingRules, make_smoke_mesh
from repro.models import registry
from repro.optim import OptimizerConfig
from repro.train.step import build_train_step, make_train_state


@pytest.fixture(scope="module")
def mesh_rules():
    mesh = make_smoke_mesh()
    return mesh, ShardingRules(mesh)


def _batch_for(cfg, b, s):
    rng = np.random.default_rng(0)
    if cfg.family == "vit":
        return {"patch_embeds": jnp.asarray(
                    rng.standard_normal((b, cfg.num_patches, cfg.d_model)),
                    jnp.bfloat16),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 1)),
                                      jnp.int32)}
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                 jnp.int32),
           "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                 jnp.int32)}
    if cfg.family == "audio":
        out["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)),
            jnp.bfloat16)
    if cfg.family == "vlm":
        out["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_patches, cfg.d_model)),
            jnp.bfloat16)
    return out


@pytest.mark.parametrize("arch", C.ASSIGNED)
def test_train_step_smoke(arch, mesh_rules):
    mesh, rules = mesh_rules
    cfg = C.get(arch).reduced()
    state = make_train_state(jax.random.PRNGKey(0), cfg, rules)
    step = jax.jit(build_train_step(cfg, mesh, rules, OptimizerConfig(),
                                    lambda s: 1e-3), donate_argnums=(0,))
    batch = _batch_for(cfg, b=4, s=32)
    new_state, metrics, grads = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(new_state.step) == 1
    # grads: full coverage, finite, correct shapes
    specs = registry.param_specs(cfg)
    assert set(grads) == set(specs)
    for k, g in grads.items():
        assert g.shape == specs[k].shape, k
        assert bool(jnp.all(jnp.isfinite(g))), k
    # params actually moved
    moved = any(not np.array_equal(np.asarray(new_state.params[k]),
                                   np.asarray(jnp.zeros(0)))  # placeholder
                for k in ())
    del moved


@pytest.mark.parametrize("arch", C.ASSIGNED)
def test_forward_shapes(arch, mesh_rules):
    mesh, rules = mesh_rules
    cfg = C.get(arch).reduced()
    params = registry.init_params(jax.random.PRNGKey(1), cfg, rules)
    b, s = 2, 16
    batch = _batch_for(cfg, b, s)
    mod = registry.family_module(cfg)
    if cfg.family == "audio":
        loss = mod.loss_fn(params, cfg, rules, batch)
        assert np.isfinite(float(loss))
    elif cfg.family == "vlm":
        logits = mod.forward(params, cfg, rules, batch["tokens"],
                             batch["patch_embeds"])
        assert logits.shape == (b, s, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    else:
        logits = mod.forward(params, cfg, rules, batch["tokens"])
        if isinstance(logits, tuple):          # moe returns (logits, aux)
            logits = logits[0]
        assert logits.shape == (b, s, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_param_counts_match_published_class():
    """Full configs land near their published parameter counts."""
    expect = {
        "zamba2-1.2b": (1.0e9, 1.6e9),
        "mamba2-2.7b": (2.4e9, 3.0e9),
        "granite-34b": (30e9, 38e9),
        "llama3.2-3b": (2.8e9, 4.0e9),
        "tinyllama-1.1b": (0.9e9, 1.3e9),
        "glm4-9b": (8.0e9, 10.5e9),
        "whisper-medium": (0.6e9, 1.1e9),
        "llava-next-mistral-7b": (6.5e9, 8.0e9),
        "dbrx-132b": (115e9, 145e9),
        "arctic-480b": (420e9, 520e9),
    }
    for arch, (lo, hi) in expect.items():
        n = C.get(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params():
    cfg = C.get("arctic-480b")
    total, active = cfg.param_count(), cfg.active_param_count()
    assert active < 0.2 * total          # 128 experts, top-2 + dense
    cfg = C.get("dbrx-132b")
    assert cfg.active_param_count() < 0.5 * cfg.param_count()
