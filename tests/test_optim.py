"""Functional optimizers, schedules, ZeRO-1 spec assignment."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim import OptimizerConfig, apply_updates, init_state
from repro.optim.schedules import cosine_schedule
from repro.optim.sharded import zero1_spec


def test_adamw_first_step():
    """Closed-form check of the very first AdamW step."""
    cfg = OptimizerConfig(name="adamw", lr=0.1, b1=0.9, b2=0.99, eps=0.0,
                          weight_decay=0.0)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    state = init_state(p)
    out = apply_updates(state, g, cfg, 0.1)
    # m-hat = g, v-hat = g^2 -> update = g/|g| = sign(g)
    np.testing.assert_allclose(np.asarray(out.params["w"]),
                               [1.0 - 0.1, -2.0 - 0.1], rtol=1e-6)


def test_weight_decay_direction():
    cfg = OptimizerConfig(lr=0.1, weight_decay=1.0)
    p = {"w": jnp.asarray([10.0])}
    g = {"w": jnp.asarray([0.0])}
    out = apply_updates(init_state(p), g, cfg, 0.1)
    assert float(out.params["w"][0]) < 10.0        # decays toward 0


def test_grad_clip_scales():
    cfg = OptimizerConfig(name="sgd", lr=1.0, momentum=0.0, grad_clip=1.0)
    p = {"w": jnp.asarray([0.0, 0.0])}
    g = {"w": jnp.asarray([3.0, 4.0])}             # norm 5 -> scaled to 1
    out = apply_updates(init_state(p), g, cfg, 1.0)
    np.testing.assert_allclose(np.asarray(out.params["w"]),
                               [-0.6, -0.8], rtol=1e-5)


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=110, min_ratio=0.1)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert abs(float(lr(110)) - 0.1) < 1e-3
    assert float(lr(60)) < float(lr(20))


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


@given(st.tuples(st.integers(1, 8).map(lambda x: x * 16),
                 st.integers(1, 64)))
@settings(max_examples=30, deadline=None)
def test_zero1_spec_picks_divisible_dim(shape):
    mesh = _FakeMesh({"data": 16, "model": 16})
    spec = zero1_spec(shape, P(), mesh)
    placed = [i for i, s in enumerate(spec) if s is not None]
    if placed:
        (i,) = placed
        assert shape[i] % 16 == 0


def test_zero1_spec_no_duplicate_axes():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # fsdp leaf already sharded over data -> zero1 must not re-use it
    spec = zero1_spec((32, 64), P(("data",), "model"), mesh)
    assert spec == P(("data",), "model")
    # TP-only leaf gets data on the free divisible dim
    spec = zero1_spec((32, 64), P(None, "model"), mesh)
    assert spec == P("data", "model")
    # nothing divisible -> untouched
    spec = zero1_spec((3, 5), P(), mesh)
    assert spec == P(None, None)
