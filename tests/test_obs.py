"""Observability plane (docs/observability.md): registry semantics and
exposition, Perfetto-loadable trace export with monotonic per-track
timestamps, deterministic golden traces under ManualClock, near-zero-cost
disabled hot paths, bit-exact stall attribution from channel send parts
through the checkpointer ledger, per-link PFC accounting, and the
``python -m repro.obs`` CLI."""
import json
import time

import numpy as np
import pytest

from repro import obs
from repro.obs import ManualClock, MetricsRegistry, Tracer, diff_snapshots
from repro.obs.metrics import NULL_INSTRUMENT
from repro.obs.trace import FABRIC_PID, HOST_PID, NULL_SPAN


# -- metrics registry ---------------------------------------------------------

def test_counter_gauge_histogram_with_labels():
    reg = MetricsRegistry()
    reg.counter("sends", "help text").inc(2, channel="a")
    reg.counter("sends").inc(3, channel="a")
    reg.counter("sends").inc(1, channel="b")
    reg.gauge("lag").set(4)
    reg.histogram("apply_s").observe(0.002, node=0)
    reg.histogram("apply_s").observe(0.2, node=0)

    snap = reg.snapshot()["metrics"]
    by_label = {s["labels"]["channel"]: s["value"]
                for s in snap["sends"]["samples"]}
    assert by_label == {"a": 5, "b": 1}
    assert snap["sends"]["type"] == "counter"
    assert snap["sends"]["help"] == "help text"
    assert snap["lag"]["samples"][0]["value"] == 4
    h = snap["apply_s"]["samples"][0]
    assert h["count"] == 2 and h["max"] == 0.2
    assert h["sum"] == pytest.approx(0.202)
    assert h["buckets"]["+Inf"] == 2              # cumulative


def test_metric_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x").inc()
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("x")


def test_prometheus_text_exposition():
    reg = MetricsRegistry()
    reg.counter("sends", "Gradient sends").inc(5, channel="inprocess")
    reg.histogram("apply_s", bounds=(0.01, 0.1)).observe(0.05)
    text = reg.to_prometheus()
    assert "# HELP sends Gradient sends" in text
    assert "# TYPE sends counter" in text
    assert 'sends{channel="inprocess"} 5' in text
    assert 'apply_s_bucket{le="0.1"} 1' in text
    assert 'apply_s_bucket{le="+Inf"} 1' in text
    assert "apply_s_count 1" in text


def test_disabled_registry_is_noop():
    reg = MetricsRegistry(enabled=False)
    # accessors hand back one shared null instrument: no allocation, no state
    assert reg.counter("a") is NULL_INSTRUMENT
    assert reg.gauge("b") is NULL_INSTRUMENT
    assert reg.histogram("c") is NULL_INSTRUMENT
    reg.counter("a").inc(10)
    assert reg.snapshot() == {"metrics": {}}


def test_diff_snapshots():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("sends").inc(1, channel="x")
    b.counter("sends").inc(4, channel="x")
    b.gauge("lag").set(2)
    rows = diff_snapshots(a.snapshot(), b.snapshot())
    assert {(r["metric"], r["before"], r["after"]) for r in rows} == {
        ("sends", 1, 4), ("lag", None, 2)}


# -- tracer -------------------------------------------------------------------

def _small_trace():
    tr = Tracer(clock=ManualClock(0.0))
    with tr.span("step.compute", args={"step": 1}):
        with tr.span("channel.send", track="train"):
            pass
    tr.instant("recovery.resume", track="recovery")
    tr.fabric_span("allgather step1", 0.0, 30e-6, track="fabric")
    tr.fabric_span("g0c0r0", 1e-6, 2e-6, track="shadow0.rx")
    tr.fabric_advance(30e-6)
    tr.fabric_span("allgather step2", 0.0, 30e-6, track="fabric")
    return tr


def test_export_is_perfetto_loadable():
    doc = _small_trace().export()
    # must be a JSON-serializable trace_event object form
    doc2 = json.loads(json.dumps(doc))
    assert doc2["displayTimeUnit"] == "ms"
    evs = doc2["traceEvents"]
    assert {e["ph"] for e in evs} <= {"M", "X"}
    meta = [e for e in evs if e["ph"] == "M"]
    names = {(e["pid"], e["name"], e["args"]["name"]) for e in meta}
    assert (HOST_PID, "process_name", "host (wall clock)") in names
    assert (FABRIC_PID, "process_name", "fabric (simulated time)") in names
    # every X event's track has thread_name metadata
    tids = {(e["pid"], e["tid"]) for e in evs if e["ph"] == "X"}
    assert tids <= {(e["pid"], e["tid"]) for e in meta
                    if e["name"] == "thread_name"}


def test_timestamps_monotonic_nonnegative_per_track():
    evs = _small_trace().events()
    seen = {}
    for e in evs:
        assert e["ts"] >= 0 and e["dur"] >= 0
        key = (e["pid"], e["tid"])
        assert e["ts"] >= seen.get(key, 0.0)      # ordered within a track
        seen[key] = e["ts"]
    # fabric_advance laid step2's allgather after step1's
    ag = [e for e in evs if e["name"].startswith("allgather")]
    assert ag[1]["ts"] >= ag[0]["ts"] + ag[0]["dur"]


def test_ring_buffer_keeps_trailing_window():
    tr = Tracer(clock=ManualClock(0.0), maxlen=8)
    for i in range(50):
        tr.instant(f"e{i}")
    evs = tr.events()
    assert len(evs) == 8
    assert evs[-1]["name"] == "e49"


def test_manual_clock_golden_scenario_trace_is_deterministic():
    """Fixed scenario + logical clock => byte-identical trace export."""
    from repro.harness import GOLDEN, run_scenario

    def one_run():
        with obs.enabled_session(clock=ManualClock(0.0)) as ob:
            result = run_scenario(GOLDEN["packetized-rail-clean"])
            assert result.passed
            return json.dumps(ob.tracer.export(), sort_keys=True)

    assert one_run() == one_run()


# -- disabled hot paths -------------------------------------------------------

def test_disabled_hot_path_is_noop_and_cheap():
    ob = obs.Observability.disabled()
    assert not ob.enabled
    # the guarantee: shared singletons, zero per-call allocation of state
    assert ob.tracer.span("channel.send") is NULL_SPAN
    assert ob.metrics.counter("sends") is NULL_INSTRUMENT
    n = 20_000
    t0 = time.perf_counter()
    for _ in range(n):
        with ob.tracer.span("channel.send", args={"step": 1}):
            pass
        ob.metrics.counter("sends").inc(1, channel="x")
    dt = time.perf_counter() - t0
    # generous CI-safe bound: ~50us/iteration would still pass; the real
    # cost is ~1us. Catches accidental work (dict churn, time syscalls)
    # sneaking into the disabled path.
    assert dt < 1.0, f"disabled hot path cost {dt / n * 1e6:.1f}us/iter"
    assert ob.metrics.snapshot() == {"metrics": {}}
    assert ob.tracer.events() == []


# -- stall attribution: channel send parts ------------------------------------

def _tree(n=4):
    rng = np.random.default_rng(0)
    return {f"l{i}.w": rng.standard_normal((8, 16)).astype(np.float32)
            for i in range(n)}


def _in_order_sum(parts: dict) -> float:
    total = 0.0
    for v in parts.values():
        total += v
    return total


@pytest.mark.parametrize("kind", ["inprocess", "packetized", "compressed"])
def test_send_parts_sum_bit_exactly_to_reported_stall(kind):
    from repro.core.buckets import layout_for_tree
    from repro.core.channel import (CompressedChannel, InProcessChannel,
                                    PacketizedChannel, StepEvent)
    tree = _tree()
    layout = layout_for_tree(tree)
    chan = {"inprocess": InProcessChannel,
            "packetized": lambda: PacketizedChannel(n_shadow_nodes=2),
            "compressed": lambda: CompressedChannel(InProcessChannel()),
            }[kind]()
    chan.open(layout)
    for step in (1, 2):
        reported = chan.send(StepEvent(step=step, grads=tree, lr=1e-3))
        parts = chan.last_send_parts
        assert parts, "every send must set last_send_parts"
        assert _in_order_sum(parts) == reported        # bit-exact, not approx
    if kind == "packetized":
        assert parts == {"send": 0.0}      # the paper's zero-overhead claim
    if kind == "compressed":
        assert "quantize" in parts and "send" in parts
    chan.close()


# -- stall attribution: checkpointer ledger -----------------------------------

def _checkmate(channel=None, n=4):
    from repro.core.buckets import layout_for_tree
    from repro.core.checkpoint import CheckmateCheckpointer
    from repro.core.shadow import ShadowCluster
    from repro.optim import OptimizerConfig
    tree = _tree(n)
    layout = layout_for_tree(tree)
    zeros = {k: np.zeros_like(v) for k, v in tree.items()}
    shadow = ShadowCluster(layout, OptimizerConfig(name="sgd", lr=1e-3),
                           n_nodes=2)
    shadow.bootstrap(tree, zeros, zeros, 0)
    return CheckmateCheckpointer(shadow, channel=channel), tree, zeros


def test_stall_total_is_in_order_ledger_sum():
    from repro.core.channel import StepEvent
    ck, tree, _ = _checkmate()
    for step in (1, 2, 3):
        ck.on_step(StepEvent(step=step, grads=tree, lr=1e-3))
    ck.restore()                                   # books consolidate-wait
    assert set(ck.stall_stages) == {"send", "inline-apply",
                                    "consolidate-wait"}
    assert ck.stall_total == _in_order_sum(ck.stall_stages)
    assert all(v >= 0.0 for v in ck.stall_stages.values())


def test_resync_and_gated_steps_attributed():
    from repro.core.channel import PacketizedChannel, StepEvent
    chan = PacketizedChannel(n_shadow_nodes=2, failures_at={1: "capture"})
    ck, tree, zeros = _checkmate(channel=chan)
    # step 1: capture lost -> gated, books nothing
    assert ck.on_step(StepEvent(step=1, grads=tree, lr=1e-3)) == 0.0
    assert ck.skipped_captures == 1 and ck.stall_stages == {}
    # step 2 carries state_fn -> full-state resync, charged to "resync"
    snap = {"params": tree, "mu": zeros, "nu": zeros, "step": 2}
    stall = ck.on_step(StepEvent(step=2, grads=tree, lr=1e-3,
                                 state_fn=lambda: snap))
    assert ck.resyncs == [2]
    assert set(ck.stall_stages) == {"resync"}
    assert ck.stall_stages["resync"] == stall
    assert ck.stall_total == _in_order_sum(ck.stall_stages)


def test_copy_persist_baseline_books_single_stage():
    from repro.core.channel import StepEvent
    from repro.core.checkpoint import SyncCheckpointer
    tree = _tree()
    zeros = {k: np.zeros_like(v) for k, v in tree.items()}
    ck = SyncCheckpointer(freq=1)
    snap = {"params": tree, "mu": zeros, "nu": zeros, "step": 1}
    ck.on_step(StepEvent(step=1, state_fn=lambda: snap))
    assert set(ck.stall_stages) == {"copy-persist"}
    assert ck.stall_total == ck.stall_stages["copy-persist"]


def test_stall_report_and_publish():
    from repro.core.channel import StepEvent
    from repro.obs.stalls import format_stall_report, stall_attribution
    ck, tree, _ = _checkmate()
    ck.on_step(StepEvent(step=1, grads=tree, lr=1e-3))
    parts = stall_attribution(ck)
    assert sum(parts.values()) == ck.stall_total
    report = format_stall_report(ck)
    assert "inline-apply" in report and "total" in report
    reg = MetricsRegistry()
    from repro.obs.stalls import publish_stalls
    publish_stalls(reg, ck)
    fam = reg.snapshot()["metrics"]["checkpoint_stall_seconds_total"]
    assert {s["labels"]["stage"] for s in fam["samples"]} == set(parts)


# -- per-link PFC -------------------------------------------------------------

def test_per_link_pfc_pause_accounting():
    from repro.net.simulator import PfcConfig, simulate_fabric
    r = simulate_fabric(2, 8, 8 * 65536, n_shadow_nodes=2, ranks_per_leaf=4,
                        replication_factor=8,
                        pfc=PfcConfig(capacity_bytes=32768, xoff_frac=0.5,
                                      xon_frac=0.25))
    assert r.pfc_pauses > 0                       # congestion actually paused
    assert r.link_pfc, "paused links must be reported individually"
    for link, st in r.link_pfc.items():
        assert "->" in link
        assert st["pauses"] > 0 and st["pause_s"] >= 0.0
    # the aggregate is exactly the per-link decomposition
    assert sum(st["pause_s"] for st in r.link_pfc.values()) == r.pfc_pause_s


def test_per_link_pfc_published_as_labeled_gauge():
    from repro.core.channel import FabricTotals
    from repro.net.simulator import PfcConfig, simulate_fabric
    from repro.obs.publish import publish_channel
    r = simulate_fabric(2, 8, 8 * 65536, n_shadow_nodes=2, ranks_per_leaf=4,
                        replication_factor=8,
                        pfc=PfcConfig(capacity_bytes=32768, xoff_frac=0.5,
                                      xon_frac=0.25))
    totals = FabricTotals()
    totals.absorb(r, 8 * 65536)

    class FakeChannel:
        name = "packetized"
    FakeChannel.totals = totals

    reg = MetricsRegistry()
    publish_channel(reg, FakeChannel())
    snap = reg.snapshot()["metrics"]
    samples = snap["fabric_link_pfc_pause_seconds"]["samples"]
    assert {s["labels"]["link"] for s in samples} == set(r.link_pfc)
    total = snap["fabric_pfc_pause_seconds_total"]["samples"][0]["value"]
    assert total == pytest.approx(r.pfc_pause_s)


# -- harness + session integration --------------------------------------------

def test_run_scenario_always_carries_trailing_trace_window():
    from repro.harness import GOLDEN, run_scenario
    assert not obs.get().enabled                  # ambient plane is the no-op
    result = run_scenario(GOLDEN["inprocess-clean"])
    assert result.trace_export is not None
    names = {e.get("name") for e in result.trace_export["traceEvents"]}
    assert "checkpoint.on_step" in names and "channel.send" in names
    assert not obs.get().enabled                  # runner restored the plane


def test_enabled_session_scopes_and_restores():
    with obs.enabled_session() as ob:
        assert obs.get() is ob and ob.enabled
        with ob.tracer.span("step.compute"):
            pass
        ob.metrics.counter("train_steps_total").inc()
    assert not obs.get().enabled


def test_shadow_apply_observed_under_session():
    from repro.core.channel import StepEvent
    with obs.enabled_session() as ob:
        ck, tree, _ = _checkmate()
        ck.on_step(StepEvent(step=1, grads=tree, lr=1e-3))
        snap = ob.metrics.snapshot()["metrics"]
        names = {e.get("name") for e in ob.tracer.events()}
    h = snap["shadow_apply_seconds"]["samples"]
    assert sum(s["count"] for s in h) >= 1
    assert "shadow.apply" in names


# -- CLI ----------------------------------------------------------------------

def test_cli_trace_covers_send_fabric_apply_for_every_step(tmp_path):
    """Acceptance: `repro.obs trace --scenario <golden>` emits send ->
    fabric -> shadow-apply spans for every non-gated step."""
    from repro.harness import GOLDEN
    from repro.obs.__main__ import main
    out = tmp_path / "t.trace.json"
    mout = tmp_path / "m.json"
    rc = main(["trace", "--scenario", "packetized-rail-clean",
               "--out", str(out), "--metrics-out", str(mout)])
    assert rc == 0
    doc = json.loads(out.read_text())
    evs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    steps = range(1, GOLDEN["packetized-rail-clean"].steps + 1)

    def steps_of(name):
        return {e.get("args", {}).get("step") for e in evs
                if e["name"] == name}

    assert set(steps) <= steps_of("channel.send")         # send
    ag = {e.get("args", {}).get("step") for e in evs
          if e["name"].startswith("allgather step")}      # fabric domain
    assert set(steps) <= ag
    assert any(e["name"] == "shadow.apply" for e in evs)  # shadow apply
    assert {e["pid"] for e in evs} == {HOST_PID, FABRIC_PID}
    # the metrics snapshot rode along
    snap = json.loads(mout.read_text())
    assert snap["metrics"]["checkpoints_total"]["samples"][0]["value"] == 5


def test_cli_diff(tmp_path, capsys):
    from repro.obs.__main__ import main
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("sends").inc(1)
    b.counter("sends").inc(7)
    pa, pb = tmp_path / "a.json", tmp_path / "b.json"
    a.write_json(pa)
    b.write_json(pb)
    assert main(["diff", str(pa), str(pb)]) == 0
    out = capsys.readouterr().out
    assert "sends" in out and "1 -> 7" in out


def test_cli_rejects_unknown_scenario(tmp_path):
    from repro.obs.__main__ import main
    with pytest.raises(SystemExit):
        main(["trace", "--scenario", "no-such-scenario"])
