"""repro.durability: tiered differential persistence behind the shadow plane.

Pins the subsystem's load-bearing claims:

* `FlushRecord` round-trips bit-exactly and EVERY truncation or payload
  corruption raises `TornRecordError` (checksummed wire format);
* tiers serialize concurrent worker puts (manifest never drops entries);
* background flushing + `restore_from_tiers` rebuild a checkpoint
  BIT-identical to `consolidate()` across optimizers x sharded
  assignments x sync/async mode (property test);
* a crash mid-flush (record cut at a random byte) is detected and
  restore falls back to the previous durable epoch, still bit-identical;
* the stateless no-EF codec never perturbs a channel `Compressor`'s
  error-feedback state (flushing is invisible to the gradient stream);
* `ShadowNodeLoss.total` names the newest durable tier;
* `recover(tiers=...)` survives both partial and total plane loss;
* the costmodel's flush/disk budget terms size the fleet.
"""
import os
import tempfile
import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import costmodel as cm
from repro.core.buckets import layout_for_tree
from repro.core.channel import (CompressedChannel, InProcessChannel,
                                StepEvent)
from repro.core.shadow import ShadowCluster, ShadowNodeLoss
from repro.dist.compression import (Compressor, dequantize_flat_stateless,
                                    quantize_flat_stateless)
from repro.durability import (DurableShadow, FlushPolicy, FlushRecord,
                              LocalDiskTier, ManifestEntry, ObjectStoreTier,
                              Tier, TierPutError, TierRestoreError,
                              TornRecordError, restore_from_tiers,
                              restore_shards_from_tiers)
from repro.optim import UPDATE_FNS, OptimizerConfig


def _tree(n_leaves=3, seed=0):
    rng = np.random.default_rng(seed)
    return {f"leaf{k}": rng.standard_normal((6 + 2 * k, 5))
            .astype(np.float32) for k in range(n_leaves)}


def _grads(params, step, seed=0):
    rng = np.random.default_rng(1_000_003 * (seed + 1) + step)
    return {k: (rng.standard_normal(v.shape) * 0.01).astype(np.float32)
            for k, v in params.items()}


def _drive(root, *, opt_name="adamw", n_nodes=2, async_mode=False,
           every=1, compress=False, rebase=3, steps=5, seed=0,
           object_store=False, fail_steps=(), assignment=None):
    """Drive a durable shadow cluster over a synthetic stream.

    Returns ``(shadow, dur, tiers, layout, states)`` with ``states`` the
    per-step consolidated checkpoints (the bit-identity references).
    The caller owns shutdown.
    """
    params = _tree(seed=seed)
    layout = layout_for_tree(params, cap_bytes=600)
    opt = OptimizerConfig(name=opt_name, lr=1e-3)
    shadow = ShadowCluster(layout, opt, n_nodes=n_nodes,
                           async_mode=async_mode, assignment=assignment)
    tiers = [LocalDiskTier(root)]
    if object_store:
        tiers.append(ObjectStoreTier())
    for s in fail_steps:
        tiers[0].fail_steps.add(s)
    dur = DurableShadow(tiers, FlushPolicy(
        every_steps=every, compress=compress,
        rebase_every=rebase)).attach(shadow)
    zeros = {k: np.zeros_like(v) for k, v in params.items()}
    shadow.bootstrap(params, zeros, zeros, 0)
    chan = InProcessChannel()
    chan.open(layout)
    states = {}
    for step in range(1, steps + 1):
        chan.send(StepEvent(step=step, grads=_grads(params, step, seed),
                            lr=1e-3))
        for d in chan.poll():
            shadow.on_delivery(d)
        dur.drain()
        states[step] = shadow.consolidate(timeout=60)
    chan.close()
    return shadow, dur, tiers, layout, states


def _payload_entries(tier):
    return [e for e in sorted(tier.entries(), key=lambda e: (e.epoch, e.node))
            if e.kind in ("base", "delta")]


# -- record wire format -------------------------------------------------------

def _record():
    rng = np.random.default_rng(7)
    return FlushRecord(
        epoch=3, node=1, step=12, kind="delta", compressed=False,
        payload={0: {"p": rng.standard_normal(40).astype(np.float32),
                     "m": rng.standard_normal(40).astype(np.float32),
                     "v": rng.standard_normal(40).astype(np.float32)},
                 2: {"p": rng.standard_normal(9).astype(np.float32),
                     "m": rng.standard_normal(9).astype(np.float32),
                     "v": rng.standard_normal(9).astype(np.float32)}})


def test_record_round_trips_bit_exactly():
    rec = _record()
    out = FlushRecord.from_bytes(rec.to_bytes())
    assert (out.epoch, out.node, out.step, out.kind, out.compressed) == \
        (rec.epoch, rec.node, rec.step, rec.kind, rec.compressed)
    assert set(out.payload) == set(rec.payload)
    for bid in rec.payload:
        for f in ("p", "m", "v"):
            a, b = rec.payload[bid][f], out.payload[bid][f]
            assert a.dtype == b.dtype and np.array_equal(a, b)


def test_every_truncation_is_torn():
    """ANY strict prefix of a record — cut in the magic, the header, or
    the payload — fails validation; no cut point parses as a shorter but
    valid record."""
    raw = _record().to_bytes()
    for cut in range(len(raw)):
        with pytest.raises(TornRecordError):
            FlushRecord.from_bytes(raw[:cut])


def test_payload_corruption_is_torn():
    raw = bytearray(_record().to_bytes())
    raw[-3] ^= 0xFF                         # flip a payload byte: crc32
    with pytest.raises(TornRecordError):
        FlushRecord.from_bytes(bytes(raw))


def test_mark_record_has_no_payload_bytes():
    rec = FlushRecord(epoch=0, node=0, step=4, kind="mark")
    assert rec.payload_nbytes == 0
    out = FlushRecord.from_bytes(rec.to_bytes())
    assert out.kind == "mark" and out.payload == {}


# -- tiers --------------------------------------------------------------------

def test_local_disk_tier_put_read_manifest(tmp_path):
    tier = LocalDiskTier(tmp_path)
    rec = _record()
    entry = tier.put(rec)
    assert isinstance(entry, ManifestEntry)
    assert tier.entries() == [entry]
    out = tier.read(entry)
    assert out.step == rec.step
    assert isinstance(tier, Tier)           # structural protocol
    assert isinstance(ObjectStoreTier(), Tier)


def test_tier_injected_failure(tmp_path):
    tier = LocalDiskTier(tmp_path)
    tier.fail_steps.add(12)
    with pytest.raises(TierPutError):
        tier.put(_record())                 # _record() is at step 12
    assert tier.entries() == []


def test_concurrent_puts_never_drop_manifest_entries(tmp_path):
    """Regression: per-node flush workers put concurrently; the manifest
    read-modify-write must serialize or entries vanish."""
    tier = LocalDiskTier(tmp_path)
    n_threads, n_each = 4, 12

    def work(node):
        for i in range(n_each):
            tier.put(FlushRecord(epoch=i, node=node, step=i, kind="mark"))

    ts = [threading.Thread(target=work, args=(n,)) for n in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(tier.entries()) == n_threads * n_each


def test_torn_blob_on_disk_is_rejected(tmp_path):
    tier = LocalDiskTier(tmp_path)
    entry = tier.put(_record())
    path = tmp_path / entry.key
    raw = path.read_bytes()
    path.write_bytes(raw[:len(raw) // 2])   # crash mid-write
    with pytest.raises(TornRecordError):
        tier.read(entry)


# -- flush + restore bit-identity (the tentpole property) ---------------------

@given(st.sampled_from(sorted(UPDATE_FNS)), st.sampled_from([1, 3]),
       st.sampled_from([False, True]), st.integers(0, 3))
@settings(max_examples=8, deadline=None)
def test_restore_bit_identical_to_consolidate(opt_name, n_nodes, async_mode,
                                              aseed):
    """Raw-policy restore == consolidate() bit for bit, across optimizers
    x random sharded bucket assignments x sync/async apply."""
    root = tempfile.mkdtemp(prefix="repro-dur-")  # fallback @given: no fixtures
    params = _tree()
    layout = layout_for_tree(params, cap_bytes=600)
    rng = np.random.default_rng(aseed)
    assignment = {b.bucket_id: int(rng.integers(0, n_nodes))
                  for b in layout.buckets}
    shadow, dur, tiers, layout, states = _drive(
        root, opt_name=opt_name, n_nodes=n_nodes, async_mode=async_mode,
        assignment=assignment, steps=4)
    try:
        assert dur.last_complete_step("local-disk") == 4
        ckpt = restore_from_tiers(tiers, layout, n_nodes=n_nodes)
        assert ckpt["step"] == 4
        ref = states[4]
        for part in ("params", "mu", "nu"):
            assert set(ckpt[part]) == set(ref[part])
            for k in ckpt[part]:
                assert np.array_equal(ckpt[part][k], ref[part][k]), \
                    (part, k, opt_name)
    finally:
        shadow.shutdown()


def test_flush_cadence_bounds_tier_lag(tmp_path):
    """every_steps=2: only even steps open epochs, so the durable point
    trails the stream by the cadence remainder."""
    shadow, dur, tiers, layout, states = _drive(tmp_path, every=2, steps=5)
    try:
        assert dur.last_complete_step("local-disk") == 4
        assert dur.newest_durable() == ("local-disk", 4)
        ckpt = restore_from_tiers(tiers, layout, n_nodes=2)
        assert ckpt["step"] == 4
        for k, v in ckpt["params"].items():
            assert np.array_equal(v, states[4]["params"][k])
    finally:
        shadow.shutdown()


def test_tier_failure_falls_back_to_other_tier(tmp_path):
    """local-disk refuses step 3; the object store still holds it, and
    restore serves the newest point ANY tier has."""
    shadow, dur, tiers, layout, states = _drive(
        tmp_path, object_store=True, fail_steps=(5,), steps=5)
    try:
        assert dur.put_failures > 0
        assert dur.last_complete_step("local-disk") == 4
        assert dur.last_complete_step("object-store") == 5
        assert dur.newest_durable() == ("object-store", 5)
        ckpt = restore_from_tiers(tiers, layout, n_nodes=2)
        assert ckpt["step"] == 5            # newest across ALL tiers
        for k, v in ckpt["params"].items():
            assert np.array_equal(v, states[5]["params"][k])
    finally:
        shadow.shutdown()


def test_restore_raises_when_no_tier_serves(tmp_path):
    layout = layout_for_tree(_tree(), cap_bytes=600)
    with pytest.raises(TierRestoreError):
        restore_from_tiers([LocalDiskTier(tmp_path)], layout)


def test_compressed_deltas_shrink_and_stay_close(tmp_path):
    """int8 delta flushing: far fewer bytes than raw, and the restore
    tracks the live state within the quantization budget (bases re-anchor
    exactly every rebase_every cycles)."""
    shadow, dur, tiers, layout, states = _drive(
        tmp_path, compress=True, rebase=10, steps=4)
    try:
        ents = tiers[0].entries()
        base_total = sum(e.nbytes for e in ents if e.kind == "base")
        epochs = {e.epoch for e in ents if e.kind == "delta"}
        assert epochs
        for ep in epochs:                   # int8 epoch < one f32 base sweep
            delta_total = sum(e.nbytes for e in ents
                              if e.kind == "delta" and e.epoch == ep)
            assert 0 < delta_total < base_total
        ckpt = restore_from_tiers(tiers, layout, n_nodes=2)
        assert ckpt["step"] == 4
        for k, v in ckpt["params"].items():
            ref = states[4]["params"][k]
            assert np.allclose(v, ref, atol=1e-2), k
    finally:
        shadow.shutdown()


# -- crash mid-flush (satellite: torn-delta property) -------------------------

@given(st.sampled_from(sorted(UPDATE_FNS)), st.sampled_from([False, True]),
       st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_crash_mid_flush_falls_back_bit_identical(opt_name, async_mode,
                                                  cut_seed):
    """Cut the newest on-disk record at a random byte (a crash mid-write,
    bypassing the atomic rename). The checksum rejects the torn blob and
    restore falls back to the previous epoch — bit-identical to the
    trainer at that older step. The property holds across optimizers and
    sync/async apply."""
    root = tempfile.mkdtemp(prefix="repro-dur-")  # fallback @given: no fixtures
    shadow, dur, tiers, layout, states = _drive(
        root, opt_name=opt_name, async_mode=async_mode, steps=4,
        rebase=100)                          # no rebase: deltas all the way
    try:
        tier = tiers[0]
        newest = _payload_entries(tier)[-1]
        assert newest.kind == "delta" and newest.step == 4
        path = tier.root / newest.key
        raw = path.read_bytes()
        cut = int(np.random.default_rng(cut_seed).integers(0, len(raw)))
        path.write_bytes(raw[:cut])
        ckpt = restore_from_tiers(tiers, layout, n_nodes=2)
        assert ckpt["step"] == 3             # previous durable epoch
        ref = states[3]
        for part in ("params", "mu", "nu"):
            for k in ckpt[part]:
                assert np.array_equal(ckpt[part][k], ref[part][k]), (part, k)
    finally:
        shadow.shutdown()


# -- the stateless no-EF codec (satellite) ------------------------------------

def test_stateless_codec_error_bounded_per_slot():
    params = _tree()
    layout = layout_for_tree(params, cap_bytes=600)
    b = layout.buckets[0]
    rng = np.random.default_rng(0)
    flat = rng.standard_normal(b.size).astype(np.float32)
    q, scales = quantize_flat_stateless(b, flat)
    assert q.dtype == np.int8 and q.shape == (b.size,)
    assert scales.dtype == np.float32 and len(scales) == len(b.slots)
    deq = dequantize_flat_stateless(b, q, scales)
    for i, sl in enumerate(b.slots):
        s = slice(sl.offset, sl.offset + sl.size)
        assert np.max(np.abs(deq[s] - flat[s])) <= scales[i] / 2 + 1e-7
    assert Compressor.quantize_flat_stateless is not None  # exposed on API


def test_flushing_never_perturbs_channel_error_feedback(tmp_path):
    """Satellite regression: the SAME compressed-channel stream, with and
    without compressed flushing attached, leaves the channel Compressor's
    EF residuals and the shadow state bit-identical — the flush plane is
    invisible to the gradient stream."""
    def run(flush: bool, root):
        params = _tree()
        layout = layout_for_tree(params, cap_bytes=600)
        shadow = ShadowCluster(layout, OptimizerConfig(lr=1e-3), n_nodes=2)
        if flush:
            DurableShadow([LocalDiskTier(root)],
                          FlushPolicy(compress=True,
                                      rebase_every=3)).attach(shadow)
        zeros = {k: np.zeros_like(v) for k, v in params.items()}
        shadow.bootstrap(params, zeros, zeros, 0)
        chan = CompressedChannel(InProcessChannel())
        chan.open(layout)
        for step in range(1, 5):
            chan.send(StepEvent(step=step, grads=_grads(params, step),
                                lr=1e-3))
            for d in chan.poll():
                shadow.on_delivery(d)
        if flush:
            shadow.durability.drain()
        ckpt = shadow.consolidate(timeout=60)
        ef = {k: np.asarray(v) for k, v in chan.compressor.ef.items()}
        chan.close()
        shadow.shutdown()
        return ckpt, ef

    ck_a, ef_a = run(False, tmp_path / "a")
    ck_b, ef_b = run(True, tmp_path / "b")
    assert set(ef_a) == set(ef_b)
    for k in ef_a:
        assert np.array_equal(ef_a[k], ef_b[k]), f"EF[{k}] perturbed"
    for part in ("params", "mu", "nu"):
        for k in ck_a[part]:
            assert np.array_equal(ck_a[part][k], ck_b[part][k]), (part, k)


# -- ShadowNodeLoss names the durable tier (satellite) ------------------------

def test_total_loss_names_newest_durable_tier(tmp_path):
    shadow, dur, tiers, layout, states = _drive(tmp_path, steps=3)
    try:
        for n in range(shadow.n_nodes):
            shadow.kill_node(n)
        with pytest.raises(ShadowNodeLoss) as ei:
            shadow.consolidate()
        e = ei.value
        assert e.total and e.durable_hint == ("local-disk", 3)
        msg = str(e)
        assert "TOTAL shadow-plane loss" in msg
        assert "local-disk" in msg and "step 3" in msg
        assert "restore_from_tiers" in msg
    finally:
        shadow.shutdown()


def test_total_loss_without_tiers_says_unrecoverable():
    params = _tree()
    layout = layout_for_tree(params, cap_bytes=600)
    shadow = ShadowCluster(layout, OptimizerConfig(lr=1e-3), n_nodes=2)
    zeros = {k: np.zeros_like(v) for k, v in params.items()}
    shadow.bootstrap(params, zeros, zeros, 0)
    shadow.kill_node(0)
    shadow.kill_node(1)
    with pytest.raises(ShadowNodeLoss) as ei:
        shadow.consolidate()
    assert ei.value.total and ei.value.durable_hint is None
    assert "unrecoverable" in str(ei.value)


def test_partial_loss_hint_names_missing_shards(tmp_path):
    shadow, dur, tiers, layout, states = _drive(tmp_path, steps=3)
    try:
        shadow.kill_node(0)
        with pytest.raises(ShadowNodeLoss) as ei:
            shadow.consolidate()
        e = ei.value
        assert not e.total and e.durable_hint == ("local-disk", 3)
        assert "holds the missing shards durably up to step 3" in str(e)
        # the composition path: dead shards rebuilt at the survivors' step
        p, m, v = restore_shards_from_tiers(
            tiers, layout, e.dead_nodes, at_step=int(e.partial["step"]))
        merged = set(e.partial["params"]) | set(p)
        assert merged == set(states[3]["params"])
        for k in p:
            assert np.array_equal(p[k], states[3]["params"][k])
            assert np.array_equal(m[k], states[3]["mu"][k])
            assert np.array_equal(v[k], states[3]["nu"][k])
    finally:
        shadow.shutdown()


# -- retention GC + object-store put retry (satellites) -----------------------

def test_retention_gc_bounds_disk_over_epochs(tmp_path):
    """retain_epochs: 20 flush epochs leave a bounded set of records on
    disk — the retained window plus the chain back to the newest all-base
    anchor — the newest base+delta chain survives, and restore stays
    bit-identical to the live shadow."""
    params = _tree()
    layout = layout_for_tree(params, cap_bytes=600)
    shadow = ShadowCluster(layout, OptimizerConfig(lr=1e-3), n_nodes=2)
    tier = LocalDiskTier(tmp_path, retain_epochs=4)
    dur = DurableShadow([tier], FlushPolicy(rebase_every=4)).attach(shadow)
    zeros = {k: np.zeros_like(v) for k, v in params.items()}
    shadow.bootstrap(params, zeros, zeros, 0)
    chan = InProcessChannel()
    chan.open(layout)
    try:
        for step in range(1, 21):
            chan.send(StepEvent(step=step, grads=_grads(params, step),
                                lr=1e-3))
            for d in chan.poll():
                shadow.on_delivery(d)
            dur.drain()
        ents = tier.entries()
        epochs = sorted({e.epoch for e in ents})
        # 21 epochs were written (bootstrap base + 20 steps); only the
        # window back to the anchor base epoch remains
        assert dur.epochs_started == 21
        assert len(epochs) <= 4 + 4           # retain + one rebase cycle
        assert all(e.kind == "base" for e in ents if e.epoch == epochs[0])
        assert tier.gc_records_total > 0
        # no manifest entry points at a missing blob, and no pruned blob
        # lingers on disk
        on_disk = {p.name for p in tmp_path.glob("rec_*.bin")}
        assert on_disk == {e.key for e in ents}
        assert tier.disk_bytes() == sum(e.nbytes for e in ents)
        ckpt = restore_from_tiers([tier], layout, n_nodes=2)
        assert ckpt["step"] == 20
        ref = shadow.consolidate(timeout=60)
        for part in ("params", "mu", "nu"):
            for k in ckpt[part]:
                assert np.array_equal(ckpt[part][k], ref[part][k]), (part, k)
    finally:
        chan.close()
        shadow.shutdown()


def test_retention_never_cuts_newest_chain():
    """With bases still ahead of the retention cutoff there is no safe
    anchor below the window — nothing is pruned, the chain stays whole."""
    tier = ObjectStoreTier(retain_epochs=2)
    rng = np.random.default_rng(0)

    def rec(epoch, kind):
        payload = {}
        if kind != "mark":
            payload = {0: {"p": rng.standard_normal(8).astype(np.float32),
                           "m": rng.standard_normal(8).astype(np.float32),
                           "v": rng.standard_normal(8).astype(np.float32)}}
        return FlushRecord(epoch=epoch, node=0, step=epoch, kind=kind,
                           compressed=False, payload=payload)

    for epoch, kind in enumerate(("base", "delta", "delta", "delta")):
        tier.put(rec(epoch, kind))
    # the only base (epoch 0) is BELOW the 2-epoch window: epochs 1+
    # chain back to it, so the anchor keeps everything
    assert sorted({e.epoch for e in tier.entries()}) == [0, 1, 2, 3]
    assert tier.gc_records_total == 0
    # a fresh base inside the window re-anchors; older epochs drop
    tier.put(rec(4, "base"))
    tier.put(rec(5, "delta"))
    assert sorted({e.epoch for e in tier.entries()}) == [4, 5]
    assert tier.gc_records_total == 4


def test_object_store_put_retries_transient_failures():
    tier = ObjectStoreTier(retry_attempts=3, retry_backoff_s=0.001)
    tier.transient_fail_steps[12] = 2       # _record() is at step 12
    entry = tier.put(_record())             # attempt 3 succeeds
    assert tier.retries_total == 2
    assert tier.entries() == [entry]
    assert tier.read(entry).step == 12


def test_retry_in_flush_plane_and_clean_give_up(tmp_path):
    """Transient object-store failures are retried to success on the
    flush-worker thread; when the budget is exhausted the tier gives up
    cleanly — the put failure is booked, the epoch stays incomplete on
    THAT tier only, and restore serves the newest point any tier has."""
    params = _tree()
    layout = layout_for_tree(params, cap_bytes=600)
    shadow = ShadowCluster(layout, OptimizerConfig(lr=1e-3), n_nodes=2)
    ost = ObjectStoreTier(retry_attempts=2)
    ost.transient_fail_steps[1] = 1         # one flake: retry succeeds
    ost.transient_fail_steps[2] = 5         # beyond the budget: give up
    tiers = [LocalDiskTier(tmp_path), ost]
    dur = DurableShadow(tiers).attach(shadow)
    zeros = {k: np.zeros_like(v) for k, v in params.items()}
    shadow.bootstrap(params, zeros, zeros, 0)
    chan = InProcessChannel()
    chan.open(layout)
    try:
        for step in (1, 2, 3):
            chan.send(StepEvent(step=step, grads=_grads(params, step),
                                lr=1e-3))
            for d in chan.poll():
                shadow.on_delivery(d)
            dur.drain()
        # step 1: both nodes flaked once, retried, landed
        assert {e.step for e in ost.entries()} == {0, 1, 3}
        # step 2: budget exhausted -> booked as failures, never raised
        # into the flush loop (the local tier is unaffected)
        assert dur.put_failures == 2
        assert ost.retries_total >= 2
        assert dur.last_complete_step("local-disk") == 3
        assert dur.last_complete_step("object-store") == 3
        assert dur.newest_durable() == ("local-disk", 3)
        ckpt = restore_from_tiers(tiers, layout, n_nodes=2)
        assert ckpt["step"] == 3
    finally:
        chan.close()
        shadow.shutdown()


# -- costmodel: flush + disk budget terms -------------------------------------

def _layout():
    return layout_for_tree(_tree(6, seed=1), cap_bytes=600)


def test_plan_without_flush_policy_unchanged():
    a = cm.plan_shadow_nodes(_layout())
    b = cm.plan_shadow_nodes(_layout(), flush_every_steps=None)
    assert a.n_nodes == b.n_nodes
    assert b.flush_bound == 1 and b.disk_bound == 1
    assert b.flush_gbps_per_node_max == 0.0


def _tight_budget(lo, slack=1.05, **kw):
    """A budget whose per-node tier barely absorbs the LARGEST bucket per
    epoch (the per-bucket feasibility floor), so the aggregate state must
    spread across several nodes."""
    big = max(cm._bucket_state_bytes(b) for b in lo.buckets)
    absorb = big * slack
    return absorb, cm.ShadowBudget(
        disk_gbps_per_node=absorb * 8.0 / 1e9 / 4.58, **kw)


def test_flush_bandwidth_bound_scales_fleet():
    lo = _layout()
    state = sum(cm._bucket_state_bytes(b) for b in lo.buckets)
    absorb, budget = _tight_budget(lo)
    plan = cm.plan_shadow_nodes(lo, budget=budget, flush_every_steps=1)
    assert plan.flush_bound >= 2
    assert plan.flush_bound >= -(-state // int(absorb))   # ceil(state/absorb)
    assert plan.n_nodes >= plan.flush_bound
    assert plan.flush_gbps_per_node_max > 0.0


def test_disk_capacity_bound_scales_fleet():
    lo = _layout()
    state = sum(cm._bucket_state_bytes(b) for b in lo.buckets)
    big = max(cm._bucket_state_bytes(b) for b in lo.buckets)
    retain = 8
    budget = cm.ShadowBudget(disk_bytes_per_node=big * (1 + retain) * 1.05)
    plan = cm.plan_shadow_nodes(lo, budget=budget, flush_every_steps=1,
                                retain_epochs=retain)
    assert plan.disk_bound >= 2
    assert plan.n_nodes >= plan.disk_bound


def test_compressed_flush_relaxes_the_bandwidth_bound():
    lo = _layout()
    _, budget = _tight_budget(lo)
    raw = cm.plan_shadow_nodes(lo, budget=budget, flush_every_steps=1)
    packed = cm.plan_shadow_nodes(lo, budget=budget, flush_every_steps=1,
                                  flush_compress=True)
    assert packed.flush_bound < raw.flush_bound


def test_infeasible_flush_epoch_is_actionable():
    lo = _layout()
    with pytest.raises(cm.ShadowPlanError) as ei:
        cm.plan_shadow_nodes(
            lo, budget=cm.ShadowBudget(disk_gbps_per_node=1e-9),
            flush_every_steps=1)
    assert "disk_gbps_per_node" in str(ei.value)
