"""Network simulator: exactly-once delivery, PFC losslessness, Fig 10
replication behaviour, §4.4 planning."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.net.pfc import PfcQueue
from repro.net.planner import PlanInput, plan
from repro.net.simulator import simulate_allgather_replication


@given(st.integers(2, 12), st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_exactly_once_delivery(n_ranks, n_nodes):
    r = simulate_allgather_replication(n_ranks, n_ranks * 64 * 1024,
                                       n_shadow_nodes=n_nodes)
    assert r.reassembled_ok
    assert r.drops == 0


def test_replication_counters_fig10():
    """Fig 10: only tagged packets replicate, so TX grows far slower than
    the replication factor."""
    base = simulate_allgather_replication(4, 1 << 26, replication_factor=1)
    r16 = simulate_allgather_replication(4, 1 << 26, replication_factor=16)
    assert base.rx_frames == r16.rx_frames          # ring traffic unchanged
    assert r16.tx_frames < 16 * base.rx_frames       # sub-linear in rf
    assert r16.reassembled_ok


def test_shadow_byte_balance():
    r = simulate_allgather_replication(8, 8 * (1 << 20), n_shadow_nodes=4)
    per = list(r.shadow_bytes.values())
    assert sum(per) == 8 * (1 << 20)
    assert max(per) <= 2 * min(p for p in per if p) + (1 << 20)


class TestPfc:
    def test_lossless_under_pressure(self):
        q = PfcQueue(capacity_bytes=1 << 20)
        sent = 0
        backlog = 10 << 20
        while sent < backlog:
            if q.offer(4096):
                sent += 4096
            else:
                q.drain(64 * 1024)              # receiver catches up
        assert q.dropped == 0
        assert q.pause_events > 0
        assert q.resume_events > 0

    def test_headroom(self):
        q = PfcQueue(capacity_bytes=2 << 20, xoff_frac=0.8)
        assert q.headroom_ok(max_inflight=256 * 1024)
        assert not q.headroom_ok(max_inflight=1 << 20)


def test_planner_llama3():
    """§4.4: 256 streams / ports, <0.8% of the 16K-GPU fabric."""
    p = plan(PlanInput(n_accelerators=16384, dp_groups=128,
                       ranks_per_group=128),
             grad_bytes_total=405e9 * 2, iter_time_s=4.58)
    assert p.multicast_streams == 256
    assert p.extra_port_fraction < 0.008
    assert p.shadow_min_nics == 2
    assert p.feasible


def test_planner_infeasible_flags():
    p = plan(PlanInput(n_accelerators=64, dp_groups=8, ranks_per_group=8,
                       accel_per_host=4, pcie_gbps=1.0),
             grad_bytes_total=1e12, iter_time_s=0.1)
    assert not p.feasible
    assert "PCIe" in p.notes
