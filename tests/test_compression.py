"""int8 gradient compression + error feedback; shadow consistency."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.dist.compression import (compress_tree, compression_ratio,
                                    dequantize_leaf, init_error_feedback,
                                    quantize_leaf)


@given(st.integers(1, 500), st.floats(0.01, 100.0))
@settings(max_examples=40, deadline=None)
def test_quantize_bounded_error(n, scale_mag):
    rng = np.random.default_rng(n)
    g = jnp.asarray(rng.standard_normal(n) * scale_mag, jnp.float32)
    ef = jnp.zeros(n, jnp.float32)
    q, scale, new_ef = quantize_leaf(g, ef)
    deq = dequantize_leaf(q, scale)
    # per-element error bounded by half a quantization step
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) * 0.5 + 1e-6
    # error feedback identity: deq + residual == original
    np.testing.assert_allclose(np.asarray(deq + new_ef), np.asarray(g),
                               rtol=1e-5, atol=1e-6)


def test_error_feedback_accumulates():
    """With EF, the *average* applied gradient converges to the truth even
    when a constant gradient is repeatedly quantized."""
    g = jnp.asarray(np.full(64, 0.301), jnp.float32)
    ef = jnp.zeros(64, jnp.float32)
    applied = []
    for _ in range(50):
        q, s, ef = quantize_leaf(g, ef)
        applied.append(np.asarray(dequantize_leaf(q, s)))
    mean_applied = np.mean(applied, axis=0)
    np.testing.assert_allclose(mean_applied, 0.301, rtol=1e-3)


def test_tree_api_and_ratio():
    rng = np.random.default_rng(0)
    grads = {"a": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32),
             "b": jnp.asarray(rng.standard_normal(17), jnp.float32)}
    ef = init_error_feedback(grads)
    dq, ef2, wire = compress_tree(grads, ef)
    assert set(dq) == set(grads) == set(ef2)
    assert wire < sum(g.size * 4 for g in grads.values())
    assert compression_ratio(grads) > 3.5      # ~4x for f32 -> int8
