"""Property tests for DDP-style bucketing (paper §4.2.2), including the
degenerate layouts the per-dtype flush must survive: empty trees, single
scalar leaves, and all-bf16 trees through build_buckets/pack_bucket_into."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.buckets import (alloc_flat, bucket_dtype, build_buckets,
                                layout_for_tree, pack_all, pack_all_into,
                                pack_bucket, pack_bucket_into, unpack_all,
                                unpack_bucket)

leaf_shapes = st.lists(
    st.tuples(st.integers(1, 8), st.integers(1, 64)), min_size=1, max_size=20)


@given(leaf_shapes, st.integers(64, 4096))
@settings(max_examples=50, deadline=None)
def test_roundtrip(shapes, cap):
    """pack -> unpack is the identity for any tree and any cap."""
    rng = np.random.default_rng(0)
    tree = {f"leaf{i}": rng.standard_normal(s).astype(np.float32)
            for i, s in enumerate(shapes)}
    layout = build_buckets([(k, v.shape, "float32") for k, v in tree.items()],
                           cap_bytes=cap)
    flats = pack_all(layout, tree)
    back = unpack_all(layout, flats)
    assert set(back) == set(tree)
    for k in tree:
        np.testing.assert_array_equal(back[k], tree[k])


@given(leaf_shapes, st.integers(128, 2048))
@settings(max_examples=50, deadline=None)
def test_cap_and_coverage(shapes, cap):
    """No multi-leaf bucket exceeds the cap; oversize leaves get dedicated
    buckets; every leaf appears exactly once."""
    leaves = [(f"l{i}", s, "float32") for i, s in enumerate(shapes)]
    layout = build_buckets(leaves, cap_bytes=cap)
    seen = []
    for b in layout.buckets:
        if len(b.slots) > 1:
            assert b.nbytes <= cap
        seen.extend(s.name for s in b.slots)
    assert sorted(seen) == sorted(n for n, _, _ in leaves)


def test_reverse_order():
    """Buckets fill from the LAST layer backwards (gradients become ready in
    backward order)."""
    leaves = [(f"layer{i}", (4,), "float32") for i in range(6)]
    layout = build_buckets(leaves, cap_bytes=10**9)
    names = [s.name for s in layout.buckets[0].slots]
    assert names == [f"layer{i}" for i in reversed(range(6))]


def test_offsets_contiguous():
    layout = build_buckets([("a", (3, 4), "float32"), ("b", (5,), "float32")],
                           cap_bytes=10**9)
    (b,) = layout.buckets
    assert b.slots[0].offset == 0
    assert b.slots[1].offset == b.slots[0].size
    assert b.size == 12 + 5


# -- degenerate layouts: the per-dtype flush edge cases -----------------------

def test_empty_tree_layout():
    """An empty tree is a valid (zero-bucket) layout end to end."""
    layout = build_buckets([])
    assert layout.buckets == ()
    assert layout.total_bytes == 0
    assert layout.leaf_index() == {}
    assert pack_all_into(layout, {}, {}) == {}
    assert layout_for_tree({}).buckets == ()


def test_single_scalar_leaf_roundtrip():
    """A shape-() leaf occupies one element and packs/unpacks exactly."""
    layout = build_buckets([("s", (), "float32")], cap_bytes=64)
    (b,) = layout.buckets
    assert b.size == 1
    assert b.slots[0].shape == () and b.slots[0].size == 1
    flat = pack_bucket_into(b, {"s": np.float32(3.5)},
                            alloc_flat(b.size, bucket_dtype(b)))
    assert flat.dtype == np.float32 and flat.tolist() == [3.5]
    back = unpack_bucket(b, flat)
    assert back["s"].shape == () and back["s"] == np.float32(3.5)


@given(st.integers(1, 10), st.integers(64, 4096))
@settings(max_examples=25, deadline=None)
def test_all_bf16_tree_packs_without_promotion(n_leaves, cap):
    """An all-bf16 tree buckets with bf16 wire buffers — the per-dtype
    flush never silently promotes, and pack_bucket_into round-trips every
    leaf bit-exactly through the narrow buffer."""
    import jax.numpy as jnp
    rng = np.random.default_rng(n_leaves * 31 + cap)
    tree = {f"w{i}": np.asarray(jnp.asarray(
                rng.standard_normal((1 + i % 3, 4)), jnp.bfloat16))
            for i in range(n_leaves)}
    layout = build_buckets([(k, v.shape, str(v.dtype))
                            for k, v in tree.items()], cap_bytes=cap)
    seen = []
    for b in layout.buckets:
        wire = bucket_dtype(b)
        assert wire == np.dtype("bfloat16")      # no promotion, loud or silent
        flat = pack_bucket_into(b, tree, alloc_flat(b.size, wire))
        assert flat.nbytes == 2 * b.size
        back = unpack_bucket(b, flat)
        for name, leaf in back.items():
            assert leaf.dtype == tree[name].dtype
            np.testing.assert_array_equal(leaf, tree[name])
        seen.extend(s.name for s in b.slots)
    assert sorted(seen) == sorted(tree)
