"""Property tests for DDP-style bucketing (paper §4.2.2)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.buckets import (build_buckets, layout_for_tree, pack_all,
                                pack_bucket, unpack_all, unpack_bucket)

leaf_shapes = st.lists(
    st.tuples(st.integers(1, 8), st.integers(1, 64)), min_size=1, max_size=20)


@given(leaf_shapes, st.integers(64, 4096))
@settings(max_examples=50, deadline=None)
def test_roundtrip(shapes, cap):
    """pack -> unpack is the identity for any tree and any cap."""
    rng = np.random.default_rng(0)
    tree = {f"leaf{i}": rng.standard_normal(s).astype(np.float32)
            for i, s in enumerate(shapes)}
    layout = build_buckets([(k, v.shape, "float32") for k, v in tree.items()],
                           cap_bytes=cap)
    flats = pack_all(layout, tree)
    back = unpack_all(layout, flats)
    assert set(back) == set(tree)
    for k in tree:
        np.testing.assert_array_equal(back[k], tree[k])


@given(leaf_shapes, st.integers(128, 2048))
@settings(max_examples=50, deadline=None)
def test_cap_and_coverage(shapes, cap):
    """No multi-leaf bucket exceeds the cap; oversize leaves get dedicated
    buckets; every leaf appears exactly once."""
    leaves = [(f"l{i}", s, "float32") for i, s in enumerate(shapes)]
    layout = build_buckets(leaves, cap_bytes=cap)
    seen = []
    for b in layout.buckets:
        if len(b.slots) > 1:
            assert b.nbytes <= cap
        seen.extend(s.name for s in b.slots)
    assert sorted(seen) == sorted(n for n, _, _ in leaves)


def test_reverse_order():
    """Buckets fill from the LAST layer backwards (gradients become ready in
    backward order)."""
    leaves = [(f"layer{i}", (4,), "float32") for i in range(6)]
    layout = build_buckets(leaves, cap_bytes=10**9)
    names = [s.name for s in layout.buckets[0].slots]
    assert names == [f"layer{i}" for i in reversed(range(6))]


def test_offsets_contiguous():
    layout = build_buckets([("a", (3, 4), "float32"), ("b", (5,), "float32")],
                           cap_bytes=10**9)
    (b,) = layout.buckets
    assert b.slots[0].offset == 0
    assert b.slots[1].offset == b.slots[0].size
    assert b.size == 12 + 5
