"""Coverage for the last test-free launch modules: the batched serving
driver (`repro.launch.serve`) and the three-term roofline model
(`repro.launch.roofline`) — a smoke test plus one property each."""
import json
import sys

import pytest
from hypothesis import given, settings, strategies as st

import repro.configs as C
from repro.configs.base import SHAPES
from repro.launch.roofline import (HBM_BW, ICI_BW, PEAK_BF16, Roofline,
                                   model_flops_for)


# -- roofline -----------------------------------------------------------------

def _roof(flops, nbytes, coll, chips=4, model=1e9):
    return Roofline(arch="a", shape="train_4k", mesh="m", chips=chips,
                    flops_per_device=flops, bytes_per_device=nbytes,
                    collective_bytes_per_device=coll, model_flops=model,
                    per_collective={})


def test_roofline_smoke_row():
    r = _roof(1e12, 1e9, 1e8, chips=2, model=5e11)
    row = r.row()
    assert row["bound"] in ("compute", "memory", "collective")
    assert row["step_time_s"] > 0
    assert row["hlo_flops_total"] == 2e12
    assert 0 < row["useful_flops_ratio"] <= 1
    assert 0 < row["mfu_at_roofline"] <= 1


@given(st.floats(1e6, 1e15), st.floats(1e3, 1e12), st.floats(0, 1e12),
       st.integers(1, 4096))
@settings(max_examples=30, deadline=None)
def test_roofline_step_time_is_binding_term(flops, nbytes, coll, chips):
    """step_time is the max of the three terms, `bound` names the binding
    one, and MFU at the roofline never exceeds the useful-FLOPs ratio
    (equality exactly when compute-bound)."""
    r = _roof(flops, nbytes, coll, chips=chips, model=flops * chips / 2)
    terms = {"compute": flops / PEAK_BF16, "memory": nbytes / HBM_BW,
             "collective": coll / ICI_BW}
    assert r.step_time_s == max(terms.values())
    assert terms[r.bound] == max(terms.values())
    assert r.mfu <= r.useful_flops_ratio + 1e-12
    if r.bound == "compute":
        assert r.mfu == pytest.approx(r.useful_flops_ratio)


def test_model_flops_follow_6nd_2nd():
    """Analytic MODEL_FLOPS: 6ND for train, 2ND forward-only, one token
    per sequence for decode — and MoE counts ACTIVE params only."""
    cfg = C.get("tinyllama-1.1b")
    n = cfg.param_count()
    train, prefill, decode = (SHAPES["train_4k"], SHAPES["prefill_32k"],
                              SHAPES["decode_32k"])
    assert model_flops_for(cfg, train) == \
        6.0 * n * train.global_batch * train.seq_len
    assert model_flops_for(cfg, prefill) == \
        2.0 * n * prefill.global_batch * prefill.seq_len
    assert model_flops_for(cfg, decode) == 2.0 * n * decode.global_batch

    moe = C.get("dbrx-132b")
    assert moe.num_experts > 0
    assert model_flops_for(moe, train) == \
        6.0 * moe.active_param_count() * train.global_batch * train.seq_len
    assert moe.active_param_count() < moe.param_count()


# -- serve --------------------------------------------------------------------

def _run_serve(monkeypatch, capsys, extra=()):
    from repro.launch import serve
    argv = ["serve", "--arch", "tinyllama-1.1b", "--reduced",
            "--batch", "2", "--prompt-len", "8", "--gen", "4", *extra]
    monkeypatch.setattr(sys, "argv", argv)
    serve.main()
    return json.loads(capsys.readouterr().out)


def test_serve_smoke(monkeypatch, capsys):
    out = _run_serve(monkeypatch, capsys)
    assert out["arch"] == "tinyllama-1.1b-smoke"
    assert out["batch"] == 2 and out["generated"] == 4
    assert out["prefill_s"] >= 0 and out["decode_s"] >= 0
    assert out["decode_tok_per_s"] > 0
    cfg = C.get("tinyllama-1.1b").reduced()
    assert len(out["sample_tokens"]) == 4       # min(gen, 8) greedy tokens
    assert all(0 <= t < cfg.vocab_size for t in out["sample_tokens"])


def test_serve_greedy_decode_deterministic(monkeypatch, capsys):
    """Greedy decode with a fixed seed is a pure function: two runs emit
    the identical token stream."""
    a = _run_serve(monkeypatch, capsys, extra=("--seed", "3"))
    b = _run_serve(monkeypatch, capsys, extra=("--seed", "3"))
    assert a["sample_tokens"] == b["sample_tokens"]
