"""Bucket-sharded shadow cluster (paper §4.2.4): a sharded consolidate is
bit-identical to the single-node merge for ANY bucket->owner assignment,
the sharded transport routes each bucket's frames only to its owner (and
loses exactly a dead owner's buckets), queue-depth accounting survives
platforms without `queue.qsize`, and every shadow-node-death golden
scenario replays bit-identically through the bundle machinery."""
import dataclasses
import json
import queue

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.buckets import layout_for_tree, pack_bucket
from repro.core.channel import (InProcessChannel, PacketizedChannel,
                                StepEvent)
from repro.core.multicast import assign_buckets
from repro.core.shadow import ShadowCluster, ShadowNodeLoss
from repro.harness import (GOLDEN, Scenario, replay_bundle, run_scenario,
                           write_bundle)
from repro.optim import OptimizerConfig

DEATH_GOLDEN = sorted(n for n, s in GOLDEN.items()
                      if s.schedule.shadow_death)
SHARDED_GOLDEN = sorted(n for n, s in GOLDEN.items() if s.channel.sharded)


def _tree(seed: int) -> dict:
    rng = np.random.default_rng(seed)
    shapes = [(7,), (3, 5), (16,), (2, 2, 3), (11,), (4, 9)]
    return {f"w{i}": rng.standard_normal(s).astype(np.float32) * 0.1
            for i, s in enumerate(shapes)}


def _zeros_like(tree: dict) -> dict:
    return {k: np.zeros_like(v) for k, v in tree.items()}


# -- the regression oracle: sharded == single-node, bit for bit --------------

@given(st.integers(0, 10_000), st.integers(1, 5),
       st.sampled_from(["adamw", "adam", "sgd"]),
       st.sampled_from([False, True]))
@settings(max_examples=10, deadline=None)
def test_sharded_consolidate_matches_single_node(seed, n_nodes, opt_name,
                                                 async_mode):
    """Distributed gather == single-node merge for random bucket->owner
    assignments, node counts, optimizers, and sync/async ingest. The
    1-node cluster (the pre-sharding code path) is the oracle."""
    rng = np.random.default_rng(seed)
    params = _tree(seed)
    layout = layout_for_tree(params, cap_bytes=256)
    assignment = {b.bucket_id: int(rng.integers(0, n_nodes))
                  for b in layout.buckets}
    opt = OptimizerConfig(name=opt_name, lr=1e-3)
    mu, nu = _zeros_like(params), _zeros_like(params)

    oracle = ShadowCluster(layout, opt, n_nodes=1)
    sharded = ShadowCluster(layout, opt, n_nodes=n_nodes,
                            async_mode=async_mode, assignment=assignment)
    oracle.bootstrap(params, mu, nu, 0)
    sharded.bootstrap(params, mu, nu, 0)
    chan = InProcessChannel()
    chan.open(layout)
    try:
        for step in range(1, 4):
            grads = {k: rng.standard_normal(v.shape).astype(np.float32)
                     for k, v in params.items()}
            chan.send(StepEvent(step=step, grads=grads, lr=1e-3))
            for d in chan.poll():
                # safe to share: the apply copies the delivery payload
                # (jnp.asarray) before the donated fused update
                oracle.on_delivery(d)
                sharded.on_delivery(d)
        want = oracle.consolidate()
        got = sharded.consolidate(timeout=60)
        assert got["step"] == want["step"] == 3
        for part in ("params", "mu", "nu"):
            assert set(got[part]) == set(want[part])
            for k in want[part]:
                assert np.array_equal(got[part][k], want[part][k]), \
                    (part, k, n_nodes, opt_name)
    finally:
        sharded.shutdown()


# -- sharded transport: owner routing, death, revival ------------------------

def _sharded_channel(layout, n_nodes=3, **kw):
    chan = PacketizedChannel(topology="rail-optimized", sharded=True,
                             n_shadow_nodes=n_nodes, **kw)
    chan.open(layout)
    return chan


def test_sharded_channel_routes_every_bucket_to_its_owner():
    params = _tree(3)
    layout = layout_for_tree(params, cap_bytes=96)
    owners = assign_buckets(layout, 3)
    assert set(owners.values()) == {0, 1, 2}    # all owners hold shards
    chan = _sharded_channel(layout)
    grads = {k: np.full(v.shape, 0.5, np.float32) for k, v in params.items()}
    chan.send(StepEvent(step=1, grads=grads, lr=1e-3))
    (d,) = chan.poll()
    assert d.complete
    assert d.node_complete == {0: True, 1: True, 2: True}
    assert all(not m for m in d.missing_buckets.values())
    assert set(d.flats) == {b.bucket_id for b in layout.buckets}
    for b in layout.buckets:                    # payload survives the wire
        np.testing.assert_array_equal(np.asarray(d.flats[b.bucket_id]),
                                      pack_bucket(b, grads, xp=np))
    chan.close()


def test_dead_owner_loses_exactly_its_buckets_until_revived():
    params = _tree(4)
    layout = layout_for_tree(params, cap_bytes=96)
    owners = assign_buckets(layout, 3)
    mine = tuple(sorted(b for b, n in owners.items() if n == 1))
    assert mine                                 # node 1 owns something
    chan = _sharded_channel(layout)
    grads = {k: np.ones(v.shape, np.float32) for k, v in params.items()}

    chan.kill_shadow_node(1)
    chan.send(StepEvent(step=1, grads=grads, lr=1e-3))
    (d,) = chan.poll()
    assert not d.complete
    assert d.node_complete == {0: True, 1: False, 2: True}
    assert tuple(d.missing_buckets[1]) == mine  # exactly its buckets
    assert not d.missing_buckets[0] and not d.missing_buckets[2]
    assert set(d.flats) == set(owners) - set(mine)   # survivors' payloads

    # deaths are persistent: the next send loses the same shard again
    chan.send(StepEvent(step=2, grads=grads, lr=1e-3))
    (d2,) = chan.poll()
    assert d2.node_complete[1] is False

    chan.revive_all()                           # replacement racked
    chan.send(StepEvent(step=3, grads=grads, lr=1e-3))
    (d3,) = chan.poll()
    assert d3.complete and all(d3.node_complete.values())
    assert set(d3.flats) == set(owners)
    chan.close()


def test_kill_shadow_node_rejects_unknown_node():
    layout = layout_for_tree(_tree(5), cap_bytes=96)
    chan = _sharded_channel(layout)
    with pytest.raises(ValueError, match="out of range"):
        chan.kill_shadow_node(7)
    chan.close()


def test_cluster_refuses_partial_delivery_for_dead_owner():
    """`on_delivery(nodes=...)` only accepts nodes the transport marked
    complete — asking for a dead owner's apply is an error, not a silent
    skip."""
    params = _tree(6)
    layout = layout_for_tree(params, cap_bytes=96)
    shadow = ShadowCluster(layout, OptimizerConfig(lr=1e-3), n_nodes=3)
    shadow.bootstrap(params, _zeros_like(params), _zeros_like(params), 0)
    chan = _sharded_channel(layout)
    chan.kill_shadow_node(2)
    grads = {k: np.ones(v.shape, np.float32) for k, v in params.items()}
    chan.send(StepEvent(step=1, grads=grads, lr=1e-3))
    (d,) = chan.poll()
    with pytest.raises(ValueError, match="incomplete for nodes \\[2\\]"):
        shadow.on_delivery(d, nodes={0, 1, 2})
    shadow.on_delivery(d, nodes={0, 1})         # survivors advance
    shadow.kill_node(2)
    with pytest.raises(ShadowNodeLoss) as e:
        shadow.consolidate()
    assert e.value.dead_nodes == [2]
    assert e.value.missing_buckets == {2: tuple(shadow.nodes[2].bucket_ids)}
    assert e.value.partial["step"] == 1         # survivors applied step 1
    chan.close()


# -- queue-depth accounting without queue.qsize ------------------------------

def test_async_ingest_survives_unimplemented_qsize(monkeypatch):
    """Regression: depth tracking used to poll `queue.qsize()`, which is
    both racy and raises NotImplementedError on some platforms (macOS
    sem_getvalue). The mutex-based `unfinished_tasks` count must carry the
    whole async path — ingest, consolidate wait, stats."""
    def boom(self):
        raise NotImplementedError("qsize unavailable on this platform")
    monkeypatch.setattr(queue.Queue, "qsize", boom)

    params = _tree(7)
    layout = layout_for_tree(params, cap_bytes=256)
    shadow = ShadowCluster(layout, OptimizerConfig(lr=1e-3), n_nodes=2,
                           async_mode=True)
    shadow.bootstrap(params, _zeros_like(params), _zeros_like(params), 0)
    chan = InProcessChannel()
    chan.open(layout)
    try:
        for step in range(1, 5):
            grads = {k: np.full(v.shape, 0.1, np.float32)
                     for k, v in params.items()}
            chan.send(StepEvent(step=step, grads=grads, lr=1e-3))
            for d in chan.poll():
                shadow.on_delivery(d)
        ckpt = shadow.consolidate(timeout=30)
        assert ckpt["step"] == 4
        assert shadow.stats().max_queue_depth >= 1   # depth was tracked
    finally:
        shadow.shutdown()


# -- golden death scenarios: replay + bundle round trips ---------------------

def test_corpus_has_enough_death_and_sharded_drills():
    assert len(DEATH_GOLDEN) >= 4
    phases = {d.phase for n in DEATH_GOLDEN
              for d in GOLDEN[n].schedule.shadow_death}
    assert phases == {"step", "consolidate"}
    assert len(SHARDED_GOLDEN) >= len(DEATH_GOLDEN) + 2   # + clean drills


@pytest.mark.parametrize("name", DEATH_GOLDEN)
def test_death_scenarios_replay_bit_identically(name):
    """Each shadow-node-death drill passes every applicable invariant and
    two runs produce byte-identical outcome bundles."""
    a = run_scenario(GOLDEN[name])
    assert a.passed, (name, a.violations)
    b = run_scenario(GOLDEN[name])
    assert a.bundle() == b.bundle()


@pytest.mark.parametrize("name", DEATH_GOLDEN)
def test_death_scenario_json_roundtrip(name):
    sc = GOLDEN[name]
    assert Scenario.from_dict(json.loads(sc.to_json())) == sc


def test_death_violation_bundle_replays(tmp_path):
    """A forced violation on a death scenario rides the write_bundle /
    replay_bundle machinery unchanged (new corpus entries need no new
    plumbing)."""
    sc = dataclasses.replace(GOLDEN["shadow-death-midstep"],
                             name="forced-bit-identity-under-death",
                             invariants=("shadow-bit-identity",
                                         "shadow-node-death"))
    result = run_scenario(sc, bundle_dir=tmp_path)
    if result.passed:
        # bit-identity skips partial trees, so force a real mismatch via
        # the bundle writer directly
        path = write_bundle(result, tmp_path)
    else:
        path = result.bundle_path
    d = json.loads(path.read_text())
    assert Scenario.from_dict(d["scenario"]) == sc
    replayed, identical = replay_bundle(path)
    assert identical
    assert replayed.bundle() == result.bundle()
