"""llava-next-style VLM: stubbed anyres vision frontend + LM backbone.

``input_specs`` provides precomputed, projected patch embeddings
(batch, num_patches, d_model); they are prepended to the token embeddings
and the standard causal LM runs over the combined sequence. The loss is
computed on text positions only. ``seq_len`` of a shape cell counts the
combined sequence (patches + text).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ShardingRules
from repro.models import layers as L
from repro.models import transformer as T


def param_specs(cfg: ModelConfig) -> dict:
    return T.param_specs(cfg)


def forward(params, cfg: ModelConfig, rules: ShardingRules, tokens,
            patch_embeds):
    cd = jnp.dtype(cfg.compute_dtype)
    b, s_text = tokens.shape
    p = patch_embeds.shape[1]
    tok = L.embed_tokens(params["embed"], tokens, rules, cfg.compute_dtype)
    x = jnp.concatenate([patch_embeds.astype(cd), tok], axis=1)
    x = rules.shard(x, "batch", "seq", "emb")
    s = p + s_text
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = T.decoder_stack(x, params, cfg, rules, positions)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    return L.lm_logits(x[:, p:], unembed, rules)   # text positions only


def loss_fn(params, cfg, rules, batch):
    logits = forward(params, cfg, rules, batch["tokens"],
                     batch["patch_embeds"])
    return L.xent_loss(logits, batch["labels"], batch.get("mask"))


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    return T.cache_specs(cfg, batch, max_seq)


def prefill(params, cfg: ModelConfig, rules: ShardingRules, tokens, max_seq,
            patch_embeds=None):
    cd = jnp.dtype(cfg.compute_dtype)
    b, s_text = tokens.shape
    p = patch_embeds.shape[1]
    tok = L.embed_tokens(params["embed"], tokens, rules, cfg.compute_dtype)
    x = jnp.concatenate([patch_embeds.astype(cd), tok], axis=1)
    x = rules.shard(x, "batch", "seq", "emb")
    s = p + s_text
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    stacked, _ = T.split_stacked(params, [k for k in T.LAYER_KEYS if k in params])

    def one_layer(x, lp):
        y, kv = T.dense_block(x, lp, cfg, rules, positions, prefill=True)
        return y, kv

    x, (ks, vs) = jax.lax.scan(one_layer, x, stacked)
    pad = [(0, 0), (0, 0), (0, max_seq - s), (0, 0), (0, 0)]
    ks = rules.shard(jnp.pad(ks, pad), "layers", "batch", "kv_seq", None, None)
    vs = rules.shard(jnp.pad(vs, pad), "layers", "batch", "kv_seq", None, None)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = L.lm_logits(x[:, -1:], unembed, rules)
    return {"k": ks, "v": vs, "length": jnp.int32(s)}, logits


def decode_step(params, cfg: ModelConfig, rules: ShardingRules, cache, token):
    return T.decode_step(params, cfg, rules, cache, token)
