"""whisper-style encoder-decoder backbone.

The log-mel + conv1d frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (batch, encoder_seq, d_model). The
backbone is faithful in structure (bidirectional encoder; decoder with causal
self-attention + cross-attention); positional encoding uses RoPE for
shape-independence (adaptation noted in docs/ARCHITECTURE.md, models).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ShardingRules
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.common import ParamSpec


def param_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    h, hd = cfg.num_heads, cfg.head_dim
    specs = {
        "embed": ParamSpec((v, d), ("vocab", "wemb"), init="normal"),
        "final_norm": ParamSpec((d,), ("unsharded",), init="ones"),
        "memory_norm": ParamSpec((d,), ("unsharded",), init="ones"),
        "unembed": ParamSpec((d, v), ("wemb", "vocab")),
    }
    specs.update({("enc_" + k): v for k, v in
                  T.layer_param_specs(cfg, cfg.encoder_layers).items()})
    specs.update({("dec_" + k): v for k, v in
                  T.layer_param_specs(cfg, cfg.num_layers).items()})
    # decoder cross-attention (stacked)
    nl = cfg.num_layers
    specs.update({
        "xattn_norm": ParamSpec((nl, d), ("layers", "unsharded"), init="ones"),
        "xwq": ParamSpec((nl, d, h * hd), ("layers", "wemb", "heads")),
        "xwk": ParamSpec((nl, d, h * hd), ("layers", "wemb", "heads")),
        "xwv": ParamSpec((nl, d, h * hd), ("layers", "wemb", "heads")),
        "xwo": ParamSpec((nl, h * hd, d), ("layers", "heads", "wemb")),
    })
    return specs


def _sub(params, prefix):
    return {k[len(prefix):]: v for k, v in params.items()
            if k.startswith(prefix)}


XATTN_KEYS = ("xattn_norm", "xwq", "xwk", "xwv", "xwo")


def encode(params, cfg: ModelConfig, rules: ShardingRules, frames):
    """frames: (b, enc_seq, d) precomputed embeddings -> encoder memory."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = rules.shard(frames.astype(cd), "batch", "seq", "emb")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    enc = _sub(params, "enc_")

    def one_layer(x, lp):
        y, _ = T.dense_block(x, lp, cfg, rules, positions, causal=False)
        return y.astype(cd), None

    body = jax.checkpoint(one_layer) if cfg.remat else one_layer
    x, _ = jax.lax.scan(body, x, enc)
    return L.rmsnorm(x, params["memory_norm"], cfg.norm_eps)


def _cross_attn(x, lp, memory, cfg, rules):
    cd = jnp.dtype(cfg.compute_dtype)
    b, s, _ = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    xn = L.rmsnorm(x, lp["xattn_norm"], cfg.norm_eps)
    q = (xn @ lp["xwq"].astype(cd)).reshape(b, s, h, hd)
    k = (memory @ lp["xwk"].astype(cd)).reshape(b, -1, h, hd)
    v = (memory @ lp["xwv"].astype(cd)).reshape(b, -1, h, hd)
    o = L.attention_qchunk(q, k, v, causal=False, q_chunk=cfg.attn_q_chunk)
    return x + o.reshape(b, s, -1) @ lp["xwo"].astype(cd)


def _decoder_stack(x, params, memory, cfg, rules, positions):
    dec = _sub(params, "dec_")
    dec.update({k: params[k] for k in XATTN_KEYS})

    def one_layer(x, lp):
        y, _ = T.attn_block(x, lp, cfg, rules, positions)
        y = _cross_attn(y, lp, memory, cfg, rules)
        xn = L.rmsnorm(y, lp["mlp_norm"], cfg.norm_eps)
        y = y + L.mlp_swiglu(xn, lp, cfg, rules)
        return rules.shard(y, "batch", "seq", "emb").astype(x.dtype), None

    body = jax.checkpoint(one_layer) if cfg.remat else one_layer
    x, _ = jax.lax.scan(body, x, dec)
    return x


def loss_fn(params, cfg: ModelConfig, rules: ShardingRules, batch):
    tokens, labels = batch["tokens"], batch["labels"]
    memory = encode(params, cfg, rules, batch["frames"])
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, rules, cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = _decoder_stack(x, params, memory, cfg, rules, positions)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(x, params["unembed"], rules)
    return L.xent_loss(logits, labels, batch.get("mask"))


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    kv, hd, h = cfg.num_kv_heads, cfg.head_dim, cfg.num_heads
    nl, es = cfg.num_layers, cfg.encoder_seq
    self_shape = (nl, batch, max_seq, kv, hd)
    self_logical = ("layers", "batch", "kv_seq", None, None)
    cross_shape = (nl, batch, es, h, hd)
    cross_logical = ("layers", "batch", None, "heads", None)
    return {
        "k": ParamSpec(self_shape, self_logical, init="zeros",
                       dtype=cfg.compute_dtype),
        "v": ParamSpec(self_shape, self_logical, init="zeros",
                       dtype=cfg.compute_dtype),
        "xk": ParamSpec(cross_shape, cross_logical, init="zeros",
                        dtype=cfg.compute_dtype),
        "xv": ParamSpec(cross_shape, cross_logical, init="zeros",
                        dtype=cfg.compute_dtype),
    }


def prefill(params, cfg: ModelConfig, rules: ShardingRules, tokens, max_seq,
            frames=None):
    cd = jnp.dtype(cfg.compute_dtype)
    memory = encode(params, cfg, rules, frames)
    b, s = tokens.shape
    h, hd = cfg.num_heads, cfg.head_dim
    x = L.embed_tokens(params["embed"], tokens, rules, cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    dec = _sub(params, "dec_")
    dec.update({k: params[k] for k in XATTN_KEYS})

    def one_layer(x, lp):
        y, kv = T.attn_block(x, lp, cfg, rules, positions, prefill=True)
        y = _cross_attn(y, lp, memory, cfg, rules)
        xn = L.rmsnorm(y, lp["mlp_norm"], cfg.norm_eps)
        y = y + L.mlp_swiglu(xn, lp, cfg, rules)
        xk = (memory @ lp["xwk"].astype(cd)).reshape(b, -1, h, hd)
        xv = (memory @ lp["xwv"].astype(cd)).reshape(b, -1, h, hd)
        return y.astype(x.dtype), (kv[0], kv[1], xk, xv)

    x, (ks, vs, xks, xvs) = jax.lax.scan(one_layer, x, dec)
    pad = [(0, 0), (0, 0), (0, max_seq - s), (0, 0), (0, 0)]
    ks = rules.shard(jnp.pad(ks, pad), "layers", "batch", "kv_seq", None, None)
    vs = rules.shard(jnp.pad(vs, pad), "layers", "batch", "kv_seq", None, None)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(x[:, -1:], params["unembed"], rules)
    cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs, "length": jnp.int32(s)}
    return cache, logits


def decode_step(params, cfg: ModelConfig, rules: ShardingRules, cache, token):
    cd = jnp.dtype(cfg.compute_dtype)
    pos = cache["length"]
    x = L.embed_tokens(params["embed"], token, rules, cfg.compute_dtype)
    b = x.shape[0]
    h, hd = cfg.num_heads, cfg.head_dim

    dec = _sub(params, "dec_")
    dec.update({k: params[k] for k in XATTN_KEYS})

    def one_layer(x, layer_in):
        lp, kc, vc, xk, xv = layer_in
        y, kc, vc = _self_then_cross(x, lp, kc, vc, xk, xv, pos, cfg, rules)
        return y.astype(x.dtype), (kc, vc)

    def _self_then_cross(x, lp, kc, vc, xk, xv, pos, cfg, rules):
        xn = L.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        pp = jnp.full((b, 1), pos, jnp.int32)
        q, k, v = L.attn_project_qkv(xn, lp, cfg, pp)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, axis=1)
        o = L.attention_decode(q, L.expand_kv(kc, cfg.num_heads),
                               L.expand_kv(vc, cfg.num_heads), length=pos + 1)
        x = x + o.reshape(b, 1, -1) @ lp["wo"].astype(cd)
        # cross attention against precomputed memory K/V
        xn = L.rmsnorm(x, lp["xattn_norm"], cfg.norm_eps)
        q = (xn @ lp["xwq"].astype(cd)).reshape(b, 1, h, hd)
        o = L.attention_decode(q, xk, xv)
        x = x + o.reshape(b, 1, -1) @ lp["xwo"].astype(cd)
        xn = L.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        return x + L.mlp_swiglu(xn, lp, cfg, rules), kc, vc

    x, (ks, vs) = jax.lax.scan(one_layer, x,
                               (dec, cache["k"], cache["v"],
                                cache["xk"], cache["xv"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(x, params["unembed"], rules)
    cache = dict(cache, k=ks, v=vs, length=pos + 1)
    return logits, cache
