"""Mamba2 / SSD (state-space duality) blocks — attention-free LM family.

Implements the chunked SSD algorithm (arXiv:2405.21060): intra-chunk
quadratic path + inter-chunk linear recurrence over chunk states, plus a
constant-memory single-token decode step. The short causal conv is applied
to x, B and C (depthwise, unrolled taps — TPU/VPU friendly, no conv
primitive needed).

TP: heads (= d_inner / head_dim) shard over 'model'; B/C are per-group
(groups=1) and replicated; all SSD einsums carry heads as a batch dim, so
the block is communication-free except the final out-projection reduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ShardingRules
from repro.models import layers as L
from repro.models.common import ParamSpec


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def layer_param_specs(cfg: ModelConfig, n_layers: int, stacked=True) -> dict:
    d, din = cfg.d_model, cfg.d_inner
    n, g, h = cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    w = cfg.ssm_conv
    lead = (n_layers,) if stacked else ()
    lax_ = ("layers",) if stacked else ()
    def S(shape, logical, **kw):
        return ParamSpec(lead + shape, lax_ + logical, **kw)
    return {
        "ssm_norm": S((d,), ("unsharded",), init="ones"),
        "wz": S((d, din), ("wemb", "ssm_inner")),
        "wx": S((d, din), ("wemb", "ssm_inner")),
        "wB": S((d, g * n), ("wemb", "unsharded")),
        "wC": S((d, g * n), ("wemb", "unsharded")),
        "wdt": S((d, h), ("wemb", "ssm_inner")),
        "conv_x": S((w, din), ("unsharded", "ssm_inner"), init="normal"),
        "conv_B": S((w, g * n), ("unsharded", "unsharded"), init="normal"),
        "conv_C": S((w, g * n), ("unsharded", "unsharded"), init="normal"),
        "A_log": S((h,), ("ssm_inner",), init="ssm_a"),
        "D": S((h,), ("ssm_inner",), init="ones"),
        "dt_bias": S((h,), ("ssm_inner",), init="ssm_dt"),
        "gate_norm": S((din,), ("ssm_inner",), init="ones"),
        "w_out": S((din, d), ("ssm_inner", "wemb")),
    }


def param_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    specs = {
        "embed": ParamSpec((v, d), ("vocab", "wemb"), init="normal"),
        "final_norm": ParamSpec((d,), ("unsharded",), init="ones"),
        "unembed": ParamSpec((d, v), ("wemb", "vocab")),
    }
    specs.update(layer_param_specs(cfg, cfg.num_layers))
    return specs


SSM_LAYER_KEYS = tuple(layer_param_specs(
    ModelConfig("x", "ssm", 1, 64, 0, 0, 0, 16, ssm_state=8), 1).keys())


# ---------------------------------------------------------------------------
# Causal depthwise conv (unrolled taps)
# ---------------------------------------------------------------------------

def causal_conv(x, kernel):
    """x: (b, s, c); kernel: (w, c). Left-padded causal depthwise conv."""
    w = kernel.shape[0]
    out = x * kernel[-1]
    for t in range(1, w):
        shifted = jnp.pad(x, ((0, 0), (t, 0), (0, 0)))[:, :-t]
        out = out + shifted * kernel[-1 - t]
    return out


def conv_step(x_t, conv_cache, kernel):
    """x_t: (b, c); conv_cache: (b, w-1, c) holding the last w-1 inputs."""
    hist = jnp.concatenate([conv_cache, x_t[:, None]], axis=1)   # (b, w, c)
    y = jnp.einsum("bwc,wc->bc", hist, kernel)
    return y, hist[:, 1:]


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD scan.

    x: (b, s, h, p); dt: (b, s, h) (post-softplus); A: (h,) negative;
    B, C: (b, s, n) (groups=1, shared across heads). Returns (y, final_state)
    with y: (b, s, h, p), final_state: (b, h, n, p).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    if s % q:
        q = s
    nc = s // q

    xr = x.reshape(b, nc, q, h, p)
    dtr = dt.reshape(b, nc, q, h)
    Br = B.reshape(b, nc, q, n)
    Cr = C.reshape(b, nc, q, n)

    dA = dtr * A                                      # (b,nc,q,h), negative
    cum = jnp.cumsum(dA, axis=2)                      # within-chunk cumulative

    # --- intra-chunk (quadratic within chunk) ---
    CB = jnp.einsum("bcqn,bckn->bcqk", Cr, Br,
                    preferred_element_type=jnp.float32)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (b,nc,q,k,h)
    mask = jnp.tril(jnp.ones((q, q), bool))
    att = CB[..., None] * decay * mask[None, None, :, :, None]
    y_intra = jnp.einsum("bcqkh,bckh,bckhp->bcqhp", att, dtr, xr,
                         preferred_element_type=jnp.float32)

    # --- chunk states ---
    last = cum[:, :, -1:, :]                          # (b,nc,1,h)
    decay_out = jnp.exp(last - cum)                   # (b,nc,q,h)
    S_c = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", Br, decay_out * dtr, xr,
                     preferred_element_type=jnp.float32)
    chunk_decay = jnp.exp(last[:, :, 0])              # (b,nc,h)

    # --- inter-chunk recurrence ---
    def step(S, inp):
        S_chunk, cd = inp                             # (b,h,n,p), (b,h)
        S_prev = S
        S = S * cd[..., None, None] + S_chunk
        return S, S_prev

    S0 = jnp.zeros((b, h, n, p), jnp.float32)
    S_final, S_prevs = jax.lax.scan(
        step, S0, (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)             # (b,nc,h,n,p)

    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", Cr, jnp.exp(cum), S_prevs,
                         preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), S_final


def ssd_decode_step(x_t, dt_t, A, B_t, C_t, S):
    """One recurrence step. x_t: (b,h,p); dt_t: (b,h); B_t,C_t: (b,n);
    S: (b,h,n,p) -> (y_t, S')."""
    dA = jnp.exp(dt_t * A)                            # (b,h)
    dBx = jnp.einsum("bn,bh,bhp->bhnp", B_t, dt_t, x_t,
                     preferred_element_type=jnp.float32)
    S = S * dA[..., None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", C_t, S, preferred_element_type=jnp.float32)
    return y.astype(x_t.dtype), S


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def mamba_block(x, lp, cfg: ModelConfig, rules: ShardingRules):
    """Full-sequence block. x: (b, s, d) -> (b, s, d)."""
    cd = jnp.dtype(cfg.compute_dtype)
    b, s, d = x.shape
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xn = L.rmsnorm(x, lp["ssm_norm"], cfg.norm_eps)
    z = xn @ lp["wz"].astype(cd)
    xi = xn @ lp["wx"].astype(cd)
    Bp = xn @ lp["wB"].astype(cd)
    Cp = xn @ lp["wC"].astype(cd)
    dt = xn @ lp["wdt"].astype(cd)
    xi = rules.shard(xi, "batch", "seq", "act_heads")
    xi = causal_conv(xi, lp["conv_x"].astype(cd))
    Bp = causal_conv(Bp, lp["conv_B"].astype(cd))
    Cp = causal_conv(Cp, lp["conv_C"].astype(cd))
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(cd)
    Bp = jax.nn.silu(Bp.astype(jnp.float32)).astype(cd)
    Cp = jax.nn.silu(Cp.astype(jnp.float32)).astype(cd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    y, _ = ssd_chunked(xi.reshape(b, s, h, p), dt, A, Bp, Cp, cfg.ssm_chunk)
    y = y + xi.reshape(b, s, h, p) * lp["D"].astype(cd)[:, None]
    y = y.reshape(b, s, -1)
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(cd),
                  lp["gate_norm"], cfg.norm_eps)
    return x + y @ lp["w_out"].astype(cd)


def mamba_decode_block(x, lp, state, conv_cache, cfg, rules):
    """x: (b, 1, d); state: (b,h,n,p); conv_cache: {"x","B","C"} each (b,w-1,c)."""
    cd = jnp.dtype(cfg.compute_dtype)
    b = x.shape[0]
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    xn = L.rmsnorm(x, lp["ssm_norm"], cfg.norm_eps)[:, 0]      # (b, d)
    z = xn @ lp["wz"].astype(cd)
    xi = xn @ lp["wx"].astype(cd)
    Bp = xn @ lp["wB"].astype(cd)
    Cp = xn @ lp["wC"].astype(cd)
    dt = xn @ lp["wdt"].astype(cd)
    xi, cx = conv_step(xi, conv_cache["x"], lp["conv_x"].astype(cd))
    Bp, cB = conv_step(Bp, conv_cache["B"], lp["conv_B"].astype(cd))
    Cp, cC = conv_step(Cp, conv_cache["C"], lp["conv_C"].astype(cd))
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(cd)
    Bp = jax.nn.silu(Bp.astype(jnp.float32)).astype(cd)
    Cp = jax.nn.silu(Cp.astype(jnp.float32)).astype(cd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    y, state = ssd_decode_step(xi.reshape(b, h, p), dt, A, Bp, Cp, state)
    y = y + xi.reshape(b, h, p) * lp["D"].astype(cd)[:, None]
    y = y.reshape(b, -1)
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(cd),
                  lp["gate_norm"], cfg.norm_eps)
    out = x + (y @ lp["w_out"].astype(cd))[:, None]
    return out, state, {"x": cx, "B": cB, "C": cC}


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def _stacked(params):
    return {k: params[k] for k in SSM_LAYER_KEYS if k in params}


def forward(params, cfg: ModelConfig, rules: ShardingRules, tokens):
    x = L.embed_tokens(params["embed"], tokens, rules, cfg.compute_dtype)

    def one_layer(x, lp):
        y = mamba_block(x, lp, cfg, rules)
        return rules.shard(y, "batch", "seq", "emb"), None

    body = jax.checkpoint(one_layer) if cfg.remat else one_layer
    x, _ = jax.lax.scan(body, x, _stacked(params))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return L.lm_logits(x, params["unembed"], rules)


def loss_fn(params, cfg, rules, batch):
    logits = forward(params, cfg, rules, batch["tokens"])
    return L.xent_loss(logits, batch["labels"], batch.get("mask"))


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    din, gn, w = cfg.d_inner, cfg.ssm_groups * cfg.ssm_state, cfg.ssm_conv
    lgl = ("layers", "batch", None, "ssm_inner")
    return {
        "state": ParamSpec((cfg.num_layers, batch, h, n, p),
                           ("layers", "batch", "ssm_inner", None, None),
                           init="zeros"),
        "conv_x": ParamSpec((cfg.num_layers, batch, w - 1, din), lgl,
                            init="zeros", dtype=cfg.compute_dtype),
        "conv_B": ParamSpec((cfg.num_layers, batch, w - 1, gn),
                            ("layers", "batch", None, None),
                            init="zeros", dtype=cfg.compute_dtype),
        "conv_C": ParamSpec((cfg.num_layers, batch, w - 1, gn),
                            ("layers", "batch", None, None),
                            init="zeros", dtype=cfg.compute_dtype),
    }


def prefill(params, cfg: ModelConfig, rules: ShardingRules, tokens, max_seq):
    """Run the prompt through SSD, collecting final states per layer."""
    del max_seq  # state is O(1) in sequence length
    cd = jnp.dtype(cfg.compute_dtype)
    b, s = tokens.shape
    h, p, w = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_conv
    x = L.embed_tokens(params["embed"], tokens, rules, cfg.compute_dtype)

    def one_layer(x, lp):
        # inline mamba_block but keep the final state + conv tail
        xn = L.rmsnorm(x, lp["ssm_norm"], cfg.norm_eps)
        z = xn @ lp["wz"].astype(cd)
        xi0 = xn @ lp["wx"].astype(cd)
        Bp0 = xn @ lp["wB"].astype(cd)
        Cp0 = xn @ lp["wC"].astype(cd)
        dt = xn @ lp["wdt"].astype(cd)
        xi = jax.nn.silu(causal_conv(xi0, lp["conv_x"].astype(cd))
                         .astype(jnp.float32)).astype(cd)
        Bp = jax.nn.silu(causal_conv(Bp0, lp["conv_B"].astype(cd))
                         .astype(jnp.float32)).astype(cd)
        Cp = jax.nn.silu(causal_conv(Cp0, lp["conv_C"].astype(cd))
                         .astype(jnp.float32)).astype(cd)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
        A = -jnp.exp(lp["A_log"].astype(jnp.float32))
        y, S = ssd_chunked(xi.reshape(b, s, h, p), dt, A, Bp, Cp, cfg.ssm_chunk)
        y = y + xi.reshape(b, s, h, p) * lp["D"].astype(cd)[:, None]
        y = y.reshape(b, s, -1)
        y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(cd),
                      lp["gate_norm"], cfg.norm_eps)
        out = x + y @ lp["w_out"].astype(cd)
        tails = (xi0[:, -(w - 1):], Bp0[:, -(w - 1):], Cp0[:, -(w - 1):])
        return out, (S, tails)

    x, (S, (tx, tB, tC)) = jax.lax.scan(one_layer, x, _stacked(params))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(x[:, -1:], params["unembed"], rules)
    cache = {"state": S, "conv_x": tx, "conv_B": tB, "conv_C": tC,
             "length": jnp.int32(s)}
    return cache, logits


def decode_step(params, cfg: ModelConfig, rules: ShardingRules, cache, token):
    x = L.embed_tokens(params["embed"], token, rules, cfg.compute_dtype)

    def one_layer(x, layer_in):
        lp, S, cx, cB, cC = layer_in
        y, S, cc = mamba_decode_block(x, lp, S, {"x": cx, "B": cB, "C": cC},
                                      cfg, rules)
        return y.astype(x.dtype), (S, cc["x"], cc["B"], cc["C"])

    x, (S, cx, cB, cC) = jax.lax.scan(
        one_layer, x,
        (_stacked(params), cache["state"], cache["conv_x"],
         cache["conv_B"], cache["conv_C"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(x, params["unembed"], rules)
    new_cache = {"state": S, "conv_x": cx, "conv_B": cB, "conv_C": cC,
                 "length": cache["length"] + 1}
    return logits, new_cache
