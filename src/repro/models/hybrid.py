"""zamba2-style hybrid: Mamba2 backbone + ONE shared attention block applied
every ``attn_every`` SSM layers (weight sharing across invocations).

Each shared-block invocation sees different activations, so at decode time it
gets its own KV cache slot: caches are stacked (n_shared, b, S, kv, hd).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ShardingRules
from repro.models import layers as L
from repro.models import ssm as M
from repro.models import transformer as T
from repro.models.common import ParamSpec


def n_shared_calls(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.attn_every


def segments(cfg: ModelConfig) -> list[tuple[int, int, bool]]:
    """List of (start, end, attn_after) covering all ssm layers."""
    out, start = [], 0
    while start < cfg.num_layers:
        end = min(start + cfg.attn_every, cfg.num_layers)
        out.append((start, end, end - start == cfg.attn_every))
        start = end
    return out


def param_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    specs = {
        "embed": ParamSpec((v, d), ("vocab", "wemb"), init="normal"),
        "final_norm": ParamSpec((d,), ("unsharded",), init="ones"),
        "unembed": ParamSpec((d, v), ("wemb", "vocab")),
    }
    specs.update(M.layer_param_specs(cfg, cfg.num_layers))
    # one shared transformer block (unstacked)
    specs.update({("shared_" + k): v for k, v in
                  T.layer_param_specs(cfg, 1, stacked=False).items()})
    return specs


def _shared_lp(params):
    return {k[len("shared_"):]: v for k, v in params.items()
            if k.startswith("shared_")}


def _ssm_stacked(params):
    return {k: params[k] for k in M.SSM_LAYER_KEYS if k in params}


def _backbone(x, params, cfg, rules, positions, *, collect=None):
    """Shared forward skeleton. ``collect``: optional fn(x, call_idx, shared_lp)
    applied at each shared-attention point; must return new x (+ side outputs
    appended to the returned list)."""
    stacked = _ssm_stacked(params)
    shared = _shared_lp(params)
    side = []

    def ssm_body(x, lp):
        y = M.mamba_block(x, lp, cfg, rules)
        return rules.shard(y, "batch", "seq", "emb"), None

    body = jax.checkpoint(ssm_body) if cfg.remat else ssm_body
    call = 0
    for (s0, s1, attn_after) in segments(cfg):
        seg = {k: v[s0:s1] for k, v in stacked.items()}
        x, _ = jax.lax.scan(body, x, seg)
        if attn_after:
            x, extra = collect(x, call, shared)
            if extra is not None:
                side.append(extra)
            call += 1
    return x, side


def forward(params, cfg: ModelConfig, rules: ShardingRules, tokens):
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, rules, cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def attn_call(x, call, shared):
        def blk(x):
            y, _ = T.dense_block(x, shared, cfg, rules, positions)
            return y
        y = jax.checkpoint(blk)(x) if cfg.remat else blk(x)
        return y, None

    x, _ = _backbone(x, params, cfg, rules, positions, collect=attn_call)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return L.lm_logits(x, params["unembed"], rules)


def loss_fn(params, cfg, rules, batch):
    logits = forward(params, cfg, rules, batch["tokens"])
    return L.xent_loss(logits, batch["labels"], batch.get("mask"))


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    specs = M.cache_specs(cfg, batch, max_seq)
    kv, hd, nsh = cfg.num_kv_heads, cfg.head_dim, n_shared_calls(cfg)
    shape = (nsh, batch, max_seq, kv, hd)
    logical = (None, "batch", "kv_seq", None, None)
    specs["attn_k"] = ParamSpec(shape, logical, init="zeros",
                                dtype=cfg.compute_dtype)
    specs["attn_v"] = ParamSpec(shape, logical, init="zeros",
                                dtype=cfg.compute_dtype)
    return specs


def prefill(params, cfg: ModelConfig, rules: ShardingRules, tokens, max_seq):
    cd = jnp.dtype(cfg.compute_dtype)
    b, s = tokens.shape
    h, p, w = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_conv
    x = L.embed_tokens(params["embed"], tokens, rules, cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    stacked = _ssm_stacked(params)
    shared = _shared_lp(params)
    ssm_states, conv_tails, attn_kvs = [], [], []

    def ssm_prefill_scan(x, seg):
        def one_layer(x, lp):
            xn = L.rmsnorm(x, lp["ssm_norm"], cfg.norm_eps)
            z = xn @ lp["wz"].astype(cd)
            xi0 = xn @ lp["wx"].astype(cd)
            Bp0 = xn @ lp["wB"].astype(cd)
            Cp0 = xn @ lp["wC"].astype(cd)
            dt = xn @ lp["wdt"].astype(cd)
            xi = jax.nn.silu(M.causal_conv(xi0, lp["conv_x"].astype(cd))
                             .astype(jnp.float32)).astype(cd)
            Bp = jax.nn.silu(M.causal_conv(Bp0, lp["conv_B"].astype(cd))
                             .astype(jnp.float32)).astype(cd)
            Cp = jax.nn.silu(M.causal_conv(Cp0, lp["conv_C"].astype(cd))
                             .astype(jnp.float32)).astype(cd)
            dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
            A = -jnp.exp(lp["A_log"].astype(jnp.float32))
            y, S = M.ssd_chunked(xi.reshape(b, s, h, p), dt, A, Bp, Cp,
                                 cfg.ssm_chunk)
            y = y + xi.reshape(b, s, h, p) * lp["D"].astype(cd)[:, None]
            y = y.reshape(b, s, -1)
            y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(cd),
                          lp["gate_norm"], cfg.norm_eps)
            tails = (xi0[:, -(w - 1):], Bp0[:, -(w - 1):], Cp0[:, -(w - 1):])
            return x + y @ lp["w_out"].astype(cd), (S, tails)
        return jax.lax.scan(one_layer, x, seg)

    for (s0, s1, attn_after) in segments(cfg):
        seg = {k: v[s0:s1] for k, v in stacked.items()}
        x, (S, tails) = ssm_prefill_scan(x, seg)
        ssm_states.append(S)
        conv_tails.append(tails)
        if attn_after:
            x, kv = T.dense_block(x, shared, cfg, rules, positions,
                                  prefill=True)
            attn_kvs.append(kv)

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(x[:, -1:], params["unembed"], rules)

    S = jnp.concatenate(ssm_states, axis=0)
    # conv tails from scan come stacked (layers_in_seg, b, w-1, c)
    tx = jnp.concatenate([t[0] for t in conv_tails], axis=0)
    tB = jnp.concatenate([t[1] for t in conv_tails], axis=0)
    tC = jnp.concatenate([t[2] for t in conv_tails], axis=0)
    pad = [(0, 0), (0, 0), (0, max_seq - s), (0, 0), (0, 0)]
    ks = rules.shard(jnp.pad(jnp.stack([k for k, _ in attn_kvs]), pad),
                     None, "batch", "kv_seq", None, None)
    vs = rules.shard(jnp.pad(jnp.stack([v for _, v in attn_kvs]), pad),
                     None, "batch", "kv_seq", None, None)
    cache = {"state": S, "conv_x": tx, "conv_B": tB, "conv_C": tC,
             "attn_k": ks, "attn_v": vs, "length": jnp.int32(s)}
    return cache, logits


def decode_step(params, cfg: ModelConfig, rules: ShardingRules, cache, token):
    x = L.embed_tokens(params["embed"], token, rules, cfg.compute_dtype)
    stacked = _ssm_stacked(params)
    shared = _shared_lp(params)
    pos = cache["length"]

    def ssm_decode_scan(x, seg):
        def one_layer(x, layer_in):
            lp, S, cx, cB, cC = layer_in
            y, S, cc = M.mamba_decode_block(
                x, lp, S, {"x": cx, "B": cB, "C": cC}, cfg, rules)
            return y.astype(x.dtype), (S, cc["x"], cc["B"], cc["C"])
        return jax.lax.scan(one_layer, x, seg)

    new_S, new_cx, new_cB, new_cC, new_k, new_v = [], [], [], [], [], []
    call = 0
    for (s0, s1, attn_after) in segments(cfg):
        seg = ({k: v[s0:s1] for k, v in stacked.items()},
               cache["state"][s0:s1], cache["conv_x"][s0:s1],
               cache["conv_B"][s0:s1], cache["conv_C"][s0:s1])
        x, (S, cx, cB, cC) = ssm_decode_scan(x, seg)
        new_S.append(S); new_cx.append(cx); new_cB.append(cB); new_cC.append(cC)
        if attn_after:
            y, kc, vc = T.decode_block(x, shared, cache["attn_k"][call],
                                       cache["attn_v"][call], pos, cfg, rules)
            x = y.astype(x.dtype)
            new_k.append(kc); new_v.append(vc)
            call += 1

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(x, params["unembed"], rules)
    new_cache = {
        "state": jnp.concatenate(new_S, axis=0),
        "conv_x": jnp.concatenate(new_cx, axis=0),
        "conv_B": jnp.concatenate(new_cB, axis=0),
        "conv_C": jnp.concatenate(new_cC, axis=0),
        "attn_k": jnp.stack(new_k), "attn_v": jnp.stack(new_v),
        "length": pos + 1,
    }
    return logits, new_cache
