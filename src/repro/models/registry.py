"""Family registry + step builders + ``input_specs`` for every
(architecture x shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, no device allocation) — the dry-run lowers
against these.
"""
from __future__ import annotations

import importlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import ShardingRules
from repro.models import common

_FAMILIES = {
    "dense": "repro.models.transformer",
    "moe": "repro.models.moe",
    "ssm": "repro.models.ssm",
    "hybrid": "repro.models.hybrid",
    "audio": "repro.models.encdec",
    "vlm": "repro.models.vlm",
    "vit": "repro.models.vit",
}


def family_module(cfg: ModelConfig):
    return importlib.import_module(_FAMILIES[cfg.family])


def param_specs(cfg: ModelConfig) -> dict:
    return family_module(cfg).param_specs(cfg)


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    return common.spec_param_count(
        param_specs(cfg), active_only=active_only,
        top_k=cfg.top_k, num_experts=cfg.num_experts)


def init_params(rng, cfg: ModelConfig, rules: ShardingRules) -> dict:
    return common.init_params(rng, param_specs(cfg), rules)


def abstract_params(cfg: ModelConfig, rules: ShardingRules) -> dict:
    return common.abstract_params(param_specs(cfg), rules)


def loss_fn(params, cfg: ModelConfig, rules: ShardingRules, batch):
    return family_module(cfg).loss_fn(params, cfg, rules, batch)


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    return family_module(cfg).cache_specs(cfg, batch, max_seq)


def abstract_cache(cfg, rules, batch, max_seq) -> dict:
    cache = common.abstract_params(cache_specs(cfg, batch, max_seq), rules)
    cache["length"] = jax.ShapeDtypeStruct((), jnp.int32)
    return cache


def init_cache(cfg, rules, batch, max_seq) -> dict:
    cache = common.init_params(jax.random.PRNGKey(0),
                               cache_specs(cfg, batch, max_seq), rules)
    cache["length"] = jnp.int32(0)
    return cache


def prefill(params, cfg, rules, tokens, max_seq, **extra):
    return family_module(cfg).prefill(params, cfg, rules, tokens, max_seq,
                                      **extra)


def decode_step(params, cfg, rules, cache, token):
    return family_module(cfg).decode_step(params, cfg, rules, cache, token)


# ---------------------------------------------------------------------------
# Input specs per shape cell
# ---------------------------------------------------------------------------

def _tok_spec(rules: ShardingRules, shape):
    return jax.ShapeDtypeStruct(
        shape, jnp.int32, sharding=rules.sharding("batch", *([None] * (len(shape) - 1)),
                                                  dims=shape))


def _embed_spec(rules: ShardingRules, shape, dtype):
    return jax.ShapeDtypeStruct(
        shape, jnp.dtype(dtype),
        sharding=rules.sharding("batch", None, None, dims=shape))


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                rules: ShardingRules) -> dict[str, Any]:
    """Model inputs for one cell, as ShapeDtypeStructs.

    train  -> the per-step batch {tokens, labels, ...}
    prefill-> {tokens, ...}
    decode -> {token} (cache specs come from ``abstract_cache``)
    """
    b, s = shape.global_batch, shape.seq_len
    cd = cfg.compute_dtype
    if shape.kind == "train":
        if cfg.family == "audio":
            return {
                "frames": _embed_spec(rules, (b, cfg.encoder_seq, cfg.d_model), cd),
                "tokens": _tok_spec(rules, (b, s)),
                "labels": _tok_spec(rules, (b, s)),
            }
        if cfg.family == "vlm":
            s_text = s - cfg.num_patches
            return {
                "patch_embeds": _embed_spec(rules, (b, cfg.num_patches, cfg.d_model), cd),
                "tokens": _tok_spec(rules, (b, s_text)),
                "labels": _tok_spec(rules, (b, s_text)),
            }
        if cfg.family == "vit":
            return {
                "patch_embeds": _embed_spec(rules, (b, cfg.num_patches, cfg.d_model), cd),
                "labels": _tok_spec(rules, (b, 1)),
            }
        return {"tokens": _tok_spec(rules, (b, s)),
                "labels": _tok_spec(rules, (b, s))}

    if shape.kind == "prefill":
        out = {"tokens": _tok_spec(rules, (b, s))}
        if cfg.family == "audio":
            out["frames"] = _embed_spec(rules, (b, cfg.encoder_seq, cfg.d_model), cd)
        if cfg.family == "vlm":
            out["tokens"] = _tok_spec(rules, (b, s - cfg.num_patches))
            out["patch_embeds"] = _embed_spec(
                rules, (b, cfg.num_patches, cfg.d_model), cd)
        return out

    # decode: one new token against a seq_len cache
    return {"token": _tok_spec(rules, (b, 1))}
