"""Shared neural layers: norms, RoPE, attention (3 paths), MLP, logits/loss.

Attention paths
---------------
* ``attention_qchunk``   — q-block-chunked online-softmax attention (grad-
  friendly; used for training and encoder/bidirectional attention). Memory is
  O(q_chunk * s_kv) per block instead of O(s^2).
* ``attention_tri``      — causal lower-triangular *block-pair* scan: computes
  exactly the s(s+1)/2 needed score blocks (no masked-out waste). Used for
  long prefill (inference; not differentiated).
* ``attention_decode``   — single-token query against a (possibly
  'model'-sharded) KV cache; softmax over the sharded kv_seq dim lowers to a
  tiny psum (flash-decode communication pattern) under GSPMD.

All matmuls run in the config compute dtype (bf16) with f32 softmax/norm
statistics, matching TPU MXU-native mixed precision.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import ShardingRules

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layernorm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs      # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]                             # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

_NEG = -1e30


def expand_kv(k, heads: int):
    """(b, s, kv, d) -> (b, s, heads, d) by GQA group broadcast."""
    kv = k.shape[-2]
    if kv == heads:
        return k
    return jnp.repeat(k, heads // kv, axis=-2)


def attention_qchunk(q, k, v, *, causal: bool, q_chunk: int,
                     q_offset=0, bias=None):
    """Online-softmax attention chunked over query blocks.

    q: (b, sq, h, d); k, v: (b, skv, h, d). Returns (b, sq, h, d).
    ``q_offset`` is the absolute position of q[0] (for causal masking of a
    suffix, e.g. chunked prefill).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    q_chunk = min(q_chunk, sq)
    if sq % q_chunk:
        q_chunk = sq                    # fallback: single chunk
    nq = sq // q_chunk
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    qb = q.reshape(b, nq, q_chunk, h, d)
    kpos = jnp.arange(skv)

    def one_block(i, qi):
        # qi: (b, q_chunk, h, d)
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, k,
                       preferred_element_type=jnp.float32)
        s = s * scale
        if bias is not None:
            s = s + bias
        if causal:
            qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None], s, _NEG)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bhqk,bkhd->bqhd", (p / l).astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o.astype(q.dtype)

    if nq == 1:
        return one_block(0, qb[:, 0])
    out = jax.lax.map(lambda args: one_block(*args),
                      (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, h, d)


def attention_tri(q, k, v, *, q_chunk: int, kv_chunk: int):
    """Exact-flops causal attention for long prefill (inference only).

    Outer scan over query blocks; inner ``fori_loop`` with a *dynamic* upper
    bound (i+1 kv blocks), so only the ~s^2/2 live score blocks are computed
    and the carried state is one block's (acc, m, l) — O(q_chunk) memory.
    Not reverse-differentiable (dynamic trip count); training uses
    attention_qchunk.
    """
    b, s, h, d = q.shape
    if s % q_chunk or s % kv_chunk or q_chunk != kv_chunk:
        return attention_qchunk(q, k, v, causal=True, q_chunk=q_chunk)
    nb = s // q_chunk
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    qb = jnp.moveaxis(q.reshape(b, nb, q_chunk, h, d), 1, 0)

    def one_q_block(args):
        i, qi = args                          # qi: (b, Q, h, d)
        qpos = i * q_chunk + jnp.arange(q_chunk)

        def body(j, carry):
            acc, m, l = carry
            kj = jax.lax.dynamic_slice_in_dim(k, j * kv_chunk, kv_chunk, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, j * kv_chunk, kv_chunk, axis=1)
            sij = jnp.einsum("bqhd,bkhd->bhqk", qi, kj,
                             preferred_element_type=jnp.float32) * scale
            kpos = j * kv_chunk + jnp.arange(kv_chunk)
            sij = jnp.where((qpos[:, None] >= kpos[None, :])[None, None],
                            sij, _NEG)
            m_new = jnp.maximum(m, jnp.max(sij, axis=-1, keepdims=True))
            p = jnp.exp(sij - m_new)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
            o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vj.dtype), vj,
                           preferred_element_type=jnp.float32)
            acc_new = acc * jnp.moveaxis(corr, 1, 2) + o
            return acc_new, m_new, l_new

        acc0 = jnp.zeros((b, q_chunk, h, d), jnp.float32)
        m0 = jnp.full((b, h, q_chunk, 1), _NEG, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk, 1), jnp.float32)
        acc, m, l = jax.lax.fori_loop(0, i + 1, body, (acc0, m0, l0))
        return (acc / jnp.moveaxis(l, 1, 2)[..., 0][..., None]).astype(q.dtype)

    out = jax.lax.map(one_q_block, (jnp.arange(nb), qb))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention_jnp(q, k, v, causal: bool = True, q_offset: int = 0):
    """Flash-semantics attention (pure jnp): the backward pass RECOMPUTES
    probabilities from (q, k, lse) instead of saving them — only (o, lse)
    are residuals. This is the dry-run/HLO twin of kernels/flash_attention
    (EXPERIMENTS.md §Perf iter 4); q, k, v: (b, s, h, d), kv pre-expanded.
    """
    o, _ = _flash_fwd_core(q, k, v, causal, q_offset)
    return o


def _flash_fwd_core(q, k, v, causal, q_offset):
    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = q_offset + jnp.arange(sq)
        mask = qpos[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None], s, _NEG)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    lse = m + jnp.log(l)
    o = jnp.einsum("bhqk,bkhd->bqhd", (p / l[..., None]).astype(v.dtype), v,
                   preferred_element_type=jnp.float32).astype(q.dtype)
    return o, lse


def _flash_fwd(q, k, v, causal, q_offset):
    o, lse = _flash_fwd_core(q, k, v, causal, q_offset)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, q_offset, res, do):
    q, k, v, o, lse = res
    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = q_offset + jnp.arange(sq)
        mask = qpos[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None], s, _NEG)
    p = jnp.exp(s - lse[..., None])                       # recomputed
    pc = p.astype(v.dtype)
    dv = jnp.einsum("bhqk,bqhd->bkhd", pc, do)
    dp = jnp.einsum("bqhd,bkhd->bhqk", do, v,
                    preferred_element_type=jnp.float32)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)                               # (b, sq, h)
    ds = p * (dp - jnp.moveaxis(delta, 1, 2)[..., None]) * scale
    dsc = ds.astype(q.dtype)
    # bf16-output einsums: cross-device partial sums (ARs) then move bf16,
    # not f32 (§Perf iter 5) — matches Megatron-style bf16 grad reduction.
    dq = jnp.einsum("bhqk,bkhd->bqhd", dsc, k)
    dk = jnp.einsum("bhqk,bqhd->bkhd", dsc, q)
    return dq, dk, dv


flash_attention_jnp.defvjp(_flash_fwd, _flash_bwd)


def attention_decode(q, k_cache, v_cache, length: Optional[int] = None):
    """q: (b, 1, h, d); caches: (b, S, h, d) (kv already expanded).

    With the cache seq dim sharded over 'model', the max/sum reductions and
    the value contraction lower to per-shard partials + psum (flash-decode).
    """
    b, _, h, d = q.shape
    S = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if length is not None:
        mask = jnp.arange(S)[None, None, None, :] < length
        s = jnp.where(mask, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + residual) — shared across families
# ---------------------------------------------------------------------------

def attn_project_qkv(x, lp, cfg, positions):
    b, s, _ = x.shape
    cd = jnp.dtype(cfg.compute_dtype)
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ lp["wq"].astype(cd)).reshape(b, s, h, hd)
    k = (x @ lp["wk"].astype(cd)).reshape(b, s, kv, hd)
    v = (x @ lp["wv"].astype(cd)).reshape(b, s, kv, hd)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def mlp(x, lp, cfg, rules: ShardingRules):
    return (mlp_swiglu if cfg.mlp == "swiglu" else mlp_gelu2)(x, lp, cfg, rules)


def mlp_gelu2(x, lp, cfg, rules: ShardingRules):
    """GPT-BigCode-style 2-matrix MLP (granite-34b)."""
    cd = jnp.dtype(cfg.compute_dtype)
    h = x @ lp["w_up"].astype(cd)
    h = rules.shard(h, "batch", "seq", "act_ff")
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(cd)
    return h @ lp["w_down"].astype(cd)


def mlp_swiglu(x, lp, cfg, rules: ShardingRules):
    cd = jnp.dtype(cfg.compute_dtype)
    g = x @ lp["w_gate"].astype(cd)
    u = x @ lp["w_up"].astype(cd)
    g = rules.shard(g, "batch", "seq", "act_ff")
    h = jax.nn.silu(g.astype(jnp.float32)).astype(cd) * u
    return h @ lp["w_down"].astype(cd)


def mlp_gelu(x, lp, cfg, rules: ShardingRules):
    cd = jnp.dtype(cfg.compute_dtype)
    h = x @ lp["w_up"].astype(cd) + lp["b_up"].astype(cd)
    h = rules.shard(h, "batch", "seq", "act_ff")
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(cd)
    return h @ lp["w_down"].astype(cd) + lp["b_down"].astype(cd)


# ---------------------------------------------------------------------------
# Embedding / logits / loss (vocab-sharded)
# ---------------------------------------------------------------------------

def embed_tokens(embed, tokens, rules: ShardingRules, compute_dtype):
    x = embed[tokens].astype(jnp.dtype(compute_dtype))
    return rules.shard(x, "batch", "seq", "emb")


def lm_logits(x, unembed, rules: ShardingRules):
    logits = x @ unembed.astype(x.dtype)
    return rules.shard(logits, "batch", "seq", "act_vocab")


def xent_loss(logits, labels, mask=None):
    """Mean next-token cross entropy; logits may be vocab-sharded."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
