"""Mixture-of-Experts family (dbrx: 16e top-4; arctic: 128e top-2 + dense
residual).

Dispatch is sort-free scatter-based ("grouped GEMM" layout): tokens are
scattered into per-expert capacity buffers (E, C, D) via position-in-expert
indices, expert FFNs run as batched einsums with the expert dim sharded over
'model' (EP), and results gather back with top-k combine weights. Overflow
beyond capacity C drops via out-of-bounds scatter semantics (mode='drop'),
matching GShard-style capacity routing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ShardingRules
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.common import ParamSpec


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> dict:
    d, v, e, fm = cfg.d_model, cfg.vocab_size, cfg.num_experts, cfg.moe_d_ff
    nl = cfg.num_layers
    specs = {
        "embed": ParamSpec((v, d), ("vocab", "wemb"), init="normal"),
        "final_norm": ParamSpec((d,), ("unsharded",), init="ones"),
        "unembed": ParamSpec((d, v), ("wemb", "vocab")),
    }
    dense = T.layer_param_specs(cfg, nl)
    if not cfg.dense_residual:
        for k in ("w_gate", "w_up", "w_down"):    # experts replace dense FFN
            dense.pop(k)
    specs.update(dense)
    specs.update({
        "router": ParamSpec((nl, d, e), ("layers", "wemb", "unsharded")),
        "we_gate": ParamSpec((nl, e, d, fm), ("layers", "expert", "wemb", None)),
        "we_up": ParamSpec((nl, e, d, fm), ("layers", "expert", "wemb", None)),
        "we_down": ParamSpec((nl, e, fm, d), ("layers", "expert", None, "wemb")),
    })
    return specs


MOE_EXTRA_KEYS = ("router", "we_gate", "we_up", "we_down")


# ---------------------------------------------------------------------------
# Expert dispatch
# ---------------------------------------------------------------------------

def moe_ffn(x, lp, cfg: ModelConfig, rules: ShardingRules):
    """x: (b, s, d) -> (y, aux_loss). Capacity-routed top-k experts.

    Dispatch layout (§Perf iters 6-7): tokens are grouped by DATA shard
    (G = dp extent) with per-group capacity, so the position-in-expert
    cumsum and the scatter/gather are device-LOCAL; the only communication
    is the all-to-all that re-aligns the (G, E, C, d) capacity buffer from
    token (G@data) to expert (E@model) sharding inside the expert einsums —
    the canonical MoE dispatch. Without the grouping, either every data
    replica computes all experts (16x flops) or GSPMD emits a cross-axis
    scatter (catastrophic collectives); both measured in EXPERIMENTS.md.
    """
    cd = jnp.dtype(cfg.compute_dtype)
    b, s, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    Tn = b * s
    G = rules.axis_size("batch")
    if b % G:
        G = 1
    TG = Tn // G
    C = max(int(cfg.capacity_factor * TG * K / E), 4)

    xt = rules.shard(x.reshape(G, TG, d), "batch", None, "emb")
    logits = (xt @ lp["router"].astype(cd)).astype(jnp.float32)   # (G,TG,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                           # (G,TG,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss (global means).
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((E,)).at[idx.reshape(-1)].add(1.0) / (Tn * K)
    aux = E * jnp.sum(me * ce)

    flat_e = idx.reshape(G, TG * K)                               # (G, TK)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)               # (G, TK, E)
    pos = jnp.take_along_axis(jnp.cumsum(oh, axis=1) - oh,
                              flat_e[..., None], axis=2)[..., 0]  # (G, TK)

    x_rep = jnp.repeat(xt, K, axis=1)                             # (G, TK, d)
    # vmap over G -> the scatter's G dim is a BATCHING dim, so GSPMD keeps
    # it sharded over data and the writes stay device-local (§Perf iter 8).
    buf = jax.vmap(
        lambda xr, e, p: jnp.zeros((E, C, d), cd)
        .at[e, p].set(xr, mode="drop"))(x_rep, flat_e, pos)
    buf = rules.shard(buf, "batch", None, None, "emb")

    h = jnp.einsum("gecd,edf->gecf", buf, lp["we_gate"].astype(cd))
    u = jnp.einsum("gecd,edf->gecf", buf, lp["we_up"].astype(cd))
    h = jax.nn.silu(h.astype(jnp.float32)).astype(cd) * u
    h = rules.shard(h, "batch", "act_expert", None, None)
    y_e = jnp.einsum("gecf,efd->gecd", h, lp["we_down"].astype(cd))
    y_e = rules.shard(y_e, "batch", None, None, "emb")            # a2a back

    y_tok = jax.vmap(
        lambda ye, e, p: ye.at[e, p].get(mode="fill", fill_value=0)
    )(y_e, flat_e, pos)                                           # (G, TK, d)
    y = (y_tok.reshape(G, TG, K, d) * gate[..., None].astype(cd)).sum(axis=2)
    return y.reshape(b, s, d), aux


def moe_block(x, lp, cfg: ModelConfig, rules: ShardingRules, positions,
              *, causal=True, prefill=False):
    x, kvs = T.attn_block(x, lp, cfg, rules, positions,
                          causal=causal, prefill=prefill)
    xn = L.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    y, aux = moe_ffn(xn, lp, cfg, rules)
    if cfg.dense_residual:
        y = y + L.mlp_swiglu(xn, lp, cfg, rules)
    x = rules.shard(x + y, "batch", "seq", "emb")
    return x, (kvs, aux)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def _stacked(params, cfg):
    keys = [k for k in T.LAYER_KEYS if k in params] + list(MOE_EXTRA_KEYS)
    return {k: params[k] for k in keys}


def forward(params, cfg: ModelConfig, rules: ShardingRules, tokens):
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, rules, cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def one_layer(carry, lp):
        x, aux_sum = carry
        y, (_, aux) = moe_block(x, lp, cfg, rules, positions)
        return (y.astype(x.dtype), aux_sum + aux), None

    body = jax.checkpoint(one_layer) if cfg.remat else one_layer
    (x, aux), _ = jax.lax.scan(body, (x, 0.0), _stacked(params, cfg))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return L.lm_logits(x, params["unembed"], rules), aux / cfg.num_layers


def loss_fn(params, cfg, rules, batch, aux_weight: float = 0.01):
    logits, aux = forward(params, cfg, rules, batch["tokens"])
    return L.xent_loss(logits, batch["labels"], batch.get("mask")) \
        + aux_weight * aux


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    return T.cache_specs(cfg, batch, max_seq)


def prefill(params, cfg: ModelConfig, rules: ShardingRules, tokens, max_seq):
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, rules, cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def one_layer(x, lp):
        y, (kv, _) = moe_block(x, lp, cfg, rules, positions, prefill=True)
        return y, kv

    x, (ks, vs) = jax.lax.scan(one_layer, x, _stacked(params, cfg))
    pad = [(0, 0), (0, 0), (0, max_seq - s), (0, 0), (0, 0)]
    ks = rules.shard(jnp.pad(ks, pad), "layers", "batch", "kv_seq", None, None)
    vs = rules.shard(jnp.pad(vs, pad), "layers", "batch", "kv_seq", None, None)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(x[:, -1:], params["unembed"], rules)
    return {"k": ks, "v": vs, "length": jnp.int32(s)}, logits


def decode_step(params, cfg: ModelConfig, rules: ShardingRules, cache, token):
    pos = cache["length"]
    x = L.embed_tokens(params["embed"], token, rules, cfg.compute_dtype)
    positions = None  # computed inside decode block

    def one_layer(x, layer_in):
        lp, kc, vc = layer_in
        xn = L.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        pp = jnp.full((x.shape[0], 1), pos, jnp.int32)
        q, k, v = L.attn_project_qkv(xn, lp, cfg, pp)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, axis=1)
        o = L.attention_decode(q, L.expand_kv(kc, cfg.num_heads),
                               L.expand_kv(vc, cfg.num_heads), length=pos + 1)
        x = x + o.reshape(x.shape[0], 1, -1) @ lp["wo"].astype(o.dtype)
        xn = L.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        y, _ = moe_ffn(xn, lp, cfg, rules)
        if cfg.dense_residual:
            y = y + L.mlp_swiglu(xn, lp, cfg, rules)
        return (x + y).astype(x.dtype), (kc, vc)

    x, (ks, vs) = jax.lax.scan(one_layer, x,
                               (_stacked(params, cfg), cache["k"], cache["v"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(x, params["unembed"], rules)
    return logits, {"k": ks, "v": vs, "length": pos + 1}
