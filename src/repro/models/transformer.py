"""Dense decoder-only LM (llama/glm/granite/tinyllama family).

Scan-over-layers with per-layer remat; ZeRO/FSDP-compatible param specs;
three lowered entry points (train loss, prefill, single-token decode).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ShardingRules
from repro.models import layers as L
from repro.models.common import ParamSpec


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def layer_param_specs(cfg: ModelConfig, n_layers: int, prefix: str = "",
                      stacked: bool = True) -> dict:
    """Per-layer attention+MLP weights, optionally stacked for scan."""
    h, kv, hd, d, f = (cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
                       cfg.d_model, cfg.d_ff)
    lead = (n_layers,) if stacked else ()
    lax_ = ("layers",) if stacked else ()
    def S(shape, logical, **kw):
        return ParamSpec(lead + shape, lax_ + logical, **kw)
    specs = {
        prefix + "attn_norm": S((d,), ("unsharded",), init="ones"),
        prefix + "wq": S((d, h * hd), ("wemb", "heads")),
        prefix + "wk": S((d, kv * hd), ("wemb", "kv_heads")),
        prefix + "wv": S((d, kv * hd), ("wemb", "kv_heads")),
        prefix + "wo": S((h * hd, d), ("heads", "wemb")),
        prefix + "mlp_norm": S((d,), ("unsharded",), init="ones"),
        prefix + "w_up": S((d, f), ("wemb", "ff")),
        prefix + "w_down": S((f, d), ("ff", "wemb")),
    }
    if cfg.mlp == "swiglu":
        specs[prefix + "w_gate"] = S((d, f), ("wemb", "ff"))
    return specs


def param_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    specs = {
        "embed": ParamSpec((v, d), ("vocab", "wemb"), init="normal"),
        "final_norm": ParamSpec((d,), ("unsharded",), init="ones"),
    }
    specs.update(layer_param_specs(cfg, cfg.num_layers))
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((d, v), ("wemb", "vocab"))
    return specs


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def attn_block(x, lp, cfg: ModelConfig, rules: ShardingRules, positions,
               *, causal=True, prefill=False):
    """Full-sequence attention block. Returns (x_out, (k, v)) when prefill.

    Head sharding (TP) when num_heads divides the model axis; otherwise
    SEQUENCE-sharded attention (context parallelism): q rows are sharded,
    k/v replicated — scores stay device-local instead of psum'd (the
    non-divisible-GQA fix measured in EXPERIMENTS.md §Perf iter 3).
    """
    xn = L.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = L.attn_project_qkv(xn, lp, cfg, positions)
    tp = rules.axis_size("act_heads")
    seq_shard = cfg.num_heads % tp != 0 and x.shape[1] % tp == 0
    ke = L.expand_kv(k, cfg.num_heads)
    ve = L.expand_kv(v, cfg.num_heads)
    if seq_shard:
        q = rules.shard(q, "batch", "kv_seq", None, None)
        ke = rules.shard(ke, "batch", None, None, None)
        ve = rules.shard(ve, "batch", None, None, None)
    else:
        q = rules.shard(q, "batch", "seq", "act_heads", None)
    if causal and x.shape[1] > 8192 and not seq_shard:
        o = L.attention_tri(q, ke, ve, q_chunk=cfg.attn_q_chunk,
                            kv_chunk=cfg.attn_q_chunk)
    elif prefill:
        q_chunk = x.shape[1] if seq_shard else cfg.attn_q_chunk
        o = L.attention_qchunk(q, ke, ve, causal=causal, q_chunk=q_chunk)
    else:
        # train: flash-semantics attention (bwd recomputes probabilities)
        o = L.flash_attention_jnp(q, ke, ve, causal, 0)
    if seq_shard:
        o = rules.shard(o, "batch", "kv_seq", None, None)
    o = o.reshape(x.shape[0], x.shape[1], -1)
    x = x + o @ lp["wo"].astype(o.dtype)
    kvs = (k, v) if prefill else None
    return x, kvs


def dense_block(x, lp, cfg, rules, positions, *, causal=True, prefill=False):
    x, kvs = attn_block(x, lp, cfg, rules, positions,
                        causal=causal, prefill=prefill)
    xn = L.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + L.mlp(xn, lp, cfg, rules)
    x = rules.shard(x, "batch", "seq", "emb")
    return x, kvs


def decode_block(x, lp, kc, vc, pos, cfg: ModelConfig, rules: ShardingRules):
    """Single-token block against one layer's KV cache.

    x: (b, 1, d); kc/vc: (b, S, kv, hd). Returns (x_out, kc', vc').
    """
    xn = L.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q, k, v = L.attn_project_qkv(xn, lp, cfg, positions)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, axis=1)
    ke = L.expand_kv(kc, cfg.num_heads)
    ve = L.expand_kv(vc, cfg.num_heads)
    o = L.attention_decode(q, ke, ve, length=pos + 1)
    o = o.reshape(x.shape[0], 1, -1)
    x = x + o @ lp["wo"].astype(o.dtype)
    xn = L.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + L.mlp(xn, lp, cfg, rules)
    return x, kc, vc


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------

def split_stacked(params: dict, stacked_keys) -> tuple[dict, dict]:
    stacked = {k: params[k] for k in stacked_keys}
    rest = {k: v for k, v in params.items() if k not in stacked_keys}
    return stacked, rest


LAYER_KEYS = ("attn_norm", "wq", "wk", "wv", "wo", "mlp_norm",
              "w_gate", "w_up", "w_down")


def decoder_stack(x, params, cfg: ModelConfig, rules: ShardingRules,
                  positions, *, causal=True, block_fn=dense_block):
    """scan-over-layers with optional remat; returns final hidden states."""
    stacked, _ = split_stacked(params, [k for k in LAYER_KEYS if k in params])

    def one_layer(x, lp):
        cd = jnp.dtype(cfg.compute_dtype)
        y, _ = block_fn(x, lp, cfg, rules, positions, causal=causal)
        return y.astype(cd), None

    body = jax.checkpoint(one_layer) if cfg.remat else one_layer
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, stacked)
    else:
        for i in range(cfg.num_layers):
            lp = {k: v[i] for k, v in stacked.items()}
            x, _ = body(x, lp)
    return x


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, rules: ShardingRules, tokens):
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, rules, cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = decoder_stack(x, params, cfg, rules, positions)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    return L.lm_logits(x, unembed, rules)


def loss_fn(params, cfg, rules, batch):
    logits = forward(params, cfg, rules, batch["tokens"])
    return L.xent_loss(logits, batch["labels"], batch.get("mask"))


# -- KV cache ----------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (cfg.num_layers, batch, max_seq, kv, hd)
    logical = ("layers", "batch", "kv_seq", None, None)
    return {
        "k": ParamSpec(shape, logical, init="zeros", dtype=cfg.compute_dtype),
        "v": ParamSpec(shape, logical, init="zeros", dtype=cfg.compute_dtype),
    }


def prefill(params, cfg: ModelConfig, rules: ShardingRules, tokens, max_seq):
    """Run the full prompt; returns (cache dict incl. per-layer k/v, logits)."""
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, rules, cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    stacked, _ = split_stacked(params, [k for k in LAYER_KEYS if k in params])

    def one_layer(x, lp):
        y, (k, v) = dense_block(x, lp, cfg, rules, positions, prefill=True)
        return y, (k, v)

    x, (ks, vs) = jax.lax.scan(one_layer, x, stacked)
    pad = [(0, 0), (0, 0), (0, max_seq - s), (0, 0), (0, 0)]
    ks = rules.shard(jnp.pad(ks, pad), "layers", "batch", "kv_seq", None, None)
    vs = rules.shard(jnp.pad(vs, pad), "layers", "batch", "kv_seq", None, None)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = L.lm_logits(x[:, -1:], unembed, rules)
    return {"k": ks, "v": vs, "length": jnp.int32(s)}, logits


def decode_step(params, cfg: ModelConfig, rules: ShardingRules, cache, token):
    """token: (b, 1) int32; cache: {"k","v","length"}. One new token."""
    pos = cache["length"]
    x = L.embed_tokens(params["embed"], token, rules, cfg.compute_dtype)
    stacked, _ = split_stacked(params, [k for k in LAYER_KEYS if k in params])

    def one_layer(x, layer_in):
        lp, kc, vc = layer_in
        y, kc, vc = decode_block(x, lp, kc, vc, pos, cfg, rules)
        return y.astype(x.dtype), (kc, vc)

    x, (ks, vs) = jax.lax.scan(one_layer, x, (stacked, cache["k"], cache["v"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = L.lm_logits(x, unembed, rules)
    new_cache = {"k": ks, "v": vs, "length": pos + 1}
    return logits, new_cache
