"""Parameter-spec infrastructure shared by all model families.

A model family module exposes ``param_specs(cfg) -> dict[path, ParamSpec]``.
The same spec tree materializes three ways:

* ``init_params``      — PRNG-initialized concrete arrays (smoke/real runs),
* ``abstract_params``  — ShapeDtypeStructs with shardings (dry-run lowering),
* ``param_count``      — analytic parameter counts (MODEL_FLOPS).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import ShardingRules


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    logical: tuple                  # one logical axis name (or None) per dim
    init: str = "fan_in"            # fan_in | zeros | ones | normal | ssm_a | ssm_dt
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


def _init_leaf(rng, spec: ParamSpec) -> jnp.ndarray:
    dt = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "ssm_a":        # A_log init: log(uniform[1,16])
        u = jax.random.uniform(rng, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dt)
    if spec.init == "ssm_dt":       # dt_bias: inverse-softplus of uniform dt
        dt0 = jnp.exp(jax.random.uniform(rng, spec.shape, jnp.float32)
                      * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
        return (dt0 + jnp.log(-jnp.expm1(-dt0))).astype(dt)
    if spec.init == "normal":
        return (jax.random.normal(rng, spec.shape, jnp.float32) * 0.02).astype(dt)
    # fan_in: scaled by 1/sqrt(fan_in) — fan_in = second-to-last dim (or last for 1D)
    fan = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = 1.0 / math.sqrt(max(fan, 1))
    return (jax.random.normal(rng, spec.shape, jnp.float32) * std).astype(dt)


def init_params(rng, specs: dict, rules: ShardingRules) -> dict:
    leaves = sorted(specs.keys())
    keys = jax.random.split(rng, len(leaves))
    out = {}
    for k, name in zip(keys, leaves):
        spec = specs[name]
        arr = _init_leaf(k, spec)
        arr = jax.device_put(arr, rules.sharding(*spec.logical, dims=spec.shape))
        out[name] = arr
    return out


def abstract_params(specs: dict, rules: ShardingRules) -> dict:
    return {
        name: jax.ShapeDtypeStruct(
            spec.shape, jnp.dtype(spec.dtype),
            sharding=rules.sharding(*spec.logical, dims=spec.shape))
        for name, spec in specs.items()
    }


def spec_param_count(specs: dict, active_only: bool = False,
                     top_k: int = 0, num_experts: int = 0) -> int:
    total = 0
    for spec in specs.values():
        n = spec.size
        if active_only and num_experts and "expert" in spec.logical:
            n = n * top_k // num_experts
        total += n
    return total
