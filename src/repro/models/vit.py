"""ViT classifier backbone (paper Table 1: ViT-H-14) for the benchmark
harness. Patch embeddings are precomputed (stub frontend); bidirectional
encoder + mean-pool + linear classifier head."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ShardingRules
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.common import ParamSpec


def param_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    specs = {
        "final_norm": ParamSpec((d,), ("unsharded",), init="ones"),
        "head": ParamSpec((d, cfg.vocab_size), ("wemb", "vocab")),
    }
    specs.update(T.layer_param_specs(cfg, cfg.num_layers))
    return specs


def forward(params, cfg: ModelConfig, rules: ShardingRules, patch_embeds):
    cd = jnp.dtype(cfg.compute_dtype)
    x = rules.shard(patch_embeds.astype(cd), "batch", "seq", "emb")
    x = T.decoder_stack(x, params, cfg, rules, positions=None, causal=False)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    pooled = x.mean(axis=1)
    return pooled @ params["head"].astype(cd)


def loss_fn(params, cfg, rules, batch):
    logits = forward(params, cfg, rules, batch["patch_embeds"]).astype(jnp.float32)
    labels = batch["labels"][:, 0] if batch["labels"].ndim > 1 else batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - ll)
