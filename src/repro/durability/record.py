"""Checksummed flush records: the durability wire format.

A `FlushRecord` is one shadow node's contribution to one flush *epoch*:
a ``base`` (every owned bucket's full flat state), a ``delta`` (only the
buckets dirtied since the previous flush), or a ``mark`` (the node had
nothing dirty — still written, so the epoch is provably complete without
a coordinator journal). Payloads are the bucket *wire format*
(`repro.core.buckets` flats) verbatim — flushing never repacks; a
compressed delta additionally carries per-slot int8 payloads + f32
scales from the stateless codec in `repro.dist.compression`.

Serialization is self-describing and torn-write detectable: a fixed
magic, a length-prefixed JSON header (epoch/node/step/kind + an array
table), then the concatenated array bytes, with the payload CRC32 in
the header. ANY truncation — mid-magic, mid-header, mid-payload — and
any bit flip in the payload raises `TornRecordError` on read; a torn
record is skipped, never half-applied (`repro.durability.restore` then
falls back to the previous consistent epoch).
"""
from __future__ import annotations

import json
import struct
import zlib
from dataclasses import dataclass, field

import numpy as np

MAGIC = b"RDUR1\n"
# payload field names: raw records carry p/m/v flats; compressed deltas
# carry int8 p/m/v plus per-slot scale vectors ps/ms/vs
RAW_FIELDS = ("p", "m", "v")
KINDS = ("base", "delta", "mark")


class TornRecordError(RuntimeError):
    """A flush record failed structural or checksum validation.

    Raised for any truncation (torn write at an arbitrary byte) or
    payload corruption. Restore treats this as "the record does not
    exist" and falls back — a torn delta must never be half-applied.
    """


@dataclass(frozen=True)
class FlushRecord:
    """One node's flush for one epoch, in bucket wire layout."""

    epoch: int
    node: int
    step: int
    kind: str                       # "base" | "delta" | "mark"
    compressed: bool = False
    # bucket_id -> {field name -> np.ndarray}
    payload: dict = field(default_factory=dict)

    def __post_init__(self):
        assert self.kind in KINDS, f"unknown record kind {self.kind!r}"

    @property
    def payload_nbytes(self) -> int:
        return sum(np.asarray(a).nbytes for fields in self.payload.values()
                   for a in fields.values())

    def to_bytes(self) -> bytes:
        """MAGIC + u32 header length + JSON header + payload blob."""
        blobs, arrays, off = [], [], 0
        for bid in sorted(self.payload):
            fields = self.payload[bid]
            for name in sorted(fields):
                a = np.ascontiguousarray(fields[name])
                b = a.tobytes()
                arrays.append({"bucket": int(bid), "field": name,
                               "dtype": str(a.dtype),
                               "shape": list(a.shape),
                               "offset": off, "nbytes": len(b)})
                blobs.append(b)
                off += len(b)
        payload = b"".join(blobs)
        header = {"epoch": int(self.epoch), "node": int(self.node),
                  "step": int(self.step), "kind": self.kind,
                  "compressed": bool(self.compressed),
                  "payload_nbytes": len(payload),
                  "payload_crc32": zlib.crc32(payload) & 0xFFFFFFFF,
                  "arrays": arrays}
        hb = json.dumps(header, sort_keys=True).encode()
        return MAGIC + struct.pack("<I", len(hb)) + hb + payload

    @classmethod
    def from_bytes(cls, buf: bytes) -> "FlushRecord":
        """Parse + validate; raises `TornRecordError` at ANY cut point."""
        if len(buf) < len(MAGIC) + 4:
            raise TornRecordError(
                f"record truncated before header ({len(buf)} bytes)")
        if buf[:len(MAGIC)] != MAGIC:
            raise TornRecordError("bad record magic")
        (hlen,) = struct.unpack_from("<I", buf, len(MAGIC))
        hstart = len(MAGIC) + 4
        if len(buf) < hstart + hlen:
            raise TornRecordError("record truncated inside header")
        try:
            header = json.loads(buf[hstart:hstart + hlen])
        except ValueError as e:
            raise TornRecordError(f"unparseable record header: {e}") from e
        payload = buf[hstart + hlen:]
        want = header.get("payload_nbytes", -1)
        if len(payload) != want:
            raise TornRecordError(
                f"record truncated inside payload "
                f"({len(payload)} of {want} bytes)")
        if (zlib.crc32(payload) & 0xFFFFFFFF) != header.get("payload_crc32"):
            raise TornRecordError("record payload checksum mismatch")
        out: dict = {}
        for a in header["arrays"]:
            raw = payload[a["offset"]:a["offset"] + a["nbytes"]]
            arr = np.frombuffer(raw, dtype=np.dtype(a["dtype"])).reshape(
                tuple(a["shape"])).copy()
            out.setdefault(int(a["bucket"]), {})[a["field"]] = arr
        return cls(epoch=int(header["epoch"]), node=int(header["node"]),
                   step=int(header["step"]), kind=header["kind"],
                   compressed=bool(header["compressed"]), payload=out)
