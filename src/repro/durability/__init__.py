"""repro.durability — tiered differential persistence behind the shadow.

The shadow fleet turns every iteration into a checkpoint, but it is
RAM: lose the whole plane (rack power, correlated NIC failure) and the
checkpoint is gone. This package adds the third leg of the story —
per-node background `FlushWorker`s snapshot dirty bucket flats into
checksummed base/delta `FlushRecord`s, write them through pluggable
`Tier`s (local disk with atomic rename + manifest, object-store stub),
and `restore_from_tiers` rebuilds a full consolidated checkpoint from
the base + delta chain — all without ever adding a microsecond to the
trainer's stall ledger. See `docs/durability.md`.
"""
from repro.durability.flush import DurableShadow, FlushPolicy, FlushWorker
from repro.durability.record import FlushRecord, TornRecordError
from repro.durability.restore import (TierRestoreError, restore_from_tiers,
                                      restore_shards_from_tiers)
from repro.durability.tiers import (LocalDiskTier, ManifestEntry,
                                    ObjectStoreTier, Tier, TierPutError)

__all__ = [
    "DurableShadow", "FlushPolicy", "FlushWorker",
    "FlushRecord", "TornRecordError",
    "TierRestoreError", "restore_from_tiers", "restore_shards_from_tiers",
    "LocalDiskTier", "ManifestEntry", "ObjectStoreTier", "Tier",
    "TierPutError",
]
