"""Tier-aware restore: base + delta chain -> full consolidated checkpoint.

`restore_from_tiers` walks a tier's manifest epochs **newest first** and
returns the first epoch it can fully reconstruct: every cluster node
present at one common step, every record chain (latest base + subsequent
deltas) intact. A torn record anywhere in a chain — detected by
`repro.durability.record`'s checksums — disqualifies that epoch and the
walk falls back to the previous one; if a whole tier is unusable the
next tier is tried. The reconstruction itself replays exactly the flush
arithmetic: raw records overwrite bucket flats; compressed deltas add
their dequantized int8 diffs to an f32 accumulator (matching the
worker's reconstruction buffer bit for bit, which is why a raw-policy
restore is bit-identical to the shadow state it snapshotted).

`restore_shards_from_tiers` is the partial-loss composition path used by
`repro.core.recovery.recover`: rebuild ONLY the dead owners' buckets at
exactly the surviving nodes' step, so survivors' live fragments and the
tiers' durable shards merge into one consistent checkpoint.
"""
from __future__ import annotations

from typing import Iterable, Optional

import numpy as np

from repro.core.buckets import BucketLayout, unpack_bucket
from repro.dist.compression import dequantize_flat_stateless
from repro.durability.record import TornRecordError
from repro.durability.tiers import ManifestEntry, Tier


class TierRestoreError(RuntimeError):
    """No tier holds a consistent, intact restore point."""


def _per_node(entries: list[ManifestEntry]) -> dict[int, list[ManifestEntry]]:
    out: dict[int, list[ManifestEntry]] = {}
    for e in entries:
        out.setdefault(e.node, []).append(e)
    for lst in out.values():
        lst.sort(key=lambda e: e.epoch)
    return out


def _chain(node_entries: list[ManifestEntry], target_epoch: int
           ) -> list[ManifestEntry]:
    """Latest base at/before ``target_epoch`` through ``target_epoch``."""
    upto = [e for e in node_entries if e.epoch <= target_epoch]
    base_idx = None
    for i, e in enumerate(upto):
        if e.kind == "base":
            base_idx = i
    if base_idx is None:
        raise TierRestoreError(
            f"no base record at/before epoch {target_epoch}")
    return upto[base_idx:]


def _reconstruct_node(tier: Tier, chain: list[ManifestEntry], by_id: dict
                      ) -> dict[int, tuple]:
    """Replay one node's chain -> {bucket_id: (p, m, v) np arrays}.

    Raises `TornRecordError` if any record in the chain fails
    validation — the caller falls back to an older epoch.
    """
    # f32 accumulators + the param wire dtype remembered from the base
    acc: dict[int, dict[str, np.ndarray]] = {}
    pdtype: dict[int, np.dtype] = {}
    for entry in chain:
        rec = tier.read(entry)
        if rec.kind == "mark":
            continue
        if not rec.compressed:
            for bid, fields in rec.payload.items():
                if rec.kind == "base" or bid not in acc:
                    pdtype[bid] = fields["p"].dtype
                acc[bid] = {"p": fields["p"].astype(np.float32),
                            "m": fields["m"].astype(np.float32),
                            "v": fields["v"].astype(np.float32)}
        else:
            for bid, fields in rec.payload.items():
                b = by_id[bid]
                cur = acc[bid]
                for name in ("p", "m", "v"):
                    cur[name] = cur[name] + dequantize_flat_stateless(
                        b, fields[name], fields[name + "s"])
    return {bid: (a["p"].astype(pdtype[bid]), a["m"], a["v"])
            for bid, a in acc.items()}


def _unpack(layout: BucketLayout, flats: dict[int, tuple], step: int
            ) -> dict:
    by_id = {b.bucket_id: b for b in layout.buckets}
    params: dict = {}
    mu: dict = {}
    nu: dict = {}
    for bid, (p, m, v) in flats.items():
        b = by_id[bid]
        params.update(unpack_bucket(b, p, xp=np))
        mu.update(unpack_bucket(b, m, xp=np))
        nu.update(unpack_bucket(b, v, xp=np))
    return {"params": params, "mu": mu, "nu": nu, "step": int(step)}


def restore_from_tiers(tiers: Iterable[Tier], layout: BucketLayout,
                       n_nodes: Optional[int] = None) -> dict:
    """Reconstruct the newest full consolidated checkpoint any tier holds.

    Returns ``{"params", "mu", "nu", "step"}`` exactly like
    `ShadowCluster.consolidate`. ``n_nodes`` pins the completeness bar
    (how many shadow nodes a full epoch must cover); by default it is
    inferred as every node id the tier has ever seen.
    """
    all_buckets = {b.bucket_id for b in layout.buckets}
    by_id = {b.bucket_id: b for b in layout.buckets}
    reasons = []
    best: Optional[tuple[int, dict]] = None      # (step, flats)
    for tier in tiers:
        try:
            entries = list(tier.entries())
        except Exception as e:               # unreadable manifest: next tier
            reasons.append(f"{tier.name}: manifest unreadable ({e})")
            continue
        if not entries:
            reasons.append(f"{tier.name}: empty")
            continue
        need = (set(range(n_nodes)) if n_nodes is not None
                else {e.node for e in entries})
        per_node = _per_node(entries)
        by_epoch: dict[int, dict[int, ManifestEntry]] = {}
        for e in entries:
            by_epoch.setdefault(e.epoch, {})[e.node] = e
        served = False
        for epoch in sorted(by_epoch, reverse=True):
            at = by_epoch[epoch]
            if not need <= set(at):
                continue                     # incomplete epoch (dead nodes)
            steps = {at[n].step for n in need}
            if len(steps) != 1:
                continue                     # nodes landed at unequal steps
            step = steps.pop()
            try:
                flats: dict[int, tuple] = {}
                for nid in sorted(need):
                    flats.update(_reconstruct_node(
                        tier, _chain(per_node[nid], epoch), by_id))
            except (TornRecordError, TierRestoreError, KeyError):
                continue                     # torn/missing: older epoch
            if set(flats) != all_buckets:
                continue                     # nodes don't cover the layout
            # a slower tier may still hold the newest epoch (e.g. the
            # faster one refused a write): keep the best across ALL tiers
            if best is None or step > best[0]:
                best = (step, flats)
            served = True
            break                            # this tier's newest; next tier
        if not served:
            reasons.append(f"{tier.name}: no consistent intact epoch")
    if best is not None:
        return _unpack(layout, best[1], best[0])
    raise TierRestoreError(
        "restore_from_tiers found no usable restore point: "
        + "; ".join(reasons))


def restore_shards_from_tiers(tiers: Iterable[Tier], layout: BucketLayout,
                              node_ids: Iterable[int], at_step: int
                              ) -> tuple[dict, dict, dict]:
    """Rebuild ONLY ``node_ids``'s buckets at exactly ``at_step``.

    Returns ``(params, mu, nu)`` leaf trees covering just those nodes'
    partitions — the merge fragment `recover` composes with the
    survivors' live partial after a non-total `ShadowNodeLoss`. Raises
    `TierRestoreError` if no tier holds every requested node at that
    exact step with an intact chain.
    """
    node_ids = sorted(set(node_ids))
    by_id = {b.bucket_id: b for b in layout.buckets}
    reasons = []
    for tier in tiers:
        try:
            entries = list(tier.entries())
        except Exception as e:
            reasons.append(f"{tier.name}: manifest unreadable ({e})")
            continue
        per_node = _per_node(entries)
        flats: dict[int, tuple] = {}
        ok = True
        for nid in node_ids:
            rebuilt = None
            cands = [e.epoch for e in per_node.get(nid, [])
                     if e.step == at_step]
            for epoch in sorted(cands, reverse=True):
                try:
                    rebuilt = _reconstruct_node(
                        tier, _chain(per_node[nid], epoch), by_id)
                    break
                except (TornRecordError, TierRestoreError, KeyError):
                    continue
            if rebuilt is None:
                reasons.append(
                    f"{tier.name}: node {nid} has no intact record at "
                    f"step {at_step}")
                ok = False
                break
            flats.update(rebuilt)
        if not ok:
            continue
        params: dict = {}
        mu: dict = {}
        nu: dict = {}
        for bid, (p, m, v) in flats.items():
            b = by_id[bid]
            params.update(unpack_bucket(b, p, xp=np))
            mu.update(unpack_bucket(b, m, xp=np))
            nu.update(unpack_bucket(b, v, xp=np))
        return params, mu, nu
    raise TierRestoreError(
        f"no tier holds nodes {node_ids} at step {at_step}: "
        + "; ".join(reasons))
