"""Background flush plane: shadow RAM -> durability tiers, off the hot path.

`DurableShadow` attaches to a `repro.core.shadow.ShadowCluster` and runs
one `FlushWorker` thread per shadow node. On every
`FlushPolicy.every_steps`-th applied step the cluster's ingest path calls
``notify(step)`` — a dict insert + queue put, never a copy — assigning a
globally ordered flush *epoch*; each worker then snapshots its node's
dirty bucket flats apply-atomically (the wire-native format — no
repacking) and writes one checksummed `FlushRecord` to every tier.

Every live node writes a record every epoch — a ``mark`` when it has
nothing dirty — so an epoch is *provably complete* (all nodes present at
one step) without a coordinator journal, and
`repro.durability.restore.restore_from_tiers` can simply walk epochs
newest-first. Dead nodes write nothing: their epochs stay visibly
incomplete and restore falls back past them.

Nothing here ever runs on the training thread: the trainer's stall
ledger stays provably free of any flush stage (the harness
`zero-flush-stall` invariant), mirroring the paper's zero-overhead claim
into durability. Compressed deltas quantize the *difference* against a
per-worker reconstruction buffer using the stateless no-EF codec
(`repro.dist.compression.quantize_flat_stateless`), so flushing can
never perturb a channel Compressor's error-feedback residuals; bases are
always raw, so the chain re-anchors exactly every
`FlushPolicy.rebase_every` cycles.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro import obs as _obs
from repro.dist.compression import (dequantize_flat_stateless,
                                    quantize_flat_stateless)
from repro.durability.record import FlushRecord
from repro.durability.tiers import Tier, TierPutError


@dataclass(frozen=True)
class FlushPolicy:
    """Knobs for the background flush plane.

    ``every_steps`` — flush epoch cadence in applied train steps (tier
    lag is bounded by ``every_steps - 1`` plus in-flight flushes).
    ``compress`` — int8-quantize delta payloads (bases stay raw; restore
    is then approximate, see `docs/durability.md`).
    ``rebase_every`` — force a raw base every N flush cycles per node,
    bounding both the restore chain length and compression drift.
    """

    every_steps: int = 1
    compress: bool = False
    rebase_every: int = 8
    drain_timeout_s: float = 30.0


class FlushWorker:
    """One background flusher per shadow node. Never blocks the trainer."""

    def __init__(self, dur: "DurableShadow", node):
        self.dur = dur
        self.node = node
        self.q: queue.Queue = queue.Queue()
        self.flush_count = 0            # cycles processed -> rebase cadence
        # compressed path: f32 reconstruction of what the tiers can rebuild
        self._recon: dict[int, dict[str, np.ndarray]] = {}
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def submit(self, epoch: int, step: int, force_base: bool):
        self.q.put((epoch, step, force_base))

    def join(self):
        self.q.join()

    def close(self):
        self.q.put(None)
        self._thread.join(timeout=5)

    def _loop(self):
        while True:
            item = self.q.get()
            if item is None:
                self.q.task_done()
                return
            try:
                self._flush(*item)
            finally:
                self.q.task_done()

    def _flush(self, epoch: int, step: int, force_base: bool):
        node, dur = self.node, self.dur
        cluster = dur.cluster
        if cluster is not None and cluster.async_mode:
            # async ingest: the apply this epoch captures may still be in
            # the node's queue — wait (HERE, off the training thread) until
            # the node has caught up to the notified step
            deadline = time.monotonic() + dur.policy.drain_timeout_s
            while (node.step < step
                   and node.node_id not in cluster.dead_nodes
                   and time.monotonic() < deadline):
                time.sleep(0.001)
        if cluster is not None and node.node_id in cluster.dead_nodes:
            return        # no record: the epoch stays visibly incomplete
        base = force_base or self.flush_count % dur.policy.rebase_every == 0
        self.flush_count += 1
        with _obs.get().tracer.span(
                "durability.flush", track=f"durability{node.node_id}",
                args={"epoch": epoch, "step": step,
                      "node": node.node_id}):
            snap, snap_step = node.snapshot_dirty(force_all=base)
            rec = self._build_record(epoch, snap_step, snap, base)
            for tier in dur.tiers:
                try:
                    entry = tier.put(rec)
                except TierPutError as e:
                    dur._put_failed(tier, rec, e)
                else:
                    dur._ack(tier, rec, entry)

    def _build_record(self, epoch: int, step: int, snap: dict,
                      base: bool) -> FlushRecord:
        node = self.node
        if base:
            payload = {}
            for bid, (p, m, v) in snap.items():
                payload[bid] = {"p": p, "m": m, "v": v}
                if self.dur.policy.compress:
                    self._recon[bid] = {"p": p.astype(np.float32),
                                        "m": m.astype(np.float32),
                                        "v": v.astype(np.float32)}
            return FlushRecord(epoch=epoch, node=node.node_id, step=step,
                               kind="base", compressed=False,
                               payload=payload)
        if not snap:
            return FlushRecord(epoch=epoch, node=node.node_id, step=step,
                               kind="mark")
        if not self.dur.policy.compress:
            payload = {bid: {"p": p, "m": m, "v": v}
                       for bid, (p, m, v) in snap.items()}
            return FlushRecord(epoch=epoch, node=node.node_id, step=step,
                               kind="delta", compressed=False,
                               payload=payload)
        by_id = node._by_id
        payload = {}
        for bid, (p, m, v) in snap.items():
            b = by_id[bid]
            recon = self._recon[bid]
            fields = {}
            for name, cur in (("p", p), ("m", m), ("v", v)):
                diff = cur.astype(np.float32) - recon[name]
                q, scales = quantize_flat_stateless(b, diff)
                recon[name] += dequantize_flat_stateless(b, q, scales)
                fields[name] = q
                fields[name + "s"] = scales
            payload[bid] = fields
        return FlushRecord(epoch=epoch, node=node.node_id, step=step,
                           kind="delta", compressed=True, payload=payload)


class DurableShadow:
    """Coordinates per-node `FlushWorker`s + epoch/ack bookkeeping."""

    def __init__(self, tiers: list[Tier],
                 policy: Optional[FlushPolicy] = None):
        self.tiers = list(tiers)
        self.policy = policy or FlushPolicy()
        self.cluster = None
        self.workers: dict[int, FlushWorker] = {}
        self._lock = threading.Lock()
        self._next_epoch = 0
        # epoch -> frozenset of node ids notified (the completeness bar)
        self._epoch_nodes: dict[int, frozenset] = {}
        # epoch -> cluster size when the epoch opened; completeness is
        # judged against THIS, not the current cluster, so epochs written
        # before an elastic re-layout stay correctly classified
        self._epoch_total: dict[int, int] = {}
        # epoch -> {node id -> step its record landed at}
        self._epoch_steps: dict[int, dict[int, int]] = {}
        # tier name -> epoch -> set of acked node ids
        self._acks: dict[str, dict[int, set]] = {t.name: {}
                                                 for t in self.tiers}
        self.put_failures = 0
        self.flush_bytes_total = 0
        self.epochs_started = 0

    # -- wiring ---------------------------------------------------------------
    def attach(self, cluster) -> "DurableShadow":
        """Hook into a ShadowCluster: the cluster's ingest/bootstrap paths
        call back into :meth:`notify` / :meth:`on_bootstrap`."""
        assert cluster.flat, \
            "durability flushes wire-layout flats; flat=False not supported"
        self.cluster = cluster
        cluster.durability = self
        self.workers = {n.node_id: FlushWorker(self, n)
                        for n in cluster.nodes}
        return self

    def reattach(self, cluster) -> "DurableShadow":
        """Migrate the flush plane to a re-laid-out cluster (elastic
        restore). Drains and retires the old workers first (no queued
        flush is silently dropped), keeps the tiers AND the epoch/ack
        history — every durable epoch written under the old layout stays
        restorable from the tiers, and epoch numbering continues
        monotonically — then starts fresh workers for the new nodes. The
        caller's subsequent ``cluster.bootstrap`` forces a full base, so
        a complete restore point exists under the new layout immediately.
        """
        self.drain()
        for w in self.workers.values():
            w.close()
        old = self.cluster
        if old is not None and old.durability is self:
            old.durability = None
        return self.attach(cluster)

    # -- hot-path hook (called from ShadowCluster._ingest) --------------------
    def notify(self, step: int, force_base: bool = False):
        """Open a flush epoch for ``step`` if the cadence says so.

        O(n_nodes) queue puts — no snapshot, no serialization, no I/O
        happens on the caller's thread.
        """
        if (not force_base and self.policy.every_steps > 1
                and step % self.policy.every_steps != 0):
            return
        cluster = self.cluster
        live = [n.node_id for n in cluster.nodes
                if n.node_id not in cluster.dead_nodes]
        if not live:
            return
        with self._lock:
            epoch = self._next_epoch
            self._next_epoch += 1
            self._epoch_nodes[epoch] = frozenset(live)
            self._epoch_total[epoch] = cluster.n_nodes
            self._epoch_steps[epoch] = {}
            self.epochs_started += 1
        for nid in live:
            self.workers[nid].submit(epoch, step, force_base)

    def on_bootstrap(self, step: int):
        """Cold path: force a raw base epoch and wait for it, so a full
        restore point exists from the moment the replica is seeded."""
        self.notify(step, force_base=True)
        self.drain()

    # -- bookkeeping (called from FlushWorker threads) ------------------------
    def _ack(self, tier: Tier, rec: FlushRecord, entry):
        with self._lock:
            self._acks[tier.name].setdefault(rec.epoch, set()).add(rec.node)
            self._epoch_steps[rec.epoch][rec.node] = rec.step
            self.flush_bytes_total += entry.nbytes
        obs = _obs.get()
        obs.metrics.counter(
            "durability_flush_bytes",
            "Bytes flushed to durability tiers").inc(
            entry.nbytes, tier=tier.name)
        last = self.last_complete_step(tier.name)
        if last is not None and self.cluster is not None:
            obs.metrics.gauge(
                "durability_tier_lag_steps",
                "Train steps the tier's newest complete epoch trails by"
            ).set(max(0, self.cluster.train_step_seen - last),
                  tier=tier.name)

    def _put_failed(self, tier: Tier, rec: FlushRecord, err: Exception):
        with self._lock:
            self.put_failures += 1
        _obs.get().metrics.counter(
            "durability_tier_put_failures_total",
            "Tier writes that failed (record not durable there)").inc(
            1, tier=tier.name)

    # -- queries --------------------------------------------------------------
    def last_complete_step(self, tier_name: str) -> Optional[int]:
        """Newest step at which EVERY cluster node's record is durable on
        ``tier_name`` within one epoch — the step `restore_from_tiers`
        would recover to from that tier."""
        best = None
        with self._lock:
            acks = self._acks.get(tier_name, {})
            for epoch, nodes in self._epoch_nodes.items():
                n_total = self._epoch_total.get(epoch)
                if n_total is not None and len(nodes) < n_total:
                    continue          # some nodes dead: not a full restore
                if not nodes <= acks.get(epoch, set()):
                    continue
                steps = {self._epoch_steps[epoch][n] for n in nodes}
                if len(steps) != 1:
                    continue          # workers raced past each other
                s = steps.pop()
                if best is None or s > best:
                    best = s
        return best

    def newest_durable(self) -> Optional[tuple[str, int]]:
        """(tier name, step) of the freshest full restore point, or None."""
        best = None
        for tier in self.tiers:
            s = self.last_complete_step(tier.name)
            if s is not None and (best is None or s > best[1]):
                best = (tier.name, s)
        return best

    # -- lifecycle ------------------------------------------------------------
    def drain(self):
        """Block until every queued flush has been written (test/cold-path
        helper — production code never calls this on the trainer)."""
        for w in self.workers.values():
            w.join()

    def close(self):
        for w in self.workers.values():
            w.close()
        self.workers = {}
