"""Pluggable persistence tiers behind the shadow plane.

A `Tier` stores `FlushRecord` blobs and a manifest of what it holds.
Two implementations:

* `LocalDiskTier` — records AND the manifest are written tmp-file +
  ``os.replace`` (atomic on POSIX), so a crash mid-flush leaves either
  the previous manifest or the new one, never a half-written entry; a
  crash mid-record leaves a torn blob the checksum rejects on read.
* `ObjectStoreTier` — in-memory stub for a remote object store with
  injectable put latency (served on the flush worker thread, never the
  trainer's) and injectable per-step failures.

Both expose ``fail_steps``: a `put` for a record at one of those steps
raises `TierPutError` — the chaos harness `TierFailure` class drives
this to prove restore falls back across tiers.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Iterable, Protocol, runtime_checkable

from repro.durability.record import FlushRecord, TornRecordError

MANIFEST = "manifest.json"


class TierPutError(RuntimeError):
    """A tier refused or failed a record write (injected or real)."""


@dataclass(frozen=True)
class ManifestEntry:
    """One durable record as the manifest advertises it."""

    epoch: int
    node: int
    step: int
    kind: str
    compressed: bool
    nbytes: int
    key: str

    @classmethod
    def for_record(cls, rec: FlushRecord, key: str, nbytes: int
                   ) -> "ManifestEntry":
        return cls(epoch=rec.epoch, node=rec.node, step=rec.step,
                   kind=rec.kind, compressed=rec.compressed,
                   nbytes=nbytes, key=key)


@runtime_checkable
class Tier(Protocol):
    name: str

    def put(self, rec: FlushRecord) -> ManifestEntry: ...
    def entries(self) -> list[ManifestEntry]: ...
    def read(self, entry: ManifestEntry) -> FlushRecord: ...


def _record_key(rec: FlushRecord) -> str:
    return f"rec_e{rec.epoch:08d}_n{rec.node:03d}.bin"


class LocalDiskTier:
    """Records on local disk with atomic rename + an atomic manifest."""

    name = "local-disk"

    def __init__(self, root):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.fail_steps: set[int] = set()
        self.put_bytes_total = 0
        # one FlushWorker per shadow node writes here concurrently; the
        # manifest update is read-modify-write and must serialize
        self._lock = threading.Lock()

    def put(self, rec: FlushRecord) -> ManifestEntry:
        if rec.step in self.fail_steps:
            raise TierPutError(
                f"{self.name}: injected put failure at step {rec.step}")
        buf = rec.to_bytes()
        key = _record_key(rec)
        tmp = self.root / (key + ".tmp")
        tmp.write_bytes(buf)
        os.replace(tmp, self.root / key)        # atomic: blob visible whole
        entry = ManifestEntry.for_record(rec, key, len(buf))
        with self._lock:
            ents = self.entries()
            ents.append(entry)
            mtmp = self.root / (MANIFEST + ".tmp")
            mtmp.write_text(json.dumps(
                {"entries": [asdict(e) for e in ents]}, sort_keys=True))
            os.replace(mtmp, self.root / MANIFEST)  # atomic: old or new
            self.put_bytes_total += len(buf)
        return entry

    def entries(self) -> list[ManifestEntry]:
        path = self.root / MANIFEST
        if not path.exists():
            return []
        data = json.loads(path.read_text())
        return [ManifestEntry(**e) for e in data["entries"]]

    def read(self, entry: ManifestEntry) -> FlushRecord:
        path = self.root / entry.key
        if not path.exists():
            raise TornRecordError(f"{self.name}: missing blob {entry.key}")
        return FlushRecord.from_bytes(path.read_bytes())


class ObjectStoreTier:
    """In-memory object-store stub: injectable latency + failures.

    Latency is paid on the *flush worker* thread — the trainer never
    blocks on it, which is exactly the property the `zero-flush-stall`
    invariant checks.
    """

    name = "object-store"

    def __init__(self, latency_s: float = 0.0):
        self.latency_s = float(latency_s)
        self.fail_steps: set[int] = set()
        self.put_bytes_total = 0
        self._blobs: dict[str, bytes] = {}
        self._entries: list[ManifestEntry] = []
        self._lock = threading.Lock()          # concurrent worker puts

    def put(self, rec: FlushRecord) -> ManifestEntry:
        if rec.step in self.fail_steps:
            raise TierPutError(
                f"{self.name}: injected put failure at step {rec.step}")
        if self.latency_s > 0:
            time.sleep(self.latency_s)
        buf = rec.to_bytes()
        key = _record_key(rec)
        entry = ManifestEntry.for_record(rec, key, len(buf))
        with self._lock:
            self._blobs[key] = buf
            self._entries.append(entry)
            self.put_bytes_total += len(buf)
        return entry

    def entries(self) -> list[ManifestEntry]:
        with self._lock:
            return list(self._entries)

    def read(self, entry: ManifestEntry) -> FlushRecord:
        try:
            buf = self._blobs[entry.key]
        except KeyError:
            raise TornRecordError(
                f"{self.name}: missing blob {entry.key}") from None
        return FlushRecord.from_bytes(buf)


def tier_names(tiers: Iterable[Tier]) -> list[str]:
    return [t.name for t in tiers]
