"""Pluggable persistence tiers behind the shadow plane.

A `Tier` stores `FlushRecord` blobs and a manifest of what it holds.
Two implementations:

* `LocalDiskTier` — records AND the manifest are written tmp-file +
  ``os.replace`` (atomic on POSIX), so a crash mid-flush leaves either
  the previous manifest or the new one, never a half-written entry; a
  crash mid-record leaves a torn blob the checksum rejects on read.
* `ObjectStoreTier` — in-memory stub for a remote object store with
  injectable put latency (served on the flush worker thread, never the
  trainer's) and injectable per-step failures.

Both expose ``fail_steps``: a `put` for a record at one of those steps
raises `TierPutError` — the chaos harness `TierFailure` class drives
this to prove restore falls back across tiers.

Retention (``retain_epochs``): with unbounded epochs a tier's footprint
grows forever, so both tiers garbage-collect on every ``put``. The
pruning rule is chain-aware, not a naive count: restore walks per-node
delta chains back to each node's most recent base, so the collector
keeps the newest ``retain_epochs`` epochs PLUS everything back to (and
including) the newest *all-base anchor* epoch at or below that window —
an epoch in which every present record is a raw base, behind which no
chain can reach. If no anchor exists below the window (e.g. the bases
are still ahead of the cutoff) nothing is pruned: the newest complete
base+delta chain is never cut, and a torn record in a retained epoch can
always fall back to the anchor.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Iterable, Protocol, runtime_checkable

from repro.durability.record import FlushRecord, TornRecordError

MANIFEST = "manifest.json"


class TierPutError(RuntimeError):
    """A tier refused or failed a record write (injected or real)."""


@dataclass(frozen=True)
class ManifestEntry:
    """One durable record as the manifest advertises it."""

    epoch: int
    node: int
    step: int
    kind: str
    compressed: bool
    nbytes: int
    key: str

    @classmethod
    def for_record(cls, rec: FlushRecord, key: str, nbytes: int
                   ) -> "ManifestEntry":
        return cls(epoch=rec.epoch, node=rec.node, step=rec.step,
                   kind=rec.kind, compressed=rec.compressed,
                   nbytes=nbytes, key=key)


@runtime_checkable
class Tier(Protocol):
    name: str

    def put(self, rec: FlushRecord) -> ManifestEntry: ...
    def entries(self) -> list[ManifestEntry]: ...
    def read(self, entry: ManifestEntry) -> FlushRecord: ...


def _record_key(rec: FlushRecord) -> str:
    return f"rec_e{rec.epoch:08d}_n{rec.node:03d}.bin"


def _prune_plan(ents: list[ManifestEntry],
                retain_epochs: "int | None") -> list[ManifestEntry]:
    """Entries the retention policy says to DROP (possibly empty).

    Keeps the newest ``retain_epochs`` distinct epochs, then walks down to
    the newest epoch at or below that cutoff whose every record is a raw
    base (the anchor) and drops only epochs strictly older — per-node
    delta chains re-anchor at each base, so nothing restorable is lost.
    Returns [] when no safe anchor exists.
    """
    if retain_epochs is None:
        return []
    epochs = sorted({e.epoch for e in ents}, reverse=True)
    if len(epochs) <= retain_epochs:
        return []
    cutoff = epochs[retain_epochs - 1]
    by_epoch: dict[int, list[ManifestEntry]] = {}
    for e in ents:
        by_epoch.setdefault(e.epoch, []).append(e)
    anchor = None
    for ep in sorted(by_epoch, reverse=True):
        if ep > cutoff:
            continue
        if all(e.kind == "base" for e in by_epoch[ep]):
            anchor = ep
            break
    if anchor is None:
        return []            # no full-base anchor below the window: keep all
    return [e for e in ents if e.epoch < anchor]


class LocalDiskTier:
    """Records on local disk with atomic rename + an atomic manifest."""

    name = "local-disk"

    def __init__(self, root, retain_epochs: "int | None" = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.fail_steps: set[int] = set()
        self.retain_epochs = retain_epochs
        self.put_bytes_total = 0
        self.gc_records_total = 0
        self.gc_bytes_total = 0
        # one FlushWorker per shadow node writes here concurrently; the
        # manifest update is read-modify-write and must serialize
        self._lock = threading.Lock()

    def put(self, rec: FlushRecord) -> ManifestEntry:
        if rec.step in self.fail_steps:
            raise TierPutError(
                f"{self.name}: injected put failure at step {rec.step}")
        buf = rec.to_bytes()
        key = _record_key(rec)
        tmp = self.root / (key + ".tmp")
        tmp.write_bytes(buf)
        os.replace(tmp, self.root / key)        # atomic: blob visible whole
        entry = ManifestEntry.for_record(rec, key, len(buf))
        with self._lock:
            ents = self.entries()
            ents.append(entry)
            drop = _prune_plan(ents, self.retain_epochs)
            if drop:
                gone = {d.key for d in drop}
                ents = [e for e in ents if e.key not in gone]
            mtmp = self.root / (MANIFEST + ".tmp")
            mtmp.write_text(json.dumps(
                {"entries": [asdict(e) for e in ents]}, sort_keys=True))
            os.replace(mtmp, self.root / MANIFEST)  # atomic: old or new
            # blobs are unlinked only AFTER the manifest stopped naming
            # them — a crash between the two leaves orphans, never a
            # manifest entry pointing at a missing blob
            for d in drop:
                try:
                    (self.root / d.key).unlink()
                except FileNotFoundError:
                    pass
                self.gc_records_total += 1
                self.gc_bytes_total += d.nbytes
            self.put_bytes_total += len(buf)
        return entry

    def disk_bytes(self) -> int:
        """Bytes currently on disk (blobs only) — the retention bound."""
        return sum(p.stat().st_size for p in self.root.glob("rec_*.bin"))

    def entries(self) -> list[ManifestEntry]:
        path = self.root / MANIFEST
        if not path.exists():
            return []
        data = json.loads(path.read_text())
        return [ManifestEntry(**e) for e in data["entries"]]

    def read(self, entry: ManifestEntry) -> FlushRecord:
        path = self.root / entry.key
        if not path.exists():
            raise TornRecordError(f"{self.name}: missing blob {entry.key}")
        return FlushRecord.from_bytes(path.read_bytes())


class ObjectStoreTier:
    """In-memory object-store stub: injectable latency + failures.

    Latency is paid on the *flush worker* thread — the trainer never
    blocks on it, which is exactly the property the `zero-flush-stall`
    invariant checks.

    Real object stores fail transiently, so ``put`` retries with bounded
    exponential backoff: up to ``retry_attempts`` total attempts, sleeping
    ``retry_backoff_s * 2**(attempt-1)`` between them (capped at
    ``retry_backoff_cap_s``), all of it on the flush-worker thread.
    ``transient_fail_steps`` maps a step to how many attempts fail before
    one succeeds (the retry drill); ``fail_steps`` stays permanent. When
    the budget is exhausted the final `TierPutError` propagates to the
    caller — `FlushWorker` catches it, books a put failure, and the tier
    simply lags (``durability_tier_lag_steps``); nothing raises into the
    flush loop.
    """

    name = "object-store"

    def __init__(self, latency_s: float = 0.0, retry_attempts: int = 1,
                 retry_backoff_s: float = 0.0,
                 retry_backoff_cap_s: float = 0.25,
                 retain_epochs: "int | None" = None):
        self.latency_s = float(latency_s)
        self.fail_steps: set[int] = set()
        self.transient_fail_steps: dict[int, int] = {}
        self.retry_attempts = max(1, int(retry_attempts))
        self.retry_backoff_s = float(retry_backoff_s)
        self.retry_backoff_cap_s = float(retry_backoff_cap_s)
        self.retain_epochs = retain_epochs
        self.put_bytes_total = 0
        self.retries_total = 0
        self.gc_records_total = 0
        self.gc_bytes_total = 0
        self._transient_seen: dict[tuple[int, int], int] = {}
        self._blobs: dict[str, bytes] = {}
        self._entries: list[ManifestEntry] = []
        self._lock = threading.Lock()          # concurrent worker puts

    def _put_once(self, rec: FlushRecord) -> ManifestEntry:
        if rec.step in self.fail_steps:
            raise TierPutError(
                f"{self.name}: injected put failure at step {rec.step}")
        budget = self.transient_fail_steps.get(rec.step, 0)
        if budget:
            k = (rec.step, rec.node)
            with self._lock:
                seen = self._transient_seen.get(k, 0)
                if seen < budget:
                    self._transient_seen[k] = seen + 1
            if seen < budget:
                raise TierPutError(
                    f"{self.name}: transient put failure at step "
                    f"{rec.step} (attempt {seen + 1}/{budget})")
        if self.latency_s > 0:
            time.sleep(self.latency_s)
        buf = rec.to_bytes()
        key = _record_key(rec)
        entry = ManifestEntry.for_record(rec, key, len(buf))
        with self._lock:
            self._blobs[key] = buf
            self._entries.append(entry)
            drop = _prune_plan(self._entries, self.retain_epochs)
            if drop:
                gone = {d.key for d in drop}
                self._entries = [e for e in self._entries
                                 if e.key not in gone]
                for d in drop:
                    self._blobs.pop(d.key, None)
                    self.gc_records_total += 1
                    self.gc_bytes_total += d.nbytes
            self.put_bytes_total += len(buf)
        return entry

    def put(self, rec: FlushRecord) -> ManifestEntry:
        attempt = 0
        while True:
            attempt += 1
            try:
                return self._put_once(rec)
            except TierPutError:
                if attempt >= self.retry_attempts:
                    raise          # budget spent: the worker books the lag
                with self._lock:
                    self.retries_total += 1
                if self.retry_backoff_s > 0:
                    time.sleep(min(self.retry_backoff_s * 2 ** (attempt - 1),
                                   self.retry_backoff_cap_s))

    def entries(self) -> list[ManifestEntry]:
        with self._lock:
            return list(self._entries)

    def read(self, entry: ManifestEntry) -> FlushRecord:
        try:
            buf = self._blobs[entry.key]
        except KeyError:
            raise TornRecordError(
                f"{self.name}: missing blob {entry.key}") from None
        return FlushRecord.from_bytes(buf)


def tier_names(tiers: Iterable[Tier]) -> list[str]:
    return [t.name for t in tiers]
