"""Multicast-update control plane (paper §4.3.1, §4.2.4).

The switch control plane is configured per DP group with the boundary ranks'
addresses; it creates protocol-independent multicast groups (next training
rank + the shadow nodes) and a shadow-node-id -> address map used to rewrite
mirrored packets. On TPU (docs/ARCHITECTURE.md, "TPU adaptation"),
"multicast group" degenerates to a shard->shadow-node routing table at the
host DMA boundary — this module provides both views.

The data plane that consumes this configuration lives in
`repro.net.switch`; the event-driven fabric simulator
(`repro.net.simulator`) instantiates one control plane per fabric and one
data plane per switch.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.buckets import BucketLayout


@dataclass(frozen=True)
class MulticastGroup:
    group_id: int
    dp_group: int
    boundary_rank: int            # tagging source (first or last rank)
    next_rank: int                # normal AllGather destination
    shadow_nodes: tuple[int, ...]


@dataclass
class SwitchControlPlane:
    """Match-action configuration for tagged-gradient replication.

    Args:
        n_dp_groups: concurrent data-parallel groups sharing the fabric.
        ranks_per_group: ring size of each group's AllGather; global rank
            ``r`` belongs to DP group ``r // ranks_per_group``.
        n_shadow_nodes: CPU shadow nodes mirrored packets may target.

    Call ``setup()`` before use: it installs two multicast streams per DP
    group (the first and last rank of each ring, §4.4) into
    ``match_table`` and assigns shadow node addresses.
    """
    n_dp_groups: int
    ranks_per_group: int
    n_shadow_nodes: int
    shadow_addr: dict[int, str] = field(default_factory=dict)
    groups: list[MulticastGroup] = field(default_factory=list)
    match_table: dict[tuple[int, int], int] = field(default_factory=dict)

    def setup(self):
        """Two multicast streams per DP group (first + last rank), §4.4."""
        gid = 0
        self.groups.clear()
        self.match_table.clear()
        for dp in range(self.n_dp_groups):
            first = dp * self.ranks_per_group
            last = first + self.ranks_per_group - 1
            for rank in {first, last}:
                nxt = first + ((rank - first + 1) % self.ranks_per_group)
                g = MulticastGroup(
                    group_id=gid, dp_group=dp, boundary_rank=rank,
                    next_rank=nxt,
                    shadow_nodes=tuple(range(self.n_shadow_nodes)))
                self.groups.append(g)
                self.match_table[(dp, rank)] = gid
                gid += 1
        for node in range(self.n_shadow_nodes):
            self.shadow_addr[node] = f"10.8.{node // 256}.{node % 256}"
        return self

    def lookup(self, dp_group: int, src_rank: int) -> Optional[MulticastGroup]:
        """Match a (DP group, global source rank) against the multicast
        table; None for non-boundary ranks (no replication rule)."""
        gid = self.match_table.get((dp_group, src_rank))
        return self.groups[gid] if gid is not None else None

    @property
    def multicast_streams(self) -> int:
        return len(self.groups)

    def extra_switch_ports(self) -> int:
        """Ports for shadow connectivity: 2 streams per DP group (§4.4)."""
        return 2 * self.n_dp_groups


def multicast_groups(n_dp_groups: int, ranks_per_group: int,
                     n_shadow_nodes: int) -> list[MulticastGroup]:
    """The fabric's multicast group set, without holding a control plane.

    Convenience for `GradientChannel.open(layout, multicast_groups)`: a
    channel only needs the group list (who replicates, to which shadow
    nodes); the stateful match-action table stays inside the simulator's
    own `SwitchControlPlane`.
    """
    return SwitchControlPlane(
        n_dp_groups, ranks_per_group, n_shadow_nodes).setup().groups


def assign_buckets(layout: BucketLayout, n_nodes: int) -> dict[int, int]:
    """bucket_id -> shadow node, byte-balanced greedy partition (§4.2.4).

    Deterministic: buckets in id order onto the currently-lightest node, so
    training nodes, switch, and shadow nodes all derive the same mapping.
    """
    load = [0] * n_nodes
    out = {}
    for b in layout.buckets:
        node = min(range(n_nodes), key=lambda i: (load[i], i))
        out[b.bucket_id] = node
        load[node] += b.nbytes
    return out


def node_partitions(layout: BucketLayout, assignment: dict[int, int],
                    n_nodes: int) -> list[list[int]]:
    parts: list[list[int]] = [[] for _ in range(n_nodes)]
    for bid, node in assignment.items():
        parts[node].append(bid)
    return parts
