"""Elastic restore: re-partition the consolidated checkpoint onto a
reconfigured mesh (ROADMAP item 1; Universal Checkpointing / Oobleck).

The shadow's consolidated checkpoint is already a full unsharded tree, so
landing it on a *different* parallelism layout than the run that produced
it needs no data movement beyond the normal restore ``device_put`` — what
has to be rebuilt is everything the old layout *derived*:

* the physical mesh + `ShardingRules` (``mesh_from_plan`` /
  ``rules_from_plan`` realize a `repro.core.costmodel.ElasticPlan`);
* the capture-side `BucketLayout` and the bucket -> shadow-node
  ownership map (under FSDP the RS-shard capture boundary moves with the
  DP width, so channel routing and shadow flats must be re-derived from
  one consistent layout — ``rebuild_shadow``);
* the shadow plane itself: a fresh `ShadowCluster` re-seeded from the
  checkpoint, with the attached `repro.durability.DurableShadow` (if any)
  migrated over — its tiers keep every durable epoch written under the
  old layout, and the re-seed forces a new full base at the resume step
  so ``newest_durable`` never moves backwards;
* the `GradientChannel` + checkpointer wiring
  (`CheckmateCheckpointer.reconfigure`), booked on the stall ledger as
  the named ``elastic-reshard`` stage.

The data stream needs no rebuild: `repro.data.synthetic.SyntheticStream`
materializes the GLOBAL batch as a pure function of (seed, step), and
``device_batch`` re-splits it per the new rules, so global batch order is
preserved across the shrink by construction.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.buckets import layout_for_tree
from repro.core.costmodel import (ElasticMeshBudget, ElasticPlan,
                                  ElasticPlanError, plan_elastic_mesh)
from repro.core.shadow import ShadowCluster
from repro.dist import compat
from repro.dist.sharding import ShardingRules

__all__ = ["ElasticMeshBudget", "ElasticPlan", "ElasticPlanError",
           "ELASTIC_STAGE", "plan_elastic_mesh", "mesh_from_plan",
           "rules_from_plan", "rebuild_shadow"]

#: Stall-ledger stage name for the whole plane reconfiguration (channel
#: close/open + shadow swap). Lives in `repro.obs.stalls.KNOWN_STAGES` and
#: the harness stall-attribution vocabulary.
ELASTIC_STAGE = "elastic-reshard"


def mesh_from_plan(plan: ElasticPlan, devices=None):
    """Build the physical mesh an `ElasticPlan` describes.

    ``devices`` defaults to ``jax.devices()``; the plan's survivor ranks
    index into it (lowest-numbered survivors fill the mesh in order).
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    if plan.n_ranks > len(devices):
        raise ElasticPlanError(
            f"plan needs {plan.n_ranks} device(s) but only "
            f"{len(devices)} are visible")
    picked = [devices[r] for r in plan.survivors] if plan.survivors \
        else devices[:plan.n_ranks]
    return compat.make_mesh(
        plan.mesh_shape, plan.axis_names, devices=picked,
        axis_types=(compat.AxisType.Auto,) * len(plan.mesh_shape))


def rules_from_plan(plan: ElasticPlan, devices=None) -> ShardingRules:
    """`ShardingRules` for the planned mesh (FSDP flag from the plan)."""
    return ShardingRules(mesh_from_plan(plan, devices), fsdp=plan.fsdp)


def rebuild_shadow(old: ShadowCluster, ckpt: dict, *,
                   n_nodes: Optional[int] = None,
                   cap_bytes: Optional[int] = None,
                   layout=None) -> ShadowCluster:
    """Re-derive the shadow plane for a re-partitioned world.

    Builds a fresh `BucketLayout` from the checkpoint's param tree (the
    capture point may have moved — pass ``cap_bytes`` to keep the old
    bucketing granularity, or ``layout`` to inject one), re-derives the
    bucket ownership map for ``n_nodes`` (default: the old fleet size),
    migrates the attached `DurableShadow` (old durable epochs stay on the
    tiers; the flush bookkeeping carries over so epoch numbering stays
    monotonic), shuts the old cluster down, and seeds the new one from
    ``ckpt`` — which, with durability attached, forces a fresh full base
    at the resume step so a complete restore point exists under the NEW
    layout from the moment the plane re-attaches.
    """
    if layout is None:
        layout = (layout_for_tree(ckpt["params"], cap_bytes)
                  if cap_bytes is not None
                  else layout_for_tree(ckpt["params"]))
    new = ShadowCluster(layout, old.opt,
                        n_nodes=old.n_nodes if n_nodes is None else n_nodes,
                        async_mode=old.async_mode, flat=old.flat)
    dur = old.durability
    old.durability = None          # keep shutdown() from closing the tiers
    if dur is not None:
        dur.reattach(new)          # drains + retires the old flush workers
    old.shutdown()
    new.bootstrap(ckpt["params"], ckpt["mu"], ckpt["nu"],
                  int(ckpt["step"]))
    return new
