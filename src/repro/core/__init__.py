from repro.core.tagging import (  # noqa: F401
    chunk_at, is_tagged, tag_schedule, tagged_chunks_per_rank,
    TagEvent,
)
from repro.core.buckets import (  # noqa: F401
    Bucket, BucketLayout, FlatTreeView, build_buckets, pack_bucket,
    pack_bucket_into, unpack_bucket,
)
from repro.core.multicast import (  # noqa: F401
    MulticastGroup, SwitchControlPlane, assign_buckets, multicast_groups,
)
from repro.core.channel import (  # noqa: F401
    CompressedChannel, Delivery, GradientChannel, InProcessChannel,
    PacketizedChannel, StepEvent,
)
from repro.core.shadow import (  # noqa: F401
    ConsolidationTimeout, ShadowCluster, ShadowNode,
)
from repro.core.checkpoint import (  # noqa: F401
    CheckmateCheckpointer, SyncCheckpointer, AsyncCheckpointer,
    ShardedAsyncCheckpointer, GeminiLikeCheckpointer, CheckFreqCheckpointer,
    NoCheckpointer,
)
from repro.core import costmodel  # noqa: F401
