from repro.core.tagging import (  # noqa: F401
    chunk_at, is_tagged, tag_schedule, tagged_chunks_per_rank, TagEvent,
)
from repro.core.buckets import (  # noqa: F401
    Bucket, BucketLayout, build_buckets, pack_bucket, unpack_bucket,
)
from repro.core.multicast import (  # noqa: F401
    MulticastGroup, SwitchControlPlane, assign_buckets,
)
from repro.core.shadow import ShadowCluster, ShadowNode  # noqa: F401
from repro.core.checkpoint import (  # noqa: F401
    CaptureGatedCheckmateCheckpointer,
    CheckmateCheckpointer, SyncCheckpointer, AsyncCheckpointer,
    ShardedAsyncCheckpointer, GeminiLikeCheckpointer, CheckFreqCheckpointer,
    NoCheckpointer,
)
from repro.core import costmodel  # noqa: F401
