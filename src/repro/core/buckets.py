"""DDP-style gradient bucketing (paper §4.2.2).

Frameworks bin-pack gradients into fixed-size buckets starting from the LAST
model layer and working backwards (the backward pass produces gradients in
that order, so buckets become ready for communication early). A leaf larger
than the cap gets a dedicated bucket. Shadow nodes keep the *same* mapping so
each model layer points at an offset inside a received bucket without extra
copies.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable

import numpy as np

DEFAULT_BUCKET_BYTES = 25 * 1024 * 1024       # PyTorch DDP default


@dataclass(frozen=True)
class LeafSlot:
    name: str
    offset: int          # element offset inside the bucket
    size: int            # element count
    shape: tuple
    dtype: str


@dataclass(frozen=True)
class Bucket:
    bucket_id: int
    slots: tuple[LeafSlot, ...]
    size: int            # total element count

    @property
    def nbytes(self) -> int:
        return sum(s.size * np.dtype(s.dtype).itemsize for s in self.slots)


@dataclass(frozen=True)
class BucketLayout:
    buckets: tuple[Bucket, ...]

    @property
    def total_bytes(self) -> int:
        return sum(b.nbytes for b in self.buckets)

    def leaf_index(self) -> dict[str, tuple[int, LeafSlot]]:
        out = {}
        for b in self.buckets:
            for s in b.slots:
                out[s.name] = (b.bucket_id, s)
        return out


def build_buckets(named_leaves: Iterable[tuple[str, tuple, str]],
                  cap_bytes: int = DEFAULT_BUCKET_BYTES,
                  reverse: bool = True) -> BucketLayout:
    """named_leaves: iterable of (name, shape, dtype) in model order."""
    leaves = list(named_leaves)
    if reverse:
        leaves = leaves[::-1]
    buckets: list[Bucket] = []
    cur: list[LeafSlot] = []
    cur_elems = 0
    cur_bytes = 0

    def flush():
        nonlocal cur, cur_elems, cur_bytes
        if cur:
            buckets.append(Bucket(len(buckets), tuple(cur), cur_elems))
            cur, cur_elems, cur_bytes = [], 0, 0

    for name, shape, dtype in leaves:
        size = int(np.prod(shape)) if shape else 1
        nbytes = size * np.dtype(dtype).itemsize
        if nbytes >= cap_bytes:                  # dedicated bucket
            flush()
            buckets.append(Bucket(
                len(buckets),
                (LeafSlot(name, 0, size, tuple(shape), dtype),), size))
            continue
        if cur_bytes + nbytes > cap_bytes:
            flush()
        cur.append(LeafSlot(name, cur_elems, size, tuple(shape), dtype))
        cur_elems += size
        cur_bytes += nbytes
    flush()
    return BucketLayout(tuple(buckets))


def layout_for_tree(tree: dict, cap_bytes: int = DEFAULT_BUCKET_BYTES
                    ) -> BucketLayout:
    return build_buckets(
        [(k, tuple(v.shape), str(v.dtype)) for k, v in tree.items()],
        cap_bytes=cap_bytes)


def pack_bucket(bucket: Bucket, tree: dict, xp=np):
    """Flatten the bucket's leaves into one contiguous array."""
    parts = [xp.ravel(xp.asarray(tree[s.name])) for s in bucket.slots]
    return xp.concatenate(parts) if len(parts) > 1 else parts[0]


def unpack_bucket(bucket: Bucket, flat, xp=np) -> dict:
    """Inverse of pack_bucket: bucket array -> {leaf name: array}."""
    out = {}
    for s in bucket.slots:
        out[s.name] = xp.reshape(flat[s.offset:s.offset + s.size], s.shape)
    return out


def pack_all(layout: BucketLayout, tree: dict, xp=np) -> dict[int, object]:
    return {b.bucket_id: pack_bucket(b, tree, xp) for b in layout.buckets}


def unpack_all(layout: BucketLayout, flats: dict[int, object], xp=np) -> dict:
    out = {}
    for b in layout.buckets:
        out.update(unpack_bucket(b, flats[b.bucket_id], xp))
    return out
