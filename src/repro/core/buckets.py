"""DDP-style gradient bucketing (paper §4.2.2).

Frameworks bin-pack gradients into fixed-size buckets starting from the LAST
model layer and working backwards (the backward pass produces gradients in
that order, so buckets become ready for communication early). A leaf larger
than the cap gets a dedicated bucket. Shadow nodes keep the *same* mapping so
each model layer points at an offset inside a received bucket without extra
copies.
"""
from __future__ import annotations

from collections.abc import Mapping
from dataclasses import dataclass, field
from typing import Iterable

import numpy as np

DEFAULT_BUCKET_BYTES = 25 * 1024 * 1024       # PyTorch DDP default


@dataclass(frozen=True)
class LeafSlot:
    name: str
    offset: int          # element offset inside the bucket
    size: int            # element count
    shape: tuple
    dtype: str


@dataclass(frozen=True)
class Bucket:
    bucket_id: int
    slots: tuple[LeafSlot, ...]
    size: int            # total element count

    @property
    def nbytes(self) -> int:
        return sum(s.size * np.dtype(s.dtype).itemsize for s in self.slots)


@dataclass(frozen=True)
class BucketLayout:
    buckets: tuple[Bucket, ...]

    @property
    def total_bytes(self) -> int:
        return sum(b.nbytes for b in self.buckets)

    def leaf_index(self) -> dict[str, tuple[int, LeafSlot]]:
        out = {}
        for b in self.buckets:
            for s in b.slots:
                out[s.name] = (b.bucket_id, s)
        return out


def build_buckets(named_leaves: Iterable[tuple[str, tuple, str]],
                  cap_bytes: int = DEFAULT_BUCKET_BYTES,
                  reverse: bool = True) -> BucketLayout:
    """named_leaves: iterable of (name, shape, dtype) in model order.

    Buckets are per-dtype (like DDP's bucketer): mixing dtypes in one
    contiguous wire buffer would silently promote the narrower leaves
    (``pack_bucket`` concatenates), changing the bytes on the wire and the
    per-step rounding the shadow replays.
    """
    leaves = list(named_leaves)
    if reverse:
        leaves = leaves[::-1]
    buckets: list[Bucket] = []
    cur: list[LeafSlot] = []
    cur_elems = 0
    cur_bytes = 0
    cur_dtype: str | None = None

    def flush():
        nonlocal cur, cur_elems, cur_bytes, cur_dtype
        if cur:
            buckets.append(Bucket(len(buckets), tuple(cur), cur_elems))
            cur, cur_elems, cur_bytes, cur_dtype = [], 0, 0, None

    for name, shape, dtype in leaves:
        size = int(np.prod(shape)) if shape else 1
        nbytes = size * np.dtype(dtype).itemsize
        if nbytes >= cap_bytes:                  # dedicated bucket
            flush()
            buckets.append(Bucket(
                len(buckets),
                (LeafSlot(name, 0, size, tuple(shape), dtype),), size))
            continue
        if cur_bytes + nbytes > cap_bytes or (cur_dtype is not None
                                              and dtype != cur_dtype):
            flush()
        cur.append(LeafSlot(name, cur_elems, size, tuple(shape), dtype))
        cur_elems += size
        cur_bytes += nbytes
        cur_dtype = dtype
    flush()
    return BucketLayout(tuple(buckets))


def layout_for_tree(tree: dict, cap_bytes: int = DEFAULT_BUCKET_BYTES
                    ) -> BucketLayout:
    return build_buckets(
        [(k, tuple(v.shape), str(v.dtype)) for k, v in tree.items()],
        cap_bytes=cap_bytes)


def pack_bucket(bucket: Bucket, tree: dict, xp=np):
    """Flatten the bucket's leaves into one contiguous array."""
    parts = [xp.ravel(xp.asarray(tree[s.name])) for s in bucket.slots]
    return xp.concatenate(parts) if len(parts) > 1 else parts[0]


def unpack_bucket(bucket: Bucket, flat, xp=np) -> dict:
    """Inverse of pack_bucket: bucket array -> {leaf name: array}."""
    out = {}
    for s in bucket.slots:
        out[s.name] = xp.reshape(flat[s.offset:s.offset + s.size], s.shape)
    return out


def pack_all(layout: BucketLayout, tree: dict, xp=np) -> dict[int, object]:
    return {b.bucket_id: pack_bucket(b, tree, xp) for b in layout.buckets}


def unpack_all(layout: BucketLayout, flats: dict[int, object], xp=np) -> dict:
    out = {}
    for b in layout.buckets:
        out.update(unpack_bucket(b, flats[b.bucket_id], xp))
    return out


# -- flat wire layout as the native state format ------------------------------

XLA_ALIGN = 64      # bytes; XLA CPU adopts >=64-byte-aligned host buffers
                    # zero-copy (jnp.asarray/device_put without a memcpy)


def alloc_flat(size: int, dtype) -> np.ndarray:
    """Allocate a flat buffer aligned so jax adopts it WITHOUT copying.

    numpy's default allocation is only 16-byte aligned; XLA's CPU client
    requires 64 to alias a host buffer. Delivering gradients in aligned
    flat buffers is what makes the shadow's fused apply a true single pass
    — the device "transfer" of the gradient bucket is free.
    """
    dtype = np.dtype(dtype)
    raw = np.empty(size * dtype.itemsize + XLA_ALIGN, np.uint8)
    ofs = (-raw.ctypes.data) % XLA_ALIGN
    return raw[ofs:ofs + size * dtype.itemsize].view(dtype)


def wire_spans(layout: BucketLayout, dtypes: tuple | None = None
               ) -> tuple[list[tuple[int, int, int]], int]:
    """Byte spans of each bucket inside the packed wire buffer.

    Returns ``([(bucket_id, start, nbytes), ...], padded_total)`` where each
    bucket starts on an ``XLA_ALIGN`` boundary (the geometry the packetized
    channel puts on the wire). ``dtypes`` overrides the per-bucket dtype
    (the compressed channel narrows buckets without rebuilding the layout).
    """
    if dtypes is None:
        dtypes = tuple(bucket_dtype(b) for b in layout.buckets)
    spans, cum = [], 0
    for b, dt in zip(layout.buckets, dtypes):
        nbytes = b.size * np.dtype(dt).itemsize
        spans.append((b.bucket_id, cum, nbytes))
        cum += nbytes
        cum = -(-cum // XLA_ALIGN) * XLA_ALIGN
    return spans, cum


def bucket_dtype(bucket: Bucket) -> np.dtype:
    """The dtype of the bucket's contiguous wire buffer.

    `build_buckets` never mixes dtypes in a bucket (a shared buffer would
    silently promote the narrower leaves); a hand-built mixed bucket is a
    layout bug, so fail loudly rather than promote.
    """
    dtypes = {s.dtype for s in bucket.slots}
    assert len(dtypes) == 1, \
        f"bucket {bucket.bucket_id} mixes dtypes {sorted(dtypes)}"
    return np.dtype(next(iter(dtypes)))


def pack_bucket_into(bucket: Bucket, tree: Mapping, out: np.ndarray
                     ) -> np.ndarray:
    """One-pass pack: write the bucket's leaves straight into ``out``
    (a preallocated flat buffer of ``bucket.size`` elements) with no
    intermediate concatenate. Returns ``out``."""
    for s in bucket.slots:
        out[s.offset:s.offset + s.size] = np.ravel(
            np.asarray(tree[s.name]), order="C")
    return out


def pack_all_into(layout: BucketLayout, tree: Mapping,
                  out: dict[int, np.ndarray]) -> dict[int, np.ndarray]:
    """Pack a whole tree into preallocated per-bucket flat buffers."""
    for b in layout.buckets:
        pack_bucket_into(b, tree, out[b.bucket_id])
    return out


def alloc_flats(layout: BucketLayout, dtype=None) -> dict[int, np.ndarray]:
    """Allocate (aligned) per-bucket flat buffers in the wire layout."""
    return {b.bucket_id: alloc_flat(b.size, bucket_dtype(b) if dtype is None
                                    else dtype)
            for b in layout.buckets}


class FlatTreeView(Mapping):
    """Lazy zero-copy leaf-dict view over per-bucket flat wire buffers.

    ``view[name]`` is a numpy *view* (``reshape`` of a contiguous slice)
    into the underlying bucket buffer — no element is copied; mutating the
    flat buffer is visible through the view and vice versa. This is what
    keeps ``Delivery.grads`` backward compatible while the flat buffers
    stay the one true payload (one HBM pass per state element).
    """

    __slots__ = ("_layout", "_flats", "_index", "_cache")

    def __init__(self, layout: BucketLayout, flats: dict[int, object]):
        self._layout = layout
        self._flats = flats
        self._index = None           # leaf name -> (bucket_id, LeafSlot)
        self._cache: dict[str, object] = {}

    def _resolve(self, name: str):
        if self._index is None:
            self._index = {s.name: (b.bucket_id, s)
                           for b in self._layout.buckets
                           if b.bucket_id in self._flats for s in b.slots}
        return self._index[name]

    def __getitem__(self, name: str):
        try:
            return self._cache[name]
        except KeyError:
            pass
        bid, s = self._resolve(name)
        flat = self._flats[bid]
        view = flat[s.offset:s.offset + s.size].reshape(s.shape)
        self._cache[name] = view
        return view

    def __iter__(self):
        for b in self._layout.buckets:
            if b.bucket_id in self._flats:
                for s in b.slots:
                    yield s.name

    def __len__(self):
        return sum(len(b.slots) for b in self._layout.buckets
                   if b.bucket_id in self._flats)

    def __repr__(self):
        return (f"FlatTreeView({len(self)} leaves over "
                f"{len(self._flats)} buckets)")
