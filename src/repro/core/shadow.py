"""Shadow cluster (paper §4.2): CPU replicas that turn captured gradients
into per-iteration checkpoints.

Each shadow node owns a byte-balanced partition of the gradient buckets
(§4.2.4) and holds params + optimizer moments for exactly the leaves in its
buckets. On every iteration it receives that iteration's reduced-gradient
buckets and applies the same functional optimizer step the training nodes
apply — no forward/backward (paper Listing 2):

    while True:
        buckets.recv()
        optimizer.step()

Async mode runs one worker thread per node (the paper's timeliness
requirement §6.3: shadow must finish before training starts the next
optimizer step); queue depth and per-apply wall time are tracked so the
timeliness condition is observable.
"""
from __future__ import annotations

import queue
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.buckets import BucketLayout, pack_bucket, unpack_bucket
from repro.core.channel import Delivery, InProcessChannel, StepEvent
from repro.core.multicast import assign_buckets
from repro.optim.functional import OptimizerConfig, UPDATE_FNS


class ConsolidationTimeout(RuntimeError):
    """Consolidation hit its deadline with shadow nodes still applying.

    Carries the lagging node ids and a partial checkpoint. Each node's
    partition is snapshotted apply-atomically (never torn between params
    and moments), but lagging partitions are at older steps than the rest:
    ``partial["step"]`` is the min across nodes, and the tree as a whole is
    only globally consistent once every node has reached that step — use
    the partial for diagnosis, retry consolidation for recovery."""

    def __init__(self, lagging_nodes: list[int], partial: dict):
        super().__init__(
            f"shadow consolidation timed out; lagging nodes: "
            f"{lagging_nodes} (partial checkpoint at step "
            f"{partial.get('step')})")
        self.lagging_nodes = lagging_nodes
        self.partial = partial


class ShadowNode:
    """One CPU shadow node: partition state + functional optimizer."""

    def __init__(self, node_id: int, opt: OptimizerConfig,
                 layout: BucketLayout, bucket_ids: list[int]):
        self.node_id = node_id
        self.opt = opt
        self.layout = layout
        self.bucket_ids = sorted(bucket_ids)
        # hot path: resolved once here, not per apply (§6.3 timeliness)
        self._by_id = {b.bucket_id: b for b in layout.buckets}
        ids = set(bucket_ids)
        self._leaves = [s.name for b in layout.buckets
                        if b.bucket_id in ids for s in b.slots]
        self.params: dict[str, jnp.ndarray] = {}
        self.mu: dict[str, jnp.ndarray] = {}
        self.nu: dict[str, jnp.ndarray] = {}
        self.step = 0
        self.apply_times: list[float] = []
        # guards the params/mu/nu/step install so a consolidation snapshot
        # never sees a torn partition (params at t+1, moments at t)
        self.state_lock = threading.Lock()
        self._update = jax.jit(self._update_fn)

    # -- state ---------------------------------------------------------------
    def bootstrap(self, params, mu, nu, step: int):
        for name in self._leaves:
            self.params[name] = jnp.asarray(params[name])
            self.mu[name] = jnp.asarray(mu[name])
            self.nu[name] = jnp.asarray(nu[name])
        self.step = int(step)

    # -- update --------------------------------------------------------------
    def _update_fn(self, params, mu, nu, grads, step, lr, scale):
        fn = UPDATE_FNS[self.opt.name]
        out_p, out_m, out_v = {}, {}, {}
        for name, g in grads.items():
            p, m, v = (fn(params[name], g * scale, mu[name], nu[name],
                          step, self.opt, lr))
            out_p[name], out_m[name], out_v[name] = p, m, v
        return out_p, out_m, out_v

    def apply(self, step: int, lr: float, flats: dict[int, np.ndarray],
              grad_scale: float = 1.0):
        """Apply one iteration's bucket gradients for this node's partition."""
        t0 = time.perf_counter()
        grads = {}
        for bid in self.bucket_ids:
            bucket = self._by_id[bid]
            grads.update(unpack_bucket(bucket, jnp.asarray(flats[bid]), xp=jnp))
        grads = {k: v for k, v in grads.items() if k in self.params}
        p, m, v = self._update(self.params, self.mu, self.nu, grads,
                               jnp.float32(step), jnp.float32(lr),
                               jnp.float32(grad_scale))
        with self.state_lock:
            self.params.update(p)
            self.mu.update(m)
            self.nu.update(v)
            self.step = step
        self.apply_times.append(time.perf_counter() - t0)


@dataclass
class ShadowStats:
    steps_applied: int
    lag: int                       # training step - shadow step
    max_queue_depth: int
    mean_apply_s: float
    max_apply_s: float
    per_node_apply_s: list[float]


class ShadowCluster:
    """Checkmate's shadow plane: N nodes x partitioned functional optimizer."""

    def __init__(self, layout: BucketLayout, opt: OptimizerConfig,
                 n_nodes: int = 1, async_mode: bool = False):
        self.layout = layout
        self.opt = opt
        self.n_nodes = n_nodes
        self.assignment = assign_buckets(layout, n_nodes)
        self.nodes = [
            ShadowNode(i, opt, layout,
                       [b for b, n in self.assignment.items() if n == i])
            for i in range(n_nodes)
        ]
        self.async_mode = async_mode
        self.train_step_seen = 0
        self.max_queue_depth = 0
        self._queues: list[queue.Queue] = []
        self._workers: list[threading.Thread] = []
        if async_mode:
            self._start_workers()

    # -- async plumbing --------------------------------------------------------
    def _start_workers(self):
        for node in self.nodes:
            q: queue.Queue = queue.Queue()
            t = threading.Thread(target=self._worker, args=(node, q),
                                 daemon=True)
            t.start()
            self._queues.append(q)
            self._workers.append(t)

    def _worker(self, node: ShadowNode, q: queue.Queue):
        by_id = node._by_id
        while True:
            item = q.get()
            if item is None:
                q.task_done()
                return
            step, lr, scale, grads = item
            # bucket packing happens HERE, on the shadow node — the caller
            # only enqueued a reference (the paper's zero-copy hand-off)
            flats = {bid: pack_bucket(by_id[bid], grads, xp=np)
                     for bid in node.bucket_ids}
            node.apply(step, lr, flats, scale)
            q.task_done()

    # -- API -------------------------------------------------------------------
    def bootstrap(self, params, mu, nu, step: int = 0):
        """Install the initial replica (paper: shadow starts from a copy)."""
        params = {k: np.asarray(v) for k, v in params.items()}
        mu = {k: np.asarray(v) for k, v in mu.items()}
        nu = {k: np.asarray(v) for k, v in nu.items()}
        for node in self.nodes:
            node.bootstrap(params, mu, nu, step)
        self.train_step_seen = int(step)

    def on_delivery(self, delivery: Delivery):
        """Consume one channel delivery (the ONLY gradient ingress).

        Gated deliveries (``complete=False``) must be filtered by the
        caller — the shadow refuses a partial apply.
        """
        if not delivery.complete:
            raise ValueError(
                f"refusing gated delivery for step {delivery.step}: "
                f"capture incomplete ({delivery.missing_captures} missing)")
        self._ingest(delivery.step, delivery.lr, delivery.grads,
                     delivery.grad_scale)

    def on_gradients(self, step: int, lr: float, grads: dict,
                     grad_scale: float = 1.0):
        """Deprecated direct hand-off; route gradients through a
        `repro.core.channel.GradientChannel` and `on_delivery` instead."""
        warnings.warn(
            "ShadowCluster.on_gradients is deprecated; deliver gradients "
            "through a repro.core.channel.GradientChannel and call "
            "ShadowCluster.on_delivery",
            DeprecationWarning, stacklevel=2)
        self._ingest(step, lr, grads, grad_scale)

    def _ingest(self, step: int, lr: float, grads: dict,
                grad_scale: float = 1.0):
        """Apply one iteration's reduced gradients to every node.

        Async mode enqueues a REFERENCE only — packing and the optimizer
        replay run on the shadow workers, off the training critical path.
        """
        self.train_step_seen = step
        if self.async_mode:
            for node, q in zip(self.nodes, self._queues):
                q.put((step, lr, grad_scale, grads))
                self.max_queue_depth = max(self.max_queue_depth, q.qsize())
        else:
            flats = {b.bucket_id: pack_bucket(b, grads, xp=np)
                     for b in self.layout.buckets}
            for node in self.nodes:
                sub = {bid: flats[bid] for bid in node.bucket_ids}
                node.apply(step, lr, sub, grad_scale)

    @staticmethod
    def _pending(q: queue.Queue) -> int:
        with q.mutex:
            return q.unfinished_tasks

    def consolidate(self, timeout: Optional[float] = None) -> dict:
        """Assemble a complete checkpoint for recovery (§4.2.4).

        Waits up to ``timeout`` seconds (default 60) for in-flight updates
        — end to end, including the apply currently executing, so a wedged
        worker cannot hang recovery — then merges node partitions into full
        params/mu/nu trees. Raises `ConsolidationTimeout` (carrying the
        lagging node ids and the partial checkpoint) if any node is still
        behind at the deadline.
        """
        if self.async_mode:
            deadline = time.time() + (60.0 if timeout is None else timeout)
            while (any(self._pending(q) for q in self._queues)
                   and time.time() < deadline):
                time.sleep(0.001)
            lagging = [i for i, q in enumerate(self._queues)
                       if self._pending(q)]
            if lagging:
                raise ConsolidationTimeout(lagging, self._merge())
        return self._merge()

    def _merge(self) -> dict:
        params: dict = {}
        mu: dict = {}
        nu: dict = {}
        steps = []
        for node in self.nodes:
            with node.state_lock:    # apply-atomic per-partition snapshot
                params.update(node.params)
                mu.update(node.mu)
                nu.update(node.nu)
                steps.append(node.step)
        return {"params": params, "mu": mu, "nu": nu,
                "step": min(steps, default=0)}

    def stats(self) -> ShadowStats:
        times = [t for n in self.nodes for t in n.apply_times]
        per_node = [float(np.mean(n.apply_times)) if n.apply_times else 0.0
                    for n in self.nodes]
        return ShadowStats(
            steps_applied=min((n.step for n in self.nodes), default=0),
            lag=self.train_step_seen - min((n.step for n in self.nodes),
                                           default=0),
            max_queue_depth=self.max_queue_depth,
            mean_apply_s=float(np.mean(times)) if times else 0.0,
            max_apply_s=float(np.max(times)) if times else 0.0,
            per_node_apply_s=per_node)

    def shutdown(self):
        if self.async_mode:
            for q in self._queues:
                q.put(None)
            for t in self._workers:
                t.join(timeout=5)


def plan_shadow_nodes(layout: BucketLayout, opt: OptimizerConfig,
                      iter_time_s: float, trial_tree: dict,
                      max_nodes: int = 16) -> tuple[int, float]:
    """Paper §4.2.4: 'Before starting training, Checkmate profiles shadow
    nodes and configures the system for optimal performance.'

    Measures one full-tree optimizer apply on this host and returns the
    minimum node count whose per-node apply time fits inside an iteration,
    plus the measured single-node apply time.
    """
    cluster = ShadowCluster(layout, opt, n_nodes=1)
    zeros = {k: np.zeros(v.shape, np.float32) for k, v in trial_tree.items()}
    cluster.bootstrap(zeros, zeros, zeros, 0)
    grads = {k: np.ones(v.shape, np.float32) for k, v in trial_tree.items()}
    chan = InProcessChannel()
    chan.open(layout)

    def deliver(step):
        chan.send(StepEvent(step=step, grads=grads, lr=1e-3))
        for d in chan.poll():
            cluster.on_delivery(d)

    deliver(1)                                # warmup/compile
    t0 = time.perf_counter()
    deliver(2)
    t1 = time.perf_counter() - t0
    need = max(1, int(np.ceil(t1 / max(iter_time_s, 1e-9))))
    return min(need, max_nodes), t1
