"""Shadow cluster (paper §4.2): CPU replicas that turn captured gradients
into per-iteration checkpoints.

Each shadow node owns a byte-balanced partition of the gradient buckets
(§4.2.4) and holds params + optimizer moments for exactly the leaves in its
buckets. On every iteration it receives that iteration's reduced-gradient
buckets and applies the same functional optimizer step the training nodes
apply — no forward/backward (paper Listing 2):

    while True:
        buckets.recv()
        optimizer.step()

The bucket *wire layout* is the node's native state format: params/mu/nu
live as per-bucket contiguous flat buffers in exactly the layout deliveries
arrive in (`repro.core.buckets`), so an apply is ONE fused optimizer pass
per bucket — `repro.kernels.ops.fused_adamw_flat` for AdamW,
`repro.optim.functional.UPDATE_FNS_FLAT` for the rest — with no per-leaf
dispatch, no dict churn, and no retrace when leaf sets vary (the paper's §5
streaming-apply story: touch each state element exactly once per
iteration). Leaf trees only exist at the cold boundaries: ``bootstrap``
packs them in, ``consolidate`` unpacks them out. ``flat=False`` keeps the
legacy per-leaf path as a regression oracle
(tests/test_flat_shadow.py, benchmarks/shadow_timing.py).

Async mode runs one worker thread per node (the paper's timeliness
requirement §6.3: shadow must finish before training starts the next
optimizer step); queue depth and per-apply wall time are tracked so the
timeliness condition is observable.

Two overlap mechanisms keep a slow applier off the critical path (GoCkpt,
PAPERS.md): the flat apply *double-buffers* deliveries — bucket i+1's
host->device transfer is staged while bucket i's fused update runs — and a
falling-behind async shadow may run with a bounded multi-step lag
(``max_lag_steps``): the worker drains up to K pending deliveries per
wakeup and replays them as K sequential fused updates on the
already-resident flats (bit-identical to K separate applies by
construction — the acceptance bar, see tests/test_flat_shadow.py), while
the trainer blocks only when the backlog would exceed the bound; that wait
is surfaced as the ``apply-lag`` stall stage (obs/stalls.py).
"""
from __future__ import annotations

import queue
import threading
import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs as _obs
from repro.core.buckets import (BucketLayout, alloc_flat, bucket_dtype,
                                pack_bucket, pack_bucket_into, unpack_bucket)
from repro.core.channel import Delivery, InProcessChannel, StepEvent
from repro.core.multicast import assign_buckets
from repro.optim.functional import (OptimizerConfig, UPDATE_FNS,
                                    UPDATE_FNS_FLAT)

APPLY_TIMES_MAXLEN = 512       # recent-apply window kept per node


class ConsolidationTimeout(RuntimeError):
    """Consolidation hit its deadline with shadow nodes still applying.

    Carries the lagging node ids and a partial checkpoint. Each node's
    partition is snapshotted apply-atomically (never torn between params
    and moments), but lagging partitions are at older steps than the rest:
    ``partial["step"]`` is the min across nodes, and the tree as a whole is
    only globally consistent once every node has reached that step — use
    the partial for diagnosis, retry consolidation for recovery."""

    def __init__(self, lagging_nodes: list[int], partial: dict,
                 lagging_buckets: Optional[dict] = None):
        msg = (f"shadow consolidation timed out; lagging nodes: "
               f"{lagging_nodes} (partial checkpoint at step "
               f"{partial.get('step')})")
        if lagging_buckets:
            msg += f"; lagging buckets: {lagging_buckets}"
        super().__init__(msg)
        self.lagging_nodes = lagging_nodes
        self.partial = partial
        # per-node lagging-bucket report: node id -> its owned bucket ids
        self.lagging_buckets = dict(lagging_buckets or {})


class ShadowNodeLoss(RuntimeError):
    """Consolidation found dead shadow nodes: their partitions are gone.

    Unlike :class:`ConsolidationTimeout` (transient — retry), a dead node's
    buckets cannot be gathered until a resync re-seeds a replacement.
    ``missing_buckets`` reports EXACTLY the dead nodes' bucket ids;
    ``partial`` is the surviving nodes' assembled fragments (each
    apply-atomic, at the survivors' current step).

    ``total`` distinguishes losing the ENTIRE plane (partial is empty —
    there is nothing to merge, only the durability tiers can help) from
    partial loss (survivors + durable shards compose). ``durable_hint``
    is ``(tier name, step)`` of the newest full restore point when a
    `repro.durability.DurableShadow` is attached — the message names it
    as the actionable recovery path."""

    def __init__(self, dead_nodes: list[int], missing_buckets: dict,
                 partial: dict, total: bool = False,
                 durable_hint: Optional[tuple] = None):
        msg = (f"shadow node(s) {dead_nodes} lost; missing buckets: "
               f"{missing_buckets} (partial checkpoint at step "
               f"{partial.get('step')})")
        if total:
            msg = (f"TOTAL shadow-plane loss: all {len(dead_nodes)} "
                   f"node(s) {dead_nodes} dead, every bucket missing")
            if durable_hint is not None:
                tname, tstep = durable_hint
                msg += (f"; recover via restore_from_tiers() — newest "
                        f"durable tier '{tname}' holds step {tstep}")
            else:
                msg += ("; no durability tier attached: the checkpoint "
                        "is unrecoverable")
        elif durable_hint is not None:
            tname, tstep = durable_hint
            msg += (f"; tier '{tname}' holds the missing shards durably "
                    f"up to step {tstep}")
        super().__init__(msg)
        self.dead_nodes = list(dead_nodes)
        self.missing_buckets = dict(missing_buckets)
        self.partial = partial
        self.total = bool(total)
        self.durable_hint = durable_hint


class ShadowNode:
    """One CPU shadow node: partition state + functional optimizer.

    ``flat=True`` (default) stores the partition as per-bucket flat
    buffers and applies deliveries with one fused pass per bucket;
    ``flat=False`` is the legacy per-leaf path (regression oracle).
    """

    def __init__(self, node_id: int, opt: OptimizerConfig,
                 layout: BucketLayout, bucket_ids: list[int],
                 flat: bool = True,
                 apply_times_maxlen: int = APPLY_TIMES_MAXLEN):
        self.node_id = node_id
        self.opt = opt
        self.layout = layout
        self.flat = flat
        self.bucket_ids = sorted(bucket_ids)
        # hot path: resolved once here, not per apply (§6.3 timeliness)
        self._by_id = {b.bucket_id: b for b in layout.buckets}
        ids = set(bucket_ids)
        self._leaves = [s.name for b in layout.buckets
                        if b.bucket_id in ids for s in b.slots]
        # legacy per-leaf state (flat=False)
        self.params: dict[str, jnp.ndarray] = {}
        self.mu: dict[str, jnp.ndarray] = {}
        self.nu: dict[str, jnp.ndarray] = {}
        # flat wire-layout state (flat=True): bucket_id -> flat buffer
        self._pf: dict[int, jnp.ndarray] = {}
        self._mf: dict[int, jnp.ndarray] = {}
        self._vf: dict[int, jnp.ndarray] = {}
        # bucket ids mutated since the last durability flush drained them;
        # maintained under state_lock (repro.durability.FlushWorker)
        self.dirty: set[int] = set()
        self.step = 0
        # bounded recent-apply window + exact running counters (long runs
        # must not grow memory; stats() stays exact via the counters)
        self.apply_times: deque = deque(maxlen=apply_times_maxlen)
        self.apply_count = 0
        self.apply_total_s = 0.0
        self.apply_max_s = 0.0
        # guards the params/mu/nu/step install so a consolidation snapshot
        # never sees a torn partition (params at t+1, moments at t)
        self.state_lock = threading.Lock()
        # Flat updates DONATE p/m/v: the state buffers are updated in place
        # (XLA reuses the donated pages), which matters on the shadow host —
        # the apply is pure memory bandwidth (§5), and re-allocating 3
        # model-sized buffers per step roughly doubles the write traffic.
        # Safe because apply() holds state_lock across the call, so no
        # snapshot can observe a donated (invalidated) buffer.
        if flat:
            if opt.name == "adamw":
                from repro.kernels import ops as _ops
                cfg = opt

                def _adamw(p, g, m, v, step, lr, scale):
                    return _ops.fused_adamw_flat(
                        p, g, m, v, step, lr, scale, b1=cfg.b1, b2=cfg.b2,
                        eps=cfg.eps, wd=cfg.weight_decay)
                self._update_flat = jax.jit(_adamw,
                                            donate_argnums=(0, 2, 3))
            else:
                fn = UPDATE_FNS_FLAT[opt.name]
                self._update_flat = jax.jit(
                    lambda p, g, m, v, step, lr, scale:
                    fn(p, g, m, v, step, self.opt, lr, scale),
                    donate_argnums=(0, 2, 3))
        else:
            self._update = jax.jit(self._update_fn)

    # -- state ---------------------------------------------------------------
    def bootstrap(self, params, mu, nu, step: int):
        """Install the replica (cold path: leaf trees -> flat partitions)."""
        if self.flat:
            pf, mf, vf = {}, {}, {}
            for bid in self.bucket_ids:
                b = self._by_id[bid]
                pf[bid] = jnp.asarray(pack_bucket_into(
                    b, params, alloc_flat(b.size, bucket_dtype(b))))
                mf[bid] = jnp.asarray(pack_bucket_into(
                    b, mu, alloc_flat(b.size, np.float32)))
                vf[bid] = jnp.asarray(pack_bucket_into(
                    b, nu, alloc_flat(b.size, np.float32)))
            with self.state_lock:
                self._pf, self._mf, self._vf = pf, mf, vf
                self.dirty = set(self.bucket_ids)
                self.step = int(step)
            return
        for name in self._leaves:
            self.params[name] = jnp.asarray(params[name])
            self.mu[name] = jnp.asarray(mu[name])
            self.nu[name] = jnp.asarray(nu[name])
        self.step = int(step)

    def snapshot(self) -> tuple[dict, dict, dict, int]:
        """Apply-atomic (params, mu, nu, step) leaf trees for this
        partition — the cold flat -> leaf boundary used by consolidate."""
        with self.state_lock:
            if not self.flat:
                return dict(self.params), dict(self.mu), dict(self.nu), \
                    self.step
            pf = {bid: np.asarray(a) for bid, a in self._pf.items()}
            mf = {bid: np.asarray(a) for bid, a in self._mf.items()}
            vf = {bid: np.asarray(a) for bid, a in self._vf.items()}
            step = self.step
        params, mu, nu = {}, {}, {}
        for bid in self.bucket_ids:
            b = self._by_id[bid]
            params.update(unpack_bucket(b, pf[bid], xp=np))
            mu.update(unpack_bucket(b, mf[bid], xp=np))
            nu.update(unpack_bucket(b, vf[bid], xp=np))
        return params, mu, nu, step

    def snapshot_dirty(self, force_all: bool = False
                       ) -> tuple[dict, int]:
        """Apply-atomic copy of the dirty bucket flats; drains ``dirty``.

        Returns ``({bucket_id: (p, m, v) np copies}, step)`` in wire
        layout — the durability flush payload, no repacking. The copy
        runs under ``state_lock`` because the fused apply DONATES the
        flat buffers; outside the lock a snapshot could read invalidated
        pages. ``force_all`` snapshots every owned bucket (a base
        record) regardless of dirtiness.
        """
        assert self.flat, "snapshot_dirty requires the flat wire layout"
        with self.state_lock:
            bids = self.bucket_ids if force_all else sorted(self.dirty)
            bids = [b for b in bids if b in self._pf]   # killed: gone
            snap = {bid: (np.array(self._pf[bid]),
                          np.array(self._mf[bid]),
                          np.array(self._vf[bid])) for bid in bids}
            self.dirty.difference_update(bids)
            step = self.step
        return snap, step

    # -- update --------------------------------------------------------------
    def _update_fn(self, params, mu, nu, grads, step, lr, scale):
        fn = UPDATE_FNS[self.opt.name]
        out_p, out_m, out_v = {}, {}, {}
        for name, g in grads.items():
            p, m, v = (fn(params[name], g * scale, mu[name], nu[name],
                          step, self.opt, lr))
            out_p[name], out_m[name], out_v[name] = p, m, v
        return out_p, out_m, out_v

    def _record(self, dt: float):
        self.apply_times.append(dt)
        self.apply_count += 1
        self.apply_total_s += dt
        if dt > self.apply_max_s:
            self.apply_max_s = dt
        _obs.get().metrics.histogram(
            "shadow_apply_seconds",
            "Per-apply wall time by shadow node").observe(
            dt, node=self.node_id)

    def apply(self, step: int, lr: float, flats: dict[int, np.ndarray],
              grad_scale: float = 1.0):
        """Apply one iteration's bucket gradients for this node's partition.

        ``flats`` is the delivery payload in wire layout; only this node's
        ``bucket_ids`` are read. Flat mode runs ONE fused optimizer pass
        per bucket directly on the flat state buffers.
        """
        with _obs.get().tracer.span("shadow.apply",
                                    track=f"shadow{self.node_id}",
                                    args={"step": step,
                                          "node": self.node_id}):
            return self._apply(step, lr, flats, grad_scale)

    def apply_batch(self, items: list[tuple]):
        """Apply K pending deliveries as K *sequential* fused updates on the
        already-resident flats — the bounded-lag catch-up path.

        ``items`` is ``[(step, lr, flats, grad_scale), ...]`` in delivery
        order. Sequential replay (not gradient summing) is deliberate: it is
        bit-identical to K separate :meth:`apply` calls by construction,
        which is the acceptance bar for lagged applies (a summed single
        update would change Adam's moment trajectory). One batched span
        covers the whole drain so catch-up is visible in traces.
        """
        if len(items) == 1:
            step, lr, flats, grad_scale = items[0]
            return self.apply(step, lr, flats, grad_scale)
        with _obs.get().tracer.span("shadow.apply_batch",
                                    track=f"shadow{self.node_id}",
                                    args={"k": len(items),
                                          "from_step": items[0][0],
                                          "to_step": items[-1][0],
                                          "node": self.node_id}):
            for step, lr, flats, grad_scale in items:
                self._apply(step, lr, flats, grad_scale)

    def _apply(self, step, lr, flats, grad_scale):
        t0 = time.perf_counter()
        if self.flat:
            step_f = jnp.float32(step)
            lr_f = jnp.float32(lr)
            scale_f = jnp.float32(grad_scale)
            # the whole update runs under state_lock: inputs are DONATED to
            # the fused kernel, so a concurrent snapshot must never read
            # them mid-apply (it would see invalidated buffers, not a torn
            # tree)
            with self.state_lock:
                ids = self.bucket_ids
                # double-buffered receive: stage bucket i+1's delivery
                # (host->device transfer) before dispatching bucket i's
                # fused update, so the transfer overlaps the async apply;
                # same per-bucket update stream, so bit-identical
                nxt = jnp.asarray(flats[ids[0]]) if ids else None
                for j, bid in enumerate(ids):
                    g, nxt = nxt, (jnp.asarray(flats[ids[j + 1]])
                                   if j + 1 < len(ids) else None)
                    p, m, v = self._update_flat(
                        self._pf[bid], g,
                        self._mf[bid], self._vf[bid], step_f, lr_f, scale_f)
                    self._pf[bid] = p
                    self._mf[bid] = m
                    self._vf[bid] = v
                jax.block_until_ready(self._pf)
                self.dirty.update(self.bucket_ids)
                self.step = step
            self._record(time.perf_counter() - t0)
            return
        grads = {}
        for bid in self.bucket_ids:
            bucket = self._by_id[bid]
            grads.update(unpack_bucket(bucket, jnp.asarray(flats[bid]),
                                       xp=jnp))
        grads = {k: v for k, v in grads.items() if k in self.params}
        p, m, v = self._update(self.params, self.mu, self.nu, grads,
                               jnp.float32(step), jnp.float32(lr),
                               jnp.float32(grad_scale))
        jax.block_until_ready(p)
        with self.state_lock:
            self.params.update(p)
            self.mu.update(m)
            self.nu.update(v)
            self.step = step
        self._record(time.perf_counter() - t0)


@dataclass
class ShadowStats:
    steps_applied: int
    lag: int                       # training step - shadow step
    max_queue_depth: int
    mean_apply_s: float
    max_apply_s: float
    per_node_apply_s: list[float]
    # bounded-lag accounting (max_lag_steps runs; defaults keep the
    # legacy construction sites valid)
    lag_waits: int = 0             # times the trainer blocked on the bound
    lag_wait_s: float = 0.0        # total seconds the trainer waited
    batched_applies: int = 0       # multi-step worker drains (k >= 2)
    max_batch: int = 1             # largest k a single drain replayed


class ShadowCluster:
    """Checkmate's shadow plane: N nodes x partitioned functional optimizer."""

    def __init__(self, layout: BucketLayout, opt: OptimizerConfig,
                 n_nodes: int = 1, async_mode: bool = False,
                 flat: bool = True,
                 apply_times_maxlen: int = APPLY_TIMES_MAXLEN,
                 assignment: Optional[dict] = None,
                 max_lag_steps: Optional[int] = None):
        if max_lag_steps is not None:
            if max_lag_steps < 1:
                raise ValueError(f"max_lag_steps must be >= 1, "
                                 f"got {max_lag_steps}")
            if not async_mode:
                raise ValueError("max_lag_steps bounds the async delivery "
                                 "queue; sync mode never lags")
        self.layout = layout
        self.opt = opt
        self.n_nodes = n_nodes
        self.flat = flat
        # bucket_id -> owner node; the default byte-balanced greedy mapping
        # is the one training nodes, switch, and channel all derive, but a
        # custom assignment may be injected (tests sweep random mappings)
        self.assignment = dict(assignment) if assignment is not None \
            else assign_buckets(layout, n_nodes)
        self.nodes = [
            ShadowNode(i, opt, layout,
                       [b for b, n in self.assignment.items() if n == i],
                       flat=flat, apply_times_maxlen=apply_times_maxlen)
            for i in range(n_nodes)
        ]
        self.async_mode = async_mode
        self.train_step_seen = 0
        self.max_queue_depth = 0
        self.dead_nodes: set[int] = set()
        # bounded multi-step lag (None = legacy unbounded queue): a worker
        # drains up to max_lag_steps pending deliveries per wakeup and the
        # trainer blocks in _ingest while a node's backlog is at the bound
        self.max_lag_steps = max_lag_steps
        self.lag_waits = 0
        self.lag_wait_s_total = 0.0
        self.batched_applies = 0
        self.max_batch = 1
        # optional repro.durability.DurableShadow (set by its attach());
        # duck-typed so core never imports the durability package
        self.durability = None
        self._queues: list[queue.Queue] = []
        self._drained: list[threading.Event] = []
        self._lag_cvs: list[threading.Condition] = []
        self._workers: list[threading.Thread] = []
        if async_mode:
            self._start_workers()

    # -- async plumbing --------------------------------------------------------
    def _start_workers(self):
        for node in self.nodes:
            q: queue.Queue = queue.Queue()
            ev = threading.Event()
            ev.set()                           # empty queue == drained
            t = threading.Thread(target=self._worker, args=(node, q, ev),
                                 daemon=True)
            t.start()
            self._queues.append(q)
            self._drained.append(ev)
            self._lag_cvs.append(threading.Condition())
            self._workers.append(t)

    def _worker(self, node: ShadowNode, q: queue.Queue,
                drained: threading.Event):
        by_id = node._by_id
        # batched drain bound: a bounded-lag shadow catches up by replaying
        # up to K pending deliveries per wakeup; legacy (None) keeps the
        # exact one-item-per-wakeup behavior
        limit = self.max_lag_steps or 1
        while True:
            item = q.get()
            stop = item is None
            batch = [] if stop else [item]
            while not stop and len(batch) < limit:
                try:
                    nxt = q.get_nowait()
                except queue.Empty:
                    break
                if nxt is None:
                    stop = True           # shutdown sentinel: drain then exit
                    break
                batch.append(nxt)
            if batch and node.node_id in self.dead_nodes:
                # killed after these items were enqueued: its state is gone,
                # applying would read a cleared partition
                self._settle(node.node_id, q, drained, len(batch))
                batch = []
            if batch:
                items = []
                for step, lr, scale, grads, flats in batch:
                    if flats is None:
                        # legacy leaf-tree hand-off: bucket packing happens
                        # HERE, on the shadow node — the caller only
                        # enqueued a reference
                        flats = {bid: pack_bucket(by_id[bid], grads, xp=np)
                                 for bid in node.bucket_ids}
                    items.append((step, lr, flats, scale))
                node.apply_batch(items)
                if len(items) > 1:
                    self.batched_applies += 1
                    if len(items) > self.max_batch:
                        self.max_batch = len(items)
                self._settle(node.node_id, q, drained,
                             len(batch) + (1 if stop else 0))
            elif stop:
                self._settle(node.node_id, q, drained, 1)
            if stop:
                drained.set()
                return

    def _settle(self, node_id: int, q: queue.Queue,
                drained: threading.Event, n: int):
        """Mark ``n`` queue items done, refresh the drain signal, and wake a
        trainer blocked on the lag bound (checked under the queue lock)."""
        for _ in range(n):
            q.task_done()
        # drain signal for the event-based consolidate wait: set only
        # when no enqueued work remains
        with q.mutex:
            if q.unfinished_tasks == 0:
                drained.set()
        if self.max_lag_steps is not None:
            cv = self._lag_cvs[node_id]
            with cv:
                cv.notify_all()

    # -- API -------------------------------------------------------------------
    def bootstrap(self, params, mu, nu, step: int = 0):
        """Install the initial replica (paper: shadow starts from a copy).

        Also the node-replacement path: re-seeding revives any nodes
        previously lost to :meth:`kill_node` (the resync that follows a
        shadow-node death hands every node a fresh partition).
        """
        params = {k: np.asarray(v) for k, v in params.items()}
        mu = {k: np.asarray(v) for k, v in mu.items()}
        nu = {k: np.asarray(v) for k, v in nu.items()}
        # a full-state install supersedes any still-queued deliveries: with
        # a lagged backlog, replaying a pre-resync gradient onto the freshly
        # seeded state would regress it (no-op when queues are drained, the
        # normal case)
        for q in self._queues:
            try:
                while True:
                    item = q.get_nowait()
                    if item is None:      # never eat a shutdown sentinel
                        q.put(None)       # (task_done below pairs our get
                    q.task_done()         # with the re-put's increment)
                    if item is None:
                        break
            except queue.Empty:
                pass
            while self._pending(q):       # an in-flight apply (already off
                time.sleep(0.001)         # the queue) finishes on the OLD
            #                               state before the install below
        self.dead_nodes.clear()
        for node in self.nodes:
            node.bootstrap(params, mu, nu, step)
        self.train_step_seen = int(step)
        if self.durability is not None:
            # cold path: force a base flush so a full restore point exists
            # from the moment the replica is (re-)seeded
            self.durability.on_bootstrap(int(step))

    def kill_node(self, node_id: int):
        """Simulated shadow-node death: the node's partition (params + both
        moments) is gone, as lost DRAM is. Pending queued work for the node
        is discarded; a later :meth:`bootstrap` re-seeds a replacement.
        """
        if node_id in self.dead_nodes:
            return
        if not 0 <= node_id < self.n_nodes:
            raise ValueError(f"no shadow node {node_id} "
                             f"(cluster has {self.n_nodes})")
        self.dead_nodes.add(node_id)
        node = self.nodes[node_id]
        if self.async_mode:
            q, ev = self._queues[node_id], self._drained[node_id]
            try:
                while True:
                    q.get_nowait()
                    q.task_done()
            except queue.Empty:
                pass
            with q.mutex:
                if q.unfinished_tasks == 0:
                    ev.set()
            if self.max_lag_steps is not None:
                cv = self._lag_cvs[node_id]
                with cv:          # wake a trainer blocked on the dead node
                    cv.notify_all()
        with node.state_lock:     # an in-flight apply finishes first
            node._pf, node._mf, node._vf = {}, {}, {}
            node.params, node.mu, node.nu = {}, {}, {}
        _obs.get().metrics.counter(
            "shadow_node_deaths_total",
            "Shadow nodes lost (partition dropped)").inc(1, node=node_id)

    def on_delivery(self, delivery: Delivery, nodes: Optional[set] = None):
        """Consume one channel delivery (the ONLY gradient ingress).

        The delivery's ``flats`` (wire layout) feed the fused per-bucket
        apply directly — no unpack, no repack. Gated deliveries
        (``complete=False``) must be filtered by the caller — the shadow
        refuses a partial apply.

        ``nodes`` restricts the apply to a subset of node ids (the sharded
        transport's per-node gating: a delivery may be complete for some
        owners and not others — see ``Delivery.node_complete``). Every
        requested node must be complete; without ``nodes`` the delivery
        must be globally complete.
        """
        if nodes is not None:
            nc = getattr(delivery, "node_complete", None)
            bad = sorted(n for n in nodes
                         if not (delivery.complete if nc is None
                                 else nc.get(n, False)))
            if bad:
                raise ValueError(
                    f"refusing sharded delivery for step {delivery.step}: "
                    f"capture incomplete for nodes {bad}")
        elif not delivery.complete:
            raise ValueError(
                f"refusing gated delivery for step {delivery.step}: "
                f"capture incomplete ({delivery.missing_captures} missing)")
        if delivery.flats is not None:
            self._ingest(delivery.step, delivery.lr, None,
                         delivery.grad_scale, flats=delivery.flats,
                         nodes=nodes)
        else:
            self._ingest(delivery.step, delivery.lr, delivery.grads,
                         delivery.grad_scale, nodes=nodes)

    def on_gradients(self, step: int, lr: float, grads: dict,
                     grad_scale: float = 1.0):
        """Deprecated direct hand-off; route gradients through a
        `repro.core.channel.GradientChannel` and `on_delivery` instead."""
        warnings.warn(
            "ShadowCluster.on_gradients is deprecated; deliver gradients "
            "through a repro.core.channel.GradientChannel and call "
            "ShadowCluster.on_delivery",
            DeprecationWarning, stacklevel=2)
        self._ingest(step, lr, grads, grad_scale)

    def _ingest(self, step: int, lr: float, grads: Optional[dict],
                grad_scale: float = 1.0,
                flats: Optional[dict] = None,
                nodes: Optional[set] = None):
        """Apply one iteration's reduced gradients, each node its partition.

        ``flats`` (the wire-layout delivery payload) is handed to nodes as
        is — zero copies between the channel rx buffer and the fused apply
        — and each node sees ONLY its owned buckets (the sharded transport
        may not even have the others). Async mode enqueues a REFERENCE only
        — any (legacy) packing and the optimizer replay run on the shadow
        workers, off the training critical path.
        """
        self.train_step_seen = step
        targets = [n for n in self.nodes
                   if n.node_id not in self.dead_nodes
                   and (nodes is None or n.node_id in nodes)]
        if self.async_mode:
            for node in targets:
                q = self._queues[node.node_id]
                if self.max_lag_steps is not None:
                    self._lag_gate(node.node_id, q)
                self._drained[node.node_id].clear()
                sub = None if flats is None else \
                    {bid: flats[bid] for bid in node.bucket_ids}
                q.put((step, lr, grad_scale, grads, sub))
                # mutex-based depth (queue.qsize() is racy and unimplemented
                # on some platforms); put() precedes, so depth >= 1 here
                depth = self._pending(q)
                self.max_queue_depth = max(self.max_queue_depth, depth)
                if self.max_lag_steps is not None:
                    _obs.get().metrics.gauge(
                        "shadow_lag_steps",
                        "Shadow applier backlog at ingest (bounded by "
                        "max_lag_steps)").set(depth, node=node.node_id)
            if self.durability is not None:
                self.durability.notify(step)      # queue puts only
            return
        if flats is None:
            need = {bid for node in targets for bid in node.bucket_ids}
            flats = {b.bucket_id: pack_bucket(b, grads, xp=np)
                     for b in self.layout.buckets if b.bucket_id in need}
        for node in targets:
            node.apply(step, lr,
                       {bid: flats[bid] for bid in node.bucket_ids},
                       grad_scale)
        if self.durability is not None:
            self.durability.notify(step)          # queue puts only

    @staticmethod
    def _pending(q: queue.Queue) -> int:
        with q.mutex:
            return q.unfinished_tasks

    def _lag_gate(self, node_id: int, q: queue.Queue):
        """Block the caller (the trainer's ingest) while ``node_id``'s
        backlog is at the lag bound — this wait IS the bounded-lag
        contract: the shadow may trail by at most ``max_lag_steps``
        iterations, and any time the trainer spends here is booked by the
        checkpointer as the ``apply-lag`` stall stage."""
        limit = self.max_lag_steps
        if self._pending(q) < limit or node_id in self.dead_nodes:
            return
        t0 = time.perf_counter()
        cv = self._lag_cvs[node_id]
        with cv:
            # timed wait (not bare) so a node killed mid-wait can't strand
            # the trainer: the dead check re-runs each wakeup
            while (self._pending(q) >= limit
                   and node_id not in self.dead_nodes):
                cv.wait(0.05)
        dt = time.perf_counter() - t0
        self.lag_waits += 1
        self.lag_wait_s_total += dt
        _obs.get().metrics.counter(
            "shadow_lag_wait_seconds_total",
            "Trainer wait for a backlogged shadow applier "
            "(the apply-lag stall stage)").inc(dt, node=node_id)

    def consolidate(self, timeout: Optional[float] = None) -> dict:
        """Distributed gather: reassemble a full checkpoint from per-node
        fragments (§4.2.4; Universal-Checkpointing shape).

        Waits up to ``timeout`` seconds (default 60) for in-flight updates
        — end to end, including the apply currently executing, so a wedged
        worker cannot hang recovery — then pulls each live node's fragment
        (concurrently; each apply-atomic) and assembles the full
        params/mu/nu trees. The wait is event-based (each worker signals
        when its queue drains), not a sleep-poll. Raises
        `ConsolidationTimeout` (lagging node ids, their owned buckets, and
        the partial checkpoint) if a live node is still behind at the
        deadline, and `ShadowNodeLoss` (dead node ids and EXACTLY their
        buckets as missing) if any node has been killed.
        """
        with _obs.get().tracer.span("shadow.consolidate", track="shadow"):
            return self._consolidate(timeout)

    def _consolidate(self, timeout: Optional[float]) -> dict:
        if self.async_mode:
            deadline = time.monotonic() + (60.0 if timeout is None else
                                           timeout)
            for i, (q, ev) in enumerate(zip(self._queues, self._drained)):
                if i in self.dead_nodes:
                    continue
                while self._pending(q):
                    remaining = deadline - time.monotonic()
                    if remaining <= 0 or not ev.wait(remaining):
                        break                  # deadline hit: node is lagging
                    if self._pending(q):
                        # stale signal (new work arrived since the worker
                        # drained): re-arm and wait for the next drain
                        ev.clear()
            lagging = [i for i, q in enumerate(self._queues)
                       if i not in self.dead_nodes and self._pending(q)]
            if lagging:
                raise ConsolidationTimeout(
                    lagging, self._gather(),
                    lagging_buckets={i: tuple(self.nodes[i].bucket_ids)
                                     for i in lagging})
        if self.dead_nodes:
            dead = sorted(self.dead_nodes)
            _obs.get().metrics.counter(
                "shadow_consolidate_missing_buckets_total",
                "Buckets unreachable at consolidate (dead owners)").inc(
                sum(len(self.nodes[n].bucket_ids) for n in dead))
            raise ShadowNodeLoss(
                dead, {n: tuple(self.nodes[n].bucket_ids) for n in dead},
                self._gather(),
                total=len(dead) == self.n_nodes,
                durable_hint=(self.durability.newest_durable()
                              if self.durability is not None else None))
        return self._gather()

    def _gather(self) -> dict:
        """Pull per-node fragments (concurrently — each node unpacks its own
        flat buffers, the distributed part of the gather) and assemble the
        tree from whatever nodes are alive."""
        live = [n for n in self.nodes if n.node_id not in self.dead_nodes]
        frags: dict[int, tuple] = {}

        def pull(node):
            frags[node.node_id] = node.snapshot()       # apply-atomic

        # one span from the calling thread (concurrent pulls would race on
        # the clock and break byte-identical ManualClock trace exports)
        with _obs.get().tracer.span("shadow.gather", track="shadow",
                                    args={"nodes": len(live)}):
            if len(live) > 1:
                threads = [threading.Thread(target=pull, args=(n,),
                                            daemon=True) for n in live]
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
            else:
                for n in live:
                    pull(n)
        params: dict = {}
        mu: dict = {}
        nu: dict = {}
        steps = []
        for nid in sorted(frags):
            p, m, v, step = frags[nid]
            params.update(p)
            mu.update(m)
            nu.update(v)
            steps.append(step)
        return {"params": params, "mu": mu, "nu": nu,
                "step": min(steps, default=0)}

    # backwards-compatible alias (pre-sharding name)
    _merge = _gather

    def stats(self) -> ShadowStats:
        count = sum(n.apply_count for n in self.nodes)
        total = sum(n.apply_total_s for n in self.nodes)
        per_node = [n.apply_total_s / n.apply_count if n.apply_count else 0.0
                    for n in self.nodes]
        live = [n for n in self.nodes if n.node_id not in self.dead_nodes]
        return ShadowStats(
            steps_applied=min((n.step for n in live), default=0),
            lag=self.train_step_seen - min((n.step for n in live),
                                           default=0),
            max_queue_depth=self.max_queue_depth,
            mean_apply_s=total / count if count else 0.0,
            max_apply_s=max((n.apply_max_s for n in self.nodes), default=0.0),
            per_node_apply_s=per_node,
            lag_waits=self.lag_waits,
            lag_wait_s=self.lag_wait_s_total,
            batched_applies=self.batched_applies,
            max_batch=self.max_batch)

    def shutdown(self):
        if self.durability is not None:
            self.durability.close()
        if self.async_mode:
            for q in self._queues:
                q.put(None)
            for t in self._workers:
                t.join(timeout=5)


def plan_shadow_nodes(layout: BucketLayout, opt: OptimizerConfig,
                      iter_time_s: float, trial_tree: dict,
                      max_nodes: int = 16) -> tuple[int, float]:
    """Paper §4.2.4: 'Before starting training, Checkmate profiles shadow
    nodes and configures the system for optimal performance.'

    Measures one full-tree optimizer apply on this host and returns the
    minimum node count whose per-node apply time fits inside an iteration,
    plus the measured single-node apply time.
    """
    cluster = ShadowCluster(layout, opt, n_nodes=1)
    zeros = {k: np.zeros(v.shape, np.float32) for k, v in trial_tree.items()}
    cluster.bootstrap(zeros, zeros, zeros, 0)
    grads = {k: np.ones(v.shape, np.float32) for k, v in trial_tree.items()}
    chan = InProcessChannel()
    chan.open(layout)

    def deliver(step):
        chan.send(StepEvent(step=step, grads=grads, lr=1e-3))
        for d in chan.poll():
            cluster.on_delivery(d)

    deliver(1)                                # warmup/compile
    t0 = time.perf_counter()
    deliver(2)
    t1 = time.perf_counter() - t0
    need = max(1, int(np.ceil(t1 / max(iter_time_s, 1e-9))))
    return min(need, max_nodes), t1
