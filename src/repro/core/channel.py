"""GradientChannel: the single pluggable delivery API from the capture
point to the shadow apply (paper §4).

Every reduced gradient that reaches the shadow plane flows through one
`GradientChannel`:

    channel.open(layout, multicast_groups)   # once, before training
    channel.send(StepEvent(...))             # per iteration, capture side
    for d in channel.poll():                 # deliveries for the shadow side
        shadow.on_delivery(d)                # (only complete captures apply)
    channel.close()

Every delivery carries the bucket *wire layout* as its primary payload
(``Delivery.flats``: bucket_id -> contiguous flat buffer) — the shadow
applies it with one fused optimizer pass per bucket, and
``Delivery.grads`` stays available as a lazy zero-copy leaf view
(`repro.core.buckets.FlatTreeView`). Three composable implementations
ship here:

* ``InProcessChannel``   — pack-once wire-layout hand-off (the delivery's
                           flats are packed at ``send`` and enqueued by
                           reference).
* ``PacketizedChannel``  — the full paper dataflow: pack buckets
                           (`core.buckets`), segment into MTU frames
                           (`net.packets`), route through the event-driven
                           fabric (`net.simulator.FabricSimulator`) with
                           switch replication per the `core.multicast`
                           group config, and reassemble the capture from
                           the frames that actually arrived at the shadow
                           hosts. An incomplete capture (e.g. a shadow-NIC
                           failure mid-iteration, §4.3.2) surfaces as a
                           gated ``Delivery`` (``complete=False``) — the
                           shadow refuses the partial apply and recovery
                           lands on the last fully-captured step.
* ``CompressedChannel``  — wraps any channel with int8 + error-feedback
                           gradient compression (`dist.compression`); the
                           delivery carries the dequantized stream.

Failure injection, compression, and topology choice are therefore
orthogonal channel options, not bespoke checkpointer code paths.
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Optional, Protocol, runtime_checkable

import numpy as np

from repro import obs as _obs
from repro.core.buckets import (XLA_ALIGN, BucketLayout, FlatTreeView,
                                alloc_flat, bucket_dtype, pack_bucket_into)
from repro.core.multicast import MulticastGroup
from repro.core.multicast import multicast_groups as _make_groups


@dataclass(frozen=True)
class StepEvent:
    """Everything the capture point knows about one training iteration.

    The checkpointer surface consumes this single frozen record
    (``Checkpointer.on_step(event)``) instead of the legacy five-kwarg
    signature.

    Args:
        step: 1-based training step the gradients belong to.
        grads: reduced gradients (host tree) — the multicast payload; None
            for checkpointers that copy state instead (baselines).
        lr: learning rate the training step applied.
        grad_scale: global-norm clipping scale the training step applied.
        iter_time: wall-clock seconds of the iteration (overlap budgets).
        state_fn: zero-arg callable producing a host snapshot of the full
            TrainState — only copy-persist baselines call it.
        flats: the same gradients already in wire layout (bucket_id ->
            contiguous flat buffer, `repro.core.buckets`). Channels that
            receive both use ``flats`` and skip the pack — this is how
            channel wrappers (e.g. `CompressedChannel`) forward an
            already-packed payload without a second pass.
    """
    step: int
    grads: Optional[dict] = None
    lr: float = 0.0
    grad_scale: float = 1.0
    iter_time: Optional[float] = None
    state_fn: Optional[Callable[[], dict]] = None
    flats: Optional[dict] = None


class Delivery:
    """One iteration's gradients as they arrived on the shadow side.

    The primary payload is ``flats`` — the bucket wire layout (bucket_id ->
    contiguous flat buffer) exactly as it left the transport's rx buffer;
    the shadow applies it with one fused optimizer pass per bucket.
    ``grads`` remains available as a backward-compatible *lazy zero-copy*
    leaf view (`repro.core.buckets.FlatTreeView` built over the same
    buffers) — reading a leaf never copies an element.

    ``complete=False`` is a *gated* delivery: the transport could not
    reassemble the full capture (lost mirror frames, dead shadow NIC);
    ``flats``/``grads`` are None and the shadow must not apply it.

    Bucket-sharded transports (``PacketizedChannel(sharded=True)``)
    additionally report *per-owner* verdicts: ``node_complete`` maps each
    shadow node id to whether every bucket it owns was fully reassembled,
    and ``missing_buckets`` maps node id -> tuple of its bucket ids that
    were not. On a partial capture (some owners dead, survivors whole)
    ``complete`` is False but ``flats`` carries the surviving owners'
    buckets, so the shadow can keep the live shard of the cluster current
    (``ShadowCluster.on_delivery(d, nodes=...)``).
    """

    __slots__ = ("step", "lr", "grad_scale", "complete", "missing_captures",
                 "wire_bytes", "fabric", "flats", "layout", "node_complete",
                 "missing_buckets", "_grads")

    def __init__(self, step: int, lr: float, grad_scale: float,
                 grads: Optional[dict] = None, complete: bool = True,
                 missing_captures: int = 0, wire_bytes: int = 0,
                 fabric: object = None, flats: Optional[dict] = None,
                 layout: Optional[BucketLayout] = None,
                 node_complete: Optional[dict] = None,
                 missing_buckets: Optional[dict] = None):
        self.step = step
        self.lr = lr
        self.grad_scale = grad_scale
        self.complete = complete
        self.missing_captures = missing_captures
        self.wire_bytes = wire_bytes
        self.fabric = fabric           # FabricResult for packetized transports
        self.flats = flats
        self.layout = layout
        self.node_complete = node_complete      # sharded: node -> bool
        self.missing_buckets = missing_buckets  # sharded: node -> bucket ids
        self._grads = grads

    @property
    def grads(self) -> Optional[dict]:
        if self._grads is None and self.flats is not None and self.complete:
            self._grads = FlatTreeView(self.layout, self.flats)
        return self._grads

    def __repr__(self):
        return (f"Delivery(step={self.step}, complete={self.complete}, "
                f"wire_bytes={self.wire_bytes})")


@dataclass
class FabricTotals:
    """Always-on cumulative wire/fabric account for one channel.

    Cheap native counters updated in place per send (no registry lookups
    on the hot path); `repro.obs.publish.publish_channel` mirrors them
    into labeled metrics once per run.
    """
    sends: int = 0
    gated: int = 0                      # incomplete captures
    wire_bytes: int = 0                 # incl. in-switch replication
    frames_tx: int = 0
    frames_rx: int = 0
    frames_mirrored: int = 0
    drops: int = 0
    retransmits: int = 0
    rerouted: int = 0
    mirror_lost: int = 0
    pfc_pauses: int = 0
    pfc_resumes: int = 0
    pfc_pause_s: float = 0.0            # aggregate link-paused virtual time
    fabric_time_s: float = 0.0          # simulated time consumed
    link_pfc: dict = field(default_factory=dict)   # per-link pause account

    def absorb(self, result, wire_bytes: int):
        """Fold one ``FabricResult`` into the running totals."""
        self.sends += 1
        if not result.reassembled_ok:
            self.gated += 1
        self.wire_bytes += wire_bytes
        self.frames_tx += result.tx_frames
        self.frames_rx += result.rx_frames
        self.frames_mirrored += result.mirrored_frames
        self.drops += result.drops
        self.retransmits += result.retransmits
        self.rerouted += result.rerouted
        self.mirror_lost += result.mirror_lost_frames
        self.pfc_pauses += result.pfc_pauses
        self.pfc_resumes += result.pfc_resumes
        self.pfc_pause_s += result.pfc_pause_s
        self.fabric_time_s += result.duration_s
        for link, st in result.link_pfc.items():
            agg = self.link_pfc.setdefault(
                link, {"pauses": 0, "resumes": 0, "pause_s": 0.0})
            agg["pauses"] += st["pauses"]
            agg["resumes"] += st["resumes"]
            agg["pause_s"] += st["pause_s"]


@runtime_checkable
class GradientChannel(Protocol):
    """Transport protocol between the capture point and the shadow plane.

    ``send`` returns the *sender-visible stall seconds*: the critical-path
    cost the training step pays to hand the capture off. Work the transport
    performs off the sender's critical path — in-switch replication, wire
    propagation, shadow-side reassembly — is not stall; the fabric's
    virtual-time account lives in ``Delivery.fabric``.

    Channels additionally set ``last_send_parts`` after every ``send``: an
    ordered ``{stage: seconds}`` decomposition of the return value whose
    in-order sum equals it *bit-exactly* (stall attribution,
    `repro.obs.stalls`). Wrappers prepend their own stages to the inner
    channel's parts.
    """
    name: str

    def open(self, layout: BucketLayout,
             multicast_groups: Optional[list[MulticastGroup]] = None
             ) -> None: ...

    def send(self, event: StepEvent) -> float: ...

    def poll(self) -> list[Delivery]: ...

    def close(self) -> None: ...


def _flats_from_event(layout: BucketLayout, event: StepEvent) -> dict:
    """The event's payload in wire layout: reuse ``event.flats`` when the
    sender already packed (channel wrappers), else pack ``event.grads``
    once — the single pass that turns the leaf tree into the native flat
    format every downstream stage consumes."""
    if event.flats is not None:
        return event.flats
    assert event.grads is not None, "channels carry gradients"
    return {b.bucket_id: pack_bucket_into(
                b, event.grads, alloc_flat(b.size, bucket_dtype(b)))
            for b in layout.buckets}


class InProcessChannel:
    """In-process hand-off in wire layout (the paper's loopback shortcut).

    ``send`` packs the gradient tree into per-bucket flat buffers ONCE (or
    adopts ``event.flats`` if the sender already packed) and enqueues those
    buffers by reference; the delivery's ``grads`` is a lazy zero-copy leaf
    view over the very same buffers. ``wire_bytes`` is 0 — nothing crossed
    a wire.

    The pack pass is deliberately charged as sender stall: in-process, the
    wire-format copy IS work the sending thread performs (DDP's bucket
    flatten is likewise a training-side copy). The paper's zero-stall
    claim belongs to `PacketizedChannel`, where the capture rides the ring
    AllGather and ``send`` returns 0.0.
    """
    name = "inprocess"

    def __init__(self):
        self._layout: Optional[BucketLayout] = None
        self._pending: list[Delivery] = []
        self.last_send_parts: dict = {}

    def open(self, layout, multicast_groups=None):
        self._layout = layout

    def send(self, event: StepEvent) -> float:
        assert self._layout is not None, "open() before send()"
        ob = _obs.get()
        t0 = time.perf_counter()
        with ob.tracer.span("channel.send", args={"step": event.step,
                                                  "channel": self.name}):
            with ob.tracer.span("bucket.pack", args={"step": event.step}):
                flats = _flats_from_event(self._layout, event)
            self._pending.append(Delivery(
                step=event.step, lr=event.lr, grad_scale=event.grad_scale,
                flats=flats, layout=self._layout, complete=True))
        dt = time.perf_counter() - t0
        self.last_send_parts = {"send": dt}
        ob.metrics.counter("channel_sends_total", "Gradient sends").inc(
            1, channel=self.name)
        return dt

    def poll(self) -> list[Delivery]:
        out, self._pending = self._pending, []
        return out

    def close(self):
        self._pending.clear()


def _canon_topology(name: str) -> str:
    aliases = {"rail-optimized": "rail", "rail": "rail",
               "strided": "leaf-spine", "leaf-spine": "leaf-spine",
               "single": "single"}
    if name not in aliases:
        raise ValueError(f"unknown topology {name!r}; "
                         f"expected one of {sorted(set(aliases))}")
    return aliases[name]


class PacketizedChannel:
    """Deliver gradients through the event-driven fabric simulator.

    Per ``send``: the gradient tree is packed into DDP buckets, laid out
    as one contiguous wire buffer, split across DP groups, segmented into
    MTU frames and pushed through one AllGather iteration of
    `FabricSimulator` — boundary-rank frames are DSCP-tagged, the ingress
    leaf's match-action table replicates them toward the shadow hosts, and
    the channel reassembles the capture from the frames that actually
    arrived (via the simulator's frame-level injection/extraction hooks).

    Args:
        topology: "rail-optimized" (alias "rail"), "leaf-spine" (alias
            "strided"), or "single" — see `repro.net.planner`.
        n_dp_groups / ranks_per_group: fabric workload shape; the wire
            buffer is split evenly across groups.
        n_shadow_nodes: shadow hosts on the fabric (transport view; the
            `ShadowCluster` node count is independent).
        replication_factor / n_channels / link_gbps / ranks_per_leaf /
            n_spines / shadow_nics / pfc / frame_quantum: forwarded to the
            simulator (see `FabricSimulator`).
        failures_at: ``{step: failures}`` fabric failure injection; each
            entry fires once (the failed hardware is replaced before the
            post-recovery rerun). ``failures`` is a `FailureSpec` sequence,
            or the string ``"capture"`` — cut every shadow NIC at t=0, so
            the ring completes but that step's capture is lost.
        sharded: bucket-sharded shadow plane — each shadow node owns the
            byte-balanced bucket subset `repro.core.multicast
            .assign_buckets` gives it (the same deterministic map a
            default `ShadowCluster` uses), the fabric routes every
            bucket's frames only to its owner (tagged frames split at
            ownership cuts), and deliveries carry per-owner
            ``node_complete`` / ``missing_buckets`` verdicts plus partial
            flats for the surviving owners.
        shadow_rails: shadow-rail leaf count (`repro.net.planner`); >1
            spreads the sharded owners' incast over independent leaves.
        fast: run each send on the simulator's calendar-queue fast engine
            (bit-identical to the per-frame oracle; see docs/netsim.md).
    """
    name = "packetized"

    def __init__(self, *, topology: str = "rail-optimized",
                 n_dp_groups: int = 1, ranks_per_group: int = 4,
                 n_shadow_nodes: int = 2, replication_factor: int = 1,
                 n_channels: int = 1, link_gbps: float = 100.0,
                 ranks_per_leaf: int = 32, n_spines: int = 2,
                 shadow_nics: int = 2, pfc=None,
                 frame_quantum: Optional[int] = None,
                 failures_at: Optional[dict] = None,
                 sharded: bool = False, shadow_rails: int = 1,
                 fast: bool = False):
        self.topology = _canon_topology(topology)
        self.n_dp_groups = n_dp_groups
        self.ranks_per_group = ranks_per_group
        self.n_shadow_nodes = n_shadow_nodes
        self.replication_factor = replication_factor
        self.n_channels = n_channels
        self.link_gbps = link_gbps
        self.ranks_per_leaf = ranks_per_leaf
        self.n_spines = n_spines
        self.shadow_nics = shadow_nics
        self.pfc = pfc
        self.frame_quantum = frame_quantum
        self.failures_at = dict(failures_at or {})
        self.sharded = sharded
        self.shadow_rails = shadow_rails
        # calendar-queue fast engine vs the per-frame oracle — bit-identical
        # results (tests/test_fabric_fastpath.py), so this is purely a
        # wall-clock knob; recorded in scenario JSON so bundles replay on
        # the exact engine that failed
        self.fast = fast
        self.dead_shadow_nodes: set[int] = set()
        self._owners: Optional[dict] = None   # bucket_id -> owner node
        self._route_starts: list[int] = []    # owner step fn over total buf
        self._route_owners: list[int] = []
        self._bucket_spans: list[tuple] = []  # (bid, start, nbytes, owner)
        self._layout: Optional[BucketLayout] = None
        self._topo = None
        self._groups: Optional[list[MulticastGroup]] = None
        self._pending: list[Delivery] = []
        # derived once at open(), reused every send (perf: send used to
        # re-derive pack metas and reallocate the wire buffer per step)
        self._metas: list[tuple] = []         # (dtype, size, nbytes, offset)
        self._per = 0                         # padded bytes per DP group
        self._total = 0                       # wire buffer size
        self._src_buf: Optional[bytearray] = None
        self._src_views: list[np.ndarray] = []
        self.totals = FabricTotals()
        self.last_send_parts: dict = {}

    def open(self, layout, multicast_groups=None):
        from repro.net.planner import build_topology
        self._layout = layout
        if self.sharded:
            from repro.core.multicast import assign_buckets
            self._owners = assign_buckets(layout, self.n_shadow_nodes)
        self._topo = build_topology(
            self.n_dp_groups, self.ranks_per_group, self.n_shadow_nodes,
            topology=self.topology, ranks_per_leaf=self.ranks_per_leaf,
            link_gbps=self.link_gbps, shadow_nics=self.shadow_nics,
            n_spines=self.n_spines, shadow_rails=self.shadow_rails)
        self._groups = (multicast_groups if multicast_groups is not None
                        else _make_groups(self.n_dp_groups,
                                          self.ranks_per_group,
                                          self.n_shadow_nodes))
        self._set_wire_geometry(tuple(bucket_dtype(b)
                                      for b in layout.buckets))

    def _set_wire_geometry(self, dtypes: tuple):
        """(Re)derive the wire-buffer geometry for per-bucket payload
        ``dtypes`` and allocate the reusable tx buffer.

        Bucket dtypes/sizes/offsets are a function of the layout plus the
        payload dtype (a `CompressedChannel` forwards the dequantized f32
        stand-in even over narrower layouts, and the wire must carry what
        the payload is — never silently downcast). The buffer is padded so
        it splits evenly into n_dp_groups payloads of rpg whole chunks
        each, and each bucket's wire slot starts XLA-aligned so the
        delivery's rx views are adoptable zero-copy by the shadow's fused
        apply.
        """
        self._wire_dtypes = dtypes
        self._metas, cum = [], 0
        for b, dt in zip(self._layout.buckets, dtypes):
            dt = np.dtype(dt)
            nbytes = b.size * dt.itemsize
            cum = -(-cum // XLA_ALIGN) * XLA_ALIGN
            self._metas.append((dt, b.size, nbytes, cum))
            cum += nbytes
        n_g, rpg = self.n_dp_groups, self.ranks_per_group
        self._per = -(-max(cum, n_g * rpg) // (n_g * rpg)) * rpg
        self._total = self._per * n_g
        # the tx wire buffer is allocated once and reused across sends —
        # its bytes are consumed synchronously inside sim.run(); the rx
        # buffer is fresh per send because the delivery's flat views alias
        # it for as long as the consumer holds them
        self._src_buf = bytearray(self._total)
        self._src_views = [
            np.frombuffer(self._src_buf, dtype=dt, count=size, offset=ofs)
            for dt, size, _, ofs in self._metas]
        if self.sharded and self._owners is not None:
            self._shard_geometry()

    def _shard_geometry(self):
        """Derive the owner step-function and per-bucket byte spans over
        the total wire buffer (offsets move when wire dtypes change, so
        this re-runs with ``_set_wire_geometry``)."""
        starts: list[int] = []
        owners: list[int] = []
        spans: list[tuple] = []
        for b, (_dt, _size, nbytes, ofs) in zip(self._layout.buckets,
                                                self._metas):
            o = self._owners[b.bucket_id]
            spans.append((b.bucket_id, ofs, nbytes, o))
            if not owners or o != owners[-1]:
                starts.append(ofs)
                owners.append(o)
        # leading byte 0 and the trailing padding keep their neighbours'
        # owner (padding has no data; its routing just needs to be total)
        starts[0] = 0
        self._route_starts = starts
        self._route_owners = owners
        self._bucket_spans = spans

    def _owner_at(self, off: int) -> int:
        """Shadow node owning total-buffer byte ``off`` (simulator's
        ``shadow_route``)."""
        return self._route_owners[
            bisect.bisect_right(self._route_starts, off) - 1]

    def _node_accounting(self, node_cov: dict, ring_done: bool):
        """Per-owner capture verdicts from the per-node coverage maps.

        ``node_cov``: ``(node_id, replica) -> {total_off: max bytes}`` of
        mirror payloads that actually arrived. Clips every covered span to
        the bucket data spans (wire padding doesn't count), then calls a
        bucket complete when every replica covered all of its bytes.
        """
        starts = [s for _, s, _, _ in self._bucket_spans]
        got: dict[tuple, int] = {}             # (bucket_id, replica) -> B
        for (_nid, rep), seen in node_cov.items():
            for off, ln in seen.items():
                while ln > 0:
                    i = bisect.bisect_right(starts, off) - 1
                    if i < 0:
                        break
                    bid, s, nb, _o = self._bucket_spans[i]
                    end = s + nb
                    if off >= end:             # padding gap: skip ahead
                        if i + 1 >= len(self._bucket_spans):
                            break
                        skip = min(ln, self._bucket_spans[i + 1][1] - off)
                        off += skip
                        ln -= skip
                        continue
                    take = min(ln, end - off)
                    key = (bid, rep)
                    got[key] = got.get(key, 0) + take
                    off += take
                    ln -= take
        rf = self.replication_factor
        missing: dict[int, list] = {n: [] for n in range(self.n_shadow_nodes)}
        for bid, _s, nb, owner in self._bucket_spans:
            if not all(got.get((bid, rep), 0) >= nb for rep in range(rf)):
                missing[owner].append(bid)
        node_complete = {n: ring_done and not missing[n]
                         for n in range(self.n_shadow_nodes)}
        return node_complete, {n: tuple(m) for n, m in missing.items()}

    def kill_shadow_node(self, node_id: int):
        """Persistently cut shadow node ``node_id``'s access NIC: every
        subsequent send loses the frames routed to it, so its buckets stay
        missing until ``revive_all`` (hardware replaced + resync)."""
        if not 0 <= node_id < self.n_shadow_nodes:
            raise ValueError(f"shadow node {node_id} out of range "
                             f"[0, {self.n_shadow_nodes})")
        self.dead_shadow_nodes.add(node_id)

    def revive_all(self):
        """Forget all shadow-node deaths (replacement hardware racked)."""
        self.dead_shadow_nodes.clear()

    def _failures_for(self, step: int):
        from repro.net.simulator import FailureSpec
        # dead shadow nodes stay dead: each send re-cuts their NICs at t=0
        # (every send builds a fresh simulator over the static topology)
        dead = tuple(FailureSpec(0.0, "shadow_nic", n)
                     for n in sorted(self.dead_shadow_nodes))
        spec = self.failures_at.pop(step, None)      # each failure fires once
        if spec is None:
            return dead
        if spec == "capture":
            return dead + tuple(FailureSpec(0.0, "shadow_nic", h)
                                for h in self._topo.shadow_hosts)
        if isinstance(spec, FailureSpec):
            return dead + (spec,)
        return dead + tuple(spec)

    def send(self, event: StepEvent) -> float:
        from repro.net.pfc import PfcConfig
        from repro.net.simulator import FabricSimulator
        assert self._layout is not None, "open() before send()"
        ob = _obs.get()
        send_span = ob.tracer.span("channel.send",
                                   args={"step": event.step,
                                         "channel": self.name})
        send_span.__enter__()

        # one pass: leaves (or an already-packed payload) straight into the
        # reused wire buffer — no intermediate per-bucket concatenate
        buckets = self._layout.buckets
        with ob.tracer.span("bucket.pack", args={"step": event.step}):
            if event.flats is not None:
                dtypes = tuple(np.dtype(event.flats[b.bucket_id].dtype)
                               for b in buckets)
                if dtypes != self._wire_dtypes:  # e.g. f32 dequantized stream
                    self._set_wire_geometry(dtypes)
                for b, dst in zip(buckets, self._src_views):
                    dst[:] = event.flats[b.bucket_id]
            else:
                assert event.grads is not None, "channels carry gradients"
                # the wire carries the GRADIENT dtype (may differ from the
                # param layout's, e.g. f32 grads over a bf16 tree) — exactly
                # what pack_bucket's concatenate would have produced
                dtypes = tuple(
                    np.result_type(*[event.grads[s.name].dtype
                                     for s in b.slots]) for b in buckets)
                if dtypes != self._wire_dtypes:
                    self._set_wire_geometry(dtypes)
                for b, dst in zip(buckets, self._src_views):
                    pack_bucket_into(b, event.grads, dst)
        per, total = self._per, self._total
        src = memoryview(self._src_buf)
        rx_np = alloc_flat(total, np.uint8)      # aligned: views adopt free
        rx = memoryview(rx_np)

        sim = FabricSimulator(
            self._topo, grad_bytes_per_group=per,
            replication_factor=self.replication_factor,
            n_channels=self.n_channels,
            pfc=self.pfc if self.pfc is not None else PfcConfig(),
            failures=self._failures_for(event.step),
            frame_quantum=self.frame_quantum,
            shadow_route=self._owner_at if self.sharded else None,
            shadow_cuts=self._route_starts[1:] if self.sharded else (),
            fast=self.fast)

        def frame_tx(f):                     # injection: slice real bytes in
            off = f.dp_group * per + sim.wire_offset(f)
            f.payload = src[off:off + f.payload_len]

        node_cov: dict = {}   # sharded: (node, replica) -> {total_off: B}

        def shadow_rx(node_id, f):           # extraction: reassemble capture
            off = f.dp_group * per + sim.wire_offset(f)
            rx[off:off + f.payload_len] = f.payload
            if self.sharded:
                seen = node_cov.setdefault((node_id, f.replica), {})
                seen[off] = max(seen.get(off, 0), f.payload_len)

        sim.frame_tx_hook = frame_tx
        sim.shadow_rx_hook = shadow_rx
        rx_frames: list[tuple] = []
        if ob.tracer.enabled:
            # per-frame fabric traversal on the simulated-time tracks:
            # record each mirror delivery (node, virtual tx/arrive times)
            def traced_rx(node_id, f, _inner=shadow_rx):
                _inner(node_id, f)
                rx_frames.append((node_id, f.dp_group, f.chunk, f.replica,
                                  f.t_send, f.t_arrive, f.n_frames,
                                  f.payload_len))
            sim.shadow_rx_hook = traced_rx
        with ob.tracer.span("fabric.simulate", args={"step": event.step}):
            result = sim.run()
        if ob.tracer.enabled:
            tr = ob.tracer
            tr.fabric_span(f"allgather step{event.step}", 0.0,
                           result.duration_s, track="fabric",
                           args={"step": event.step,
                                 "events": result.events,
                                 "reassembled_ok": result.reassembled_ok})
            for nid, dp, chunk, rep, t_tx, t_rx, nf, pl in rx_frames:
                tr.fabric_span(f"g{dp}c{chunk}r{rep}", t_tx, t_rx,
                               track=f"shadow{nid}.rx",
                               args={"step": event.step, "frames": nf,
                                     "bytes": pl})
            tr.fabric_advance(result.duration_s)

        # no live registry incs here: the always-on FabricTotals above is
        # this channel's single metrics source, mirrored into the registry
        # once per run by publish_channel (avoids double counting)
        self.totals.absorb(result, total * self.replication_factor)

        node_complete = missing_buckets = None
        if self.sharded:
            node_complete, missing_buckets = self._node_accounting(
                node_cov, result.ring_completed)

        flats = None
        if result.reassembled_ok:
            # the delivery's flats ARE the rx buffer: zero-copy per-bucket
            # views which keep rx_np alive; Delivery.grads is a lazy leaf
            # view over the same bytes
            flats = {b.bucket_id: rx_np[ofs:ofs + nbytes].view(dt)
                     for b, (dt, _, nbytes, ofs) in zip(buckets, self._metas)}
        elif node_complete is not None and any(node_complete.values()):
            # partial capture: the surviving owners' buckets are whole —
            # ship them so the live shard of the shadow can stay current
            flats = {b.bucket_id: rx_np[ofs:ofs + nbytes].view(dt)
                     for b, (dt, _, nbytes, ofs) in zip(buckets, self._metas)
                     if node_complete[self._owners[b.bucket_id]]}
        self._pending.append(Delivery(
            step=event.step, lr=event.lr, grad_scale=event.grad_scale,
            flats=flats, layout=self._layout,
            complete=result.reassembled_ok,
            missing_captures=result.missing_captures,
            wire_bytes=total * self.replication_factor, fabric=result,
            node_complete=node_complete, missing_buckets=missing_buckets))
        send_span.__exit__(None, None, None)
        # Zero sender-visible stall (§4 zero-overhead claim): the gradient
        # frames ride the ring AllGather training performs anyway, and
        # replication happens in-switch. The event loop above is simulation
        # cost on this host — its virtual-time account is Delivery.fabric.
        self.last_send_parts = {"send": 0.0}
        return 0.0

    def poll(self) -> list[Delivery]:
        out, self._pending = self._pending, []
        return out

    def close(self):
        self._pending.clear()
        self._topo = None
        self._src_buf = None
        self._src_views = []


class CompressedChannel:
    """Wrap any channel with int8 + error-feedback gradient compression.

    ``send`` packs the gradient tree into wire layout once, quantizes the
    flat buckets in a single pass (`dist.compression.Compressor
    .compress_flats`, residuals carried across iterations as flat buffers
    in the same layout), and forwards the *dequantized* flats to the inner
    channel — exactly what a compressed multicast payload delivers, with
    no leaf-dict churn on the hot path. The shadow replica therefore
    tracks the compressed stream; divergence from raw-gradient training is
    bounded by the error-feedback invariant
    (tests/test_compression_shadow.py).

    Quantization runs on the sender's critical path, so ``send`` charges it
    as stall (plus the inner channel's). ``Delivery.wire_bytes`` reports
    the *compressed* payload (int8 + per-leaf scale) — what a compressed
    multicast puts on the wire — even when the inner transport ships the
    dequantized f32 stand-in.

    The error-feedback residual assumes every sent payload is eventually
    consumed; a lossy inner transport is safe because the checkpointer
    enforces stream contiguity — a gated delivery freezes the shadow until
    a full-state resync or recovery, so quantized mass is never silently
    dropped from the stream the shadow applies.
    """
    name = "compressed"

    def __init__(self, inner: Optional[GradientChannel] = None):
        from repro.dist.compression import Compressor
        self.inner: GradientChannel = (inner if inner is not None
                                       else InProcessChannel())
        self.compressor = Compressor()
        self.name = f"compressed[{self.inner.name}]"
        self._layout: Optional[BucketLayout] = None
        self._sent_bytes: dict[int, int] = {}
        self.last_send_parts: dict = {}

    def open(self, layout, multicast_groups=None):
        self._layout = layout
        self.inner.open(layout, multicast_groups)

    def send(self, event: StepEvent) -> float:
        assert self._layout is not None, "open() before send()"
        ob = _obs.get()
        t0 = time.perf_counter()
        with ob.tracer.span("channel.quantize", args={"step": event.step}):
            before = self.compressor.wire_bytes_total
            flats = _flats_from_event(self._layout, event)  # pack once
            deq = self.compressor.compress_flats(self._layout, flats)
        self._sent_bytes[event.step] = (self.compressor.wire_bytes_total
                                        - before)
        stall = time.perf_counter() - t0
        inner_stall = self.inner.send(
            dataclasses.replace(event, grads=None, flats=deq))
        # attribution: quantize + the inner channel's own decomposition
        # (which sums in-order to inner_stall), so the parts' in-order sum
        # equals the stall + inner_stall returned below bit-exactly
        self.last_send_parts = {
            "quantize": stall,
            **dict(getattr(self.inner, "last_send_parts", None)
                   or {"send": float(inner_stall or 0.0)})}
        ob.metrics.counter("channel_wire_bytes_total",
                           "Bytes put on the wire (incl. replication)").inc(
            self._sent_bytes[event.step], channel="compressed")
        return stall + inner_stall

    def poll(self) -> list[Delivery]:
        out = self.inner.poll()
        for d in out:
            d.wire_bytes = self._sent_bytes.pop(d.step, d.wire_bytes)
        return out

    def kill_shadow_node(self, node_id: int):
        """Forward a shadow-node death to the inner (sharded) transport."""
        self.inner.kill_shadow_node(node_id)

    def revive_all(self):
        fn = getattr(self.inner, "revive_all", None)
        if fn is not None:
            fn()

    def close(self):
        self._sent_bytes.clear()
        self.inner.close()
