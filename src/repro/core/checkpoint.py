"""Checkpointer implementations: Checkmate + the copy-persist baselines the
paper compares against (§2.2, §6.2).

All baselines do *real* work (host copies, in-memory persists) so the
CPU-wall-clock benchmark harness reproduces the paper's relative overheads:

  * ``SyncCheckpointer``       — pause; copy + persist inline (worst case)
  * ``AsyncCheckpointer``      — copy inline, persist on a background thread;
                                 blocks if the previous persist is unfinished
                                 (the unbounded-memory guard the paper cites)
  * ``ShardedAsyncCheckpointer`` — Torch-DCP-like: each of N nodes handles 1/N
  * ``GeminiLikeCheckpointer`` — checkpoint to remote CPU memory over the
                                 training network; stall = transfer time not
                                 hidden by the per-iteration overlap budget
  * ``CheckFreqCheckpointer``  — async + profiling that tunes frequency so
                                 overhead stays under a target fraction
  * ``CheckmateCheckpointer``  — hands the already-captured reduced gradients
                                 to the shadow cluster; zero training stall

The training loop calls ``on_step`` every iteration and adds the returned
stall seconds to its critical path.
"""
from __future__ import annotations

import io
import threading
import time
from typing import Callable, Optional

import numpy as np

from repro.core.shadow import ShadowCluster


def _flatten_state(state: dict) -> list[np.ndarray]:
    out = []
    for v in state.values():
        if isinstance(v, dict):
            out.extend(_flatten_state(v))
        else:
            out.append(np.asarray(v))
    return out


def _persist(leaves: list[np.ndarray], sink: io.BytesIO):
    sink.seek(0)
    for a in leaves:
        sink.write(memoryview(a).cast("B"))


class BaseCheckpointer:
    name = "base"

    def __init__(self, freq: int = 1):
        self.freq = max(1, freq)
        self.n_checkpoints = 0
        self.stall_total = 0.0
        self._latest: Optional[dict] = None

    def on_step(self, step: int, *, state_fn: Callable[[], dict],
                grads=None, lr: float = 0.0, grad_scale: float = 1.0,
                iter_time: Optional[float] = None) -> float:
        if step % self.freq != 0:
            return 0.0
        t0 = time.perf_counter()
        self._checkpoint(step, state_fn, grads, lr, grad_scale, iter_time)
        stall = time.perf_counter() - t0
        self.stall_total += stall
        self.n_checkpoints += 1
        return stall

    def _checkpoint(self, step, state_fn, grads, lr, grad_scale, iter_time):
        raise NotImplementedError

    def restore(self) -> Optional[dict]:
        return self._latest

    def finalize(self):
        pass


class NoCheckpointer(BaseCheckpointer):
    name = "no_checkpoint"

    def on_step(self, step, **kw) -> float:
        return 0.0


class SyncCheckpointer(BaseCheckpointer):
    name = "sync"

    def __init__(self, freq: int = 1):
        super().__init__(freq)
        self._sink = io.BytesIO()

    def _checkpoint(self, step, state_fn, grads, lr, grad_scale, iter_time):
        state = state_fn()                       # device -> host copy
        leaves = [np.copy(a) for a in _flatten_state(state)]   # clone
        _persist(leaves, self._sink)             # persist inline
        self._latest = state


class AsyncCheckpointer(BaseCheckpointer):
    name = "async"

    def __init__(self, freq: int = 1):
        super().__init__(freq)
        self._sink = io.BytesIO()
        self._thread: Optional[threading.Thread] = None

    def _checkpoint(self, step, state_fn, grads, lr, grad_scale, iter_time):
        if self._thread is not None:
            self._thread.join()                  # previous persist must finish
        state = state_fn()
        leaves = [np.copy(a) for a in _flatten_state(state)]
        self._latest = state
        self._thread = threading.Thread(
            target=_persist, args=(leaves, self._sink), daemon=True)
        self._thread.start()

    def finalize(self):
        if self._thread is not None:
            self._thread.join()


class ShardedAsyncCheckpointer(AsyncCheckpointer):
    """Torch-DCP-like: checkpoint sharded across N training nodes, so each
    node copies/persists 1/N of the state."""
    name = "torch_dcp"

    def __init__(self, freq: int = 1, n_shards: int = 4):
        super().__init__(freq)
        self.n_shards = n_shards

    def _checkpoint(self, step, state_fn, grads, lr, grad_scale, iter_time):
        if self._thread is not None:
            self._thread.join()
        state = state_fn()
        # this node's shard: 1/N of every leaf (flattened prefix slice)
        leaves = []
        for a in _flatten_state(state):
            flat = a.reshape(-1)
            leaves.append(np.copy(flat[:max(1, flat.size // self.n_shards)]))
        self._latest = state
        self._thread = threading.Thread(
            target=_persist, args=(leaves, self._sink), daemon=True)
        self._thread.start()


class GeminiLikeCheckpointer(BaseCheckpointer):
    """Checkpoint into remote CPU memory over the training network,
    interleaved with training traffic (paper §6.2).

    Transfer = bytes / network bandwidth; stall = transfer time minus the
    overlap budget (idle network time per iteration). Short iterations give
    less overlap, which is exactly the regime where Gemini slows down.
    """
    name = "gemini"

    def __init__(self, freq: int = 1, network_gbps: float = 100.0,
                 overlap_fraction: float = 0.5, replication: int = 1):
        super().__init__(freq)
        self.network_gbps = network_gbps
        self.overlap_fraction = overlap_fraction
        self.replication = replication
        self._remote: list[np.ndarray] = []

    def _checkpoint(self, step, state_fn, grads, lr, grad_scale, iter_time):
        state = state_fn()
        leaves = _flatten_state(state)
        nbytes = sum(a.nbytes for a in leaves) * self.replication
        self._remote = [np.copy(a) for a in leaves]      # the real copy
        self._latest = state
        transfer = nbytes * 8 / (self.network_gbps * 1e9)
        budget = (iter_time or 0.0) * self.overlap_fraction
        residual = max(0.0, transfer - budget)
        time.sleep(min(residual, 0.25))                  # bounded for benches


class CheckFreqCheckpointer(AsyncCheckpointer):
    """CheckFreq: profile checkpoint overhead for the first few steps, then
    pick the frequency that keeps overhead under ``target_overhead``."""
    name = "checkfreq"

    def __init__(self, target_overhead: float = 0.035, profile_steps: int = 3):
        super().__init__(freq=1)
        self.target = target_overhead
        self.profile_steps = profile_steps
        self._profiled: list[float] = []
        self._iter_times: list[float] = []
        self.tuned_freq: Optional[int] = None

    def on_step(self, step, *, state_fn, grads=None, lr=0.0, grad_scale=1.0,
                iter_time=None) -> float:
        if iter_time:
            self._iter_times.append(iter_time)
        if self.tuned_freq is None and len(self._profiled) >= self.profile_steps:
            ovh = float(np.mean(self._profiled))
            it = float(np.mean(self._iter_times)) if self._iter_times else 1.0
            self.tuned_freq = max(1, int(np.ceil(ovh / (self.target * it))))
            self.freq = self.tuned_freq
        stall = super().on_step(step, state_fn=state_fn, grads=grads, lr=lr,
                                grad_scale=grad_scale, iter_time=iter_time)
        if self.tuned_freq is None and stall > 0:
            self._profiled.append(stall)
        return stall


class CheckmateCheckpointer(BaseCheckpointer):
    """Per-iteration checkpointing with zero training stall.

    The reduced gradients are an *output of the train step* (the RS capture
    point, docs/ARCHITECTURE.md) — handing them to the shadow cluster is a
    pointer
    enqueue; the optimizer replay happens on shadow CPU threads off the
    training critical path.
    """
    name = "checkmate"

    def __init__(self, shadow: ShadowCluster):
        super().__init__(freq=1)
        self.shadow = shadow

    def _checkpoint(self, step, state_fn, grads, lr, grad_scale, iter_time):
        assert grads is not None, "Checkmate consumes captured gradients"
        self.shadow.on_gradients(step, lr, grads, grad_scale)

    def restore(self) -> Optional[dict]:
        return self.shadow.consolidate()

    def finalize(self):
        self.shadow.consolidate()


class CaptureGatedCheckmateCheckpointer(CheckmateCheckpointer):
    """Checkmate checkpointer that skips iterations whose network capture
    was incomplete.

    The fabric simulator (`repro.net.simulator`) reports incomplete
    captures (e.g. a shadow-NIC failure mid-iteration: mirrored copies are
    not retransmitted, §4.3.2) via ``FabricResult.reassembled_ok``. Feeding
    the affected step numbers here models the shadow cluster refusing a
    partial apply; recovery then consolidates at the last fully-captured
    step. Each lost step fires once — the failed hardware is replaced
    before the post-recovery rerun, exactly like `recovery.FailurePlan`.
    """
    name = "checkmate_gated"

    def __init__(self, shadow: ShadowCluster, lost_steps=()):
        super().__init__(shadow)
        self.lost = set(lost_steps)

    def _checkpoint(self, step, state_fn, grads, lr, grad_scale, iter_time):
        if step in self.lost:
            self.lost.discard(step)
            return
        super()._checkpoint(step, state_fn, grads, lr, grad_scale,
                            iter_time)
