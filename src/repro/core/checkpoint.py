"""Checkpointer implementations: Checkmate + the copy-persist baselines the
paper compares against (§2.2, §6.2).

All baselines do *real* work (host copies, in-memory persists) so the
CPU-wall-clock benchmark harness reproduces the paper's relative overheads:

  * ``SyncCheckpointer``       — pause; copy + persist inline (worst case)
  * ``AsyncCheckpointer``      — copy inline, persist on a background thread;
                                 blocks if the previous persist is unfinished
                                 (the unbounded-memory guard the paper cites)
  * ``ShardedAsyncCheckpointer`` — Torch-DCP-like: each of N nodes handles 1/N
  * ``GeminiLikeCheckpointer`` — checkpoint to remote CPU memory over the
                                 training network; stall = transfer time not
                                 hidden by the per-iteration overlap budget
  * ``CheckFreqCheckpointer``  — async + profiling that tunes frequency so
                                 overhead stays under a target fraction
  * ``CheckmateCheckpointer``  — sends the already-captured reduced gradients
                                 through a `GradientChannel` to the shadow
                                 cluster; zero training stall

The training loop calls ``on_step(event)`` every iteration with a single
frozen `repro.core.channel.StepEvent` and adds the returned stall seconds to
its critical path. The legacy five-kwarg signature
(``on_step(step, state_fn=..., grads=..., lr=..., ...)``) still works for
one release but emits a ``DeprecationWarning``.
"""
from __future__ import annotations

import io
import threading
import time
import warnings
from typing import Optional

import numpy as np

from repro import obs as _obs
from repro.core.channel import (GradientChannel, InProcessChannel, StepEvent)
from repro.core.shadow import ShadowCluster

_ON_STEP_DEPRECATION = (
    "Checkpointer.on_step(step, state_fn=..., grads=..., ...) is "
    "deprecated; pass a single repro.core.channel.StepEvent instead")


def _flatten_state(state: dict) -> list[np.ndarray]:
    out = []
    for v in state.values():
        if isinstance(v, dict):
            out.extend(_flatten_state(v))
        else:
            out.append(np.asarray(v))
    return out


def _persist(leaves: list[np.ndarray], sink: io.BytesIO):
    sink.seek(0)
    for a in leaves:
        sink.write(memoryview(a).cast("B"))


class BaseCheckpointer:
    name = "base"
    # whether on_step reads event.grads: only gradient-streaming
    # checkpointers do — the training loop skips the per-step
    # device->host gradient copy for everyone else (copy-persist
    # baselines consume state_fn snapshots instead)
    consumes_grads = False
    # default attribution stage for this checkpointer's whole stall
    # (repro.obs.stalls.KNOWN_STAGES); gradient-streaming checkpointers
    # book fine-grained stages via _parts instead
    stage = "copy-persist"

    def __init__(self, freq: int = 1):
        self.freq = max(1, freq)
        self.n_checkpoints = 0
        self.skipped_captures = 0
        # ordered stall ledger: stage -> booked seconds, in first-booked
        # order. stall_total is DEFINED as its in-order sum, so the
        # stall-attribution report (repro.obs.stalls) sums bit-exactly to
        # the total by construction.
        self.stall_stages: dict[str, float] = {}
        self._parts: Optional[dict] = None
        self._latest: Optional[dict] = None

    @property
    def stall_total(self) -> float:
        total = 0.0
        for sec in self.stall_stages.values():
            total += sec
        return total

    def _book(self, stage: str, seconds: float):
        self.stall_stages[stage] = (self.stall_stages.get(stage, 0.0)
                                    + seconds)

    @staticmethod
    def _coerce_event(event, legacy: dict) -> StepEvent:
        """Accept the new single-StepEvent call or the deprecated kwargs."""
        if isinstance(event, StepEvent):
            if legacy:
                raise TypeError(
                    f"on_step(StepEvent) takes no extra kwargs: "
                    f"{sorted(legacy)}")
            return event
        warnings.warn(_ON_STEP_DEPRECATION, DeprecationWarning, stacklevel=3)
        return StepEvent(step=int(event), grads=legacy.get("grads"),
                         lr=legacy.get("lr", 0.0),
                         grad_scale=legacy.get("grad_scale", 1.0),
                         iter_time=legacy.get("iter_time"),
                         state_fn=legacy.get("state_fn"))

    def on_step(self, event, **legacy) -> float:
        """Consume one iteration; returns stall seconds on the critical
        path. A gated capture (``_checkpoint`` returning False) produces NO
        checkpoint: it is counted in ``skipped_captures`` and contributes
        neither to ``n_checkpoints`` nor to the stall accounting."""
        event = self._coerce_event(event, legacy)
        if event.step % self.freq != 0:
            return 0.0
        ob = _obs.get()
        t0 = time.perf_counter()
        self._parts = None
        with ob.tracer.span("checkpoint.on_step", track="checkpoint",
                            args={"step": event.step, "ck": self.name}):
            captured = self._checkpoint(event)
        if captured is False:
            self.skipped_captures += 1
            return 0.0
        stall = (captured if isinstance(captured, float)
                 else time.perf_counter() - t0)
        # book the stall by stage: _checkpoint may stage a fine-grained
        # breakdown in self._parts (whose in-order sum equals the stall it
        # returned bit-exactly); otherwise the whole stall goes to the
        # checkpointer's default stage
        parts = self._parts if self._parts is not None else {self.stage: stall}
        for part_stage, sec in parts.items():
            self._book(part_stage, sec)
        self.n_checkpoints += 1
        return stall

    def _checkpoint(self, event: StepEvent):
        """Perform one capture; return False if it was gated/skipped, or a
        float to charge that exact stall instead of the wall time of this
        call (transports that do off-critical-path work, e.g. a simulated
        fabric, report their sender-visible cost this way)."""
        raise NotImplementedError

    def restore(self) -> Optional[dict]:
        return self._latest

    def finalize(self):
        pass


class NoCheckpointer(BaseCheckpointer):
    name = "no_checkpoint"

    def on_step(self, event=None, **legacy) -> float:
        return 0.0


class SyncCheckpointer(BaseCheckpointer):
    name = "sync"

    def __init__(self, freq: int = 1):
        super().__init__(freq)
        self._sink = io.BytesIO()

    def _checkpoint(self, event: StepEvent):
        state = event.state_fn()                 # device -> host copy
        leaves = [np.copy(a) for a in _flatten_state(state)]   # clone
        _persist(leaves, self._sink)             # persist inline
        self._latest = state


class AsyncCheckpointer(BaseCheckpointer):
    name = "async"

    def __init__(self, freq: int = 1):
        super().__init__(freq)
        self._sink = io.BytesIO()
        self._thread: Optional[threading.Thread] = None

    def _checkpoint(self, event: StepEvent):
        if self._thread is not None:
            self._thread.join()                  # previous persist must finish
        state = event.state_fn()
        leaves = [np.copy(a) for a in _flatten_state(state)]
        self._latest = state
        self._thread = threading.Thread(
            target=_persist, args=(leaves, self._sink), daemon=True)
        self._thread.start()

    def finalize(self):
        if self._thread is not None:
            self._thread.join()


class ShardedAsyncCheckpointer(AsyncCheckpointer):
    """Torch-DCP-like: checkpoint sharded across N training nodes, so each
    node copies/persists 1/N of the state."""
    name = "torch_dcp"

    def __init__(self, freq: int = 1, n_shards: int = 4):
        super().__init__(freq)
        self.n_shards = n_shards

    def _checkpoint(self, event: StepEvent):
        if self._thread is not None:
            self._thread.join()
        state = event.state_fn()
        # this node's shard: 1/N of every leaf (flattened prefix slice)
        leaves = []
        for a in _flatten_state(state):
            flat = a.reshape(-1)
            leaves.append(np.copy(flat[:max(1, flat.size // self.n_shards)]))
        self._latest = state
        self._thread = threading.Thread(
            target=_persist, args=(leaves, self._sink), daemon=True)
        self._thread.start()


class GeminiLikeCheckpointer(BaseCheckpointer):
    """Checkpoint into remote CPU memory over the training network,
    interleaved with training traffic (paper §6.2).

    Transfer = bytes / network bandwidth; stall = transfer time minus the
    overlap budget (idle network time per iteration). Short iterations give
    less overlap, which is exactly the regime where Gemini slows down.
    """
    name = "gemini"

    def __init__(self, freq: int = 1, network_gbps: float = 100.0,
                 overlap_fraction: float = 0.5, replication: int = 1):
        super().__init__(freq)
        self.network_gbps = network_gbps
        self.overlap_fraction = overlap_fraction
        self.replication = replication
        self._remote: list[np.ndarray] = []

    def _checkpoint(self, event: StepEvent):
        state = event.state_fn()
        leaves = _flatten_state(state)
        nbytes = sum(a.nbytes for a in leaves) * self.replication
        self._remote = [np.copy(a) for a in leaves]      # the real copy
        self._latest = state
        transfer = nbytes * 8 / (self.network_gbps * 1e9)
        budget = (event.iter_time or 0.0) * self.overlap_fraction
        residual = max(0.0, transfer - budget)
        time.sleep(min(residual, 0.25))                  # bounded for benches


class CheckFreqCheckpointer(AsyncCheckpointer):
    """CheckFreq: profile checkpoint overhead for the first few steps, then
    pick the frequency that keeps overhead under ``target_overhead``."""
    name = "checkfreq"

    def __init__(self, target_overhead: float = 0.035, profile_steps: int = 3):
        super().__init__(freq=1)
        self.target = target_overhead
        self.profile_steps = profile_steps
        self._profiled: list[float] = []
        self._iter_times: list[float] = []
        self.tuned_freq: Optional[int] = None

    def on_step(self, event, **legacy) -> float:
        event = self._coerce_event(event, legacy)
        if event.iter_time:
            self._iter_times.append(event.iter_time)
        if self.tuned_freq is None and len(self._profiled) >= self.profile_steps:
            ovh = float(np.mean(self._profiled))
            it = float(np.mean(self._iter_times)) if self._iter_times else 1.0
            self.tuned_freq = max(1, int(np.ceil(ovh / (self.target * it))))
            self.freq = self.tuned_freq
        stall = super().on_step(event)
        if self.tuned_freq is None and stall > 0:
            self._profiled.append(stall)
        return stall


class CheckmateCheckpointer(BaseCheckpointer):
    """Per-iteration checkpointing with zero training stall.

    The reduced gradients are an *output of the train step* (the RS capture
    point, docs/ARCHITECTURE.md); ``on_step`` sends them into a
    `GradientChannel` (default: `InProcessChannel`) and applies the
    channel's deliveries to the shadow cluster — the optimizer replay
    happens on shadow CPU threads off the training critical path. The
    channel packs the capture into bucket wire layout ONCE at send; the
    delivery's flat buffers feed the shadow's fused per-bucket apply
    directly (one pass per state element, docs/channels.md), and
    ``Delivery.grads`` stays available as a lazy zero-copy leaf view. The
    stall charged per step is the channel's sender-visible send cost
    (``GradientChannel.send``'s return value), so a `PacketizedChannel`'s
    event-loop wall time — host CPU *simulating* the network — is never
    booked as training stall.

    A gated delivery (incomplete capture reported by the transport, e.g. a
    `PacketizedChannel` whose fabric lost mirror frames, §4.3.2) is NOT
    applied and NOT counted as a checkpoint — and it *desynchronizes* the
    stream: the shadow replays a contiguous gradient sequence, so applying
    step k+1 onto a replica missing step k would manufacture a state that
    never existed in training. While desynced the shadow stays frozen at
    the last fully-captured step (``skipped_steps`` records every refused
    step) until one of two resync points:

    * the next ``on_step`` whose event carries ``state_fn`` — the
      checkpointer takes a full-state copy (charged as that step's stall,
      like a sync checkpoint) and the stream resumes from it;
    * ``restore()`` — recovery rewinds training to exactly the shadow's
      state, so the resumed stream is contiguous again by construction.

    Bucket-sharded transports (``PacketizedChannel(sharded=True)``) gate
    *per owner node* instead: a delivery's ``node_complete`` verdicts mark
    which owners captured their buckets, and the two failure classes are
    distinguished by what the control plane knows:

    * a DEAD owner (``shadow.dead_nodes`` — the cluster was told the node
      died) loses exactly its shard. The surviving owners keep replaying
      the stream (``ShadowCluster.on_delivery(d, nodes=live)``) so the
      rest of the state stays current, and consolidation reports precisely
      the dead buckets as missing (`ShadowNodeLoss`). Such partial applies
      are NOT checkpoints — the step is booked as a skipped capture with
      zero stall and recorded in both ``skipped_steps`` and
      ``partial_steps`` — because the cluster as a whole cannot serve it.
    * an ALIVE owner that missed capture spans desynchronizes the cluster
      as a whole, exactly like the unsharded gate: letting the other
      owners advance would tear the consolidated tree across steps (that
      owner still serves its now-stale shard), so everyone freezes at the
      last fully-captured step.

    Either way the next ``state_fn`` resync makes the cluster whole: the
    shadow is re-bootstrapped (reviving dead owners — replacement hardware
    seeded by the full-state copy) and ``channel.revive_all()`` re-arms
    the transport.
    """
    name = "checkmate"
    consumes_grads = True

    def __init__(self, shadow: ShadowCluster,
                 channel: Optional[GradientChannel] = None,
                 durability=None):
        super().__init__(freq=1)
        self.shadow = shadow
        self.channel: GradientChannel = (channel if channel is not None
                                         else InProcessChannel())
        self.channel.open(shadow.layout)
        # optional repro.durability.DurableShadow: flush epochs ride the
        # shadow's OWN ingest path (ShadowCluster._ingest -> notify), so
        # a gated/skipped capture — which never reaches the shadow —
        # opens no epoch and the tier lag simply grows until the next
        # applied step; nothing here touches the stall ledger (duck-typed
        # so core never imports the durability package)
        self.durability = durability
        if durability is not None and durability.cluster is not shadow:
            durability.attach(shadow)
        self.skipped_steps: list[int] = []
        self.partial_steps: list[int] = []   # sharded: survivors-only applies
        self.resyncs: list[int] = []
        self._desynced = False
        self._dead_desynced = False      # dead shards seen: arm a resync

    def _apply_deliveries(self):
        for d in self.channel.poll():
            nc = getattr(d, "node_complete", None)
            if nc is None:               # unsharded transport: global gate
                if not d.complete:
                    self._desynced = True
                    self.skipped_steps.append(d.step)
                elif self._desynced:     # contiguity: refuse post-gap applies
                    self.skipped_steps.append(d.step)
                else:
                    self.shadow.on_delivery(d)
                continue
            # sharded transport: per-owner verdicts (see class docstring).
            # Holes confined to DEAD owners cost exactly those shards —
            # the survivors keep replaying. A hole on an ALIVE owner
            # desynchronizes the whole cluster: advancing the rest would
            # tear the consolidated tree across steps.
            dead = set(getattr(self.shadow, "dead_nodes", None) or ())
            incomplete = {n for n, ok in nc.items() if not ok}
            if incomplete - dead:
                self._desynced = True    # an alive owner lost capture spans
            elif incomplete:
                self._dead_desynced = True
            if self._desynced or incomplete:
                self.skipped_steps.append(d.step)
                if not self._desynced:
                    live = set(nc) - dead
                    if live:
                        self.shadow.on_delivery(d, nodes=live)
                        self.partial_steps.append(d.step)
            else:
                self.shadow.on_delivery(d)

    def _checkpoint(self, event: StepEvent):
        ob = _obs.get()
        t0 = time.perf_counter()
        if self._desynced or self._dead_desynced:
            if event.state_fn is not None:
                with ob.tracer.span("checkpoint.resync", track="checkpoint",
                                    args={"step": event.step}):
                    self.channel.poll()  # superseded by the full-state copy
                    snap = event.state_fn()
                    self.shadow.bootstrap(snap["params"], snap["mu"],
                                          snap["nu"], int(snap["step"]))
                revive = getattr(self.channel, "revive_all", None)
                if revive is not None:
                    revive()             # replacement shadow hardware racked
                self._desynced = False
                self._dead_desynced = False
                self.resyncs.append(event.step)
                dt = time.perf_counter() - t0
                self._parts = {"resync": dt}
                return dt
            if self._desynced:
                self.skipped_steps.append(event.step)
                return False             # frozen until resync or recovery
            # dead owners only: their shards are lost either way — keep
            # the survivors replaying (consolidate reports the holes)
        assert event.grads is not None, "Checkmate consumes captured gradients"
        n_skipped = len(self.skipped_steps)
        lag0 = float(getattr(self.shadow, "lag_wait_s_total", 0.0))
        stall = float(self.channel.send(event) or 0.0)
        t1 = time.perf_counter()
        self._apply_deliveries()
        if self._desynced or len(self.skipped_steps) > n_skipped:
            return False    # gated or partial: not a checkpoint, no stall
        # the sender-visible channel cost plus the inline hand-off/apply
        # (sync-mode shadows run the optimizer on this thread)
        inline = time.perf_counter() - t1
        # stage the attribution: the channel decomposes its own sender
        # stall (its parts sum in-order to `stall` bit-exactly), and the
        # inline apply is booked on top — so parts sum == stall + inline
        parts = dict(getattr(self.channel, "last_send_parts", None)
                     or {"send": stall})
        # a bounded-lag shadow (ShadowCluster(max_lag_steps=...)) may have
        # blocked this ingest until its backlog dropped under the bound —
        # split that wait out of the inline hand-off as the named
        # `apply-lag` stage (the zero-overhead budget a too-slow applier
        # actually costs the trainer); parts stay sum-consistent
        lag_wait = float(getattr(self.shadow, "lag_wait_s_total", 0.0)) - lag0
        if lag_wait > 0.0:
            parts["apply-lag"] = lag_wait
            inline = max(0.0, inline - lag_wait)
        parts["inline-apply"] = inline
        self._parts = parts
        return sum(parts.values())

    def reconfigure(self, shadow: ShadowCluster,
                    channel: Optional[GradientChannel] = None) -> float:
        """Swap in a re-laid-out shadow plane after an elastic restore.

        ``shadow`` is the rebuilt cluster (`repro.core.elastic.
        rebuild_shadow` — already seeded from the consolidated
        checkpoint, durability migrated). The old channel is closed and
        the new one (or the old instance, re-opened — `PacketizedChannel.
        open` re-derives owners/topology/wire geometry from the layout)
        is opened against the NEW layout, so channel routing and shadow
        ownership are rebuilt from one consistent derivation. Any desync
        is cleared: the stream restarts from the re-seeded replica, which
        is contiguous by construction. The wall time is booked on the
        stall ledger as the named ``elastic-reshard`` stage and returned.
        """
        ob = _obs.get()
        t0 = time.perf_counter()
        with ob.tracer.span("checkpoint.elastic-reshard", track="checkpoint",
                            args={"n_nodes": shadow.n_nodes}):
            self.channel.close()
            if channel is not None:
                self.channel = channel
            self.channel.open(shadow.layout)
            self.shadow = shadow
            if shadow.durability is not None:
                self.durability = shadow.durability
            revive = getattr(self.channel, "revive_all", None)
            if revive is not None:
                revive()
            self._desynced = False
            self._dead_desynced = False
        dt = time.perf_counter() - t0
        self._book("elastic-reshard", dt)
        return dt

    def restore(self) -> Optional[dict]:
        ob = _obs.get()
        t0 = time.perf_counter()
        with ob.tracer.span("recovery.consolidate", track="recovery"):
            out = self.shadow.consolidate()
        # recovery genuinely stalls training while shadows drain
        self._book("consolidate-wait", time.perf_counter() - t0)
        self._desynced = False           # training rewinds to this state
        self._dead_desynced = False
        return out

    def finalize(self):
        from repro.core.shadow import ShadowNodeLoss
        self._apply_deliveries()
        self.channel.close()
        try:
            self.shadow.consolidate()
        except ShadowNodeLoss:
            pass        # dead shards at shutdown: the partial is all there is
        if self.durability is not None:
            self.durability.drain()      # everything applied is durable
            self.durability.close()
