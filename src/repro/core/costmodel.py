"""Appendix A/B cost model: LLaMA-style FLOPs, iteration time, wasted
GPU-hours, optimal checkpoint frequency, and Checkmate savings.

Reproduces Figure 1 (wasted GPU-hours vs checkpoint frequency), Figure 11
(savings vs scale / failure rate / overhead), and the §6.7 headline numbers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


# ---------------------------------------------------------------------------
# Appendix A: FLOPs + iteration time
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LlamaDims:
    b: int          # batch size (sequences)
    s: int          # sequence length
    L: int          # layers
    h: int          # hidden dim
    f: int          # FFN dim
    v: int          # vocab
    a: int          # query heads
    g: int          # kv groups  (paper notation: K/V heads)


LLAMA3_405B = LlamaDims(b=2048, s=8192, L=126, h=16384, f=53248,
                        v=128256, a=128, g=8)


def forward_flops(d: LlamaDims) -> float:
    """Appendix A, component by component — the paper's formulas VERBATIM
    (note the paper counts the FFN as two linear maps, 4bshf, not swiglu's
    three; we keep its convention so the validation numbers line up)."""
    head_dim = d.h // d.a
    kv_dim = d.g * head_dim                    # the paper's (g*a) term
    qkv = 2 * (d.b * d.s * d.h ** 2 + 2 * d.b * d.s * d.h * kv_dim)
    attn = 4 * d.b * d.s ** 2 * d.h
    attn_out = 2 * d.b * d.s * d.h * kv_dim
    ffn = 4 * d.b * d.s * d.h * d.f
    rope = 2 * d.b * d.s * d.h
    per_layer = qkv + attn + attn_out + ffn + rope
    vocab = 4 * d.b * d.s * d.h * d.v
    return per_layer * d.L + vocab


def iteration_flops(d: LlamaDims) -> float:
    """fwd + bwd = 3x fwd (no activation checkpointing, per the report)."""
    return 3.0 * forward_flops(d)


def iteration_time(d: LlamaDims, achieved_flops_per_gpu: float,
                   n_gpus: int) -> float:
    return iteration_flops(d) / (achieved_flops_per_gpu * n_gpus)


def checkpoint_time(params: float, bytes_per_param: float = 5.93,
                    storage_tput: float = 2e12) -> float:
    """Paper App. A: 405B checkpoint over a 2 TB/s storage cluster ~ 1.2 s."""
    return params * bytes_per_param / storage_tput


# ---------------------------------------------------------------------------
# Appendix B: waste + cost
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CostParams:
    failure_rate: float = 2.0e-5     # lambda: failures per GPU-hour (Meta)
    n_gpus: int = 16384              # N
    duration_h: float = 54 * 24      # D: training duration (hours)
    iter_time_s: float = 4.58        # t
    ckpt_stall_s: float = 1.2        # omega
    gpu_price: float = 11.06         # $/GPU/h (H100 SXM5, GCP)
    cpu_price: float = 1.28          # $/CPU-node/h (32 cores / 128 GB)
    cpu_nodes: int = 128             # C (Checkmate shadow cluster)


def wasted_gpu_hours_sota(f: float, p: CostParams) -> float:
    """Eq. 2: ND( 0.5*lambda*N*f*t + omega/(f*t) ), times in hours."""
    t = p.iter_time_s / 3600.0
    w = p.ckpt_stall_s / 3600.0
    return p.n_gpus * p.duration_h * (
        0.5 * p.failure_rate * p.n_gpus * f * t + w / (f * t))


def optimal_frequency(p: CostParams) -> float:
    """f* = sqrt(2*omega / (lambda*N*t^2)), floored at 1 (Appendix B)."""
    t = p.iter_time_s / 3600.0
    w = p.ckpt_stall_s / 3600.0
    f = math.sqrt(2.0 * w / (p.failure_rate * p.n_gpus * t * t))
    return max(f, 1.0)


def wasted_gpu_hours_sota_min(p: CostParams) -> float:
    return wasted_gpu_hours_sota(optimal_frequency(p), p)


def wasted_gpu_hours_checkmate(p: CostParams) -> float:
    """Per-iteration checkpoints: half an iteration repeated per failure."""
    t = p.iter_time_s / 3600.0
    return 0.5 * p.failure_rate * p.n_gpus ** 2 * p.duration_h * t


def cost_sota_min(p: CostParams) -> float:
    return p.gpu_price * wasted_gpu_hours_sota_min(p)


def cost_checkmate(p: CostParams) -> float:
    """Eq. 4: wasted GPU cost + shadow-cluster CPU cost."""
    return (p.gpu_price * wasted_gpu_hours_checkmate(p)
            + p.cpu_price * p.duration_h * p.cpu_nodes)


def cpu_node_hours(p: CostParams) -> float:
    return p.duration_h * p.cpu_nodes


def gpu_hours_saved_per_day(p: CostParams) -> float:
    """Figure 11 y-axis: expected GPU-hours saved per day vs tuned SOTA."""
    per_run = wasted_gpu_hours_sota_min(p) - wasted_gpu_hours_checkmate(p)
    return per_run / (p.duration_h / 24.0)


def savings_usd(p: CostParams) -> float:
    return cost_sota_min(p) - cost_checkmate(p)


def sweep_frequencies(p: CostParams, freqs) -> list[tuple[float, float]]:
    """(f, wasted GPU-hours) pairs — Figure 1 curve."""
    return [(f, wasted_gpu_hours_sota(f, p)) for f in freqs]


def sweep_overhead(p: CostParams, overheads_s, cluster_sizes
                   ) -> dict[int, list[tuple[float, float]]]:
    """Figure 11: {cluster size: [(omega, saved GPU-h/day), ...]}."""
    out = {}
    for n in cluster_sizes:
        rows = []
        for w in overheads_s:
            q = CostParams(failure_rate=p.failure_rate, n_gpus=n,
                           duration_h=p.duration_h, iter_time_s=p.iter_time_s,
                           ckpt_stall_s=w, gpu_price=p.gpu_price,
                           cpu_price=p.cpu_price, cpu_nodes=p.cpu_nodes)
            rows.append((w, gpu_hours_saved_per_day(q)))
        out[n] = rows
    return out


# ---------------------------------------------------------------------------
# Shadow-plane budgets (§4.1.1, §6.3): how many shadow nodes does a given
# capture layout need, and does it fit at all?
# ---------------------------------------------------------------------------

#: Resident optimizer-state streams per gradient element on a shadow node:
#: params (wire dtype) + mu + nu (float32 each) — §4.2's functional replay.
MOMENT_BYTES_PER_ELEM = 8          # mu + nu, float32 each


@dataclass(frozen=True)
class ShadowBudget:
    """Per-node resources of one shadow box.

    Defaults model the paper's dual-NIC CPU host (2x100 GbE, §4.1.1) with a
    1.5 TB DRAM configuration; ``ram_headroom`` reserves a fraction for the
    OS, rx buffers, and consolidation scratch.
    """
    ram_bytes_per_node: float = 1.5e12
    nic_gbps_per_node: float = 200.0
    max_nodes: int = 64
    ram_headroom: float = 0.9
    # durability tier behind the node (repro.durability): sustained local
    # write bandwidth and capacity for the flushed base + delta chain.
    # Defaults model a 4-NVMe RAID-0 scratch volume.
    disk_gbps_per_node: float = 96.0
    disk_bytes_per_node: float = 30e12

    @property
    def usable_ram(self) -> float:
        return self.ram_bytes_per_node * self.ram_headroom


class ShadowPlanError(ValueError):
    """No shadow fleet within budget can absorb this layout (the planner's
    loud refusal — the message says which resource failed and what to change)."""


@dataclass(frozen=True)
class ShadowPlan:
    """Feasible sharding of a capture layout across shadow nodes."""
    n_nodes: int               # minimum feasible node count
    ram_bound: int             # nodes needed by aggregate resident state
    nic_bound: int             # nodes needed by per-iteration wire bytes
    grad_bytes: int            # wire bytes per iteration (all buckets)
    state_bytes: int           # resident p+mu+nu bytes across the fleet
    bytes_per_node_max: int    # largest per-node resident state (RSS proxy)
    gbps_per_node_max: float   # hottest node's ingest rate
    n_buckets: int
    # durability flush budget terms (1/0.0 when no flush policy given):
    flush_bound: int = 1       # nodes needed by sustained flush bandwidth
    disk_bound: int = 1        # nodes needed by retained base+delta bytes
    flush_gbps_per_node_max: float = 0.0   # hottest node's flush rate


def _bucket_state_bytes(bucket) -> int:
    import numpy as np
    from repro.core.buckets import bucket_dtype
    return bucket.size * (np.dtype(bucket_dtype(bucket)).itemsize
                          + MOMENT_BYTES_PER_ELEM)


#: int8 payload + per-slot f32 scales vs the raw p+mu+nu streams — the
#: planning-time shrink factor for a compressed delta flush.
FLUSH_COMPRESS_FACTOR = 0.25


def plan_shadow_nodes(layout, *, iter_time_s: float = 4.58,
                      budget: ShadowBudget = ShadowBudget(),
                      flush_every_steps: int | None = None,
                      flush_compress: bool = False,
                      retain_epochs: int = 8) -> ShadowPlan:
    """Minimum shadow-node count for ``layout`` under ``budget``.

    Two aggregate bounds (RAM: resident p+mu+nu must fit the fleet; NIC:
    each node must ingest its buckets' wire bytes within one iteration)
    plus a granularity pass: buckets are indivisible, so the byte-balanced
    assignment at the candidate count must actually fit per node. Raises
    :class:`ShadowPlanError` with an actionable message when nothing
    within ``budget.max_nodes`` fits.

    ``flush_every_steps`` adds the durability budget (repro.durability):
    each node must sustain flushing its partition's worst-case dirty
    state (every bucket, p+mu+nu; times :data:`FLUSH_COMPRESS_FACTOR`
    when ``flush_compress``) to its tier once per flush epoch within the
    epoch's wall time, and retain one base plus ``retain_epochs`` deltas
    on ``budget.disk_bytes_per_node``. ``None`` (default) skips the
    durability terms entirely — plans are unchanged from a fleet with no
    tiers attached.
    """
    from repro.core.multicast import assign_buckets, node_partitions

    if not layout.buckets:
        raise ShadowPlanError("empty layout: nothing to shadow")
    grad_bytes = layout.total_bytes
    state_bytes = sum(_bucket_state_bytes(b) for b in layout.buckets)
    nic_bytes_per_iter = budget.nic_gbps_per_node * 1e9 / 8.0 * iter_time_s

    # Indivisible-bucket feasibility: the largest bucket must fit ONE node.
    big = max(layout.buckets, key=_bucket_state_bytes)
    if _bucket_state_bytes(big) > budget.usable_ram:
        raise ShadowPlanError(
            f"bucket {big.bucket_id} ({len(big.slots)} leaves) needs "
            f"{_bucket_state_bytes(big) / 1e9:.1f} GB resident state but a "
            f"node offers {budget.usable_ram / 1e9:.1f} GB usable; buckets "
            "are indivisible — rebucket the capture with a smaller "
            "cap_bytes or raise ShadowBudget.ram_bytes_per_node")
    if big.nbytes > nic_bytes_per_iter:
        raise ShadowPlanError(
            f"bucket {big.bucket_id} carries {big.nbytes / 1e9:.1f} GB per "
            f"iteration but a node's NIC absorbs "
            f"{nic_bytes_per_iter / 1e9:.1f} GB in {iter_time_s:.2f} s; "
            "rebucket with a smaller cap_bytes or raise "
            "ShadowBudget.nic_gbps_per_node")

    ram_bound = max(1, math.ceil(state_bytes / budget.usable_ram))
    nic_bound = max(1, math.ceil(grad_bytes / nic_bytes_per_iter))

    # durability terms: worst-case flush bytes per epoch + retained chain
    flush_factor = FLUSH_COMPRESS_FACTOR if flush_compress else 1.0
    flush_bound = disk_bound = 1
    flush_bytes_per_epoch = retained_bytes = 0.0
    disk_bytes_per_epoch = 0.0
    if flush_every_steps is not None:
        if flush_every_steps < 1:
            raise ShadowPlanError(
                f"flush_every_steps must be >= 1, got {flush_every_steps}")
        epoch_s = flush_every_steps * iter_time_s
        disk_bytes_per_epoch = budget.disk_gbps_per_node * 1e9 / 8.0 * epoch_s
        flush_bytes_per_epoch = state_bytes * flush_factor
        retained_bytes = state_bytes * (1.0 + retain_epochs * flush_factor)
        big_flush = _bucket_state_bytes(big) * flush_factor
        if big_flush > disk_bytes_per_epoch:
            raise ShadowPlanError(
                f"bucket {big.bucket_id} flushes {big_flush / 1e9:.1f} GB "
                f"per epoch but a node's tier absorbs "
                f"{disk_bytes_per_epoch / 1e9:.1f} GB in {epoch_s:.2f} s; "
                "rebucket with a smaller cap_bytes, raise "
                "ShadowBudget.disk_gbps_per_node, or flush less often "
                "(FlushPolicy.every_steps)")
        if _bucket_state_bytes(big) * (1.0 + retain_epochs * flush_factor) \
                > budget.disk_bytes_per_node:
            raise ShadowPlanError(
                f"bucket {big.bucket_id}'s retained base+delta chain "
                f"exceeds ShadowBudget.disk_bytes_per_node="
                f"{budget.disk_bytes_per_node / 1e12:.1f} TB; lower "
                "retain_epochs or add tier capacity")
        flush_bound = max(1, math.ceil(
            flush_bytes_per_epoch / disk_bytes_per_epoch))
        disk_bound = max(1, math.ceil(
            retained_bytes / budget.disk_bytes_per_node))

    by_id = {b.bucket_id: b for b in layout.buckets}
    n = max(ram_bound, nic_bound, flush_bound, disk_bound)
    while n <= budget.max_nodes:
        owners = assign_buckets(layout, n)
        parts = node_partitions(layout, owners, n)
        per_state = [sum(_bucket_state_bytes(by_id[i]) for i in bs)
                     for bs in parts]
        per_wire = [sum(by_id[i].nbytes for i in bs) for bs in parts]
        fits = (max(per_state) <= budget.usable_ram
                and max(per_wire) <= nic_bytes_per_iter)
        flush_gbps_max = 0.0
        if fits and flush_every_steps is not None:
            per_flush = [s * flush_factor for s in per_state]
            per_retained = [s * (1.0 + retain_epochs * flush_factor)
                            for s in per_state]
            fits = (max(per_flush) <= disk_bytes_per_epoch
                    and max(per_retained) <= budget.disk_bytes_per_node)
            flush_gbps_max = (max(per_flush) * 8.0
                              / (flush_every_steps * iter_time_s) / 1e9)
        if fits:
            return ShadowPlan(
                n_nodes=n, ram_bound=ram_bound, nic_bound=nic_bound,
                grad_bytes=grad_bytes, state_bytes=state_bytes,
                bytes_per_node_max=max(per_state),
                gbps_per_node_max=max(per_wire) * 8.0 / iter_time_s / 1e9,
                n_buckets=len(layout.buckets),
                flush_bound=flush_bound, disk_bound=disk_bound,
                flush_gbps_per_node_max=flush_gbps_max)
        n += 1
    raise ShadowPlanError(
        f"layout ({grad_bytes / 1e9:.1f} GB wire, {state_bytes / 1e9:.1f} GB "
        f"resident) is infeasible within ShadowBudget.max_nodes="
        f"{budget.max_nodes} (RAM bound {ram_bound}, NIC bound {nic_bound}, "
        f"flush bound {flush_bound}, disk bound {disk_bound}); raise "
        "max_nodes, add RAM/NIC/disk per node, or lengthen iter_time_s")


# ---------------------------------------------------------------------------
# Elastic replanning: when N train ranks die with no hot spare, pick the
# largest feasible parallelism layout the survivors can host (Universal
# Checkpointing / Oobleck shape — the consolidated shadow checkpoint is
# layout-agnostic, so restore re-partitions onto whatever this plans).
# ---------------------------------------------------------------------------


class ElasticPlanError(ValueError):
    """No layout on the surviving ranks can host the job (the elastic
    planner's loud refusal — the message says which constraint failed and
    what to change)."""


@dataclass(frozen=True)
class ElasticMeshBudget:
    """Per-rank resources + layout constraints for elastic replanning.

    ``model_parallel`` and ``pipeline_stages`` are fixed by the lowered
    program (tensor/pipeline splits can't change without recompiling the
    whole partition strategy); only the DP width flexes. ``global_batch``
    (sequences) constrains feasible DP widths to even divisors so the
    re-split data stream preserves global batch order exactly.
    ``allow_fsdp`` lets the planner flip ZeRO-3-style weight sharding on
    when a full replica no longer fits a rank's HBM.
    """
    hbm_bytes_per_rank: float = 80e9      # one H100 SXM
    model_parallel: int = 1
    pipeline_stages: int = 1
    min_dp: int = 1
    global_batch: int | None = None
    allow_fsdp: bool = True
    hbm_headroom: float = 0.9             # activations, rx buffers, compiler

    @property
    def usable_hbm(self) -> float:
        return self.hbm_bytes_per_rank * self.hbm_headroom


@dataclass(frozen=True)
class ElasticPlan:
    """Largest feasible layout on the survivors (see `plan_elastic_mesh`)."""
    dp: int                        # new data-parallel width
    model: int                     # tensor-parallel width (unchanged)
    stages: int                    # pipeline depth (unchanged)
    fsdp: bool                     # weight sharding flipped on to fit?
    survivors: tuple[int, ...]     # rank ids the new mesh is built from
    dropped: tuple[int, ...]       # surviving ranks the layout can't use
    mesh_shape: tuple[int, ...]    # physical mesh extents, axis order below
    axis_names: tuple[str, ...]    # ("data", "model") [+ "stage"]
    state_bytes_per_rank: int      # resident p+mu+nu bytes per rank

    @property
    def n_ranks(self) -> int:
        return self.dp * self.model * self.stages


def plan_elastic_mesh(survivors, budget: ElasticMeshBudget = ElasticMeshBudget(),
                      *, state_bytes: int | None = None,
                      layout=None, fsdp: bool = False) -> ElasticPlan:
    """Largest feasible layout from the surviving ranks.

    ``survivors`` is the surviving rank ids (or a bare count). The planner
    keeps the model/pipeline split fixed and walks the DP width DOWN from
    the widest the survivors allow, taking the first width that (a) divides
    ``budget.global_batch`` evenly when given — the re-split stream must
    preserve global batch order — and (b) fits each rank's HBM: a pure-DP
    replica holds the full ``state_bytes`` (p+mu+nu, computed from
    ``layout`` when given) per model shard; if that overflows and
    ``budget.allow_fsdp``, the planner flips FSDP on, sharding state across
    the DP width. ``fsdp=True`` pins the incoming layout's flag (an FSDP
    run never silently un-shards onto fewer ranks).

    Deterministic: the lowest-numbered survivors fill the mesh; leftover
    ranks are reported as ``dropped``. Raises :class:`ElasticPlanError`
    with an actionable message when nothing fits.
    """
    if isinstance(survivors, int):
        ids = tuple(range(survivors))
    else:
        ids = tuple(sorted(survivors))
    if len(set(ids)) != len(ids):
        raise ElasticPlanError(f"duplicate survivor rank ids: {ids}")
    per_replica = budget.model_parallel * budget.pipeline_stages
    if state_bytes is None and layout is not None:
        state_bytes = sum(_bucket_state_bytes(b) for b in layout.buckets)
    dp_max = len(ids) // per_replica
    if dp_max < budget.min_dp:
        raise ElasticPlanError(
            f"{len(ids)} survivor(s) cannot host even min_dp="
            f"{budget.min_dp} replicas of a {budget.model_parallel}-way "
            f"model x {budget.pipeline_stages}-stage split "
            f"({per_replica * budget.min_dp} ranks needed); the job cannot "
            "shrink further — restore onto replacement hardware instead")
    tried: list[str] = []
    for dp in range(dp_max, budget.min_dp - 1, -1):
        if budget.global_batch is not None and budget.global_batch % dp:
            tried.append(f"dp={dp}: does not divide global_batch="
                         f"{budget.global_batch}")
            continue
        for use_fsdp in ((True,) if fsdp else
                         (False, True) if budget.allow_fsdp else (False,)):
            per_rank = 0
            if state_bytes is not None:
                per_rank = math.ceil(state_bytes / budget.model_parallel
                                     / budget.pipeline_stages
                                     / (dp if use_fsdp else 1))
                if per_rank > budget.usable_hbm:
                    tried.append(
                        f"dp={dp}{' fsdp' if use_fsdp else ''}: "
                        f"{per_rank / 1e9:.1f} GB/rank > "
                        f"{budget.usable_hbm / 1e9:.1f} GB usable")
                    continue
            n = dp * per_replica
            shape: tuple[int, ...] = (dp, budget.model_parallel)
            names: tuple[str, ...] = ("data", "model")
            if budget.pipeline_stages > 1:
                shape += (budget.pipeline_stages,)
                names += ("stage",)
            return ElasticPlan(
                dp=dp, model=budget.model_parallel,
                stages=budget.pipeline_stages, fsdp=use_fsdp,
                survivors=ids[:n], dropped=ids[n:],
                mesh_shape=shape, axis_names=names,
                state_bytes_per_rank=int(per_rank))
    detail = "; ".join(tried) if tried else "no DP width in range"
    raise ElasticPlanError(
        f"no feasible layout on {len(ids)} survivor(s) "
        f"(model_parallel={budget.model_parallel}, "
        f"stages={budget.pipeline_stages}, min_dp={budget.min_dp}): "
        f"{detail}; relax min_dp, raise hbm_bytes_per_rank, or allow_fsdp")


def capture_leaf_specs(cfg) -> list[tuple[str, tuple, str]]:
    """``(name, shape, dtype)`` leaves as the DDP capture side sees them.

    The repo's jax models scan-stack per-layer (and per-expert) weights
    into mega-leaves; the capture-side bucketer sees them UNSTACKED — one
    leaf per layer (per expert for MoE). Metadata only: nothing allocates,
    so this scales to arctic_480b's ~480B params.
    """
    from repro.models.registry import param_specs

    out: list[tuple[str, tuple, str]] = []
    for name, spec in param_specs(cfg).items():
        entries = [(name, tuple(spec.shape), tuple(spec.logical))]
        while entries and entries[0][2] and \
                entries[0][2][0] in ("layers", "expert"):
            axis = entries[0][2][0]
            entries = [(f"{nm}.{axis}{i}", shape[1:], logical[1:])
                       for nm, shape, logical in entries
                       for i in range(shape[0])]
        out.extend((nm, shape, str(spec.dtype)) for nm, shape, _ in entries)
    return out


def capture_layout(cfg, cap_bytes: int | None = None):
    """Metadata-only :class:`~repro.core.buckets.BucketLayout` of a config's
    capture-side leaves (default DDP 25 MB cap)."""
    from repro.core.buckets import DEFAULT_BUCKET_BYTES, build_buckets
    return build_buckets(capture_leaf_specs(cfg),
                         cap_bytes=cap_bytes or DEFAULT_BUCKET_BYTES)


def shadow_plan_for_config(cfg, *, cap_bytes: int | None = None,
                           iter_time_s: float = 4.58,
                           budget: ShadowBudget = ShadowBudget()
                           ) -> ShadowPlan:
    """Budget-check one architecture config end to end (metadata only)."""
    return plan_shadow_nodes(capture_layout(cfg, cap_bytes),
                             iter_time_s=iter_time_s, budget=budget)
