"""Appendix A/B cost model: LLaMA-style FLOPs, iteration time, wasted
GPU-hours, optimal checkpoint frequency, and Checkmate savings.

Reproduces Figure 1 (wasted GPU-hours vs checkpoint frequency), Figure 11
(savings vs scale / failure rate / overhead), and the §6.7 headline numbers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


# ---------------------------------------------------------------------------
# Appendix A: FLOPs + iteration time
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LlamaDims:
    b: int          # batch size (sequences)
    s: int          # sequence length
    L: int          # layers
    h: int          # hidden dim
    f: int          # FFN dim
    v: int          # vocab
    a: int          # query heads
    g: int          # kv groups  (paper notation: K/V heads)


LLAMA3_405B = LlamaDims(b=2048, s=8192, L=126, h=16384, f=53248,
                        v=128256, a=128, g=8)


def forward_flops(d: LlamaDims) -> float:
    """Appendix A, component by component — the paper's formulas VERBATIM
    (note the paper counts the FFN as two linear maps, 4bshf, not swiglu's
    three; we keep its convention so the validation numbers line up)."""
    head_dim = d.h // d.a
    kv_dim = d.g * head_dim                    # the paper's (g*a) term
    qkv = 2 * (d.b * d.s * d.h ** 2 + 2 * d.b * d.s * d.h * kv_dim)
    attn = 4 * d.b * d.s ** 2 * d.h
    attn_out = 2 * d.b * d.s * d.h * kv_dim
    ffn = 4 * d.b * d.s * d.h * d.f
    rope = 2 * d.b * d.s * d.h
    per_layer = qkv + attn + attn_out + ffn + rope
    vocab = 4 * d.b * d.s * d.h * d.v
    return per_layer * d.L + vocab


def iteration_flops(d: LlamaDims) -> float:
    """fwd + bwd = 3x fwd (no activation checkpointing, per the report)."""
    return 3.0 * forward_flops(d)


def iteration_time(d: LlamaDims, achieved_flops_per_gpu: float,
                   n_gpus: int) -> float:
    return iteration_flops(d) / (achieved_flops_per_gpu * n_gpus)


def checkpoint_time(params: float, bytes_per_param: float = 5.93,
                    storage_tput: float = 2e12) -> float:
    """Paper App. A: 405B checkpoint over a 2 TB/s storage cluster ~ 1.2 s."""
    return params * bytes_per_param / storage_tput


# ---------------------------------------------------------------------------
# Appendix B: waste + cost
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CostParams:
    failure_rate: float = 2.0e-5     # lambda: failures per GPU-hour (Meta)
    n_gpus: int = 16384              # N
    duration_h: float = 54 * 24      # D: training duration (hours)
    iter_time_s: float = 4.58        # t
    ckpt_stall_s: float = 1.2        # omega
    gpu_price: float = 11.06         # $/GPU/h (H100 SXM5, GCP)
    cpu_price: float = 1.28          # $/CPU-node/h (32 cores / 128 GB)
    cpu_nodes: int = 128             # C (Checkmate shadow cluster)


def wasted_gpu_hours_sota(f: float, p: CostParams) -> float:
    """Eq. 2: ND( 0.5*lambda*N*f*t + omega/(f*t) ), times in hours."""
    t = p.iter_time_s / 3600.0
    w = p.ckpt_stall_s / 3600.0
    return p.n_gpus * p.duration_h * (
        0.5 * p.failure_rate * p.n_gpus * f * t + w / (f * t))


def optimal_frequency(p: CostParams) -> float:
    """f* = sqrt(2*omega / (lambda*N*t^2)), floored at 1 (Appendix B)."""
    t = p.iter_time_s / 3600.0
    w = p.ckpt_stall_s / 3600.0
    f = math.sqrt(2.0 * w / (p.failure_rate * p.n_gpus * t * t))
    return max(f, 1.0)


def wasted_gpu_hours_sota_min(p: CostParams) -> float:
    return wasted_gpu_hours_sota(optimal_frequency(p), p)


def wasted_gpu_hours_checkmate(p: CostParams) -> float:
    """Per-iteration checkpoints: half an iteration repeated per failure."""
    t = p.iter_time_s / 3600.0
    return 0.5 * p.failure_rate * p.n_gpus ** 2 * p.duration_h * t


def cost_sota_min(p: CostParams) -> float:
    return p.gpu_price * wasted_gpu_hours_sota_min(p)


def cost_checkmate(p: CostParams) -> float:
    """Eq. 4: wasted GPU cost + shadow-cluster CPU cost."""
    return (p.gpu_price * wasted_gpu_hours_checkmate(p)
            + p.cpu_price * p.duration_h * p.cpu_nodes)


def cpu_node_hours(p: CostParams) -> float:
    return p.duration_h * p.cpu_nodes


def gpu_hours_saved_per_day(p: CostParams) -> float:
    """Figure 11 y-axis: expected GPU-hours saved per day vs tuned SOTA."""
    per_run = wasted_gpu_hours_sota_min(p) - wasted_gpu_hours_checkmate(p)
    return per_run / (p.duration_h / 24.0)


def savings_usd(p: CostParams) -> float:
    return cost_sota_min(p) - cost_checkmate(p)


def sweep_frequencies(p: CostParams, freqs) -> list[tuple[float, float]]:
    """(f, wasted GPU-hours) pairs — Figure 1 curve."""
    return [(f, wasted_gpu_hours_sota(f, p)) for f in freqs]


def sweep_overhead(p: CostParams, overheads_s, cluster_sizes
                   ) -> dict[int, list[tuple[float, float]]]:
    """Figure 11: {cluster size: [(omega, saved GPU-h/day), ...]}."""
    out = {}
    for n in cluster_sizes:
        rows = []
        for w in overheads_s:
            q = CostParams(failure_rate=p.failure_rate, n_gpus=n,
                           duration_h=p.duration_h, iter_time_s=p.iter_time_s,
                           ckpt_stall_s=w, gpu_price=p.gpu_price,
                           cpu_price=p.cpu_price, cpu_nodes=p.cpu_nodes)
            rows.append((w, gpu_hours_saved_per_day(q)))
        out[n] = rows
    return out
