"""Failure injection + recovery from the shadow checkpoint, including
elastic restart (restore onto a different mesh / DP width).

Recovery flow (paper §4.2.4): consolidate shadow partitions into a full
checkpoint (configurable timeout), rebuild the device TrainState from it,
and reset the data iterator to the checkpoint step. Because the data
pipeline is PRNG-counter addressed (repro.data.synthetic), resume is exact:
the recovered run replays the identical batch sequence.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.shadow import ShadowCluster, ShadowNodeLoss
from repro.dist.sharding import ShardingRules
from repro.optim import TrainState
from repro.train.step import state_shardings


@dataclass
class FailurePlan:
    """Deterministic failure injection for tests/benchmarks.

    Each planned failure fires ONCE (a failure is an event): after recovery
    the re-executed iteration proceeds normally, exactly like a real node
    replacement."""
    fail_at_steps: tuple[int, ...] = ()
    fired: set = dataclasses.field(default_factory=set)

    def should_fail(self, step: int) -> bool:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            return True
        return False


def state_from_checkpoint(ckpt: dict, cfg, rules: ShardingRules) -> TrainState:
    """Rebuild a device TrainState from a consolidated shadow checkpoint.

    Works across meshes: leaves are host arrays; ``device_put`` against the
    *target* mesh's shardings performs the elastic reshard.
    """
    sh = state_shardings(cfg, rules)
    params = {k: jax.device_put(np.asarray(v), sh.params[k])
              for k, v in ckpt["params"].items()}
    mu = {k: jax.device_put(np.asarray(v), sh.mu[k])
          for k, v in ckpt["mu"].items()}
    nu = {k: jax.device_put(np.asarray(v), sh.nu[k])
          for k, v in ckpt["nu"].items()}
    return TrainState(params=params, mu=mu, nu=nu,
                      step=jnp.asarray(ckpt["step"], jnp.int32))


def checkpoint_from_state(state: TrainState) -> dict:
    """Host-side snapshot of a TrainState (used by baselines & tests)."""
    return {
        "params": {k: np.asarray(v) for k, v in state.params.items()},
        "mu": {k: np.asarray(v) for k, v in state.mu.items()},
        "nu": {k: np.asarray(v) for k, v in state.nu.items()},
        "step": int(state.step),
    }


def recover(shadow: ShadowCluster, cfg, rules: ShardingRules,
            timeout: Optional[float] = None,
            allow_partial: bool = False) -> tuple[TrainState, int]:
    """Consolidate the shadow cluster and rebuild training state.

    Returns (state, resume_step). The paper's consolidation is a
    distributed gather: every shadow node serves exactly the bucket
    fragments it owns and the full tree is reassembled from them
    (`ShadowCluster.consolidate`).

    A dead shadow node surfaces as `repro.core.shadow.ShadowNodeLoss`
    naming exactly the missing buckets. By default that propagates —
    recovery must not silently hand back a checkpoint with holes. Pass
    ``allow_partial=True`` to rebuild the surviving leaves anyway (e.g. to
    warm-start everything the cluster still holds before refetching the
    dead shard from durable storage); the returned state then contains
    only the surviving nodes' leaves.
    """
    try:
        ckpt = shadow.consolidate(timeout=timeout)
    except ShadowNodeLoss as e:
        if not allow_partial:
            raise
        ckpt = e.partial
    state = state_from_checkpoint(ckpt, cfg, rules)
    return state, int(ckpt["step"])
