"""Failure injection + recovery from the shadow checkpoint, including
elastic restart (restore onto a different mesh / DP width).

Recovery flow (paper §4.2.4): consolidate shadow partitions into a full
checkpoint (configurable timeout), rebuild the device TrainState from it,
and reset the data iterator to the checkpoint step. Because the data
pipeline is PRNG-counter addressed (repro.data.synthetic), resume is exact:
the recovered run replays the identical batch sequence.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.shadow import ShadowCluster, ShadowNodeLoss
from repro.dist.sharding import ShardingRules
from repro.optim import TrainState
from repro.train.step import state_shardings


@dataclass
class FailurePlan:
    """Deterministic failure injection for tests/benchmarks.

    Each planned failure fires ONCE (a failure is an event): after recovery
    the re-executed iteration proceeds normally, exactly like a real node
    replacement."""
    fail_at_steps: tuple[int, ...] = ()
    fired: set = dataclasses.field(default_factory=set)

    def should_fail(self, step: int) -> bool:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            return True
        return False


def state_from_checkpoint(ckpt: dict, cfg, rules: ShardingRules) -> TrainState:
    """Rebuild a device TrainState from a consolidated shadow checkpoint.

    Works across meshes: leaves are host arrays; ``device_put`` against the
    *target* mesh's shardings performs the elastic reshard.
    """
    sh = state_shardings(cfg, rules)
    params = {k: jax.device_put(np.asarray(v), sh.params[k])
              for k, v in ckpt["params"].items()}
    mu = {k: jax.device_put(np.asarray(v), sh.mu[k])
          for k, v in ckpt["mu"].items()}
    nu = {k: jax.device_put(np.asarray(v), sh.nu[k])
          for k, v in ckpt["nu"].items()}
    return TrainState(params=params, mu=mu, nu=nu,
                      step=jnp.asarray(ckpt["step"], jnp.int32))


def checkpoint_from_state(state: TrainState) -> dict:
    """Host-side snapshot of a TrainState (used by baselines & tests)."""
    return {
        "params": {k: np.asarray(v) for k, v in state.params.items()},
        "mu": {k: np.asarray(v) for k, v in state.mu.items()},
        "nu": {k: np.asarray(v) for k, v in state.nu.items()},
        "step": int(state.step),
    }


def recover(shadow: ShadowCluster, cfg, rules: ShardingRules,
            timeout: Optional[float] = None,
            allow_partial: bool = False,
            tiers=None,
            new_rules: Optional[ShardingRules] = None
            ) -> tuple[TrainState, int]:
    """Consolidate the shadow cluster and rebuild training state.

    Returns (state, resume_step). The paper's consolidation is a
    distributed gather: every shadow node serves exactly the bucket
    fragments it owns and the full tree is reassembled from them
    (`ShadowCluster.consolidate`).

    A dead shadow node surfaces as `repro.core.shadow.ShadowNodeLoss`
    naming exactly the missing buckets. By default that propagates —
    recovery must not silently hand back a checkpoint with holes. Pass
    ``allow_partial=True`` to rebuild the surviving leaves anyway (e.g. to
    warm-start everything the cluster still holds before refetching the
    dead shard from durable storage); the returned state then contains
    only the surviving nodes' leaves.

    ``tiers`` (a list of `repro.durability` Tier objects) is the durable
    fallback behind both cases. On a *partial* loss the dead owners'
    shards are rebuilt from the tiers at exactly the survivors' step and
    merged with the live partial — a full checkpoint with zero holes. On
    a *total* plane loss (``ShadowNodeLoss.total``) the entire
    checkpoint is reconstructed via
    `repro.durability.restore_from_tiers`, landing at the newest flushed
    step (the one `ShadowNodeLoss.durable_hint` names). Only if the
    tiers cannot serve the exact step does ``allow_partial`` apply.

    ``new_rules`` is the elastic-restart path (`repro.core.elastic`):
    the consolidated checkpoint — a full unsharded tree, whether it came
    from the live plane or the tiers — is re-partitioned onto a
    *different* mesh / FSDP split than the run that produced it. The
    tiers are always read with the OLD capture layout (``shadow.layout``
    and ``shadow.n_nodes`` wrote those records); only the final
    ``device_put`` targets the new rules. The caller then rebuilds
    everything the old layout derived (bucket layout, ownership map,
    channel geometry) via `repro.core.elastic.rebuild_shadow` +
    `CheckmateCheckpointer.reconfigure`.
    """
    try:
        ckpt = shadow.consolidate(timeout=timeout)
    except ShadowNodeLoss as e:
        ckpt = None
        if tiers:
            from repro.durability.restore import (TierRestoreError,
                                                  restore_from_tiers,
                                                  restore_shards_from_tiers)
            try:
                if e.total:
                    ckpt = restore_from_tiers(tiers, shadow.layout,
                                              n_nodes=shadow.n_nodes)
                else:
                    p, m, v = restore_shards_from_tiers(
                        tiers, shadow.layout, e.dead_nodes,
                        at_step=int(e.partial["step"]))
                    ckpt = {"params": {**e.partial["params"], **p},
                            "mu": {**e.partial["mu"], **m},
                            "nu": {**e.partial["nu"], **v},
                            "step": int(e.partial["step"])}
            except TierRestoreError:
                ckpt = None          # tiers can't serve: fall through
        if ckpt is None:
            if not allow_partial:
                raise
            ckpt = e.partial
    state = state_from_checkpoint(
        ckpt, cfg, new_rules if new_rules is not None else rules)
    return state, int(ckpt["step"])
