"""Heartbeat-based gradient tagging for Ring AllGather (paper §4.1, Fig 4).

Ring AllGather over n ranks: after ReduceScatter, rank ``r`` holds reduced
chunk ``(r + 1) % n``; in round ``t`` (of n-1 rounds) it sends chunk
``(r + 1 - t) % n`` to rank ``(r + 1) % n``.

The heartbeat rule tags on the *boundary ranks only*:
  * rank 0 tags only in round 0,
  * rank n-1 tags in every round.

This yields exactly-once coverage of all n chunks (property-tested), with at
most two concurrent taggers per round (round 0), which is why the paper gives
each shadow node two NICs.

Sequence numbers: the network layer keeps one counter per channel,
incremented only for tagged chunks and carried in a custom TCP option; the
switch rewrites the stream's TCP sequence so the shadow node sees one
continuous stream per channel (§4.1.2). ``tag_schedule`` emits those
per-channel sequence numbers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator


def chunk_at(rank: int, rnd: int, n: int) -> int:
    """Chunk held/sent by ``rank`` in AllGather round ``rnd`` (0-based)."""
    return (rank + 1 - rnd) % n


def is_tagged(rank: int, rnd: int, n: int) -> bool:
    if n == 1:
        return rnd == 0
    return (rank == 0 and rnd == 0) or rank == n - 1


def tagged_chunks_per_rank(n: int) -> dict[int, list[int]]:
    """rank -> chunks it tags, in round order."""
    out: dict[int, list[int]] = {}
    rounds = max(n - 1, 1)
    for rnd in range(rounds):
        for rank in range(n):
            if is_tagged(rank, rnd, n):
                out.setdefault(rank, []).append(chunk_at(rank, rnd, n))
    return out


@dataclass(frozen=True)
class TagEvent:
    """One tagged chunk transmission observed by the switch."""
    round: int
    src_rank: int
    chunk: int
    channel: int
    seq: int          # per-channel shadow-stream sequence number
    shadow_node: int  # destination shadow node id (optimizer scale-out)


def tag_schedule(n_ranks: int, n_channels: int = 1,
                 n_shadow_nodes: int = 1,
                 chunk_to_node=None) -> list[TagEvent]:
    """Full per-iteration tag schedule across channels.

    ``chunk_to_node``: optional fn(channel, chunk) -> shadow node id; default
    round-robins chunks over shadow nodes (the paper encodes the node id in
    the packet for the switch, §4.2.4).
    """
    if chunk_to_node is None:
        def chunk_to_node(ch, c):
            return (ch * n_ranks + c) % n_shadow_nodes
    events = []
    seq = [0] * n_channels
    rounds = max(n_ranks - 1, 1)
    for rnd in range(rounds):
        for rank in range(n_ranks):
            if not is_tagged(rank, rnd, n_ranks):
                continue
            for ch in range(n_channels):
                c = chunk_at(rank, rnd, n_ranks)
                events.append(TagEvent(round=rnd, src_rank=rank, chunk=c,
                                       channel=ch, seq=seq[ch],
                                       shadow_node=chunk_to_node(ch, c)))
                seq[ch] += 1
    return events


def fabric_tag_schedule(n_dp_groups: int, ranks_per_group: int,
                        n_channels: int = 1,
                        n_shadow_nodes: int = 1) -> dict[int, list[TagEvent]]:
    """Per-DP-group tag schedules for a shared fabric (§4.4).

    Every DP group runs its own ring AllGather concurrently; each group has
    its own pair of tagging (boundary) ranks and its own per-channel
    shadow-stream sequence space.  ``TagEvent.src_rank`` stays *group-local*
    (0..ranks_per_group-1): callers translate to global ranks via
    ``dp * ranks_per_group + src_rank``.

    Chunks are spread over shadow nodes with a per-group offset so that
    multiple groups do not all hammer shadow node 0 first.

    Returns ``{dp_group: [TagEvent, ...]}``.
    """
    out: dict[int, list[TagEvent]] = {}
    for dp in range(n_dp_groups):
        def chunk_to_node(ch, c, _dp=dp):
            return (_dp + ch * ranks_per_group + c) % n_shadow_nodes
        out[dp] = tag_schedule(ranks_per_group, n_channels=n_channels,
                               n_shadow_nodes=n_shadow_nodes,
                               chunk_to_node=chunk_to_node)
    return out


def verify_exactly_once(n_ranks: int) -> bool:
    """Every chunk tagged exactly once across the schedule."""
    seen: dict[int, int] = {}
    for ev in tag_schedule(n_ranks):
        seen[ev.chunk] = seen.get(ev.chunk, 0) + 1
    return (set(seen) == set(range(n_ranks))
            and all(v == 1 for v in seen.values()))


def incast_per_round(n_ranks: int) -> dict[int, int]:
    """round -> number of simultaneous taggers (shadow-bound flows)."""
    out: dict[int, int] = {}
    for ev in tag_schedule(n_ranks):
        out[ev.round] = out.get(ev.round, 0) + 1
    return out
