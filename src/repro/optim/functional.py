"""Functional optimizers — the paper's §4.2.4 requirement.

Each parameter's update is a deterministic, per-element pure function of
(param, grad, moments, step). This is exactly what lets Checkmate partition
the optimizer step across shadow nodes "without affecting algorithmic
correctness or introducing synchronization overhead": any contiguous slice
of any leaf can be updated independently, so training nodes (TPU) and shadow
nodes (CPU) running the same function produce bit-identical states.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adam | sgd
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    momentum: float = 0.9          # sgd
    grad_clip: float = 0.0         # 0 = off (global-norm clip)


# -- per-leaf updates (pure; used identically by train + shadow) -------------

def adamw_leaf(p, g, m, v, step, cfg: OptimizerConfig, lr):
    g = g.astype(jnp.float32)
    p32 = p.astype(jnp.float32)
    m = cfg.b1 * m + (1.0 - cfg.b1) * g
    v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
    bc1 = 1.0 - cfg.b1 ** step
    bc2 = 1.0 - cfg.b2 ** step
    update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps) + cfg.weight_decay * p32
    return (p32 - lr * update).astype(p.dtype), m, v


def adam_leaf(p, g, m, v, step, cfg: OptimizerConfig, lr):
    g = g.astype(jnp.float32)
    p32 = p.astype(jnp.float32)
    m = cfg.b1 * m + (1.0 - cfg.b1) * g
    v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
    bc1 = 1.0 - cfg.b1 ** step
    bc2 = 1.0 - cfg.b2 ** step
    update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
    return (p32 - lr * update).astype(p.dtype), m, v


def sgd_leaf(p, g, m, v, step, cfg: OptimizerConfig, lr):
    del step
    g = g.astype(jnp.float32)
    m = cfg.momentum * m + g
    return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m, v


UPDATE_FNS = {"adamw": adamw_leaf, "adam": adam_leaf, "sgd": sgd_leaf}


# -- flat (wire-layout) updates ----------------------------------------------
#
# The shadow plane stores params/moments as per-bucket contiguous flat
# buffers (repro.core.buckets wire layout). Because every update above is
# purely element-wise, the flat variant of an optimizer is the same function
# applied to the 1-D bucket buffer — one fused pass over each state element,
# no per-leaf dispatch, no retrace when leaf sets vary. The gradient scale
# (global-norm clip, computed on the training side) is folded into the same
# pass instead of materializing ``g * scale``.
#
# Bit-identity with the per-leaf path is a tested invariant
# (tests/test_flat_shadow.py): element-wise math has no cross-element
# reductions, so per-bucket == per-leaf bitwise.

def adamw_flat(p, g, m, v, step, cfg: OptimizerConfig, lr, scale=1.0):
    return adamw_leaf(p, g * scale, m, v, step, cfg, lr)


def adam_flat(p, g, m, v, step, cfg: OptimizerConfig, lr, scale=1.0):
    return adam_leaf(p, g * scale, m, v, step, cfg, lr)


def sgd_flat(p, g, m, v, step, cfg: OptimizerConfig, lr, scale=1.0):
    return sgd_leaf(p, g * scale, m, v, step, cfg, lr)


UPDATE_FNS_FLAT = {"adamw": adamw_flat, "adam": adam_flat, "sgd": sgd_flat}


# -- train state --------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclass
class TrainState:
    params: dict
    mu: dict
    nu: dict
    step: jnp.ndarray

    def tree_flatten(self):
        return (self.params, self.mu, self.nu, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_state(params) -> TrainState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(params=params,
                      mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros),
                      step=jnp.zeros((), jnp.int32))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(state: TrainState, grads, cfg: OptimizerConfig,
                  lr) -> TrainState:
    """One optimizer step over the whole tree (train + shadow both call this)."""
    if cfg.grad_clip:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    fn = UPDATE_FNS[cfg.name]
    out = jax.tree.map(
        lambda p, g, m, v: fn(p, g, m, v, step.astype(jnp.float32), cfg, lr),
        state.params, grads, state.mu, state.nu)
    params = jax.tree.map(lambda _, o: o[0], state.params, out)
    mu = jax.tree.map(lambda _, o: o[1], state.params, out)
    nu = jax.tree.map(lambda _, o: o[2], state.params, out)
    return TrainState(params=params, mu=mu, nu=nu, step=step)
