from repro.optim.functional import (  # noqa: F401
    OptimizerConfig, TrainState, adamw_leaf, adam_leaf, sgd_leaf,
    init_state, apply_updates, UPDATE_FNS, UPDATE_FNS_FLAT,
)
from repro.optim.schedules import cosine_schedule  # noqa: F401
