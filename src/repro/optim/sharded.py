"""ZeRO-1 optimizer-state sharding over the data axes.

The gradient all-reduce decomposes into reduce-scatter -> sharded update ->
param all-gather. The reduce-scatter *output* is Checkmate's capture point:
each device owns a disjoint slice of the final reduced gradients — the
exactly-once property the paper builds heartbeat tagging for (§4.1) falls
out of the output sharding (docs/ARCHITECTURE.md "capture point").

For each leaf we shard the largest dim divisible by the DP extent (leaves
with no such dim stay replicated — they are tiny).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import ShardingRules, dp_axes, dp_size


def zero1_spec(shape, param_spec: P, mesh) -> P:
    """Extend a param PartitionSpec with DP sharding on the best free dim."""
    dp = dp_axes(mesh)
    if not dp:
        return param_spec
    n = int(np.prod([mesh.shape[a] for a in dp]))
    parts = list(param_spec) + [None] * (len(shape) - len(param_spec))
    used = {a for p in parts if p is not None
            for a in ((p,) if isinstance(p, str) else p)}
    if used & set(dp):
        return P(*parts)        # FSDP already shards over the dp axes
    best, best_size = -1, 0
    for i, (dim, cur) in enumerate(zip(shape, parts)):
        if cur is not None:
            continue
        if dim % n == 0 and dim > best_size:
            best, best_size = i, dim
    if best >= 0:
        parts[best] = dp if len(dp) > 1 else dp[0]
    return P(*parts)


def zero1_shardings(abstract_tree, mesh):
    """NamedSharding tree for optimizer state / reduce-scattered grads."""
    def one(leaf):
        spec = leaf.sharding.spec if hasattr(leaf.sharding, "spec") else P()
        return NamedSharding(mesh, zero1_spec(leaf.shape, spec, mesh))
    return jax.tree.map(one, abstract_tree)


def constrain_zero1(tree, mesh):
    """with_sharding_constraint to the ZeRO-1 layout (the RS point)."""
    def one(x):
        spec = zero1_spec(x.shape, _current_spec(x, mesh), mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.tree.map(one, tree)


def _current_spec(x, mesh) -> P:
    sh = getattr(x, "sharding", None)
    if isinstance(sh, NamedSharding):
        return sh.spec
    return P()
