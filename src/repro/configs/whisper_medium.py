"""whisper-medium [audio] — encoder-decoder backbone; conv frontend STUB.

24L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=51865
[arXiv:2212.04356; unverified]

``input_specs()`` provides precomputed frame embeddings (batch, 1500, d_model)
in place of the log-mel + conv1d frontend, per the assignment note.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,            # decoder layers
    encoder_layers=24,
    encoder_seq=1500,         # frames after the stubbed conv frontend
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    microbatches=8,
)
