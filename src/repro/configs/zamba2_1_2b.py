"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,          # one shared transformer block applied every 6 ssm layers
    microbatches=8,
)
