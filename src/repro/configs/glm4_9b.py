"""glm4-9b [dense] — RoPE, GQA, very large vocabulary.

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552
[hf:THUDM/glm-4-9b; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    head_dim=128,
    microbatches=8,
)
