"""llava-next-mistral-7b [vlm] — anyres tiling frontend STUB + mistral backbone.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

``input_specs()`` provides precomputed, projected patch embeddings
(batch, num_patches, d_model); the CLIP tower + anyres tiler are stubbed.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    num_patches=576,          # one base-resolution tile worth of patches
    microbatches=8,
)
