"""gpt3-xl (1.3B) — paper Table 1 model (benchmark harness)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gpt3-xl", family="dense",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=50257, head_dim=128, microbatches=4,
)
