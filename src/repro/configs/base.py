"""Model / shape / run configuration dataclasses and the architecture registry.

Every assigned architecture provides a module in ``repro.configs`` exporting
``CONFIG`` (the full published configuration) built from :class:`ModelConfig`.
``repro.configs.get(name)`` resolves an architecture id (e.g. ``glm4-9b``).

Shapes follow the assignment:

=============  =========  ============  ====================
shape          seq_len    global_batch  lowered step
=============  =========  ============  ====================
train_4k       4,096      256           train_step
prefill_32k    32,768     32            prefill_step
decode_32k     32,768     128           serve_step (1 token)
long_500k      524,288    1             serve_step (1 token)
=============  =========  ============  ====================
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description (family-polymorphic superset)."""

    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # -- MoE ---------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # expert hidden dim (0 -> d_ff)
    dense_residual: bool = False    # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25

    # -- SSM (Mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0              # N: state dimension per head
    ssm_expand: int = 2             # d_inner = expand * d_model
    ssm_conv: int = 4               # short causal conv width
    ssm_head_dim: int = 64          # P: SSD head dim
    ssm_groups: int = 1             # B/C groups
    ssm_chunk: int = 256            # SSD chunk length

    # -- hybrid (zamba2) -----------------------------------------------------
    attn_every: int = 0             # shared attention block every k ssm layers

    # -- encoder/decoder (whisper) -------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0            # source frames after the (stubbed) conv

    # -- VLM (llava) ---------------------------------------------------------
    num_patches: int = 0            # precomputed projected patch embeddings

    # -- common --------------------------------------------------------------
    mlp: str = "swiglu"             # swiglu (3 mats) | gelu2 (2 mats)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "float32"    # master weights
    compute_dtype: str = "bfloat16"

    # -- distribution defaults (overridable per run) ---------------------------
    remat: bool = True
    scan_layers: bool = True
    fsdp: bool = False              # shard params over data axes between uses
    zero1: bool = True              # shard optimizer state over data axes
    microbatches: int = 16          # gradient-accumulation steps for train_4k
    attn_q_chunk: int = 512         # online-softmax q block
    attn_kv_chunk: int = 1024       # online-softmax kv block

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # -- derived -------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Supports 500k-token decode (SSM state / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def reduced(self, **over) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 0,
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            microbatches=1,
            attn_q_chunk=16,
            attn_kv_chunk=32,
        )
        if self.num_experts:
            kw.update(num_experts=4, top_k=min(self.top_k, 2), moe_d_ff=64)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.attn_every:
            kw.update(attn_every=1, num_layers=2)
        if self.encoder_layers:
            kw.update(encoder_layers=2, encoder_seq=32)
        if self.num_patches:
            kw.update(num_patches=8)
        kw.update(over)
        return replace(self, **kw)

    # Parameter counting (analytic, used for 6*N*D model flops) --------------
    def param_count(self) -> int:
        from repro.models import registry as _m
        return _m.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import registry as _m
        return _m.param_count(self, active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")
SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch, shape) cell is runnable; else the documented skip."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention family: 500k-token decode KV cache is "
                       "outside the architecture family's operating envelope "
                       "(see docs/ARCHITECTURE.md, models); run only for ssm/hybrid")
    return True, ""


@dataclass(frozen=True)
class RunConfig:
    """One dry-run / training cell."""
    arch: str
    shape: str
    multi_pod: bool = False
    microbatches: Optional[int] = None    # override config default
    fsdp: Optional[bool] = None
    zero1: Optional[bool] = None
    remat_policy: str = "full"            # full | dots | none

    def resolve(self) -> tuple[ModelConfig, ShapeConfig]:
        import repro.configs as C
        cfg = C.get(self.arch)
        over = {}
        if self.microbatches is not None:
            over["microbatches"] = self.microbatches
        if self.fsdp is not None:
            over["fsdp"] = self.fsdp
        if self.zero1 is not None:
            over["zero1"] = self.zero1
        if over:
            cfg = replace(cfg, **over)
        return cfg, SHAPES[self.shape]
