"""arctic-480b [moe] — 128 experts top-2 + dense residual branch.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2
[hf:Snowflake/snowflake-arctic-base; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,                # dense residual FFN width
    vocab_size=32000,
    head_dim=128,
    num_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_residual=True,
    fsdp=True,
    microbatches=8,
)
