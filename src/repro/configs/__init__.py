"""Architecture registry: 10 assigned architectures + the paper's own models."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeConfig, RunConfig,
    SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
    shape_applicable,
)

# Assigned architectures (public pool) — one module per id.
ASSIGNED = [
    "zamba2-1.2b",
    "mamba2-2.7b",
    "granite-34b",
    "llama3.2-3b",
    "tinyllama-1.1b",
    "glm4-9b",
    "whisper-medium",
    "llava-next-mistral-7b",
    "dbrx-132b",
    "arctic-480b",
]

# The paper's own evaluation models (Table 1) used by the benchmark harness.
PAPER = ["gpt2-1.5b", "gpt3-xl", "gpt3-6.7b", "vit-h-14", "llama2-7b"]

_MODULES = {n: "repro.configs." + n.replace("-", "_").replace(".", "_") for n in ASSIGNED + PAPER}


def get(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def all_archs() -> list[str]:
    return list(ASSIGNED)
