"""gpt2-1.5b — paper Table 1 model (benchmark harness)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gpt2-1.5b", family="dense",
    num_layers=48, d_model=1600, num_heads=25, num_kv_heads=25,
    d_ff=6400, vocab_size=50257, head_dim=64, microbatches=4,
)
