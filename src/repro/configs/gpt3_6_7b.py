"""gpt3-6.7b — paper Table 1 model (benchmark harness)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gpt3-6.7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=16384, vocab_size=50257, head_dim=128, microbatches=8,
)
