"""llama2-7b — paper Table 1 model (benchmark harness; 2PP x 6DP in paper)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab_size=32000, head_dim=128, microbatches=8,
)
