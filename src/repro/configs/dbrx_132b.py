"""dbrx-132b [moe] — 16 experts top-4, fine-grained.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4
[hf:databricks/dbrx-base; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    head_dim=128,
    num_experts=16,
    top_k=4,
    moe_d_ff=10752,
    fsdp=True,
    microbatches=8,
)
