"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.

64L d_model=2560 (attn-free) d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    microbatches=8,
)
