"""vit-h-14 (633.5M) — paper Table 1 vision model (benchmark harness).

Modeled as the transformer backbone over precomputed patch embeddings
(the patchify conv is a stub, same policy as the assigned [vlm] entry).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="vit-h-14", family="vlm",
    num_layers=32, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=1000,     # classification head over 1000 classes
    head_dim=80, num_patches=256, microbatches=2,
)
