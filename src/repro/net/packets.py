"""Packet/frame model for the gradient-replication data plane.

Frames carry: a 1-bit DSCP tag (§4.1), the per-channel shadow-stream
sequence number in a custom TCP option (§4.1.2), and the shadow node id the
switch uses to pick the mirror destination (§4.2.4).
"""
from __future__ import annotations

from dataclasses import dataclass, field

MTU = 4096                      # payload bytes per frame (jumbo-ish)


@dataclass
class Frame:
    src: int                    # training rank (or switch port)
    dst: int                    # destination rank / shadow node
    payload_off: int            # byte offset within the chunk
    payload_len: int
    chunk: int                  # gradient chunk id
    channel: int
    tcp_seq: int                # original stream sequence
    tagged: bool = False        # DSCP bit
    shadow_seq: int = -1        # custom TCP option (per-channel counter)
    shadow_node: int = -1       # encoded shadow node id
    mirrored: bool = False      # set on switch-replicated copies


def frames_for_chunk(src: int, dst: int, *, chunk: int, channel: int,
                     chunk_bytes: int, start_seq: int, tagged: bool,
                     shadow_seq0: int, shadow_node: int) -> list[Frame]:
    """Segment one chunk transmission into MTU frames."""
    frames = []
    off = 0
    seq = start_seq
    sseq = shadow_seq0
    while off < chunk_bytes:
        ln = min(MTU, chunk_bytes - off)
        frames.append(Frame(src=src, dst=dst, payload_off=off, payload_len=ln,
                            chunk=chunk, channel=channel, tcp_seq=seq,
                            tagged=tagged,
                            shadow_seq=sseq if tagged else -1,
                            shadow_node=shadow_node if tagged else -1))
        off += ln
        seq += ln
        sseq += ln
    return frames
