"""Packet/frame model for the gradient-replication data plane.

Frames carry: a 1-bit DSCP tag (§4.1), the per-channel shadow-stream
sequence number in a custom TCP option (§4.1.2), and the shadow node id the
switch uses to pick the mirror destination (§4.2.4).

For the event-driven fabric simulator (`repro.net.simulator`) a frame also
records its DP group, a replica index (which of the ``replication_factor``
mirror copies it is), per-frame timestamps, and a coalescing count
``n_frames``: one ``Frame`` object may stand in for ``n_frames`` wire-level
MTU frames when simulating very large transfers, with all switch counters
scaled accordingly (byte totals and TX/RX ratios are exact either way).
"""
from __future__ import annotations

from dataclasses import dataclass, field

MTU = 4096                      # payload bytes per wire frame (jumbo-ish)


@dataclass(slots=True)
class Frame:
    """One simulated data-plane frame (or a coalesced run of them).

    Args:
        src: source training rank (global) or switch port id.
        dst: destination rank / shadow node id.
        payload_off: byte offset of this frame within its chunk.
        payload_len: payload bytes carried (``n_frames`` wire frames' worth).
        chunk: gradient chunk id (AllGather chunk index within the group).
        channel: collective channel id (per-channel shadow streams, §4.1.2).
        tcp_seq: sequence number of the original training-plane stream.
        tagged: DSCP replication bit (§4.1).
        shadow_seq: custom-TCP-option shadow-stream sequence (tagged only).
        shadow_node: shadow node id encoded for the switch (§4.2.4).
        mirrored: set on switch-replicated copies.
        dp_group: data-parallel group this frame's ring belongs to.
        replica: mirror copy index in ``range(replication_factor)``.
        n_frames: wire frames this object represents (counter weight).
        t_send: simulation time the frame first entered the fabric.
        t_arrive: simulation time of final delivery (-1 until delivered).
        retx: how many times this frame was retransmitted after loss.
        payload: optional real payload bytes (memoryview/bytes) attached by
            a frame-injection hook (`FabricSimulator(frame_tx_hook=...)`) so
            gradient channels can flow actual data through the fabric;
            mirrored copies share the same buffer (zero-copy replication).
    """
    src: int                    # training rank (or switch port)
    dst: int                    # destination rank / shadow node
    payload_off: int            # byte offset within the chunk
    payload_len: int
    chunk: int                  # gradient chunk id
    channel: int
    tcp_seq: int                # original stream sequence
    tagged: bool = False        # DSCP bit
    shadow_seq: int = -1        # custom TCP option (per-channel counter)
    shadow_node: int = -1       # encoded shadow node id
    mirrored: bool = False      # set on switch-replicated copies
    dp_group: int = 0
    replica: int = 0
    n_frames: int = 1
    t_send: float = -1.0
    t_arrive: float = -1.0
    retx: int = 0
    payload: object = None


def frames_for_chunk(src: int, dst: int, *, chunk: int, channel: int,
                     chunk_bytes: int, start_seq: int, tagged: bool,
                     shadow_seq0: int, shadow_node: int,
                     dp_group: int = 0,
                     quantum: int = 1) -> list[Frame]:
    """Segment one chunk transmission into MTU frames.

    Args:
        quantum: coalescing factor — emit one ``Frame`` per ``quantum`` MTU
            frames (``n_frames`` keeps exact wire-frame counts).  ``1``
            reproduces the wire exactly; large chunks can use a bigger
            quantum so event counts stay bounded.
    """
    frames = []
    off = 0
    seq = start_seq
    sseq = shadow_seq0
    step = MTU * max(quantum, 1)
    while off < chunk_bytes:
        ln = min(step, chunk_bytes - off)
        nf = (ln + MTU - 1) // MTU
        frames.append(Frame(src=src, dst=dst, payload_off=off, payload_len=ln,
                            chunk=chunk, channel=channel, tcp_seq=seq,
                            tagged=tagged,
                            shadow_seq=sseq if tagged else -1,
                            shadow_node=shadow_node if tagged else -1,
                            dp_group=dp_group, n_frames=nf))
        off += ln
        seq += ln
        sseq += ln
    return frames
