"""Priority Flow Control model (paper §4.3.3): lossless delivery to shadow
nodes under transient receiver-side pressure.

Two views live here:

* ``PfcQueue`` — the original self-contained bounded queue with XOFF/XON
  thresholds, used by the unit tests and the legacy per-round simulator.
* ``PfcConfig`` — threshold/propagation parameters consumed by the
  event-driven fabric simulator (`repro.net.simulator`), where occupancy is
  tracked per switch-egress queue and PAUSE/RESUME signals propagate to
  upstream transmitters with a configurable delay (hop-by-hop PFC, the way
  real 802.1Qbb behaves).

The invariant in both: when thresholds leave headroom for in-flight bytes,
a paused upstream never overflows the queue, so the lossless class drops
nothing.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class PfcConfig:
    """PFC parameters for one switch egress queue in the fabric simulator.

    Args:
        capacity_bytes: physical buffer bound; enqueue beyond it drops.
        xoff_frac: occupancy fraction at which PAUSE is sent upstream.
        xon_frac: occupancy fraction at which RESUME is sent upstream.
        pause_prop_s: one-way PAUSE/RESUME signal propagation delay.
        enabled: disable to model a lossy (drop + retransmit) class.
    """
    capacity_bytes: int = 2 * 1024 * 1024
    xoff_frac: float = 0.8
    xon_frac: float = 0.5
    pause_prop_s: float = 2e-6
    enabled: bool = True

    @property
    def xoff(self) -> int:
        return int(self.capacity_bytes * self.xoff_frac)

    @property
    def xon(self) -> int:
        return int(self.capacity_bytes * self.xon_frac)


@dataclass
class PfcQueue:
    capacity_bytes: int = 2 * 1024 * 1024
    xoff_frac: float = 0.8
    xon_frac: float = 0.5
    occupancy: int = 0
    paused: bool = False
    pause_events: int = 0
    resume_events: int = 0
    dropped: int = 0
    enqueued_bytes: int = 0
    paused_offers: int = 0         # offers refused while paused (held bytes)

    @property
    def xoff(self) -> int:
        return int(self.capacity_bytes * self.xoff_frac)

    @property
    def xon(self) -> int:
        return int(self.capacity_bytes * self.xon_frac)

    def offer(self, nbytes: int) -> bool:
        """Try to enqueue. Returns False when the sender must hold (paused).
        A correct PFC sender never loses data: drops only happen on overflow,
        which pause prevents."""
        if self.paused:
            self.paused_offers += 1
            return False
        if self.occupancy + nbytes > self.capacity_bytes:
            # would overflow: this cannot happen if thresholds are sane,
            # because XOFF fires first — count it as a (model) drop.
            self.dropped += 1
            return False
        self.occupancy += nbytes
        self.enqueued_bytes += nbytes
        if self.occupancy >= self.xoff and not self.paused:
            self.paused = True
            self.pause_events += 1
        return True

    def drain(self, nbytes: int):
        self.occupancy = max(0, self.occupancy - nbytes)
        if self.paused and self.occupancy <= self.xon:
            self.paused = False
            self.resume_events += 1

    def headroom_ok(self, max_inflight: int) -> bool:
        """XOFF must leave room for in-flight bytes (cable + reaction)."""
        return self.capacity_bytes - self.xoff >= max_inflight
