"""Priority Flow Control model (paper §4.3.3): lossless delivery to shadow
nodes under transient receiver-side pressure.

A bounded egress queue per shadow port; when occupancy crosses the XOFF
threshold the upstream source pauses (no drops); it resumes below XON.
The invariant tests assert zero drops for any drain-rate pattern.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class PfcQueue:
    capacity_bytes: int = 2 * 1024 * 1024
    xoff_frac: float = 0.8
    xon_frac: float = 0.5
    occupancy: int = 0
    paused: bool = False
    pause_events: int = 0
    resume_events: int = 0
    dropped: int = 0
    enqueued_bytes: int = 0

    @property
    def xoff(self) -> int:
        return int(self.capacity_bytes * self.xoff_frac)

    @property
    def xon(self) -> int:
        return int(self.capacity_bytes * self.xon_frac)

    def offer(self, nbytes: int) -> bool:
        """Try to enqueue. Returns False when the sender must hold (paused).
        A correct PFC sender never loses data: drops only happen on overflow,
        which pause prevents."""
        if self.paused:
            return False
        if self.occupancy + nbytes > self.capacity_bytes:
            # would overflow: this cannot happen if thresholds are sane,
            # because XOFF fires first — count it as a (model) drop.
            self.dropped += 1
            return False
        self.occupancy += nbytes
        self.enqueued_bytes += nbytes
        if self.occupancy >= self.xoff and not self.paused:
            self.paused = True
            self.pause_events += 1
        return True

    def drain(self, nbytes: int):
        self.occupancy = max(0, self.occupancy - nbytes)
        if self.paused and self.occupancy <= self.xon:
            self.paused = False
            self.resume_events += 1

    def headroom_ok(self, max_inflight: int) -> bool:
        """XOFF must leave room for in-flight bytes (cable + reaction)."""
        return self.capacity_bytes - self.xoff >= max_inflight
