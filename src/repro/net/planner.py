"""Network resource planning (paper §4.4) + the TPU host-DMA budget from
DESIGN.md §2.

Paper accounting: 2 multicast streams per DP group -> 2 extra ToR ports,
NICs and transceivers per DP group; for LLaMA3-405B (128 DP groups on 16K
GPUs) that is 256 ports < 0.8% of cluster network resources.

TPU adaptation: the replication point is the host PCIe boundary. Each v5e
host (4 chips) DMAs its reduce-scattered gradient shard; the budget check
verifies grad-shard bytes/host/iteration fit PCIe and the shadow-plane
ingest bandwidth.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class PlanInput:
    n_accelerators: int
    dp_groups: int
    ranks_per_group: int
    ports_per_tor: int = 32
    accel_per_host: int = 4          # v5e host
    pcie_gbps: float = 128.0         # PCIe gen4 x16 ~ 16 GB/s = 128 Gbps
    link_gbps: float = 100.0


@dataclass(frozen=True)
class Plan:
    multicast_streams: int
    extra_ports: int
    extra_port_fraction: float
    shadow_min_nics: int
    hosts: int
    grad_bytes_per_host: float
    pcie_util: float
    feasible: bool
    notes: str


def plan(inp: PlanInput, grad_bytes_total: float, iter_time_s: float) -> Plan:
    streams = 2 * inp.dp_groups
    total_ports = (inp.n_accelerators // max(inp.ports_per_tor // 2, 1)
                   ) * inp.ports_per_tor
    frac = streams / max(total_ports, 1)
    hosts = inp.n_accelerators // inp.accel_per_host
    per_host = grad_bytes_total / max(hosts, 1)
    pcie_util = (per_host * 8 / 1e9) / (inp.pcie_gbps * iter_time_s) \
        if iter_time_s else 0.0
    feasible = pcie_util < 0.5 and frac < 0.05
    notes = []
    if pcie_util >= 0.5:
        notes.append(f"host DMA uses {pcie_util:.0%} of PCIe — shard the "
                     "capture across more hosts or lengthen the interval")
    if frac >= 0.05:
        notes.append("extra ToR ports exceed 5% of fabric — repurpose "
                     "uplinks (spine-free) per §4.4")
    return Plan(multicast_streams=streams, extra_ports=streams,
                extra_port_fraction=frac,
                shadow_min_nics=2,           # round-0 double rate (§4.1.1)
                hosts=hosts, grad_bytes_per_host=per_host,
                pcie_util=pcie_util, feasible=feasible,
                notes="; ".join(notes) or "ok")
