"""Network resource planning (paper §4.4) and fabric topology construction
for the event-driven simulator (see docs/ARCHITECTURE.md §net).

Two concerns live here:

* ``plan`` — the paper's §4.4 port/NIC accounting (2 multicast streams per
  DP group) plus the TPU host-DMA budget check: for LLaMA3-405B (128 DP
  groups on 16K GPUs) the 256 extra ToR ports are < 0.8% of cluster network
  resources.
* ``build_topology`` — constructs the multi-switch fabric the event-driven
  simulator (`repro.net.simulator`) runs on: hosts, shadow hosts, leaf and
  spine switches, and directed capacity links with static next-hop routing
  and deterministic ECMP spine selection.

Topology flavors:

* ``single``      — every host and shadow NIC on one switch (the legacy
                    idealization; the compatibility wrapper uses this).
* ``rail``        — rail-optimized leaf/spine: ring-consecutive ranks of a
                    DP group are packed onto the same leaf, so ring traffic
                    is overwhelmingly leaf-local and only DP-group boundary
                    hops and mirror traffic cross the spine.
* ``leaf-spine``  — same switches, but ranks are strided across leaves, so
                    every ring hop crosses the spine (the pessimal
                    placement; useful as a contention baseline).
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class PlanInput:
    n_accelerators: int
    dp_groups: int
    ranks_per_group: int
    ports_per_tor: int = 32
    accel_per_host: int = 4          # v5e host
    pcie_gbps: float = 128.0         # PCIe gen4 x16 ~ 16 GB/s = 128 Gbps
    link_gbps: float = 100.0


@dataclass(frozen=True)
class Plan:
    multicast_streams: int
    extra_ports: int
    extra_port_fraction: float
    shadow_min_nics: int
    hosts: int
    grad_bytes_per_host: float
    pcie_util: float
    feasible: bool
    notes: str


def plan(inp: PlanInput, grad_bytes_total: float, iter_time_s: float) -> Plan:
    """§4.4 feasibility check: extra ports and host-DMA budget.

    Args:
        inp: cluster shape and per-component bandwidths.
        grad_bytes_total: full reduced-gradient payload per iteration.
        iter_time_s: training iteration time the capture must hide inside.
    """
    streams = 2 * inp.dp_groups
    total_ports = (inp.n_accelerators // max(inp.ports_per_tor // 2, 1)
                   ) * inp.ports_per_tor
    frac = streams / max(total_ports, 1)
    hosts = inp.n_accelerators // inp.accel_per_host
    per_host = grad_bytes_total / max(hosts, 1)
    pcie_util = (per_host * 8 / 1e9) / (inp.pcie_gbps * iter_time_s) \
        if iter_time_s else 0.0
    feasible = pcie_util < 0.5 and frac < 0.05
    notes = []
    if pcie_util >= 0.5:
        notes.append(f"host DMA uses {pcie_util:.0%} of PCIe — shard the "
                     "capture across more hosts or lengthen the interval")
    if frac >= 0.05:
        notes.append("extra ToR ports exceed 5% of fabric — repurpose "
                     "uplinks (spine-free) per §4.4")
    return Plan(multicast_streams=streams, extra_ports=streams,
                extra_port_fraction=frac,
                shadow_min_nics=2,           # round-0 double rate (§4.1.1)
                hosts=hosts, grad_bytes_per_host=per_host,
                pcie_util=pcie_util, feasible=feasible,
                notes="; ".join(notes) or "ok")


# ---------------------------------------------------------------------------
# Fabric topology for the event-driven simulator
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LinkSpec:
    """One directed capacity link (an egress queue + serializer).

    Args:
        src/dst: node names ("h3", "leaf0", "spine1", "s0").
        gbps: line rate; a bonded shadow NIC pair is one link at 2x rate.
        prop_s: propagation + forwarding latency to the far end.
        nics: physical NICs bonded into this link (reporting only).
    """
    src: str
    dst: str
    gbps: float
    prop_s: float = 1e-6
    nics: int = 1


@dataclass
class Topology:
    """Static fabric description consumed by ``repro.net.simulator``.

    Node naming: training hosts are ``h{global_rank}``, shadow hosts
    ``s{node}``, leaves ``leaf{i}`` (plus ``leafS`` for the shadow rail when
    present), spines ``spine{i}``.  ``links`` holds both directions of every
    cable as separate ``LinkSpec`` entries (full duplex).
    """
    name: str
    n_ranks: int
    n_dp_groups: int
    ranks_per_group: int
    n_shadow: int
    hosts: list[str]
    shadow_hosts: list[str]
    leaves: list[str]
    spines: list[str]
    links: dict[tuple[str, str], LinkSpec]
    attach: dict[str, str]              # host/shadow -> its leaf
    host_of_rank: dict[int, str]
    shadow_host_of: dict[int, str]


def _duplex(links: dict, a: str, b: str, gbps: float, prop_s: float = 1e-6,
            nics: int = 1):
    links[(a, b)] = LinkSpec(a, b, gbps, prop_s, nics)
    links[(b, a)] = LinkSpec(b, a, gbps, prop_s, nics)


def build_topology(n_dp_groups: int, ranks_per_group: int, n_shadow: int = 1,
                   *, topology: str = "rail", ranks_per_leaf: int = 32,
                   link_gbps: float = 100.0, spine_gbps: float | None = None,
                   shadow_nics: int = 2, n_spines: int = 2,
                   shadow_rails: int = 1, prop_s: float = 1e-6) -> Topology:
    """Build a fabric for the event-driven simulator.

    Args:
        topology: "single" | "rail" | "leaf-spine" (see module docstring).
        ranks_per_leaf: leaf radix used by the multi-switch flavors.
        link_gbps: host and shadow access link rate per NIC.
        spine_gbps: leaf->spine uplink rate (default ``4 * link_gbps``).
        shadow_nics: bonded NICs per shadow host (§4.1.1 says >= 2 so the
            round-0 double-rate incast does not pause the fabric).
        n_spines: spine count; leaf->spine selection is deterministic ECMP
            with failover in the simulator.
        shadow_rails: shadow-rail leaf count; a bucket-sharded shadow
            cluster spreads its owner nodes round-robin across rails so
            mirror incast splits over independent leaves. ``1`` keeps the
            legacy single ``leafS`` rail (name included).
    """
    n_ranks = n_dp_groups * ranks_per_group
    hosts = [f"h{r}" for r in range(n_ranks)]
    shadow_hosts = [f"s{n}" for n in range(n_shadow)]
    host_of_rank = dict(enumerate(hosts))
    shadow_host_of = dict(enumerate(shadow_hosts))
    links: dict[tuple[str, str], LinkSpec] = {}
    attach: dict[str, str] = {}

    if topology == "single":
        leaves, spines = ["sw0"], []
        for h in hosts:
            attach[h] = "sw0"
            _duplex(links, h, "sw0", link_gbps, prop_s)
        for s in shadow_hosts:
            attach[s] = "sw0"
            _duplex(links, s, "sw0", link_gbps * shadow_nics, prop_s,
                    nics=shadow_nics)
        return Topology("single", n_ranks, n_dp_groups, ranks_per_group,
                        n_shadow, hosts, shadow_hosts, leaves, spines, links,
                        attach, host_of_rank, shadow_host_of)

    if topology not in ("rail", "leaf-spine"):
        raise ValueError(f"unknown topology {topology!r}")

    n_leaves = max(1, (n_ranks + ranks_per_leaf - 1) // ranks_per_leaf)
    leaves = [f"leaf{i}" for i in range(n_leaves)]
    spines = [f"spine{i}" for i in range(max(n_spines, 1))]
    spine_gbps = spine_gbps or 4 * link_gbps
    for r, h in enumerate(hosts):
        if topology == "rail":
            leaf = leaves[r // ranks_per_leaf]          # consecutive packing
        else:
            leaf = leaves[r % n_leaves]                 # strided (pessimal)
        attach[h] = leaf
        _duplex(links, h, leaf, link_gbps, prop_s)
    # shadow rail(s): shadow hosts share dedicated leaves reachable via
    # spines; multiple rails spread a sharded cluster's incast round-robin
    rails = max(1, shadow_rails)
    shadow_leaves = (["leafS"] if rails == 1
                     else [f"leafS{r}" for r in range(rails)])
    leaves = leaves + shadow_leaves
    for i, s in enumerate(shadow_hosts):
        shadow_leaf = shadow_leaves[i % rails]
        attach[s] = shadow_leaf
        _duplex(links, s, shadow_leaf, link_gbps * shadow_nics, prop_s,
                nics=shadow_nics)
    for leaf in leaves:
        for sp in spines:
            _duplex(links, leaf, sp, spine_gbps, prop_s)
    return Topology(topology, n_ranks, n_dp_groups, ranks_per_group,
                    n_shadow, hosts, shadow_hosts, leaves, spines, links,
                    attach, host_of_rank, shadow_host_of)
