"""Event-driven fabric simulator for gradient multicast (paper §4, Fig 10).

A global event queue (`heapq`) advances simulated time over a multi-switch
topology built by `repro.net.planner.build_topology`.  First-class resources:

* **links** — every directed link is an egress queue plus a serializer:
  frames wait FIFO, transmit at line rate (serialization delay), then
  propagate (`prop_s`) to the far node,
* **switch egress queues** — bounded buffers; crossing the PFC XOFF
  threshold sends PAUSE to every upstream transmitter of that switch
  (propagated with `PfcConfig.pause_prop_s`), RESUME below XON — so incast
  at the shadow rail visibly backpressures the fabric hop by hop,
* **NICs** — host/shadow access links (bonded shadow NIC pairs are one link
  at aggregate rate, §4.1.1),
* **shadow drain** — the shadow access link's serializer is the drain.

Losses: a full lossy queue or a killed link drops frames.  Ring (training)
frames are retransmitted by their source after `retx_timeout_s` (TCP);
switch-mirrored copies are **not** — the switch PRE keeps no state and the
shadow stream's ACKs are dropped (§4.3.2), so a mirror loss means that
iteration's capture is incomplete, which is exactly the signal
`repro.core.recovery` consumes (see tests/test_fabric.py).

The workload is one AllGather iteration per DP group, all groups sharing
the fabric concurrently: rank ``r`` sends round ``t+1``'s chunk only after
fully receiving round ``t``'s (the real ring dependency), with heartbeat
tagging and per-channel shadow streams from `repro.core.tagging`.

`simulate_allgather_replication` is kept as a thin compatibility wrapper
(single-switch topology, one DP group) over this engine; the original
per-round arithmetic model survives as `_legacy_simulate_allgather` for
regression comparison.  See docs/netsim.md for the full model and a worked
Fig 10 example, and docs/ARCHITECTURE.md for where this sits in the system.
"""
from __future__ import annotations

import argparse
import bisect
import dataclasses
import heapq
from collections import deque
from dataclasses import dataclass, field

from repro.core.multicast import SwitchControlPlane
from repro.core.tagging import chunk_at, fabric_tag_schedule, is_tagged, \
    tag_schedule
from repro.net.packets import MTU, Frame, frames_for_chunk
from repro.net.pfc import PfcConfig, PfcQueue
from repro.net.planner import Topology, build_topology
from repro.net.switch import SwitchCounters, SwitchDataPlane

_HOST, _SWITCH, _SHADOW = 0, 1, 2


@dataclass(frozen=True)
class FailureSpec:
    """Fabric-level failure injection: fires once at ``at_s``.

    Args:
        at_s: simulation time of the failure (seconds).
        kind: "link" (cut a cable: both directions), "switch" (kill every
            link touching the switch), or "shadow_nic" (cut a shadow host's
            access link).
        target: ("a", "b") node-name pair for "link"; a switch name for
            "switch"; a shadow host name ("s0") or node id for "shadow_nic".
    """
    at_s: float
    kind: str
    target: tuple | str | int


@dataclass
class FabricResult:
    """Outcome of one fabric iteration (see docs/netsim.md)."""
    topology: str
    n_ranks: int
    n_dp_groups: int
    ranks_per_group: int
    n_shadow: int
    replication_factor: int
    grad_bytes_per_group: int
    duration_s: float
    group_done_s: dict
    ring_completed: bool
    algo_bandwidth_gbps: float
    bus_bandwidth_gbps: float
    rx_frames: int
    tx_frames: int
    mirrored_frames: int
    tx_over_rx: float
    switch_counters: dict
    shadow_bytes: dict
    reassembled_ok: bool
    missing_captures: int
    duplicate_mirror_bytes: int
    mirror_lost_frames: int
    drops: int
    retransmits: int
    rerouted: int
    pfc_pauses: int
    pfc_resumes: int
    latency: dict
    # processed heap events — identical between fast=True and the
    # per-frame oracle (the fast engine walks the exact same event
    # stream, it just dispatches it cheaper); the differential suite
    # (tests/test_fabric_fastpath.py) asserts full equality
    events: int
    # per-link PFC pause-duration account (was aggregate-only): total
    # link-paused virtual seconds, plus {"src->dst": {pauses, resumes,
    # pause_s}} for every link that ever paused
    pfc_pause_s: float = 0.0
    link_pfc: dict = field(default_factory=dict)


class _Link:
    """Runtime state of one directed link: FIFO egress queue + serializer."""
    __slots__ = ("src", "dst", "rate_bps", "prop", "q", "qbytes", "busy",
                 "up", "pause_count", "sent_xoff", "cap", "xoff", "xon",
                 "epoch", "drops", "pause_events", "resume_events",
                 "paused_since", "pause_s", "key", "ser_chunk")

    def __init__(self, spec, bounded: bool, pfc: PfcConfig,
                 min_cap: int = 0):
        self.key = (spec.src, spec.dst)
        self.src, self.dst = spec.src, spec.dst
        self.rate_bps = spec.gbps * 1e9
        self.prop = spec.prop_s
        self.q: deque = deque()
        self.qbytes = 0
        self.busy = False
        self.up = True
        self.pause_count = 0            # XOFFs currently held against us
        self.sent_xoff = False          # our queue has paused our feeders
        # frame coalescing makes enqueues burstier than the wire (one event
        # may carry quantum * rf MTU frames), so the lossless class scales
        # its buffer up with min_cap to keep the same relative headroom the
        # real frames have; the lossy class keeps the user's capacity (its
        # drops are the experiment) and bounds the quantum instead
        cap = max(pfc.capacity_bytes, min_cap) if pfc.enabled \
            else pfc.capacity_bytes
        self.cap = cap if bounded else None
        self.xoff = int(cap * pfc.xoff_frac)
        self.xon = int(cap * pfc.xon_frac)
        self.epoch = 0                  # bumped on kill: stale events no-op
        self.drops = 0
        self.pause_events = 0
        self.resume_events = 0
        self.paused_since = 0.0         # sim time the open pause began
        self.pause_s = 0.0              # closed-pause virtual time total


class FabricSimulator:
    """One AllGather iteration of every DP group over a shared fabric.

    Args:
        topo: static fabric from `repro.net.planner.build_topology`.
        grad_bytes_per_group: reduced-gradient payload per DP group.
        replication_factor: mirror copies per tagged frame (Fig 10).
        n_channels: collective channels; each gets its own shadow stream.
        pfc: thresholds + PAUSE propagation for switch egress queues; pass
            ``PfcConfig(enabled=False)`` for a lossy class (drops + retx).
        failures: `FailureSpec` events to inject mid-iteration.
        frame_quantum: coalesce this many MTU frames per event (None =
            auto-pick so a chunk is <= ~256 events; counters stay exact).
        retx_timeout_s / max_retx: source retransmission for ring frames.
        max_time_s: hard simulation-time stop (guards unreachable rings).
        frame_tx_hook: injection point — called once per frame as it is
            created at its source host (before first enqueue); gradient
            channels use it to attach real payload bytes (`Frame.payload`)
            via `wire_offset`. Retransmissions reuse the same frame object,
            and switch mirrors share the buffer, so the hook fires exactly
            once per logical frame.
        shadow_rx_hook: extraction point — called as ``hook(node_id,
            frame)`` when a (mirrored) frame is finally delivered to a
            shadow host; channels use it to reassemble the capture.
        shadow_route: bucket-sharded shadow plane — maps a frame byte's
            *total-buffer* offset (``total_offset``) to the shadow node
            that owns it, overriding the round-robin tag schedule. The
            sender packetizes the shadow stream (§4.2.4 — it encodes the
            shadow node id per packet), so tagged frames are split at
            ``shadow_cuts`` and every piece is stamped with its owner.
        shadow_cuts: sorted total-buffer offsets where bucket ownership
            changes; tagged frames straddling a cut are split there.
        fast: run the specialized event engine (``_run_fast``). It walks
            the exact same heap with the exact same keys and float
            arithmetic as the per-frame loop — every event fires at the
            same instant in the same order — but the hot
            serialize -> arrive -> route -> enqueue chain is inlined into
            one dispatch loop with hoisted lookups, and every rare branch
            (tagged/mirror traffic, kills, drops, PFC transitions,
            multi-channel or sharded sends) falls back to the exact
            per-frame methods mid-chain. Results are bit-exact against
            ``fast=False`` including ``FabricResult.events``;
            tests/test_fabric_fastpath.py is the differential suite.
    """

    def __init__(self, topo: Topology, *, grad_bytes_per_group: int,
                 replication_factor: int = 1, n_channels: int = 1,
                 pfc: PfcConfig = PfcConfig(), failures=(),
                 frame_quantum: int | None = None,
                 retx_timeout_s: float = 100e-6, max_retx: int = 10,
                 max_time_s: float = 30.0,
                 frame_tx_hook=None, shadow_rx_hook=None,
                 shadow_route=None, shadow_cuts=(), fast: bool = False):
        self.topo = topo
        self.fast = bool(fast)
        self.pfc = pfc
        self.shadow_route = shadow_route
        self.shadow_cuts = sorted(shadow_cuts)
        self.rf = max(1, replication_factor)
        self.n_channels = max(1, n_channels)
        self.retx_timeout = retx_timeout_s
        self.max_retx = max_retx
        self.max_time = max_time_s
        self.frame_tx_hook = frame_tx_hook
        self.shadow_rx_hook = shadow_rx_hook
        n, rpg = topo.n_ranks, topo.ranks_per_group
        self.rounds = max(rpg - 1, 1)
        self.chunk_bytes = grad_bytes_per_group // rpg
        if self.chunk_bytes <= 0:
            raise ValueError("grad_bytes_per_group must cover >=1 byte/rank")
        nc = self.n_channels
        base, rem = divmod(self.chunk_bytes, nc)
        self.split = [base + (1 if i < rem else 0) for i in range(nc)]
        if frame_quantum is None:
            raw = (max(self.split) + MTU - 1) // MTU
            frame_quantum = max(1, (raw + 255) // 256)
            if not pfc.enabled:
                # lossy buffers stay at the configured size, so a coalesced
                # frame must stay well under it or every enqueue drops
                frame_quantum = min(frame_quantum,
                                    max(1, pfc.capacity_bytes // (4 * MTU)))
        self.quantum = frame_quantum

        self.control = SwitchControlPlane(
            topo.n_dp_groups, rpg, topo.n_shadow).setup()
        switch_names = list(topo.leaves) + list(topo.spines)
        self.dataplanes = {s: SwitchDataPlane(self.control, name=s)
                           for s in switch_names}
        self._kind = {h: _HOST for h in topo.hosts}
        self._kind.update({s: _SWITCH for s in switch_names})
        self._kind.update({s: _SHADOW for s in topo.shadow_hosts})
        self._shadow_id = {h: i for i, h in topo.shadow_host_of.items()}
        self._leaf_idx = {l: i for i, l in enumerate(topo.leaves)}
        self._spine_set = set(topo.spines)
        # worst case between XOFF firing and it taking effect: two taggers
        # (round 0, §4.1.1) each land one quantum*rf mirror burst plus a
        # pause-propagation window of line-rate arrivals — 16x covers it
        # with the default xoff_frac of 0.8 (headroom = 3.2 * burst)
        min_cap = 16 * self.quantum * MTU * self.rf
        self.links = {k: _Link(spec, bounded=self._kind[spec.src] == _SWITCH,
                               pfc=pfc, min_cap=min_cap)
                      for k, spec in topo.links.items()}
        self._feeders = {}              # node -> [links whose dst == node]
        for lk in self.links.values():
            self._feeders.setdefault(lk.dst, []).append(lk)
        self._attach_of_rank = [topo.attach[topo.host_of_rank[r]]
                                for r in range(n)]

        # tag schedule: (group, round, local_rank, channel) -> TagEvent
        self.schedule = {}
        for g, evs in fabric_tag_schedule(
                topo.n_dp_groups, rpg, n_channels=nc,
                n_shadow_nodes=topo.n_shadow).items():
            for ev in evs:
                self.schedule[(g, ev.round, ev.src_rank, ev.channel)] = ev

        # expected shadow capture: (g, ch, chunk, replica) -> bytes
        self.expected = {}
        for (g, _r, _lr, ch), ev in self.schedule.items():
            for rep in range(self.rf):
                self.expected[(g, ch, ev.chunk, rep)] = self.split[ch]
        self._cov: dict = {}            # key -> {offset: bytes}
        self.shadow_bytes = {i: 0 for i in range(topo.n_shadow)}
        self.duplicate_mirror_bytes = 0

        # ring receive bookkeeping
        self._rx_round = [dict() for _ in range(n)]     # rank -> {round: B}
        self._done_rounds = [set() for _ in range(n)]
        self._send_next = [1] * n
        self._group_rounds_left = {g: rpg * self.rounds
                                   for g in range(topo.n_dp_groups)}
        self.group_done_s: dict = {}

        self._heap: list = []
        self._seq = 0
        self.now = 0.0
        self.events = 0
        # memoize the hot bound methods: every heap push reuses ONE object,
        # so the fast loop can dispatch by identity (`fn is arrive`) and
        # classic pushes skip re-binding. Reads still resolve through the
        # instance, so both loops push the very same objects.
        self._tx_done = self._tx_done
        self._arrive = self._arrive
        self.retransmits = 0
        self.rerouted = 0
        self.mirror_lost = 0
        self.undelivered = 0
        self._lat = {"ring": [0, 0.0, 0.0], "mirror": [0, 0.0, 0.0]}
        for spec in failures:
            self._at(spec.at_s, self._fail, spec)

    # -- event plumbing ----------------------------------------------------
    # Heap entries are (fire_t, seq, fn, arg): same-instant events fire in
    # creation order. Both engines push through this one function (or an
    # inline copy with identical keys), so event order never depends on
    # which engine runs.
    def _at(self, t: float, fn, arg):
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, fn, arg))

    def _after(self, dt: float, fn, arg):
        self._at(self.now + dt, fn, arg)

    # -- failures ----------------------------------------------------------
    def _fail(self, spec: FailureSpec):
        if spec.kind == "link":
            a, b = spec.target
            self._kill((a, b))
            self._kill((b, a))
        elif spec.kind == "switch":
            for key in list(self.links):
                if spec.target in key:
                    self._kill(key)
        elif spec.kind == "shadow_nic":
            t = spec.target
            host = t if isinstance(t, str) else self.topo.shadow_host_of[t]
            leaf = self.topo.attach[host]
            self._kill((leaf, host))
            self._kill((host, leaf))
        else:
            raise ValueError(f"unknown failure kind {spec.kind!r}")

    def _kill(self, key):
        lk = self.links.get(key)
        if lk is None or not lk.up:
            return
        lk.up = False
        lk.epoch += 1
        lk.busy = False
        lost = list(lk.q)
        lk.q.clear()
        lk.qbytes = 0
        if lk.sent_xoff:                # dead queue must release its PAUSEs
            lk.sent_xoff = False
            for f in self._feeders.get(lk.src, []):
                self._after(self.pfc.pause_prop_s, self._resume, f)
        for fr in lost:
            self._lost(fr)

    # -- loss / retransmission --------------------------------------------
    def _lost(self, f: Frame):
        if f.mirrored:
            # the switch PRE keeps no state and shadow ACKs are dropped
            # (§4.3.2): a lost mirror is an incomplete capture, not a retx
            self.mirror_lost += f.n_frames
            return
        if f.retx >= self.max_retx:
            self.undelivered += f.n_frames
            return
        f.retx += 1
        self.retransmits += f.n_frames
        self._after(self.retx_timeout, self._inject, f)

    def _inject(self, f: Frame):
        src_host = self.topo.host_of_rank[f.src]
        self._enqueue(self.links[(src_host, self.topo.attach[src_host])], f)

    # -- link machinery ----------------------------------------------------
    def _enqueue(self, lk: _Link, f: Frame):
        if not lk.up:
            self._lost(f)
            return
        if lk.cap is not None and lk.qbytes + f.payload_len > lk.cap:
            lk.drops += f.n_frames
            self._lost(f)
            return
        lk.q.append(f)
        lk.qbytes += f.payload_len
        if (self.pfc.enabled and lk.cap is not None
                and lk.qbytes >= lk.xoff and not lk.sent_xoff):
            lk.sent_xoff = True
            for feeder in self._feeders.get(lk.src, []):
                self._after(self.pfc.pause_prop_s, self._pause, feeder)
        self._try_tx(lk)

    def _pause(self, lk: _Link):
        if lk.pause_count == 0:          # pause interval opens
            lk.paused_since = self.now
        lk.pause_count += 1
        lk.pause_events += 1

    def _resume(self, lk: _Link):
        if lk.pause_count > 0:
            lk.pause_count -= 1
            lk.resume_events += 1
            if lk.pause_count == 0:      # pause interval closes
                lk.pause_s += self.now - lk.paused_since
            self._try_tx(lk)

    def _try_tx(self, lk: _Link):
        if lk.busy or lk.pause_count or not lk.q or not lk.up:
            return
        lk.busy = True
        self._after(lk.q[0].payload_len * 8 / lk.rate_bps, self._tx_done,
                    (lk, lk.epoch))

    def _tx_done(self, arg):
        lk, epoch = arg
        if epoch != lk.epoch:
            return                      # link was killed mid-serialization
        f = lk.q.popleft()
        lk.qbytes -= f.payload_len
        lk.busy = False
        if lk.sent_xoff and lk.qbytes <= lk.xon:
            lk.sent_xoff = False
            for feeder in self._feeders.get(lk.src, []):
                self._after(self.pfc.pause_prop_s, self._resume, feeder)
        self._after(lk.prop, self._arrive, (f, lk.dst))
        self._try_tx(lk)

    # -- routing -----------------------------------------------------------
    @staticmethod
    def _ecmp_mix(a: int, b: int, c: int) -> int:
        """Deterministic avalanche mix for ECMP flow hashing (a plain
        linear combination keeps src/dst parity, which collapses all
        adjacent-leaf ring flows onto one spine)."""
        x = (a * 0x9E3779B1 + b * 0x85EBCA77 + c * 0xC2B2AE3D) & 0xFFFFFFFF
        x ^= x >> 16
        x = (x * 0x045D9F3B) & 0xFFFFFFFF
        return x ^ (x >> 16)

    def _route(self, sw: str, dst_host: str, f: Frame):
        """Next hop from switch ``sw`` toward ``dst_host`` (None = no path).

        Deterministic per-flow ECMP over spines with failover: the preferred
        spine hashes (src leaf, dst leaf, source rank) so flows spread, and
        a dead spine or uplink reroutes to the next live one.
        """
        topo = self.topo
        leaf_dst = topo.attach[dst_host]
        if sw == leaf_dst:
            return dst_host if self.links[(sw, dst_host)].up else None
        if sw in self._spine_set:
            return leaf_dst if self.links[(sw, leaf_dst)].up else None
        spines = topo.spines
        i0 = self._ecmp_mix(self._leaf_idx[sw], self._leaf_idx[leaf_dst],
                            f.src) % len(spines)
        for k in range(len(spines)):
            sp = spines[(i0 + k) % len(spines)]
            if self.links[(sw, sp)].up and self.links[(sp, leaf_dst)].up:
                if k:
                    self.rerouted += f.n_frames
                return sp
        return None

    # -- node arrival ------------------------------------------------------
    def _arrive(self, arg):
        f, node = arg
        kind = self._kind[node]
        if kind == _SWITCH:
            replicate = (f.tagged and not f.mirrored
                         and node == self._attach_of_rank[f.src])
            out = self.dataplanes[node].process(f, self.rf,
                                                replicate=replicate)
            topo = self.topo
            for g in out:
                dst_host = (topo.shadow_host_of[g.dst] if g.mirrored
                            else topo.host_of_rank[g.dst])
                if g.mirrored and g is not f:
                    g.t_send = self.now
                nh = self._route(node, dst_host, g)
                if nh is None:
                    self._lost(g)
                else:
                    self._enqueue(self.links[(node, nh)], g)
        elif kind == _HOST:
            f.t_arrive = self.now
            self._stat("ring", f)
            self._host_recv(f)
        else:
            f.t_arrive = self.now
            self._stat("mirror", f)
            self._shadow_recv(node, f)
            # the shadow's TCP stack ACKs; its leaf's data plane drops it
            self.dataplanes[self.topo.attach[node]].process_ack()

    def _stat(self, cls: str, f: Frame):
        s = self._lat[cls]
        d = self.now - f.t_send
        s[0] += f.n_frames
        s[1] += d * f.n_frames
        s[2] = max(s[2], d)

    def _host_recv(self, f: Frame):
        rank = f.dst
        rpg = self.topo.ranks_per_group
        lr = rank - f.dp_group * rpg
        rnd = (lr - f.chunk) % rpg if rpg > 1 else 0
        acc = self._rx_round[rank]
        got = acc.get(rnd, 0) + f.payload_len
        acc[rnd] = got
        if got < self.chunk_bytes or rnd in self._done_rounds[rank]:
            return
        self._done_rounds[rank].add(rnd)
        g = f.dp_group
        self._group_rounds_left[g] -= 1
        if self._group_rounds_left[g] == 0:
            self.group_done_s[g] = self.now
        # ring dependency: receiving round t releases send of round t+1
        while (self._send_next[rank] <= self.rounds - 1
               and self._send_next[rank] - 1 in self._done_rounds[rank]):
            t = self._send_next[rank]
            self._send_next[rank] += 1
            self._send_round(g, lr, t)

    def wire_offset(self, f: Frame) -> int:
        """Byte offset of ``f``'s payload inside its DP group's contiguous
        reduced-gradient buffer (chunk-major, channel-split within a chunk).
        Gradient channels use this to slice payload at injection and to
        place received spans at extraction."""
        return (f.chunk * self.chunk_bytes
                + sum(self.split[:f.channel]) + f.payload_off)

    def total_offset(self, f: Frame) -> int:
        """Byte offset of ``f``'s payload inside the concatenated
        all-groups wire buffer (group-major) — the coordinate system the
        sharded shadow plane's owner map (``shadow_route``) speaks."""
        return (f.dp_group * self.chunk_bytes * self.topo.ranks_per_group
                + self.wire_offset(f))

    def _owner_split(self, f: Frame):
        """Route a tagged frame to its bucket-owner shadow node(s).

        The sender packetizes the shadow stream (§4.2.4: it encodes the
        shadow node id per packet), so it aligns frame boundaries to
        bucket-ownership cuts: a frame straddling a cut is split into
        per-owner pieces, each a self-consistent frame (offsets, TCP and
        shadow sequence numbers advanced; wire-frame count re-derived).
        """
        route = self.shadow_route
        if route is None or not f.tagged:
            return (f,)
        w0 = self.total_offset(f)
        w1 = w0 + f.payload_len
        cuts = self.shadow_cuts
        i = bisect.bisect_right(cuts, w0)
        j = bisect.bisect_left(cuts, w1, i)
        if i == j:                          # one owner: stamp in place
            f.shadow_node = route(w0)
            return (f,)
        out = []
        bounds = [w0, *cuts[i:j], w1]
        for a, b in zip(bounds, bounds[1:]):
            d = a - w0
            out.append(dataclasses.replace(
                f, payload_off=f.payload_off + d, payload_len=b - a,
                tcp_seq=f.tcp_seq + d,
                shadow_seq=(f.shadow_seq + d) if f.shadow_seq >= 0 else -1,
                shadow_node=route(a),
                n_frames=(b - a + MTU - 1) // MTU))
        return out

    def _shadow_recv(self, node: str, f: Frame):
        nid = self._shadow_id[node]
        self.shadow_bytes[nid] += f.payload_len
        key = (f.dp_group, f.channel, f.chunk, f.replica)
        seen = self._cov.setdefault(key, {})
        if f.payload_off in seen:
            self.duplicate_mirror_bytes += min(seen[f.payload_off],
                                               f.payload_len)
        seen[f.payload_off] = max(seen.get(f.payload_off, 0), f.payload_len)
        if self.shadow_rx_hook is not None:
            self.shadow_rx_hook(nid, f)

    # -- workload ----------------------------------------------------------
    def _send_round(self, g: int, lr: int, rnd: int):
        topo = self.topo
        rpg = topo.ranks_per_group
        src = g * rpg + lr
        dst = g * rpg + (lr + 1) % rpg
        chunk = chunk_at(lr, rnd, rpg)
        tagged = is_tagged(lr, rnd, rpg)
        src_host = topo.host_of_rank[src]
        lk = self.links[(src_host, topo.attach[src_host])]
        off = 0
        for ch in range(self.n_channels):
            ev = self.schedule.get((g, rnd, lr, ch)) if tagged else None
            for f in frames_for_chunk(
                    src, dst, chunk=chunk, channel=ch,
                    chunk_bytes=self.split[ch], start_seq=off,
                    tagged=tagged,
                    shadow_seq0=(ev.seq * self.split[ch]) if ev else -1,
                    shadow_node=ev.shadow_node if ev else -1,
                    dp_group=g, quantum=self.quantum):
                for sf in self._owner_split(f):
                    sf.t_send = self.now
                    if self.frame_tx_hook is not None:
                        self.frame_tx_hook(sf)
                    self._enqueue(lk, sf)
            off += self.split[ch]

    # -- run ---------------------------------------------------------------
    def run(self) -> FabricResult:
        topo = self.topo
        for g in range(topo.n_dp_groups):
            for lr in range(topo.ranks_per_group):
                self._send_round(g, lr, 0)
        if self.fast:
            self._run_fast()
        else:
            heap = self._heap
            pop = heapq.heappop
            max_time = self.max_time
            events = 0
            while heap:
                item = pop(heap)
                t = item[0]
                if t > max_time:
                    break
                self.now = t
                events += 1
                item[2](item[3])
            self.events = events
        return self._result()

    def _run_fast(self):
        """The fast engine: the exact event stream of the per-frame loop,
        dispatched cheaper.

        Two mechanically-verifiable equivalences carry the whole design:

        * **Order.** The per-frame loop fires events in ``(fire_t, seq)``
          order, and ``seq`` is globally monotonic in *push* order. So a
          calendar queue — a dict from fire time to a FIFO bucket plus a
          heap of distinct times — fires events in exactly the same order
          (same instant => insertion order == seq order) while replacing
          log-n 4-tuple comparisons with list appends. Slow-path methods
          keep scheduling through ``self._at``, which is rebound to the
          bucket push for the duration of the run.
        * **Arithmetic.** ``_tx_done`` and ``_arrive`` (the two handlers
          that are ~all events) are inlined with hoisted lookups but
          compute the identical float expressions on identical inputs in
          the identical sequence; every rare branch (tagged/mirror
          traffic, kills, drops, PFC transitions, multi-channel or
          sharded sends) falls back to the exact per-frame methods
          mid-chain.

        Results are therefore bit-identical by construction — including
        ``FabricResult.events`` — and tests/test_fabric_fastpath.py
        holds this engine to that bar against the per-frame loop."""
        times: list = []            # heap of DISTINCT fire times
        buckets: dict = {}          # fire time -> FIFO of flat event items
        pop_t = heapq.heappop
        push_t = heapq.heappush
        txdone = self._tx_done
        arrive = self._arrive

        # bucket items are flat triples — (arrive, frame, node) /
        # (txdone, link, epoch) / (other_fn, arg, None) — so the hot
        # pushes allocate one tuple and the pop unpacks once
        def fast_at(t2, fn, arg, _g=buckets.get):
            if fn is arrive or fn is txdone:
                item = (fn, arg[0], arg[1])
            else:
                item = (fn, arg, None)
            b = _g(t2)
            if b is None:
                buckets[t2] = [item]
                push_t(times, t2)
            else:
                b.append(item)

        # drain events scheduled before the run (initial sends, failure
        # timers) into the calendar in (fire_t, seq) order, then route
        # every later self._at/_after through the calendar as well
        for t2, _sq, fn, arg in sorted(self._heap):
            fast_at(t2, fn, arg)
        self._heap.clear()
        self._at = fast_at          # instance attr shadows the method

        links = self.links
        kindof = self._kind
        topo = self.topo
        attach = topo.attach
        host_of_rank = topo.host_of_rank
        spine_set = self._spine_set
        feeders = self._feeders
        pfc_enabled = self.pfc.enabled
        pause_prop = self.pfc.pause_prop_s
        lat_ring = self._lat["ring"]
        lat_mirror = self._lat["mirror"]
        rx_round = self._rx_round
        done_rounds = self._done_rounds
        send_next = self._send_next
        grl = self._group_rounds_left
        group_done = self.group_done_s
        rpg = topo.ranks_per_group
        rpg_m1 = rpg - 1
        multi_rank = rpg > 1
        chunk_bytes = self.chunk_bytes
        last_round = self.rounds - 1
        max_time = self.max_time
        bget = buckets.get
        # the single-channel unsharded untagged send (one coalesced frame
        # per chunk, no payload hook) is frequent enough to build inline
        simple_send = (self.n_channels == 1 and self.shadow_route is None
                       and self.frame_tx_hook is None
                       and self.split[0] <= MTU * self.quantum)
        nf0 = (chunk_bytes + MTU - 1) // MTU
        # per-rank forwarding table: a ring frame to rank r always lands on
        # r's access downlink from r's leaf (the topology is static; kills
        # fall back to the exact methods via the `up` checks)
        dst_info = []
        for r in range(topo.n_ranks):
            h = host_of_rank[r]
            leaf = attach[h]
            dst_info.append((leaf, links[(leaf, h)]))
        access = [links[(h, attach[h])]
                  for h in (host_of_rank[r] for r in range(topo.n_ranks))]
        # full-chunk serialization time per link, precomputed with the
        # oracle's exact expression (pl * 8 == chunk_bytes * 8 => same div)
        for lk in links.values():
            lk.ser_chunk = chunk_bytes * 8 / lk.rate_bps
        counters_of = {s: dp.counters for s, dp in self.dataplanes.items()}
        # one lookup per arrival: node -> (kind, payload) where payload is
        # a forward-count cell for switches (untagged L2 forwards bump rx
        # and tx by the same frame count, tallied here and merged into the
        # slow-path-shared SwitchCounters after the loop) and the attached
        # leaf's counters for shadow hosts (its ACK drop accounting)
        fwd_count = {s: [0] for s in counters_of}
        node_info = {}
        for nd, kind in kindof.items():
            if kind == _SWITCH:
                node_info[nd] = (kind, fwd_count[nd])
            elif kind == _HOST:
                node_info[nd] = (kind, None)
            else:
                node_info[nd] = (kind, counters_of[attach[nd]])
        # per-site bucket memos: same-instant events overwhelmingly push
        # to the same future instant (equal rates / equal propagation), so
        # remember the last (time, bucket) per push site. A memo hit can
        # never alias a drained bucket: pushes target t2 >= now, drained
        # buckets have time < now (the active bucket stays in the dict
        # until fully processed, so zero-delay pushes stay correct too).
        m1t = m2t = m3t = m4t = -1.0
        m1b = m2b = m3b = m4b = None
        events = 0
        try:
            while times:
                tcur = pop_t(times)
                if tcur > max_time:
                    break
                self.now = t = tcur
                b = buckets[tcur]
                i = 0
                while True:
                    n = len(b)      # same-instant pushes grow the bucket
                    if i >= n:
                        break
                    for fn, a1, a2 in b[i:n]:
                        if fn is arrive:
                            f = a1
                            node = a2
                            info = node_info[node]
                            kind = info[0]
                            if kind == _SWITCH:
                                if f.tagged:    # mirror path: exact
                                    arrive((f, node))
                                    continue
                                info[1][0] += f.n_frames
                                leaf_dst, nlk = dst_info[f.dst]
                                if node != leaf_dst:
                                    if node in spine_set:
                                        nlk = links[(node, leaf_dst)]
                                    else:
                                        nh = self._route(
                                            node, host_of_rank[f.dst], f)
                                        if nh is None:
                                            self._lost(f)
                                            continue
                                        nlk = links[(node, nh)]
                                pl = f.payload_len
                                # inline _enqueue (drops/dead links exact)
                                if not nlk.up or (
                                        nlk.cap is not None
                                        and nlk.qbytes + pl > nlk.cap):
                                    self._enqueue(nlk, f)
                                    continue
                                nlk.q.append(f)
                                nlk.qbytes += pl
                                if (nlk.qbytes >= nlk.xoff and pfc_enabled
                                        and nlk.cap is not None
                                        and not nlk.sent_xoff):
                                    nlk.sent_xoff = True
                                    for fd in feeders.get(nlk.src, []):
                                        fast_at(t + pause_prop,
                                                self._pause, fd)
                                if nlk.busy or nlk.pause_count:
                                    continue
                                # inline _try_tx; the head IS f (idle +
                                # unpaused means the queue was empty)
                                nlk.busy = True
                                t2 = t + (nlk.ser_chunk
                                          if pl == chunk_bytes
                                          else pl * 8 / nlk.rate_bps)
                                if t2 == m3t:
                                    m3b.append((txdone, nlk, nlk.epoch))
                                else:
                                    b2 = bget(t2)
                                    if b2 is None:
                                        buckets[t2] = b2 = [
                                            (txdone, nlk, nlk.epoch)]
                                        push_t(times, t2)
                                    else:
                                        b2.append((txdone, nlk,
                                                   nlk.epoch))
                                    m3t = t2
                                    m3b = b2
                            elif kind == _HOST:
                                f.t_arrive = t
                                d = t - f.t_send    # inline _stat("ring")
                                nf = f.n_frames
                                lat_ring[0] += nf
                                lat_ring[1] += d * nf
                                if d > lat_ring[2]:
                                    lat_ring[2] = d
                                rank = f.dst        # inline _host_recv
                                g = f.dp_group
                                lr = rank - g * rpg
                                rnd = (lr - f.chunk) % rpg if multi_rank \
                                    else 0
                                dr = done_rounds[rank]
                                pl = f.payload_len
                                if pl == chunk_bytes:
                                    # whole chunk in one frame: the byte
                                    # accumulator can't be partial
                                    if rnd in dr:
                                        continue
                                else:
                                    acc = rx_round[rank]
                                    got = acc.get(rnd, 0) + pl
                                    acc[rnd] = got
                                    if got < chunk_bytes or rnd in dr:
                                        continue
                                dr.add(rnd)
                                left = grl[g] - 1
                                grl[g] = left
                                if left == 0:
                                    group_done[g] = t
                                # round rr-1 received releases send of rr
                                rr = send_next[rank]
                                while rr <= last_round and rr - 1 in dr:
                                    send_next[rank] = rr + 1
                                    if (not simple_send or lr == rpg_m1
                                            or (lr == 0 and rr == 0)):
                                        self._send_round(g, lr, rr)
                                        rr += 1
                                        continue
                                    # inline _send_round: one untagged
                                    # coalesced frame, positional args
                                    sf = Frame(rank,
                                               g * rpg + (lr + 1) % rpg,
                                               0, chunk_bytes,
                                               (lr + 1 - rr) % rpg,
                                               0, 0, False, -1, -1, False,
                                               g, 0, nf0, t)
                                    rr += 1
                                    nlk = access[rank]
                                    # inline _enqueue (host NIC)
                                    if not nlk.up or (
                                            nlk.cap is not None
                                            and nlk.qbytes + chunk_bytes
                                            > nlk.cap):
                                        self._enqueue(nlk, sf)
                                        continue
                                    nlk.q.append(sf)
                                    nlk.qbytes += chunk_bytes
                                    if (nlk.qbytes >= nlk.xoff
                                            and pfc_enabled
                                            and nlk.cap is not None
                                            and not nlk.sent_xoff):
                                        nlk.sent_xoff = True
                                        for fd in feeders.get(nlk.src, []):
                                            fast_at(t + pause_prop,
                                                    self._pause, fd)
                                    if nlk.busy or nlk.pause_count:
                                        continue
                                    # idle + unpaused: the head is sf
                                    nlk.busy = True
                                    t2 = t + nlk.ser_chunk
                                    if t2 == m4t:
                                        m4b.append((txdone, nlk,
                                                    nlk.epoch))
                                        continue
                                    b2 = bget(t2)
                                    if b2 is None:
                                        buckets[t2] = b2 = [
                                            (txdone, nlk, nlk.epoch)]
                                        push_t(times, t2)
                                    else:
                                        b2.append((txdone, nlk,
                                                   nlk.epoch))
                                    m4t = t2
                                    m4b = b2
                            else:
                                f.t_arrive = t
                                d = t - f.t_send   # inline _stat("mirror")
                                nf = f.n_frames
                                lat_mirror[0] += nf
                                lat_mirror[1] += d * nf
                                if d > lat_mirror[2]:
                                    lat_mirror[2] = d
                                self._shadow_recv(node, f)
                                # inline process_ack(): leaf drops the ACK
                                info[1].dropped_acks += 1
                        elif fn is txdone:
                            lk = a1
                            if a2 != lk.epoch:  # killed mid-serialize
                                continue
                            f = lk.q.popleft()
                            lk.qbytes -= f.payload_len
                            lk.busy = False
                            if lk.sent_xoff and lk.qbytes <= lk.xon:
                                lk.sent_xoff = False
                                for fd in feeders.get(lk.src, []):
                                    fast_at(t + pause_prop,
                                            self._resume, fd)
                            t2 = t + lk.prop
                            if t2 == m1t:
                                m1b.append((arrive, f, lk.dst))
                            else:
                                b2 = bget(t2)
                                if b2 is None:
                                    buckets[t2] = b2 = [
                                        (arrive, f, lk.dst)]
                                    push_t(times, t2)
                                else:
                                    b2.append((arrive, f, lk.dst))
                                m1t = t2
                                m1b = b2
                            if lk.q and not lk.pause_count:  # _try_tx
                                lk.busy = True
                                pl = lk.q[0].payload_len
                                t2 = t + (lk.ser_chunk
                                          if pl == chunk_bytes
                                          else pl * 8 / lk.rate_bps)
                                if t2 == m2t:
                                    m2b.append((txdone, lk, lk.epoch))
                                    continue
                                b2 = bget(t2)
                                if b2 is None:
                                    buckets[t2] = b2 = [
                                        (txdone, lk, lk.epoch)]
                                    push_t(times, t2)
                                else:
                                    b2.append((txdone, lk, lk.epoch))
                                m2t = t2
                                m2b = b2
                        else:
                            fn(a1)
                    i = n
                events += i
                del buckets[tcur]
        finally:
            del self._at            # restore the heap-backed method
        for node, cell in fwd_count.items():
            if cell[0]:
                c = counters_of[node]
                c.rx_frames += cell[0]
                c.tx_frames += cell[0]
        self.events = events

    def _result(self) -> FabricResult:
        topo = self.topo
        missing = 0
        ok = True
        for key, nbytes in self.expected.items():
            got = sum(self._cov.get(key, {}).values())
            if got != nbytes:
                ok = False
                missing += 1
        total = SwitchCounters()
        per_switch = {}
        for name, dp in self.dataplanes.items():
            per_switch[name] = dp.counters
            total = total.merge(dp.counters)
        ring_done = len(self.group_done_s) == topo.n_dp_groups
        duration = (max(self.group_done_s.values())
                    if self.group_done_s else self.now)
        gbits = self.chunk_bytes * topo.ranks_per_group * 8
        per_group_bw = [gbits / max(t, 1e-12) / 1e9
                        for t in self.group_done_s.values()]
        algbw = (sum(per_group_bw) / len(per_group_bw)) if per_group_bw \
            else 0.0
        n = topo.ranks_per_group
        lat = {cls: (c, (s / c) if c else 0.0, mx)
               for cls, (c, s, mx) in self._lat.items()}
        link_pfc = {}
        for lk in self.links.values():
            if not lk.pause_events:
                continue
            # flush a still-open pause interval up to the end of the run
            eff = lk.pause_s + (self.now - lk.paused_since
                                if lk.pause_count else 0.0)
            link_pfc[f"{lk.src}->{lk.dst}"] = {
                "pauses": lk.pause_events, "resumes": lk.resume_events,
                "pause_s": eff}
        return FabricResult(
            topology=topo.name, n_ranks=topo.n_ranks,
            n_dp_groups=topo.n_dp_groups, ranks_per_group=n,
            n_shadow=topo.n_shadow, replication_factor=self.rf,
            grad_bytes_per_group=self.chunk_bytes * n,
            duration_s=duration, group_done_s=dict(self.group_done_s),
            ring_completed=ring_done,
            algo_bandwidth_gbps=algbw,
            bus_bandwidth_gbps=algbw * (n - 1) / n if n > 1 else algbw,
            rx_frames=total.rx_frames, tx_frames=total.tx_frames,
            mirrored_frames=total.mirrored_frames,
            tx_over_rx=total.tx_over_rx,
            switch_counters=per_switch,
            shadow_bytes=dict(self.shadow_bytes),
            reassembled_ok=ok and ring_done,
            missing_captures=missing,
            duplicate_mirror_bytes=self.duplicate_mirror_bytes,
            mirror_lost_frames=self.mirror_lost,
            drops=sum(lk.drops for lk in self.links.values()),
            retransmits=self.retransmits, rerouted=self.rerouted,
            pfc_pauses=sum(lk.pause_events for lk in self.links.values()),
            pfc_resumes=sum(lk.resume_events for lk in self.links.values()),
            latency=lat, events=self.events,
            pfc_pause_s=sum(st["pause_s"] for st in link_pfc.values()),
            link_pfc=link_pfc)


def simulate_fabric(n_dp_groups: int, ranks_per_group: int,
                    grad_bytes_per_group: int, *,
                    topology: str | Topology = "rail",
                    n_shadow_nodes: int = 1, link_gbps: float = 100.0,
                    replication_factor: int = 1, n_channels: int = 1,
                    shadow_nics: int = 2, ranks_per_leaf: int = 32,
                    n_spines: int = 2, spine_gbps: float | None = None,
                    pfc: PfcConfig = PfcConfig(), failures=(),
                    frame_quantum: int | None = None,
                    retx_timeout_s: float = 100e-6, max_retx: int = 10,
                    max_time_s: float = 30.0,
                    fast: bool = False) -> FabricResult:
    """Run one multi-DP-group AllGather iteration on a simulated fabric.

    The main entry point for topology/replication sweeps; see the class
    docstring of `FabricSimulator` for per-argument semantics and
    docs/netsim.md for worked examples.
    """
    topo = topology if isinstance(topology, Topology) else build_topology(
        n_dp_groups, ranks_per_group, n_shadow_nodes, topology=topology,
        ranks_per_leaf=ranks_per_leaf, link_gbps=link_gbps,
        spine_gbps=spine_gbps, shadow_nics=shadow_nics, n_spines=n_spines)
    sim = FabricSimulator(
        topo, grad_bytes_per_group=grad_bytes_per_group,
        replication_factor=replication_factor, n_channels=n_channels,
        pfc=pfc, failures=failures, frame_quantum=frame_quantum,
        retx_timeout_s=retx_timeout_s, max_retx=max_retx,
        max_time_s=max_time_s, fast=fast)
    return sim.run()


def sweep_replication(factors, **kw) -> list[FabricResult]:
    """Fig 10 sweep: one fabric run per replication factor."""
    return [simulate_fabric(replication_factor=f, **kw) for f in factors]


def sweep_topology(names, **kw) -> dict:
    """Same workload across topology flavors (rail vs strided vs single)."""
    return {name: simulate_fabric(topology=name, **kw) for name in names}


# ---------------------------------------------------------------------------
# Compatibility wrapper + legacy reference model
# ---------------------------------------------------------------------------

@dataclass
class SimResult:
    n_ranks: int
    total_bytes: int
    duration_s: float
    bus_bandwidth_gbps: float
    algo_bandwidth_gbps: float
    rx_frames: int
    tx_frames: int
    tx_over_rx: float
    mirrored_frames: int
    shadow_bytes: dict
    reassembled_ok: bool
    pfc_pauses: int
    drops: int


def simulate_allgather_replication(
        n_ranks: int,
        grad_bytes: int,
        link_gbps: float = 100.0,
        n_shadow_nodes: int = 1,
        shadow_nics: int = 2,
        shadow_drain_gbps: float | None = None,
        replication_factor: int = 1,
        n_channels: int = 1) -> SimResult:
    """Single-switch, one-DP-group view of the fabric simulator.

    Kept signature-compatible with the original per-round model (whose
    arithmetic survives as `_legacy_simulate_allgather`): frame counters and
    reassembly verdicts are identical; durations now come from the event
    engine instead of the per-round max() approximation.

    grad_bytes: total reduced-gradient bytes (the AllGather payload).
    replication_factor: mirrors per tagged packet (Fig 10 sweeps this).
    shadow_drain_gbps: aggregate shadow access rate (default: one NIC-bonded
        link at ``link_gbps * shadow_nics``, §4.1.1).
    """
    drain = shadow_drain_gbps or (link_gbps * shadow_nics)
    topo = build_topology(1, n_ranks, n_shadow_nodes, topology="single",
                          link_gbps=link_gbps,
                          shadow_nics=max(1, round(drain / link_gbps)))
    # exact drain override (bonded NICs may not divide evenly)
    for (a, b), spec in list(topo.links.items()):
        if a in topo.shadow_hosts or b in topo.shadow_hosts:
            topo.links[(a, b)] = type(spec)(spec.src, spec.dst, drain,
                                            spec.prop_s, spec.nics)
    r = FabricSimulator(topo, grad_bytes_per_group=grad_bytes,
                        replication_factor=replication_factor,
                        n_channels=n_channels).run()
    t = r.duration_s
    algbw = (grad_bytes * 8 / t) / 1e9 if t else 0.0
    return SimResult(
        n_ranks=n_ranks, total_bytes=grad_bytes, duration_s=t,
        bus_bandwidth_gbps=algbw * (n_ranks - 1) / n_ranks,
        algo_bandwidth_gbps=algbw,
        rx_frames=r.rx_frames, tx_frames=r.tx_frames,
        tx_over_rx=r.tx_over_rx, mirrored_frames=r.mirrored_frames,
        shadow_bytes=r.shadow_bytes, reassembled_ok=r.reassembled_ok,
        pfc_pauses=r.pfc_pauses, drops=r.drops)


def _legacy_simulate_allgather(
        n_ranks: int,
        grad_bytes: int,
        link_gbps: float = 100.0,
        n_shadow_nodes: int = 1,
        shadow_nics: int = 2,
        shadow_drain_gbps: float | None = None,
        replication_factor: int = 1,
        n_channels: int = 1) -> SimResult:
    """The original per-round arithmetic model, kept as a regression oracle
    for the event engine's counters (tests/test_fabric.py)."""
    chunk_bytes = grad_bytes // n_ranks
    control = SwitchControlPlane(1, n_ranks, n_shadow_nodes).setup()
    switch = SwitchDataPlane(control)
    shadow_drain_gbps = shadow_drain_gbps or (link_gbps * shadow_nics)

    schedule = {(ev.round, ev.src_rank): ev
                for ev in tag_schedule(n_ranks, n_channels=1,
                                       n_shadow_nodes=n_shadow_nodes)}
    shadow_rx: dict[int, dict] = {n: {} for n in range(n_shadow_nodes)}
    shadow_bytes = {n: 0 for n in range(n_shadow_nodes)}
    pfc = {n: PfcQueue() for n in range(n_shadow_nodes)}

    t = 0.0
    seqs = [0] * max(n_channels, 1)
    rounds = max(n_ranks - 1, 1)
    for rnd in range(rounds):
        # every rank sends one chunk to its neighbour concurrently at line
        # rate
        link_time = chunk_bytes * 8 / (link_gbps * 1e9)
        shadow_round_bytes = {n: 0 for n in range(n_shadow_nodes)}
        for rank in range(n_ranks):
            chunk = chunk_at(rank, rnd, n_ranks)
            tagged = is_tagged(rank, rnd, n_ranks)
            ev = schedule.get((rnd, rank))
            frames = frames_for_chunk(
                rank, (rank + 1) % n_ranks, chunk=chunk, channel=0,
                chunk_bytes=chunk_bytes, start_seq=0, tagged=tagged,
                shadow_seq0=seqs[0] * chunk_bytes if tagged else -1,
                shadow_node=(ev.shadow_node if ev else -1))
            if tagged:
                seqs[0] += 1
            for f in frames:
                out = switch.process(f)
                for g in out[1:]:
                    for _ in range(replication_factor):
                        node = g.shadow_node % n_shadow_nodes
                        pfc[node].offer(g.payload_len)
                        shadow_rx[node].setdefault(g.chunk, 0)
                        shadow_rx[node][g.chunk] += g.payload_len
                        shadow_bytes[node] += g.payload_len
                        shadow_round_bytes[node] += g.payload_len
                switch.counters.tx_frames += \
                    (replication_factor - 1) * (len(out) - 1)
        # round duration: slower of ring link vs shadow drain
        drain_times = [b * 8 / (shadow_drain_gbps * 1e9)
                       for b in shadow_round_bytes.values()] or [0.0]
        round_time = max([link_time] + drain_times)
        for n in range(n_shadow_nodes):
            pfc[n].drain(int(shadow_drain_gbps * 1e9 / 8 * round_time))
        t += round_time

    # reassembly check: every chunk fully received exactly once across nodes
    got: dict[int, int] = {}
    for n, chunks in shadow_rx.items():
        for c, b in chunks.items():
            got[c] = got.get(c, 0) + b
    expected = {c: chunk_bytes * replication_factor for c in range(n_ranks)}
    ok = got == expected

    # bus bandwidth convention (nccl-tests): busbw = algbw * 2(n-1)/n
    # AllGather moves (n-1)/n of the data per rank per phase.
    algbw = (grad_bytes * 8 / t) / 1e9 if t else 0.0
    busbw = algbw * (n_ranks - 1) / n_ranks

    return SimResult(
        n_ranks=n_ranks, total_bytes=grad_bytes, duration_s=t,
        bus_bandwidth_gbps=busbw, algo_bandwidth_gbps=algbw,
        rx_frames=switch.counters.rx_frames,
        tx_frames=switch.counters.tx_frames,
        tx_over_rx=switch.counters.tx_over_rx,
        mirrored_frames=switch.counters.mirrored_frames,
        shadow_bytes=shadow_bytes,
        reassembled_ok=ok,
        pfc_pauses=sum(q.pause_events for q in pfc.values()),
        drops=sum(q.dropped for q in pfc.values()))


# ---------------------------------------------------------------------------
# CLI: topology / replication sweeps
# ---------------------------------------------------------------------------

def _parse_kill(spec: str) -> FailureSpec:
    """"link:leaf0:spine0@120" / "switch:spine1@80" / "shadow_nic:s0@50"
    — the trailing number is the failure time in microseconds."""
    body, _, at = spec.partition("@")
    parts = body.split(":")
    kind = parts[0]
    try:
        at_s = float(at) * 1e-6 if at else 0.0
        if kind == "link":
            return FailureSpec(at_s, "link", (parts[1], parts[2]))
        if kind in ("switch", "shadow_nic"):
            return FailureSpec(at_s, kind, parts[1])
    except (IndexError, ValueError):
        pass
    raise ValueError(
        f"bad --kill spec {spec!r}: expected link:A:B[@US], "
        f"switch:NAME[@US], or shadow_nic:NAME[@US]")


def main(argv=None):
    p = argparse.ArgumentParser(
        description="Event-driven gradient-multicast fabric simulator "
                    "(Checkmate §4 / Fig 10); see docs/netsim.md")
    p.add_argument("--ranks", type=int, default=64,
                   help="total training ranks across all DP groups")
    p.add_argument("--dp-groups", type=int, default=2)
    p.add_argument("--shadow-nodes", type=int, default=2)
    p.add_argument("--topology", default="rail",
                   choices=["single", "rail", "leaf-spine"])
    p.add_argument("--ranks-per-leaf", type=int, default=16)
    p.add_argument("--spines", type=int, default=2)
    p.add_argument("--grad-kb", type=int, default=1024,
                   help="reduced-gradient payload per DP group (KiB)")
    p.add_argument("--link-gbps", type=float, default=100.0)
    p.add_argument("--replication", default="1,2,4",
                   help="comma-separated Fig 10 replication factors")
    p.add_argument("--channels", type=int, default=1)
    p.add_argument("--kill", action="append", default=[],
                   metavar="KIND:TARGET[@US]",
                   help="failure injection, e.g. link:leaf0:spine0@120, "
                        "switch:spine1@80, shadow_nic:s0@50")
    p.add_argument("--fast", action="store_true",
                   help="inlined fast event engine (bit-exact results; "
                        "see docs/netsim.md)")
    args = p.parse_args(argv)

    if args.ranks % args.dp_groups:
        p.error("--ranks must be divisible by --dp-groups")
    rpg = args.ranks // args.dp_groups
    try:
        failures = tuple(_parse_kill(s) for s in args.kill)
    except ValueError as e:
        p.error(str(e))
    factors = [int(x) for x in args.replication.split(",")]

    hdr = (f"{'rf':>3} {'dur_us':>9} {'busbw':>8} {'tx/rx':>6} "
           f"{'pauses':>6} {'drops':>5} {'retx':>5} {'rerte':>5} "
           f"{'lost':>5} {'ok':>3}")
    print(f"# {args.topology}: {args.ranks} ranks, {args.dp_groups} DP "
          f"groups, {args.shadow_nodes} shadow nodes, "
          f"{args.grad_kb} KiB/group"
          + (f", failures={[str(k) for k in args.kill]}" if args.kill
             else ""))
    print(hdr)
    for rf in factors:
        r = simulate_fabric(
            args.dp_groups, rpg, args.grad_kb * 1024,
            topology=args.topology, n_shadow_nodes=args.shadow_nodes,
            link_gbps=args.link_gbps, replication_factor=rf,
            n_channels=args.channels, ranks_per_leaf=args.ranks_per_leaf,
            n_spines=args.spines, failures=failures, fast=args.fast)
        print(f"{rf:>3} {r.duration_s * 1e6:>9.1f} "
              f"{r.bus_bandwidth_gbps:>8.1f} {r.tx_over_rx:>6.3f} "
              f"{r.pfc_pauses:>6} {r.drops:>5} {r.retransmits:>5} "
              f"{r.rerouted:>5} {r.mirror_lost_frames:>5} "
              f"{'y' if r.reassembled_ok else 'N':>3}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
