"""Discrete-event replication simulator: replays the heartbeat tag schedule
through the switch model over link/NIC bandwidth constraints.

Reproduces:
  * §4.1 exactly-once capture (asserted by reassembly),
  * §6.6 / Fig 10: replication factor vs AllReduce bus bandwidth and
    TX/RX frame ratio,
  * dual-NIC shadow provisioning (§4.1.1): round-0 double-rate reception.

Time advances in per-round steps of the AllGather; within a round each
link transmits a chunk's frames at line rate, and the round lasts
max(link serialization, shadow drain) — which is how incast shows up.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.multicast import SwitchControlPlane
from repro.core.tagging import chunk_at, is_tagged, tag_schedule
from repro.net.packets import MTU, Frame, frames_for_chunk
from repro.net.pfc import PfcQueue
from repro.net.switch import SwitchDataPlane


@dataclass
class SimResult:
    n_ranks: int
    total_bytes: int
    duration_s: float
    bus_bandwidth_gbps: float
    algo_bandwidth_gbps: float
    rx_frames: int
    tx_frames: int
    tx_over_rx: float
    mirrored_frames: int
    shadow_bytes: dict
    reassembled_ok: bool
    pfc_pauses: int
    drops: int


def simulate_allgather_replication(
        n_ranks: int,
        grad_bytes: int,
        link_gbps: float = 100.0,
        n_shadow_nodes: int = 1,
        shadow_nics: int = 2,
        shadow_drain_gbps: float | None = None,
        replication_factor: int = 1,
        n_channels: int = 1) -> SimResult:
    """Simulate the AllGather phase of one iteration with tag replication.

    grad_bytes: total reduced-gradient bytes (the AllGather payload).
    replication_factor: mirrors per tagged packet (Fig 10 sweeps this).
    """
    chunk_bytes = grad_bytes // n_ranks
    control = SwitchControlPlane(1, n_ranks, n_shadow_nodes).setup()
    switch = SwitchDataPlane(control)
    shadow_drain_gbps = shadow_drain_gbps or (link_gbps * shadow_nics)

    schedule = {(ev.round, ev.src_rank): ev
                for ev in tag_schedule(n_ranks, n_channels=1,
                                       n_shadow_nodes=n_shadow_nodes)}
    shadow_rx: dict[int, dict] = {n: {} for n in range(n_shadow_nodes)}
    shadow_bytes = {n: 0 for n in range(n_shadow_nodes)}
    pfc = {n: PfcQueue() for n in range(n_shadow_nodes)}

    t = 0.0
    seqs = [0] * max(n_channels, 1)
    rounds = max(n_ranks - 1, 1)
    for rnd in range(rounds):
        # every rank sends one chunk to its neighbour concurrently at line rate
        link_time = chunk_bytes * 8 / (link_gbps * 1e9)
        shadow_round_bytes = {n: 0 for n in range(n_shadow_nodes)}
        for rank in range(n_ranks):
            chunk = chunk_at(rank, rnd, n_ranks)
            tagged = is_tagged(rank, rnd, n_ranks)
            ev = schedule.get((rnd, rank))
            frames = frames_for_chunk(
                rank, (rank + 1) % n_ranks, chunk=chunk, channel=0,
                chunk_bytes=chunk_bytes, start_seq=0, tagged=tagged,
                shadow_seq0=seqs[0] * chunk_bytes if tagged else -1,
                shadow_node=(ev.shadow_node if ev else -1))
            if tagged:
                seqs[0] += 1
            for f in frames:
                out = switch.process(f)
                for g in out[1:]:
                    for _ in range(replication_factor):
                        node = g.shadow_node % n_shadow_nodes
                        pfc[node].offer(g.payload_len)
                        shadow_rx[node].setdefault(g.chunk, 0)
                        shadow_rx[node][g.chunk] += g.payload_len
                        shadow_bytes[node] += g.payload_len
                        shadow_round_bytes[node] += g.payload_len
                switch.counters.tx_frames += (replication_factor - 1) * (len(out) - 1)
        # round duration: slower of ring link vs shadow drain
        drain_times = [b * 8 / (shadow_drain_gbps * 1e9)
                       for b in shadow_round_bytes.values()] or [0.0]
        round_time = max([link_time] + drain_times)
        for n in range(n_shadow_nodes):
            pfc[n].drain(int(shadow_drain_gbps * 1e9 / 8 * round_time))
        t += round_time

    # reassembly check: every chunk fully received exactly once across nodes
    got: dict[int, int] = {}
    for n, chunks in shadow_rx.items():
        for c, b in chunks.items():
            got[c] = got.get(c, 0) + b
    expected = {c: chunk_bytes * replication_factor for c in range(n_ranks)}
    ok = got == expected

    # bus bandwidth convention (nccl-tests): busbw = algbw * 2(n-1)/n
    # AllGather moves (n-1)/n of the data per rank per phase.
    total_moved = grad_bytes * (n_ranks - 1)
    algbw = (grad_bytes * 8 / t) / 1e9 if t else 0.0
    busbw = algbw * (n_ranks - 1) / n_ranks

    return SimResult(
        n_ranks=n_ranks, total_bytes=grad_bytes, duration_s=t,
        bus_bandwidth_gbps=busbw, algo_bandwidth_gbps=algbw,
        rx_frames=switch.counters.rx_frames,
        tx_frames=switch.counters.tx_frames,
        tx_over_rx=switch.counters.tx_over_rx,
        mirrored_frames=switch.counters.mirrored_frames,
        shadow_bytes=shadow_bytes,
        reassembled_ok=ok,
        pfc_pauses=sum(q.pause_events for q in pfc.values()),
        drops=sum(q.dropped for q in pfc.values()))
