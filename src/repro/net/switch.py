"""Switch data plane (paper §4.3.2): stateless match-action processing.

Ingress: untagged packets get normal L2 forwarding; tagged packets are
assigned a multicast group and replicated by the PRE. Egress (for mirrored
copies): rewrite the TCP sequence number to the shadow-stream counter from
the custom option, and rewrite src/dst for the shadow node's TCP stream.
ACKs from shadow nodes are dropped (the switch emulates the TCP server).

In the multi-switch fabric simulator every leaf and spine instantiates its
own ``SwitchDataPlane`` (own counters); the multicast/mirror rules are only
installed — i.e. ``replicate=True`` — on the ingress leaf of each boundary
rank, matching where the control plane (§4.3.1) programs the match-action
table.  All counters are weighted by ``Frame.n_frames`` so coalesced frames
report exact wire-frame counts.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

from repro.core.multicast import SwitchControlPlane
from repro.net.packets import Frame


@dataclass
class SwitchCounters:
    rx_frames: int = 0
    tx_frames: int = 0
    mirrored_frames: int = 0
    dropped_acks: int = 0

    @property
    def tx_over_rx(self) -> float:
        return self.tx_frames / self.rx_frames if self.rx_frames else 0.0

    def merge(self, other: "SwitchCounters") -> "SwitchCounters":
        """Aggregate counters across switches (fabric-wide totals)."""
        return SwitchCounters(
            rx_frames=self.rx_frames + other.rx_frames,
            tx_frames=self.tx_frames + other.tx_frames,
            mirrored_frames=self.mirrored_frames + other.mirrored_frames,
            dropped_acks=self.dropped_acks + other.dropped_acks)

    def as_dict(self) -> dict:
        """Plain-dict view for metrics publication / JSON snapshots."""
        d = dataclasses.asdict(self)
        d["tx_over_rx"] = self.tx_over_rx
        return d


class SwitchDataPlane:
    """Match-action pipeline of one physical switch.

    Args:
        control: the fabric-wide control plane (match table + shadow map).
        rank_to_dp: maps a global source rank to its DP group; defaults to
            contiguous groups of ``control.ranks_per_group`` ranks.
        name: switch id for per-switch counter reporting ("sw0", "leaf3",
            "spine1", ...).
    """

    def __init__(self, control: SwitchControlPlane,
                 rank_to_dp=None, name: str = "sw0"):
        self.control = control
        self.name = name
        self.counters = SwitchCounters()
        self.rank_to_dp = rank_to_dp or (
            lambda r: r // control.ranks_per_group)

    def process(self, frame: Frame, replication_factor: int = 1,
                replicate: bool = True) -> list[Frame]:
        """One ingress frame -> egress frames (forward + mirrors).

        Args:
            replication_factor: mirror copies per tagged frame (Fig 10
                sweeps this); each copy gets a distinct ``replica`` index.
            replicate: False on switches where the multicast rule is not
                installed (spines / non-boundary leaves) — pure forwarding.
        """
        self.counters.rx_frames += frame.n_frames
        out = [frame]                            # normal L2 forward
        if replicate and frame.tagged and not frame.mirrored:
            dp = self.rank_to_dp(frame.src)
            group = self.control.lookup(dp, frame.src)
            if group is not None:
                for rep in range(replication_factor):
                    out.append(dataclasses.replace(
                        frame,
                        dst=frame.shadow_node,
                        # egress rewrite: shadow-stream sequence (§4.3.2)
                        tcp_seq=frame.shadow_seq,
                        mirrored=True, replica=rep))
                    self.counters.mirrored_frames += frame.n_frames
        self.counters.tx_frames += sum(f.n_frames for f in out)
        return out

    def process_ack(self):
        self.counters.dropped_acks += 1
        return []
