"""Switch data plane (paper §4.3.2): stateless match-action processing.

Ingress: untagged packets get normal L2 forwarding; tagged packets are
assigned a multicast group and replicated by the PRE. Egress (for mirrored
copies): rewrite the TCP sequence number to the shadow-stream counter from
the custom option, and rewrite src/dst for the shadow node's TCP stream.
ACKs from shadow nodes are dropped (the switch emulates the TCP server).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.multicast import SwitchControlPlane
from repro.net.packets import Frame


@dataclass
class SwitchCounters:
    rx_frames: int = 0
    tx_frames: int = 0
    mirrored_frames: int = 0
    dropped_acks: int = 0

    @property
    def tx_over_rx(self) -> float:
        return self.tx_frames / self.rx_frames if self.rx_frames else 0.0


class SwitchDataPlane:
    def __init__(self, control: SwitchControlPlane,
                 rank_to_dp=None):
        self.control = control
        self.counters = SwitchCounters()
        self.rank_to_dp = rank_to_dp or (
            lambda r: r // control.ranks_per_group)

    def process(self, frame: Frame) -> list[Frame]:
        """One ingress frame -> egress frames (forward + mirrors)."""
        self.counters.rx_frames += 1
        out = [frame]                            # normal L2 forward
        if frame.tagged:
            dp = self.rank_to_dp(frame.src)
            group = self.control.lookup(dp, frame.src)
            if group is not None:
                mirror = Frame(
                    src=frame.src, dst=frame.shadow_node,
                    payload_off=frame.payload_off,
                    payload_len=frame.payload_len,
                    chunk=frame.chunk, channel=frame.channel,
                    # egress rewrite: shadow-stream sequence (§4.3.2)
                    tcp_seq=frame.shadow_seq,
                    tagged=True, shadow_seq=frame.shadow_seq,
                    shadow_node=frame.shadow_node, mirrored=True)
                out.append(mirror)
                self.counters.mirrored_frames += 1
        self.counters.tx_frames += len(out)
        return out

    def process_ack(self):
        self.counters.dropped_acks += 1
        return []
