"""`repro.net` — the network plane of the Checkmate reproduction.

Packet/frame model (`packets`), switch match-action data plane (`switch`),
priority flow control (`pfc`), fabric topology construction + §4.4 resource
planning (`planner`), and the event-driven multi-switch simulator
(`simulator`).  See docs/ARCHITECTURE.md for the package map and
docs/netsim.md for the simulator's model and usage.
"""
from repro.net.packets import MTU, Frame, frames_for_chunk  # noqa: F401
from repro.net.pfc import PfcConfig, PfcQueue  # noqa: F401
from repro.net.planner import (  # noqa: F401
    LinkSpec, Plan, PlanInput, Topology, build_topology, plan,
)
from repro.net.switch import SwitchCounters, SwitchDataPlane  # noqa: F401

_SIMULATOR_API = (
    "FabricResult", "FabricSimulator", "FailureSpec", "SimResult",
    "simulate_allgather_replication", "simulate_fabric",
    "sweep_replication", "sweep_topology",
)


def __getattr__(name):
    # lazy so `python -m repro.net.simulator` does not double-import the
    # module it is about to execute (runpy RuntimeWarning)
    if name in _SIMULATOR_API:
        from repro.net import simulator
        return getattr(simulator, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
