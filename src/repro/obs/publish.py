"""Publish subsystem state into a `MetricsRegistry`, and render a digest.

The instrumented hot paths update cheap native counters in place
(`SwitchCounters`, `FabricTotals`, `ShadowNode` apply stats, checkpointer
stall ledgers); these publishers mirror that state into labeled registry
metrics *once per run* so every number ends up behind a single exposition
surface. Duck-typed on attribute presence, so any channel/checkpointer/
shadow combination (or a bare subset) publishes cleanly.
"""
from __future__ import annotations

from repro.obs.stalls import format_stall_report, publish_stalls


def _unwrap_channels(channel):
    """The channel plus its ``.inner`` chain (Compressed->Packetized etc.)."""
    out = []
    while channel is not None and channel not in out:
        out.append(channel)
        channel = getattr(channel, "inner", None)
    return out


def publish_checkpointer(reg, ck, labels=None) -> None:
    labels = labels or {}
    reg.counter("checkpoints_total", "Captures that completed").inc(
        getattr(ck, "n_checkpoints", 0), **labels)
    reg.counter("checkpoint_skipped_captures_total",
                "Captures gated off by injected failures").inc(
        getattr(ck, "skipped_captures", 0), **labels)
    resyncs = getattr(ck, "resyncs", 0)      # checkmate keeps a step list
    if hasattr(resyncs, "__len__"):
        resyncs = len(resyncs)
    reg.counter("checkpoint_resyncs_total",
                "Full-state re-replications after desync").inc(
        resyncs, **labels)
    publish_stalls(reg, ck, labels=labels)


def publish_shadow(reg, shadow) -> None:
    """Shadow-cluster apply stats (per node + aggregate gauges)."""
    stats = shadow.stats()
    reg.gauge("shadow_apply_mean_seconds",
              "Mean per-node shadow apply time").set(stats.mean_apply_s)
    reg.gauge("shadow_apply_max_seconds",
              "Max single shadow apply time").set(stats.max_apply_s)
    reg.gauge("shadow_lag_steps",
              "Trainer step minus slowest shadow step").set(stats.lag)
    reg.gauge("shadow_queue_depth",
              "Peak pending async-ingest deliveries").set(
        stats.max_queue_depth)
    applies = reg.counter("shadow_applies_total", "Fused optimizer applies")
    for node in getattr(shadow, "nodes", []):
        applies.inc(getattr(node, "apply_count", 0),
                    node=getattr(node, "node_id", "?"))


def publish_channel(reg, channel) -> None:
    """Wire/fabric accounting for a channel stack (outermost first)."""
    for ch in _unwrap_channels(channel):
        name = getattr(ch, "name", type(ch).__name__)
        totals = getattr(ch, "totals", None)
        if totals is None:
            continue
        reg.counter("channel_sends_total", "Gradient sends").inc(
            totals.sends, channel=name)
        reg.counter("channel_gated_total",
                    "Sends gated off by capture failures").inc(
            totals.gated, channel=name)
        reg.counter("channel_wire_bytes_total",
                    "Bytes put on the wire (incl. replication)").inc(
            totals.wire_bytes, channel=name)
        frames = reg.counter("fabric_frames_total",
                             "Frames by lifecycle stage")
        for kind in ("tx", "rx", "mirrored"):
            frames.inc(getattr(totals, f"frames_{kind}"), kind=kind)
        loss = reg.counter("fabric_loss_events_total",
                           "Loss/recovery events in the fabric")
        for kind in ("drops", "retransmits", "rerouted", "mirror_lost"):
            loss.inc(getattr(totals, kind), kind=kind)
        reg.counter("fabric_pfc_pauses_total", "PFC pause frames").inc(
            totals.pfc_pauses)
        reg.counter("fabric_pfc_resumes_total", "PFC resume frames").inc(
            totals.pfc_resumes)
        reg.counter("fabric_pfc_pause_seconds_total",
                    "Aggregate link-paused virtual time").inc(
            totals.pfc_pause_s)
        reg.counter("fabric_time_seconds_total",
                    "Simulated fabric time consumed").inc(
            totals.fabric_time_s)
        # satellite: per-link PFC pause duration, labeled (was aggregate-only)
        pause_g = reg.gauge("fabric_link_pfc_pause_seconds",
                            "Paused virtual time per link")
        pauses_c = reg.counter("fabric_link_pfc_pauses_total",
                               "Pause frames per link")
        for link, st in sorted(totals.link_pfc.items()):
            pause_g.set(st.get("pause_s", 0.0), link=link)
            pauses_c.inc(st.get("pauses", 0), link=link)


def collect_run(reg, checkpointer=None, shadow=None, channel=None) -> dict:
    """Publish everything present, then return the registry snapshot."""
    if checkpointer is not None:
        publish_checkpointer(reg, checkpointer)
        if channel is None:
            channel = getattr(checkpointer, "channel", None)
        if shadow is None:
            shadow = getattr(checkpointer, "shadow", None)
    if channel is not None:
        publish_channel(reg, channel)
    if shadow is not None:
        publish_shadow(reg, shadow)
    return reg.snapshot()


def _val(snap, name, **labels):
    fam = snap.get("metrics", {}).get(name)
    if not fam:
        return None
    want = {k: str(v) for k, v in labels.items()}
    for s in fam["samples"]:
        if s["labels"] == want:
            return s.get("value", s.get("sum"))
    return None


def render_digest(snapshot: dict, ck=None) -> str:
    """One-screen end-of-run metrics digest sourced from a registry
    snapshot (the ``launch.train`` / ``repro.obs summary`` epilogue)."""
    lines = ["== run digest =="]

    def row(label, value, fmt="{}"):
        if value is not None:
            lines.append(f"  {label:<26} " + fmt.format(value))

    row("checkpoints", _val(snapshot, "checkpoints_total"))
    row("skipped captures",
        _val(snapshot, "checkpoint_skipped_captures_total"))
    row("resyncs", _val(snapshot, "checkpoint_resyncs_total"))
    row("shadow apply mean/max",
        (_val(snapshot, "shadow_apply_mean_seconds"),
         _val(snapshot, "shadow_apply_max_seconds"))
        if _val(snapshot, "shadow_apply_mean_seconds") is not None else None,
        "{0[0]:.6f}s / {0[1]:.6f}s")
    frames = {k: _val(snapshot, "fabric_frames_total", kind=k)
              for k in ("tx", "rx", "mirrored")}
    if any(v is not None for v in frames.values()):
        lines.append("  {:<26} tx={} rx={} mirrored={}".format(
            "frames", *(frames[k] or 0 for k in ("tx", "rx", "mirrored"))))
    wire = snapshot.get("metrics", {}).get("channel_wire_bytes_total")
    if wire and wire["samples"]:
        row("bytes on wire", sum(s["value"] for s in wire["samples"]))
    row("fabric time", _val(snapshot, "fabric_time_seconds_total"),
        "{:.6f}s")
    row("pfc pause time",
        _val(snapshot, "fabric_pfc_pause_seconds_total"), "{:.6f}s")
    stall_fam = snapshot.get("metrics", {}).get(
        "checkpoint_stall_seconds_total")
    if stall_fam and stall_fam["samples"]:
        lines.append("  stall attribution:")
        for s in stall_fam["samples"]:
            stage = s["labels"].get("stage", "?")
            lines.append(f"    {stage:<22} {s['value']:.6f}s")
    if ck is not None:
        lines.append(format_stall_report(ck))
    return "\n".join(lines)
