"""Stall attribution: decompose every booked stall second by stage.

Checkpointers book stalls into an ordered per-stage ledger
(``BaseCheckpointer.stall_stages``) instead of one opaque float;
``stall_total`` is *defined* as the in-order sum of that ledger, so the
attribution here sums bit-exactly to the total by construction — no
float-reassociation slop, which the ``stall-attribution`` harness
invariant checks.

Stage vocabulary (KNOWN_STAGES):

* ``send``              — synchronous time inside ``channel.send`` (pack +
                          hand-off; zero for the packetized path, which is
                          the paper's zero-overhead claim)
* ``quantize``          — gradient compression ahead of the wire
* ``inline-apply``      — trainer-thread shadow apply (sync ingest mode)
* ``apply-lag``         — trainer blocked on a bounded-lag shadow whose
                          backlog hit ``max_lag_steps`` (the only cost a
                          too-slow async applier may charge the trainer)
* ``resync``            — full-state re-replication after a desync
* ``consolidate-wait``  — waiting on shadow consolidation during recovery
* ``copy-persist``      — the copy-then-persist baselines' whole stall
* ``elastic-reshard``   — rebuilding channel + shadow plane onto a
                          reconfigured mesh after an elastic shrink
"""
from __future__ import annotations

KNOWN_STAGES = ("send", "quantize", "inline-apply", "apply-lag", "resync",
                "consolidate-wait", "copy-persist", "elastic-reshard")


def stall_attribution(ck) -> dict:
    """Per-stage stall seconds for one checkpointer, in booking order."""
    return dict(getattr(ck, "stall_stages", {}) or {})


def format_stall_report(ck, title: str = "stall attribution") -> str:
    """One-screen table: stage | seconds | share of total."""
    stages = stall_attribution(ck)
    total = getattr(ck, "stall_total", 0.0)
    lines = [f"{title}  (total {total:.6f}s over "
             f"{getattr(ck, 'n_checkpoints', 0)} checkpoints)"]
    if not stages:
        lines.append("  (no stalls booked)")
        return "\n".join(lines)
    width = max(len(s) for s in stages)
    for stage, sec in stages.items():
        pct = 100.0 * sec / total if total else 0.0
        lines.append(f"  {stage:<{width}}  {sec:12.6f}s  {pct:6.2f}%")
    return "\n".join(lines)


def publish_stalls(reg, ck, labels=None) -> None:
    """Mirror one checkpointer's stall ledger into the registry.

    Call once per run (counters are cumulative; re-publishing would
    double-book)."""
    labels = labels or {}
    c = reg.counter("checkpoint_stall_seconds_total",
                    "Booked stall seconds by stage")
    for stage, sec in stall_attribution(ck).items():
        c.inc(sec, stage=stage, **labels)
