"""repro.obs — unified tracing & metrics plane (docs/observability.md).

One `Observability` holder pairs a `MetricsRegistry` with a `Tracer`; the
module-level active instance (default: fully disabled) is what every
instrumented hot path reads via `get()`:

    from repro import obs
    ob = obs.get()
    with ob.tracer.span("channel.send", args={"step": step}):
        ...
    ob.metrics.counter("channel_sends_total").inc(1, channel=name)

Both calls are near-zero-cost no-ops until a session is installed:

    with obs.enabled_session() as ob:
        run_scenario(GOLDEN["packetized-rail-clean"])
        ob.tracer.write("trace.json")        # Chrome/Perfetto JSON
        print(ob.metrics.to_prometheus())

CLI: ``python -m repro.obs {trace,summary,diff}``.
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass
from typing import Optional

from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry, diff_snapshots)
from repro.obs.trace import ManualClock, Tracer            # noqa: F401


@dataclass
class Observability:
    """One metrics registry + one tracer, enabled/disabled together."""
    metrics: MetricsRegistry
    tracer: Tracer

    @property
    def enabled(self) -> bool:
        return self.metrics.enabled or self.tracer.enabled

    @classmethod
    def disabled(cls) -> "Observability":
        return cls(MetricsRegistry(enabled=False), Tracer(enabled=False))

    @classmethod
    def session(cls, clock=None,
                trace_maxlen: Optional[int] = None) -> "Observability":
        return cls(MetricsRegistry(),
                   Tracer(clock=clock, maxlen=trace_maxlen))


_ACTIVE = Observability.disabled()


def get() -> Observability:
    """The active observability plane (disabled no-op by default)."""
    return _ACTIVE


def install(ob: Observability) -> Observability:
    """Swap the active plane; returns the previous one (for restore)."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, ob
    return prev


@contextmanager
def enabled_session(clock=None, trace_maxlen: Optional[int] = None):
    """Scoped fully-enabled plane; restores the previous one on exit."""
    ob = Observability.session(clock=clock, trace_maxlen=trace_maxlen)
    prev = install(ob)
    try:
        yield ob
    finally:
        install(prev)
