"""Labeled metrics registry: counters, gauges, bounded histograms.

One `MetricsRegistry` unifies the scattered per-subsystem counters
(`SwitchCounters`, PFC pause/resume totals, `ShadowNode` apply stats,
checkpointer stall/resync accounting, per-channel wire bytes) behind a
single exposition surface: `snapshot()` returns a deterministic JSON-able
dict, `to_prometheus()` the text exposition format.

The registry is *near-zero-cost when disabled*: every instrument accessor
returns one shared no-op instrument whose methods do nothing, so a hot
path may write

    reg.counter("channel_sends_total").inc(1, channel=name)

unconditionally and pay only an attribute lookup + a no-op call when the
registry is off. Instrument state is guarded by a per-family lock, so
shadow worker threads can observe concurrently with the training thread.
"""
from __future__ import annotations

import bisect
import json
import threading
from typing import Optional

DEFAULT_BOUNDS = (1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0)


class _NullInstrument:
    """Shared no-op stand-in handed out by a disabled registry."""
    __slots__ = ()

    def inc(self, value=1, **labels):
        pass

    def set(self, value, **labels):
        pass

    def observe(self, value, **labels):
        pass


NULL_INSTRUMENT = _NullInstrument()


def _key(labels: dict) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Family:
    """One named metric family; children are keyed by sorted label tuples."""
    kind = "untyped"
    __slots__ = ("name", "help", "_data", "_lock")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._data: dict = {}
        self._lock = threading.Lock()

    def labelsets(self) -> list[tuple]:
        with self._lock:
            return sorted(self._data)

    def _sample_value(self, raw):
        return raw

    def samples(self) -> list[dict]:
        with self._lock:
            items = sorted(self._data.items())
        return [{"labels": dict(k), **self._sample_value(v)}
                for k, v in items]


class Counter(_Family):
    kind = "counter"
    __slots__ = ()

    def inc(self, value=1, **labels):
        k = _key(labels)
        with self._lock:
            self._data[k] = self._data.get(k, 0) + value

    def value(self, **labels):
        return self._data.get(_key(labels), 0)

    def _sample_value(self, raw):
        return {"value": raw}


class Gauge(_Family):
    kind = "gauge"
    __slots__ = ()

    def set(self, value, **labels):
        with self._lock:
            self._data[_key(labels)] = value

    def inc(self, value=1, **labels):
        k = _key(labels)
        with self._lock:
            self._data[k] = self._data.get(k, 0) + value

    def value(self, **labels):
        return self._data.get(_key(labels), 0)

    def _sample_value(self, raw):
        return {"value": raw}


class Histogram(_Family):
    """Bounded histogram: fixed bucket bounds, exact count/sum, no sample
    retention — safe for long runs (unlike an unbounded list of applies)."""
    kind = "histogram"
    __slots__ = ("bounds",)

    def __init__(self, name: str, help: str = "",
                 bounds: tuple = DEFAULT_BOUNDS):
        super().__init__(name, help)
        self.bounds = tuple(sorted(bounds))

    def observe(self, value, **labels):
        k = _key(labels)
        i = bisect.bisect_left(self.bounds, value)
        with self._lock:
            st = self._data.get(k)
            if st is None:
                st = self._data[k] = {
                    "buckets": [0] * (len(self.bounds) + 1),
                    "sum": 0.0, "count": 0, "max": value}
            st["buckets"][i] += 1
            st["sum"] += value
            st["count"] += 1
            if value > st["max"]:
                st["max"] = value

    def _sample_value(self, raw):
        cum, out = 0, {}
        for bound, n in zip(self.bounds, raw["buckets"]):
            cum += n
            out[repr(bound)] = cum
        out["+Inf"] = cum + raw["buckets"][-1]
        return {"count": raw["count"], "sum": raw["sum"],
                "max": raw["max"], "buckets": out}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """The one place metrics live. ``enabled=False`` turns every accessor
    into a constant returning the shared no-op instrument."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._families: dict[str, _Family] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, **kw):
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = cls(name, help, **kw)
            elif not isinstance(fam, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{fam.kind}, not {cls.kind}")
            return fam

    def counter(self, name: str, help: str = "") -> Counter:
        if not self.enabled:
            return NULL_INSTRUMENT
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        if not self.enabled:
            return NULL_INSTRUMENT
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  bounds: Optional[tuple] = None) -> Histogram:
        if not self.enabled:
            return NULL_INSTRUMENT
        return self._get(Histogram, name, help,
                         bounds=bounds or DEFAULT_BOUNDS)

    # -- exposition ----------------------------------------------------------
    def snapshot(self) -> dict:
        """Deterministic JSON-able view: families sorted by name, samples
        by label tuple."""
        out = {}
        for name in sorted(self._families):
            fam = self._families[name]
            out[name] = {"type": fam.kind, "help": fam.help,
                         "samples": fam.samples()}
        return {"metrics": out}

    def write_json(self, path):
        from pathlib import Path
        Path(path).write_text(json.dumps(self.snapshot(), indent=2,
                                         sort_keys=True))

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        lines = []
        for name in sorted(self._families):
            fam = self._families[name]
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for s in fam.samples():
                lbl = ",".join(f'{k}="{v}"'
                               for k, v in sorted(s["labels"].items()))
                if fam.kind == "histogram":
                    for bound, cum in s["buckets"].items():
                        ble = (lbl + "," if lbl else "") + f'le="{bound}"'
                        lines.append(f"{name}_bucket{{{ble}}} {cum}")
                    suffix = f"{{{lbl}}}" if lbl else ""
                    lines.append(f"{name}_sum{suffix} {s['sum']}")
                    lines.append(f"{name}_count{suffix} {s['count']}")
                else:
                    suffix = f"{{{lbl}}}" if lbl else ""
                    lines.append(f"{name}{suffix} {s['value']}")
        return "\n".join(lines) + "\n"


def diff_snapshots(before: dict, after: dict) -> list[dict]:
    """Changed/new samples between two `snapshot()` dicts (or files the
    CLI loaded) — the trend-tracking primitive behind ``repro.obs diff``."""

    def flat(snap):
        out = {}
        for name, fam in snap.get("metrics", {}).items():
            for s in fam["samples"]:
                lbl = tuple(sorted(s["labels"].items()))
                val = s.get("value", s.get("sum"))
                out[(name, lbl)] = val
        return out

    a, b = flat(before), flat(after)
    rows = []
    for key in sorted(set(a) | set(b)):
        va, vb = a.get(key), b.get(key)
        if va != vb:
            name, lbl = key
            rows.append({"metric": name, "labels": dict(lbl),
                         "before": va, "after": vb})
    return rows
