"""``python -m repro.obs`` — trace / summary / diff for any run.

Subcommands:

* ``trace``   — run a harness scenario (golden name or sampled seed) or a
  reduced training config under a fully-enabled observability session and
  write the Chrome/Perfetto trace_event JSON (plus, optionally, the
  metrics snapshot).
* ``summary`` — same run selection, but print the one-screen metrics
  digest and the stall-attribution report instead of a trace file.
* ``diff``    — compare two metrics snapshot JSONs metric by metric.

Examples::

    PYTHONPATH=src python -m repro.obs trace --scenario packetized-rail-clean
    PYTHONPATH=src python -m repro.obs trace --seed 7 --out seed7.trace.json
    PYTHONPATH=src python -m repro.obs summary --train tinyllama-1.1b \
        --steps 5 --channel packetized
    PYTHONPATH=src python -m repro.obs diff before.json after.json

``--manual-clock`` swaps the host wall clock for a deterministic logical
clock, so a fixed scenario exports a byte-identical trace every run.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro import obs
from repro.obs.publish import collect_run, render_digest
from repro.obs.stalls import format_stall_report


# -- run selection -------------------------------------------------------------

def _add_run_args(ap: argparse.ArgumentParser):
    sel = ap.add_mutually_exclusive_group(required=True)
    sel.add_argument("--scenario",
                     help="golden scenario name (repro.harness.GOLDEN)")
    sel.add_argument("--seed", type=int,
                     help="sample a random scenario from one integer")
    sel.add_argument("--train", metavar="ARCH",
                     help="run a reduced training config (repro.configs)")
    ap.add_argument("--level", default="channel",
                    choices=["channel", "full"],
                    help="stack depth for --seed sampling")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--channel", default="inprocess",
                    choices=["inprocess", "packetized"],
                    help="gradient transport for --train")
    ap.add_argument("--shadow-nodes", type=int, default=2)
    ap.add_argument("--manual-clock", action="store_true",
                    help="deterministic logical host clock (golden traces)")


def _run_scenario(args, ob):
    from repro.harness import GOLDEN, run_scenario, sample_scenario

    if args.scenario is not None:
        if args.scenario not in GOLDEN:
            sys.exit(f"unknown scenario {args.scenario!r}; golden names:\n  "
                     + "\n  ".join(sorted(GOLDEN)))
        sc = GOLDEN[args.scenario]
    else:
        sc = sample_scenario(args.seed, level=args.level)
    result = run_scenario(sc)
    ck = result.trace.checkpointer
    collect_run(ob.metrics, checkpointer=ck, channel=result.trace.channel)
    return sc.name, ck, result


def _run_train(args, ob):
    import jax

    import repro.configs as C
    from repro.core.buckets import layout_for_tree
    from repro.core.channel import InProcessChannel, PacketizedChannel
    from repro.core.checkpoint import CheckmateCheckpointer
    from repro.core.shadow import ShadowCluster
    from repro.dist.sharding import ShardingRules, make_smoke_mesh
    from repro.optim import OptimizerConfig
    from repro.train.loop import train
    from repro.train.step import make_train_state

    cfg = C.get(args.train).reduced()
    rules = ShardingRules(make_smoke_mesh())
    opt = OptimizerConfig(name="adamw", lr=1e-3)
    s0 = make_train_state(jax.random.PRNGKey(0), cfg, rules)
    shadow = ShadowCluster(layout_for_tree(s0.params), opt,
                           n_nodes=args.shadow_nodes)
    shadow.bootstrap(s0.params, s0.mu, s0.nu, 0)
    if args.channel == "packetized":
        channel = PacketizedChannel(n_shadow_nodes=args.shadow_nodes)
    else:
        channel = InProcessChannel()
    ck = CheckmateCheckpointer(shadow, channel=channel)
    train(cfg, rules, steps=args.steps, batch=args.batch, seq=args.seq,
          opt=opt, lr_fn=lambda _: 1e-3, checkpointer=ck, seed=0, state=s0)
    collect_run(ob.metrics, checkpointer=ck)
    return f"train-{cfg.name}", ck, None


def _run(args, ob):
    if args.train is not None:
        return _run_train(args, ob)
    return _run_scenario(args, ob)


# -- subcommands ---------------------------------------------------------------

def cmd_trace(args) -> int:
    clock = obs.ManualClock(0.0) if args.manual_clock else None
    with obs.enabled_session(clock=clock) as ob:
        name, ck, _ = _run(args, ob)
        out = args.out or f"{name}.trace.json"
        ob.tracer.write(out)
        n = len(ob.tracer.events())
        if args.metrics_out:
            ob.metrics.write_json(args.metrics_out)
    print(f"{name}: {n} trace events -> {out}")
    if args.metrics_out:
        print(f"{name}: metrics snapshot -> {args.metrics_out}")
    return 0


def cmd_summary(args) -> int:
    clock = obs.ManualClock(0.0) if args.manual_clock else None
    with obs.enabled_session(clock=clock) as ob:
        name, ck, result = _run(args, ob)
        snap = ob.metrics.snapshot()
    print(f"== {name} ==")
    if result is not None:
        print(result.describe())
    print(render_digest(snap))
    if ck is not None:
        print(format_stall_report(ck))
    return 0


def cmd_diff(args) -> int:
    before = json.loads(open(args.before).read())
    after = json.loads(open(args.after).read())
    rows = obs.diff_snapshots(before, after)
    if not rows:
        print("no metric changed")
        return 0
    w = max(len(r["metric"]) for r in rows)
    for r in rows:
        labels = ",".join(f"{k}={v}" for k, v in sorted(r["labels"].items()))
        print(f"{r['metric']:<{w}} {{{labels}}} "
              f"{r['before']} -> {r['after']}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__.split("\n\n")[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    t = sub.add_parser("trace", help="run + export Chrome trace JSON")
    _add_run_args(t)
    t.add_argument("--out", help="trace path (default <name>.trace.json)")
    t.add_argument("--metrics-out", help="also write the metrics snapshot")
    t.set_defaults(fn=cmd_trace)

    s = sub.add_parser("summary", help="run + print the metrics digest")
    _add_run_args(s)
    s.set_defaults(fn=cmd_summary)

    d = sub.add_parser("diff", help="diff two metrics snapshot JSONs")
    d.add_argument("before")
    d.add_argument("after")
    d.set_defaults(fn=cmd_diff)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
