"""Structured span/event tracer with Chrome/Perfetto ``trace_event`` export.

Spans are emitted at every stage boundary of the capture->shadow pipeline
(step compute, bucket pack, channel send, per-frame fabric traversal,
shadow apply, resync, recovery). Two *clock domains* live on separate
process tracks in the export:

* ``pid 1`` — **host wall clock**: spans timed with the tracer's injected
  clock (default ``time.perf_counter``; `ManualClock` for deterministic
  golden traces).
* ``pid 2`` — **simulated fabric time**: the event-driven simulator's
  virtual timestamps (`Frame.t_send`/``t_arrive``, `FabricResult
  .duration_s`). Each fabric iteration is laid out after the previous one
  via ``fabric_advance``, so a multi-step run reads as a contiguous
  virtual-time timeline.

The tracer is *near-zero-cost when disabled*: ``span()`` returns one
shared no-op context manager and ``instant``/``fabric_span`` return
immediately, so hot paths may call them unconditionally. ``maxlen`` makes
the event buffer a ring — the harness uses that to keep only the trailing
trace window it embeds in violation repro bundles.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Optional

HOST_PID = 1
FABRIC_PID = 2
_PROCESS_NAMES = {HOST_PID: "host (wall clock)",
                  FABRIC_PID: "fabric (simulated time)"}


class ManualClock:
    """Deterministic logical clock: every read advances by ``tick``.

    Makes trace output a pure function of the traced code path (golden
    deterministic exports in tests), at the cost of spans measuring call
    counts, not wall time.
    """

    def __init__(self, start: float = 0.0, tick: float = 1e-6):
        self._t = float(start)
        self._tick = float(tick)

    def __call__(self) -> float:
        t = self._t
        self._t = t + self._tick
        return t


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("tr", "name", "track", "cat", "args", "t0")

    def __init__(self, tr, name, track, cat, args):
        self.tr = tr
        self.name = name
        self.track = track
        self.cat = cat
        self.args = args

    def __enter__(self):
        self.t0 = self.tr._clock()
        return self

    def __exit__(self, *exc):
        tr = self.tr
        tr._emit(self.name, HOST_PID, self.track, self.cat,
                 self.t0 - tr._t0, tr._clock() - tr._t0, self.args)
        return False


class Tracer:
    """Span/event collector; export() renders Chrome ``trace_event`` JSON."""

    def __init__(self, enabled: bool = True, clock=None,
                 maxlen: Optional[int] = None):
        self.enabled = enabled
        self._clock = clock if clock is not None else time.perf_counter
        self._t0 = self._clock() if enabled else 0.0
        self._events = deque(maxlen=maxlen)
        self._tracks: dict[tuple, int] = {}
        self._lock = threading.Lock()
        self._seq = 0
        self.fabric_base_s = 0.0           # virtual-time offset of this step

    # -- emission ------------------------------------------------------------
    def _tid(self, pid: int, track: str) -> int:
        key = (pid, track)
        tid = self._tracks.get(key)
        if tid is None:
            with self._lock:
                tid = self._tracks.setdefault(key,
                                              len(self._tracks) + 1)
        return tid

    def _emit(self, name, pid, track, cat, t0_s, t1_s, args):
        with self._lock:
            self._seq += 1
            seq = self._seq
        ev = {"name": name, "ph": "X", "cat": cat, "pid": pid,
              "tid": self._tid(pid, track),
              "ts": round(t0_s * 1e6, 3),
              "dur": round(max(t1_s - t0_s, 0.0) * 1e6, 3)}
        if args:
            ev["args"] = args
        ev["_seq"] = seq
        self._events.append(ev)

    # -- host clock domain ---------------------------------------------------
    def span(self, name: str, track: str = "train", cat: str = "host",
             args: Optional[dict] = None):
        """Context manager timing one host-side stage; no-op when disabled."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, track, cat, args)

    def instant(self, name: str, track: str = "train", cat: str = "host",
                args: Optional[dict] = None):
        if not self.enabled:
            return
        t = self._clock() - self._t0
        self._emit(name, HOST_PID, track, cat, t, t, args)

    # -- fabric (simulated-time) clock domain --------------------------------
    def fabric_span(self, name: str, t0_s: float, t1_s: float,
                    track: str = "fabric", args: Optional[dict] = None):
        """One span on the simulated-time tracks, at this step's virtual
        offset. ``t0_s``/``t1_s`` are simulator timestamps within the
        current fabric iteration (e.g. ``Frame.t_send``/``t_arrive``)."""
        if not self.enabled:
            return
        base = self.fabric_base_s
        self._emit(name, FABRIC_PID, track, "fabric",
                   base + t0_s, base + t1_s, args)

    def fabric_advance(self, duration_s: float):
        """Lay the next fabric iteration after this one in virtual time."""
        self.fabric_base_s += max(duration_s, 0.0)

    # -- export --------------------------------------------------------------
    def events(self) -> list[dict]:
        """The raw buffered events (ring-truncated when ``maxlen`` is set),
        without export metadata, ordered and stripped of internals."""
        evs = sorted(self._events, key=lambda e: (e["pid"], e["tid"],
                                                  e["ts"], e["_seq"]))
        return [{k: v for k, v in e.items() if k != "_seq"} for e in evs]

    def export(self) -> dict:
        """Chrome/Perfetto ``trace_event`` JSON object (load via
        chrome://tracing or https://ui.perfetto.dev)."""
        meta = []
        pids = sorted({pid for pid, _ in self._tracks})
        for pid in pids:
            meta.append({"name": "process_name", "ph": "M", "pid": pid,
                         "tid": 0,
                         "args": {"name": _PROCESS_NAMES.get(pid,
                                                             f"pid{pid}")}})
        for (pid, track), tid in sorted(self._tracks.items(),
                                        key=lambda kv: kv[1]):
            meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                         "tid": tid, "args": {"name": track}})
        return {"traceEvents": meta + self.events(),
                "displayTimeUnit": "ms"}

    def write(self, path):
        from pathlib import Path
        Path(path).write_text(json.dumps(self.export(), indent=1,
                                         sort_keys=True))
