"""Declarative chaos scenarios: everything a co-simulation run needs,
expandable from a single integer seed.

A `Scenario` names the full stack configuration — model (or synthetic
tree), channel stack, optimizer, DP groups, shadow plane — plus a
`FailureSchedule` of link/switch/shadow-NIC kills, gated-capture bursts,
worker wedges, and training-node failures. Scenarios are frozen,
JSON-round-trippable (`to_dict`/`from_dict`), and `sample_scenario(seed)`
expands a random-but-valid scenario deterministically from one RNG seed —
which is what makes every chaos run replayable from one integer
(`python -m repro.harness replay --seed N`).
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass, field

import numpy as np


def repro_seed(default: int = 0) -> int:
    """The process-wide base seed: ``REPRO_SEED`` env var (see
    tests/conftest.py, which prints it in the pytest header) or
    ``default``. Every harness RNG derives from a scenario seed, and
    seeded sweeps derive scenario seeds from this."""
    return int(os.environ.get("REPRO_SEED", default))


@dataclass(frozen=True)
class ChannelSpec:
    """How gradients travel from the capture point to the shadow plane.

    ``kind`` picks the `repro.core.channel` implementation; ``inner`` is
    the transport a ``compressed`` channel wraps. The remaining fields are
    forwarded to `PacketizedChannel` (fabric shape). ``sharded`` turns on
    bucket-sharded mirror routing: each shadow node receives only the
    buckets it owns, deliveries carry per-owner ``node_complete``
    verdicts, and ``shadow_rails`` spreads the owners across that many
    shadow leaf switches.
    """
    kind: str = "inprocess"            # inprocess | packetized | compressed
    inner: str = "inprocess"           # compressed only: inner transport
    topology: str = "rail-optimized"
    n_dp_groups: int = 1
    ranks_per_group: int = 4
    ranks_per_leaf: int = 4
    n_spines: int = 2
    shadow_nics: int = 2
    n_channels: int = 1
    replication_factor: int = 1
    sharded: bool = False              # packetized only: bucket->owner routing
    shadow_rails: int = 1
    # fabric engine: False = per-frame oracle, True = calendar-queue fast
    # path (bit-identical; tests/test_fabric_fastpath.py). Serialized into
    # every scenario/bundle JSON so a violation replays on the exact
    # engine that produced it.
    fast: bool = False

    @property
    def has_fabric(self) -> bool:
        """Whether a fabric simulator sits somewhere in the stack (i.e.
        fabric failure injection is meaningful)."""
        return self.kind == "packetized" or (
            self.kind == "compressed" and self.inner == "packetized")

    def build(self, failures_at: dict, n_shadow_nodes: int = 2):
        """Instantiate the channel stack (fabric failures attach to the
        packetized transport). ``n_shadow_nodes`` is the scenario's shadow
        cluster size, so the fabric models exactly the shadow hosts the
        scenario declares."""
        from repro.core.channel import (CompressedChannel, InProcessChannel,
                                        PacketizedChannel)

        def packetized():
            return PacketizedChannel(
                topology=self.topology, n_dp_groups=self.n_dp_groups,
                ranks_per_group=self.ranks_per_group,
                n_shadow_nodes=n_shadow_nodes,
                ranks_per_leaf=self.ranks_per_leaf, n_spines=self.n_spines,
                shadow_nics=self.shadow_nics, n_channels=self.n_channels,
                replication_factor=self.replication_factor,
                sharded=self.sharded, shadow_rails=self.shadow_rails,
                failures_at=failures_at, fast=self.fast)

        if self.kind == "inprocess":
            if failures_at:
                raise ValueError("fabric failures need a packetized "
                                 "transport in the channel stack")
            return InProcessChannel()
        if self.kind == "packetized":
            return packetized()
        if self.kind == "compressed":
            if self.inner == "packetized":
                return CompressedChannel(packetized())
            if failures_at:
                raise ValueError("fabric failures need a packetized "
                                 "transport in the channel stack")
            return CompressedChannel(InProcessChannel())
        raise ValueError(f"unknown channel kind {self.kind!r}")


@dataclass(frozen=True)
class FabricFailure:
    """One fabric-level failure bound to a training step.

    kind: "capture" (cut every shadow NIC at t=0 — that step's capture is
    lost, §4.3.2), or a `repro.net.simulator.FailureSpec` kind ("link",
    "switch", "shadow_nic") fired ``at_us`` microseconds into that step's
    fabric iteration. ``target`` follows FailureSpec conventions
    (("leaf0", "spine0") for links, a switch/shadow-host name otherwise).
    """
    step: int
    kind: str
    target: tuple | str | None = None
    at_us: float = 0.0


@dataclass(frozen=True)
class ShadowDeath:
    """Kill one shadow node of a bucket-sharded cluster at a step.

    ``phase`` places the death inside the iteration: ``"step"`` kills the
    node before that step's capture is sent (the delivery arrives with the
    dead owner's buckets missing), ``"consolidate"`` kills it after the
    step applied but before that step's consolidation (the gather itself
    discovers the loss). The node stays dead — every later capture keeps
    losing its shard — until a resync re-seeds replacement hardware.
    """
    step: int
    node: int
    phase: str = "step"                # step | consolidate


@dataclass(frozen=True)
class ShadowPlaneLoss:
    """Kill the ENTIRE shadow plane after ``step`` applied (rack power
    loss, correlated shadow-NIC failure, operator error).

    Every node dies at once — consolidation raises
    `ShadowNodeLoss(total=True)`, there is no surviving partial to merge,
    and the ONLY way back is `repro.durability.restore_from_tiers`: the
    runner restores from the newest flushed epoch, rewinds the trainer
    onto it, re-seeds a replacement fleet, and replays. Requires
    ``Scenario.durability.enabled``.
    """
    step: int


@dataclass(frozen=True)
class TrainNodeLoss:
    """Kill ``ranks`` train nodes after ``step`` with NO hot spare: the
    job must elastically shrink onto the survivors (ROADMAP item 1).

    The runner consolidates the shadow into a layout-agnostic
    checkpoint, replans the largest feasible layout on the surviving
    ranks (`repro.core.costmodel.plan_elastic_mesh`), rebuilds the
    channel geometry + bucket layout + shadow ownership map for the
    shrunken world (`repro.core.elastic.rebuild_shadow` +
    `CheckmateCheckpointer.reconfigure`, booked as the
    ``elastic-reshard`` stall stage), rewinds onto the checkpoint, and
    resumes at the new DP width. ``ranks`` are ORIGINAL-world rank ids;
    a second `TrainNodeLoss` at a later step shrinks again (double
    shrink). At full level the drill restores onto an FSDP-flipped
    `ShardingRules` — the one layout change expressible on the 1-device
    smoke mesh.
    """
    step: int
    ranks: tuple[int, ...] = (0,)


@dataclass(frozen=True)
class TierFailure:
    """Injected durability-tier write failure: every flush record for
    ``step`` raises `TierPutError` on the named tier (the record is still
    written to the OTHER tiers — restore falls back across tiers).
    """
    step: int
    tier: str = "local-disk"           # local-disk | object-store


@dataclass(frozen=True)
class DurabilitySpec:
    """The persistence tiers behind the scenario's shadow plane.

    ``enabled`` attaches a `repro.durability.DurableShadow` (a
    `LocalDiskTier` in a run-scoped tempdir, plus an `ObjectStoreTier`
    stub when ``object_store``) with a
    `FlushPolicy(every_steps, compress, rebase_every)`. The runner drains
    flushes between steps so tier lag is deterministic:
    ``every_steps - 1`` at worst.
    """
    enabled: bool = False
    every_steps: int = 1
    compress: bool = False
    rebase_every: int = 4
    object_store: bool = False
    object_latency_s: float = 0.0


@dataclass(frozen=True)
class FailureSchedule:
    """Everything that goes wrong during one scenario.

    * ``train_fail_steps`` — training-node failures (the iteration aborts
      mid-step and recovery restores from the checkpointer), fired once
      each (`repro.core.recovery.FailurePlan`).
    * ``fabric`` — `FabricFailure` events injected into the channel's
      fabric simulator, one-shot per step.
    * ``shadow_death`` — `ShadowDeath` kills of sharded shadow owners
      (persistent, unlike one-shot fabric failures).
    * ``wedge_node`` — wedge this shadow node's apply before the final
      step so consolidation hits its deadline (`ConsolidationTimeout`
      drill); requires an async shadow cluster. ``wedge_release_s`` is how
      long the worker stays wedged.
    * ``plane_loss`` — `ShadowPlaneLoss`: the whole shadow plane dies at
      once; recovery goes through the durability tiers.
    * ``tier_fail`` — `TierFailure`: a tier refuses one step's flush
      records (restore must fall back to another tier).
    * ``train_node_loss`` — `TrainNodeLoss`: train ranks die with no hot
      spare; the job elastically shrinks onto the survivors.
    """
    train_fail_steps: tuple[int, ...] = ()
    fabric: tuple[FabricFailure, ...] = ()
    shadow_death: tuple[ShadowDeath, ...] = ()
    wedge_node: int | None = None
    wedge_release_s: float = 1.5
    plane_loss: tuple[ShadowPlaneLoss, ...] = ()
    tier_fail: tuple[TierFailure, ...] = ()
    train_node_loss: tuple[TrainNodeLoss, ...] = ()

    def failures_at(self) -> dict:
        """The fabric schedule in `PacketizedChannel(failures_at=...)`
        form: {step: "capture" | (FailureSpec, ...)}."""
        from repro.net.simulator import FailureSpec
        by_step: dict[int, list[FabricFailure]] = {}
        for f in self.fabric:
            by_step.setdefault(f.step, []).append(f)
        out: dict = {}
        for step, fs in by_step.items():
            kinds = {f.kind for f in fs}
            if "capture" in kinds:
                if len(fs) > 1:
                    raise ValueError(
                        f"step {step}: 'capture' (kill every shadow NIC) "
                        f"cannot combine with other failures")
                out[step] = "capture"
            else:
                out[step] = tuple(
                    FailureSpec(f.at_us * 1e-6, f.kind,
                                tuple(f.target) if isinstance(
                                    f.target, (list, tuple)) else f.target)
                    for f in fs)
        return out

    @property
    def fabric_steps(self) -> frozenset[int]:
        return frozenset(f.step for f in self.fabric)


@dataclass(frozen=True)
class Scenario:
    """One declarative chaos co-simulation run (see docs/harness.md).

    ``level`` picks the stack depth:

    * ``"channel"`` — synthetic gradient stream through
      checkpointer -> channel -> fabric -> shadow, with a functional-
      optimizer reference trainer maintained side by side (fast; most of
      the golden corpus).
    * ``"full"`` — the real `repro.train.loop.train` loop on a reduced
      model config, with an uninterrupted reference run for bit-identity.

    ``invariants`` empty means auto-select every registered invariant
    whose ``applies()`` matches the scenario; naming invariants forces
    exactly those (used to demonstrate violation bundles).
    ``resync`` (channel level) mirrors whether events carry ``state_fn``,
    i.e. whether a gated capture heals via full-state copy (the training
    loop always resyncs) or freezes the shadow.
    """
    name: str
    level: str = "channel"             # channel | full
    seed: int = 0
    steps: int = 5
    # full level: model + data shape
    arch: str = "tinyllama-1.1b"
    batch: int = 2
    seq: int = 16
    # channel level: synthetic tree shape
    n_leaves: int = 3
    leaf_cols: int = 5
    cap_bytes: int = 4096
    resync: bool = True
    # shared
    optimizer: str = "adamw"
    lr: float = 1e-3
    momentum: float = 0.9
    shadow_nodes: int = 2
    shadow_async: bool = False
    # bounded multi-step shadow lag (async only): the applier may trail the
    # trainer by at most this many queued deliveries; a worker at the bound
    # catches up with one batched K-step replay, and the trainer's wait is
    # booked as the `apply-lag` stall stage. None = legacy unbounded queue.
    max_lag_steps: int | None = None
    # throttle every shadow apply by this many seconds (a deliberately slow
    # applier — the slow-apply golden drills); 0.0 = no throttle
    apply_delay_s: float = 0.0
    checkpointer: str = "checkmate"    # checkmate | sync | none
    ckpt_freq: int = 1
    channel: ChannelSpec = field(default_factory=ChannelSpec)
    schedule: FailureSchedule = field(default_factory=FailureSchedule)
    durability: DurabilitySpec = field(default_factory=DurabilitySpec)
    invariants: tuple[str, ...] = ()

    # -- construction helpers -------------------------------------------------
    def opt_config(self):
        from repro.optim import OptimizerConfig
        return OptimizerConfig(name=self.optimizer, lr=self.lr,
                               momentum=self.momentum)

    def validate(self) -> "Scenario":
        if self.level not in ("channel", "full"):
            raise ValueError(f"unknown level {self.level!r}")
        if self.seed < 0:
            raise ValueError(f"{self.name}: seed must be non-negative")
        if self.schedule.fabric and not self.channel.has_fabric:
            raise ValueError(
                f"{self.name}: fabric failures scheduled but channel "
                f"{self.channel.kind!r} has no fabric transport")
        if self.schedule.wedge_node is not None:
            if not self.shadow_async:
                raise ValueError(f"{self.name}: wedge_node requires an "
                                 f"async shadow cluster")
            if self.schedule.wedge_node >= self.shadow_nodes:
                raise ValueError(f"{self.name}: wedge_node out of range")
            if self.level != "channel":
                raise ValueError(f"{self.name}: wedge drills are "
                                 f"channel-level scenarios")
        if self.channel.sharded and self.channel.kind != "packetized":
            raise ValueError(f"{self.name}: sharded delivery is a "
                             f"packetized-transport feature")
        if self.channel.shadow_rails > max(1, self.shadow_nodes):
            raise ValueError(f"{self.name}: {self.channel.shadow_rails} "
                             f"shadow rails but only {self.shadow_nodes} "
                             f"shadow nodes to spread over them")
        if self.schedule.shadow_death:
            if not self.channel.sharded:
                raise ValueError(f"{self.name}: shadow_death needs a "
                                 f"sharded channel (per-owner delivery)")
            if self.level != "channel":
                raise ValueError(f"{self.name}: shadow_death drills are "
                                 f"channel-level scenarios")
            if self.schedule.wedge_node is not None:
                raise ValueError(f"{self.name}: shadow_death cannot "
                                 f"combine with a wedge drill")
            if self.schedule.train_fail_steps:
                raise ValueError(
                    f"{self.name}: shadow_death cannot combine with "
                    f"train_fail_steps — a dead shard makes shadow-only "
                    f"recovery partial (see recover(allow_partial=True))")
            for d in self.schedule.shadow_death:
                if d.phase not in ("step", "consolidate"):
                    raise ValueError(f"{self.name}: unknown death phase "
                                     f"{d.phase!r}")
                if not 0 <= d.node < self.shadow_nodes:
                    raise ValueError(f"{self.name}: shadow_death node "
                                     f"{d.node} out of range "
                                     f"0..{self.shadow_nodes - 1}")
                if not 1 <= d.step <= self.steps:
                    raise ValueError(f"{self.name}: shadow_death step "
                                     f"{d.step} outside 1..{self.steps}")
            if self.shadow_nodes < 2:
                raise ValueError(f"{self.name}: shadow_death needs >= 2 "
                                 f"shadow nodes (someone must survive)")
        if self.durability.enabled:
            if self.level != "channel":
                raise ValueError(f"{self.name}: durability tiers are "
                                 f"channel-level scenarios")
            if self.durability.every_steps < 1:
                raise ValueError(f"{self.name}: durability.every_steps "
                                 f"must be >= 1")
        if self.schedule.plane_loss:
            if not self.durability.enabled:
                raise ValueError(
                    f"{self.name}: plane_loss without durability tiers is "
                    f"unrecoverable — enable Scenario.durability")
            if not self.channel.sharded:
                raise ValueError(f"{self.name}: plane_loss drills drive a "
                                 f"sharded channel (per-owner routing)")
            if self.schedule.shadow_death or self.schedule.wedge_node \
                    is not None or self.schedule.train_fail_steps:
                raise ValueError(
                    f"{self.name}: plane_loss cannot combine with "
                    f"shadow_death / wedge / train_fail drills")
            if self.durability.compress:
                raise ValueError(
                    f"{self.name}: plane_loss needs raw (compress=False) "
                    f"flushes — a lossy restore cannot resume the trainer "
                    f"bit-identically")
            for p in self.schedule.plane_loss:
                if not 1 <= p.step <= self.steps:
                    raise ValueError(f"{self.name}: plane_loss step "
                                     f"{p.step} outside 1..{self.steps}")
        if self.schedule.tier_fail:
            if not self.durability.enabled:
                raise ValueError(f"{self.name}: tier_fail needs "
                                 f"durability tiers enabled")
            for t in self.schedule.tier_fail:
                if t.tier not in ("local-disk", "object-store"):
                    raise ValueError(f"{self.name}: unknown tier "
                                     f"{t.tier!r}")
                if t.tier == "object-store" \
                        and not self.durability.object_store:
                    raise ValueError(
                        f"{self.name}: tier_fail targets object-store but "
                        f"durability.object_store is off")
                if not 1 <= t.step <= self.steps:
                    raise ValueError(f"{self.name}: tier_fail step "
                                     f"{t.step} outside 1..{self.steps}")
        if self.schedule.train_node_loss:
            if self.checkpointer != "checkmate":
                raise ValueError(f"{self.name}: elastic shrink drills "
                                 f"drive a CheckmateCheckpointer")
            if self.schedule.wedge_node is not None \
                    or self.schedule.shadow_death:
                raise ValueError(
                    f"{self.name}: train_node_loss cannot combine with "
                    f"wedge / shadow_death drills — the shrink rebuilds "
                    f"the whole shadow plane")
            losses = self.schedule.train_node_loss
            world = self.channel.n_dp_groups * self.channel.ranks_per_group
            killed: set[int] = set()
            prev = 0
            for tl in losses:
                if not 1 <= tl.step <= self.steps:
                    raise ValueError(f"{self.name}: train_node_loss step "
                                     f"{tl.step} outside 1..{self.steps}")
                if tl.step <= prev:
                    raise ValueError(f"{self.name}: train_node_loss steps "
                                     f"must strictly increase")
                prev = tl.step
                if not tl.ranks:
                    raise ValueError(f"{self.name}: train_node_loss with "
                                     f"no ranks to kill")
                if len(set(tl.ranks)) != len(tl.ranks):
                    raise ValueError(f"{self.name}: duplicate ranks in "
                                     f"one train_node_loss")
                if self.level == "channel":
                    bad = [r for r in tl.ranks if not 0 <= r < world]
                    if bad:
                        raise ValueError(
                            f"{self.name}: train_node_loss ranks {bad} "
                            f"outside the original world 0..{world - 1}")
                    if killed & set(tl.ranks):
                        raise ValueError(
                            f"{self.name}: ranks "
                            f"{sorted(killed & set(tl.ranks))} killed "
                            f"twice across train_node_loss events")
                    killed |= set(tl.ranks)
            if self.level == "channel" and len(killed) >= world:
                raise ValueError(f"{self.name}: train_node_loss kills the "
                                 f"whole {world}-rank world — no survivor "
                                 f"can host the job")
            if self.level == "full" and len(losses) > 1:
                raise ValueError(f"{self.name}: full-level shrink drills "
                                 f"fire once (one FSDP flip)")
        if self.apply_delay_s < 0:
            raise ValueError(f"{self.name}: apply_delay_s must be >= 0")
        if self.apply_delay_s and self.level != "channel":
            raise ValueError(f"{self.name}: slow-apply throttles are "
                             f"channel-level scenarios")
        if self.max_lag_steps is not None:
            if self.max_lag_steps < 1:
                raise ValueError(f"{self.name}: max_lag_steps must be >= 1")
            if not self.shadow_async:
                raise ValueError(f"{self.name}: max_lag_steps bounds the "
                                 f"async delivery queue — requires "
                                 f"shadow_async")
            # bounded-lag runs consolidate only at the END (consolidating
            # every step would drain the backlog the drill exists to
            # build), so drills that need per-step consolidation or
            # per-step flush settlement cannot combine with it
            if (self.schedule.wedge_node is not None
                    or self.schedule.shadow_death
                    or self.schedule.plane_loss
                    or self.schedule.train_node_loss
                    or self.durability.enabled):
                raise ValueError(
                    f"{self.name}: max_lag_steps cannot combine with wedge "
                    f"/ shadow_death / plane_loss / elastic / durability "
                    f"drills — those settle the shadow plane every step, "
                    f"which defeats the lag bound under test")
        if self.checkpointer != "checkmate" and self.level == "channel":
            raise ValueError(f"{self.name}: channel-level scenarios drive "
                             f"a CheckmateCheckpointer")
        bad = [s for s in self.schedule.fabric_steps
               if not 1 <= s <= self.steps]
        if bad:
            raise ValueError(f"{self.name}: fabric failure steps {bad} "
                             f"outside 1..{self.steps}")
        bad = [s for s in self.schedule.train_fail_steps
               if not 1 <= s <= self.steps]
        if bad:
            raise ValueError(f"{self.name}: train failure steps {bad} "
                             f"outside 1..{self.steps} — they would never "
                             f"fire")
        return self

    # -- JSON round trip ------------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, **kw)

    @classmethod
    def from_dict(cls, d: dict) -> "Scenario":
        d = dict(d)
        d["channel"] = ChannelSpec(**d.get("channel", {}))
        sched = dict(d.get("schedule", {}))
        sched["train_fail_steps"] = tuple(sched.get("train_fail_steps", ()))
        sched["fabric"] = tuple(
            FabricFailure(**{**f, "target": tuple(f["target"])
                             if isinstance(f.get("target"), list)
                             else f.get("target")})
            for f in sched.get("fabric", ()))
        sched["shadow_death"] = tuple(
            ShadowDeath(**s) for s in sched.get("shadow_death", ()))
        sched["plane_loss"] = tuple(
            ShadowPlaneLoss(**p) for p in sched.get("plane_loss", ()))
        sched["tier_fail"] = tuple(
            TierFailure(**t) for t in sched.get("tier_fail", ()))
        sched["train_node_loss"] = tuple(
            TrainNodeLoss(**{**t, "ranks": tuple(t.get("ranks", (0,)))})
            for t in sched.get("train_node_loss", ()))
        d["schedule"] = FailureSchedule(**sched)
        d["durability"] = DurabilitySpec(**d.get("durability", {}))
        d["invariants"] = tuple(d.get("invariants", ()))
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "Scenario":
        return cls.from_dict(json.loads(s))


# -- random scenarios from one integer ---------------------------------------

def sample_scenario(seed: int, level: str | None = None) -> Scenario:
    """Deterministically expand one integer into a valid random scenario.

    The whole scenario space the golden corpus spans is sampled here:
    channel kind x topology x DP shape x optimizer x sharded shadow
    routing x failure classes (captures, bursts, hardware kills,
    shadow-node deaths, training failures, multi-failure sequences).
    Every sampled scenario must PASS all auto-selected invariants — a
    violation is a real bug, and the CLI writes its repro bundle.
    """
    seed = int(seed) & 0xFFFFFFFFFFFFFFFF      # negative CLI seeds wrap
    rng = np.random.default_rng(seed)
    if level is None:
        level = "full" if rng.random() < 0.2 else "channel"
    steps = int(rng.integers(4, 8))

    kind = str(rng.choice(["inprocess", "packetized", "packetized",
                           "compressed"]))
    inner = ("packetized" if kind == "compressed" and rng.random() < 0.4
             else "inprocess")
    topology = str(rng.choice(["single", "rail-optimized", "leaf-spine"]))
    spec = ChannelSpec(
        kind=kind, inner=inner, topology=topology,
        n_dp_groups=int(rng.choice([1, 2])),
        ranks_per_group=int(rng.choice([2, 4])),
        ranks_per_leaf=4,
        replication_factor=int(rng.choice([1, 1, 2])))

    if kind == "compressed" and rng.random() < 0.5:
        optimizer, momentum = "sgd", 0.0    # the sharp EF-bound regime
    else:
        optimizer = str(rng.choice(["adamw", "adam", "sgd"]))
        momentum = 0.9

    fabric: list[FabricFailure] = []
    if spec.has_fabric and steps >= 2:
        r = rng.random()
        s = int(rng.integers(2, steps + 1))
        if r < 0.30:                                    # one lost capture
            fabric.append(FabricFailure(step=s, kind="capture"))
        elif r < 0.45 and s < steps:                    # gated-capture burst
            fabric += [FabricFailure(step=s, kind="capture"),
                       FabricFailure(step=s + 1, kind="capture")]
        elif r < 0.70:                                  # hardware kill(s)
            at = float(round(rng.uniform(0.0, 200.0), 1))
            if topology == "single":
                fabric.append(FabricFailure(step=s, kind="shadow_nic",
                                            target="s0", at_us=at))
            else:
                hw = str(rng.choice(["switch", "link", "shadow_nic"]))
                target = {"switch": "spine0",
                          "link": ("leaf0", "spine0"),
                          "shadow_nic": "s0"}[hw]
                fabric.append(FabricFailure(step=s, kind=hw, target=target,
                                            at_us=at))
                if rng.random() < 0.3:                  # multi-failure seq
                    fabric.append(FabricFailure(
                        step=s, kind="switch", target="spine1",
                        at_us=at + 20.0))

    train_fails: tuple[int, ...] = ()
    if rng.random() < 0.4:
        train_fails = (int(rng.integers(2, steps + 1)),)

    shadow_nodes = int(rng.integers(1, 4))
    deaths: tuple[ShadowDeath, ...] = ()
    if kind == "packetized" and rng.random() < 0.3:   # bucket-sharded owners
        spec = dataclasses.replace(
            spec, sharded=True,
            shadow_rails=int(rng.integers(1, min(shadow_nodes, 2) + 1)))
        if (level == "channel" and shadow_nodes >= 2 and not train_fails
                and rng.random() < 0.5):
            deaths = (ShadowDeath(
                step=int(rng.integers(2, steps + 1)),
                node=int(rng.integers(0, shadow_nodes)),
                phase=str(rng.choice(["step", "consolidate"]))),)

    # draw order matters: these were the Scenario(...) argument draws
    # before durability existed — new draws must append strictly AFTER
    # them so every pre-existing seed expands to the same scenario fields
    n_leaves = int(rng.integers(2, 5))
    cap_bytes = int(rng.choice([1024, 4096, 1 << 16]))
    resync = bool(rng.random() < 0.5)
    shadow_async = bool(level == "channel" and rng.random() < 0.25)

    durability = DurabilitySpec()
    plane_loss: tuple[ShadowPlaneLoss, ...] = ()
    tier_fail: tuple[TierFailure, ...] = ()
    if level == "channel" and spec.sharded and rng.random() < 0.5:
        obj = bool(rng.random() < 0.5)
        durability = DurabilitySpec(
            enabled=True,
            every_steps=int(rng.choice([1, 1, 2])),
            compress=bool(rng.random() < 0.25),
            rebase_every=int(rng.choice([2, 4])),
            object_store=obj)
        if (not fabric and not deaths and not train_fails
                and steps >= 2 and rng.random() < 0.5):
            plane_loss = (ShadowPlaneLoss(
                step=int(rng.integers(2, steps + 1))),)
            if durability.compress:       # lossy restore can't resume
                durability = dataclasses.replace(durability,
                                                 compress=False)
        if obj and rng.random() < 0.3:
            tier_fail = (TierFailure(step=int(rng.integers(1, steps + 1)),
                                     tier="local-disk"),)

    # elastic shrink drills (append-only draws: everything above must keep
    # its draw order so pre-existing seeds expand identically)
    node_loss: tuple[TrainNodeLoss, ...] = ()
    world = spec.n_dp_groups * spec.ranks_per_group
    if (level == "channel" and world >= 4 and steps >= 3
            and not fabric and not deaths and not train_fails
            and not plane_loss and not tier_fail
            and rng.random() < 0.25):
        n_kill = int(rng.integers(1, world // 2 + 1))
        ranks = tuple(sorted(int(r) for r in rng.choice(
            world, size=n_kill, replace=False)))
        node_loss = (TrainNodeLoss(step=int(rng.integers(2, steps + 1)),
                                   ranks=ranks),)

    # fabric engine + bounded shadow lag (append-only draws, same rule as
    # above: nothing before this point may change its draw order)
    if spec.has_fabric and rng.random() < 0.5:
        spec = dataclasses.replace(spec, fast=True)   # calendar-queue engine
    max_lag_steps = None
    if (shadow_async and not deaths and not plane_loss and not tier_fail
            and not durability.enabled and not node_loss
            and rng.random() < 0.5):
        max_lag_steps = int(rng.integers(1, 5))

    return Scenario(
        name=f"sampled-{seed}", level=level, seed=int(seed) & 0x7FFFFFFF,
        steps=steps,
        n_leaves=n_leaves,
        cap_bytes=cap_bytes,
        resync=resync,
        optimizer=optimizer, momentum=momentum,
        shadow_nodes=shadow_nodes,
        shadow_async=shadow_async,
        max_lag_steps=max_lag_steps,
        channel=spec,
        schedule=FailureSchedule(train_fail_steps=train_fails,
                                 fabric=tuple(fabric),
                                 shadow_death=deaths,
                                 plane_loss=plane_loss,
                                 tier_fail=tier_fail,
                                 train_node_loss=node_loss),
        durability=durability,
    ).validate()


def scenario_strategy(level: str = "channel"):
    """A hypothesis strategy over valid random scenarios (works with the
    deterministic fallback too — it only needs integers().map)."""
    from hypothesis import strategies as st
    return st.integers(0, 2 ** 20).map(
        lambda s: sample_scenario(s, level=level))
