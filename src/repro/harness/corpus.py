"""The golden scenario corpus: named chaos drills spanning the scenario
space — single / rail-optimized / strided topologies x all three channel
stacks x every failure class (link, switch, shadow-NIC, gated-capture
bursts, shadow-node deaths on sharded clusters, worker wedge, training-node
failures, multi-failure sequences).

Every golden scenario must pass every applicable invariant;
``python -m repro.harness run --corpus golden`` is the CI chaos gate.
Channel-level scenarios drive checkpointer -> channel -> fabric -> shadow
on a synthetic stream (fast); full-level ones run the real training loop.
"""
from __future__ import annotations

from repro.harness.scenario import (ChannelSpec, DurabilitySpec,
                                    FabricFailure, FailureSchedule,
                                    Scenario, ShadowDeath, ShadowPlaneLoss,
                                    TierFailure, TrainNodeLoss)

_RAIL = dict(kind="packetized", topology="rail-optimized")
# bucket-sharded owner routing; small buckets so 3 owners all hold shards
_SHARD = dict(kind="packetized", topology="rail-optimized", sharded=True)


def _sc(name: str, **kw) -> Scenario:
    return Scenario(name=name, **kw).validate()


GOLDEN: dict[str, Scenario] = {s.name: s for s in [
    # -- clean transports: every topology, every channel stack --------------
    _sc("inprocess-clean", seed=11, steps=5),
    _sc("packetized-single-clean", seed=12, steps=5,
        channel=ChannelSpec(kind="packetized", topology="single")),
    _sc("packetized-rail-clean", seed=13, steps=5,
        channel=ChannelSpec(**_RAIL)),
    _sc("packetized-strided-clean", seed=14, steps=5,
        channel=ChannelSpec(kind="packetized", topology="leaf-spine")),
    _sc("packetized-two-groups", seed=15, steps=4, n_leaves=4,
        channel=ChannelSpec(**_RAIL, n_dp_groups=2, ranks_per_group=4)),
    _sc("packetized-replicated", seed=16, steps=4,
        channel=ChannelSpec(**_RAIL, replication_factor=2)),
    _sc("async-shadow-clean", seed=17, steps=5, shadow_async=True,
        shadow_nodes=3, channel=ChannelSpec(**_RAIL)),
    _sc("adam-nodes3-clean", seed=18, steps=5, optimizer="adam",
        shadow_nodes=3, channel=ChannelSpec(kind="packetized",
                                            topology="single")),

    # -- gated captures: freeze, resync, burst ------------------------------
    _sc("capture-frozen", seed=21, steps=4, resync=False,
        channel=ChannelSpec(**_RAIL),
        schedule=FailureSchedule(fabric=(
            FabricFailure(step=2, kind="capture"),))),
    _sc("capture-resync", seed=22, steps=5,
        channel=ChannelSpec(**_RAIL),
        schedule=FailureSchedule(fabric=(
            FabricFailure(step=3, kind="capture"),))),
    _sc("capture-burst", seed=23, steps=6,
        channel=ChannelSpec(**_RAIL),
        schedule=FailureSchedule(fabric=(
            FabricFailure(step=3, kind="capture"),
            FabricFailure(step=4, kind="capture")))),

    # -- hardware kills mid-iteration ---------------------------------------
    _sc("shadow-nic-kill", seed=31, steps=5,
        channel=ChannelSpec(**_RAIL),
        schedule=FailureSchedule(fabric=(
            FabricFailure(step=3, kind="shadow_nic", target="s0"),))),
    _sc("spine-kill-reroutes", seed=32, steps=5,
        channel=ChannelSpec(**_RAIL),
        schedule=FailureSchedule(fabric=(
            FabricFailure(step=3, kind="switch", target="spine0"),))),
    _sc("uplink-cut-reroutes", seed=33, steps=5,
        channel=ChannelSpec(kind="packetized", topology="leaf-spine"),
        schedule=FailureSchedule(fabric=(
            FabricFailure(step=2, kind="link",
                          target=("leaf0", "spine0")),))),
    _sc("multi-failure-sequence", seed=34, steps=5,
        channel=ChannelSpec(**_RAIL),
        schedule=FailureSchedule(fabric=(
            FabricFailure(step=2, kind="link", target=("leaf0", "spine0")),
            FabricFailure(step=2, kind="switch", target="spine1",
                          at_us=1.0),
            FabricFailure(step=4, kind="shadow_nic", target="s1")))),

    # -- recovery: training-node failures rewind onto the shadow ------------
    _sc("inprocess-recovery", seed=41, steps=6,
        schedule=FailureSchedule(train_fail_steps=(4,))),
    _sc("gated-then-recovery", seed=42, steps=6,
        channel=ChannelSpec(**_RAIL),
        schedule=FailureSchedule(
            train_fail_steps=(5,),
            fabric=(FabricFailure(step=4, kind="capture"),))),
    _sc("double-recovery", seed=43, steps=7,
        channel=ChannelSpec(kind="packetized", topology="single"),
        schedule=FailureSchedule(train_fail_steps=(3, 6))),

    # -- compressed stream: EF bound + gated compressed captures ------------
    _sc("compressed-sgd-ef-bound", seed=51, steps=5, optimizer="sgd",
        momentum=0.0, lr=0.1,
        channel=ChannelSpec(kind="compressed")),
    _sc("compressed-packetized", seed=52, steps=5,
        channel=ChannelSpec(kind="compressed", inner="packetized",
                            topology="rail-optimized")),
    _sc("compressed-capture-resync", seed=53, steps=5,
        channel=ChannelSpec(kind="compressed", inner="packetized",
                            topology="single"),
        schedule=FailureSchedule(fabric=(
            FabricFailure(step=3, kind="capture"),))),

    # -- bucket-sharded shadow cluster: owner routing + node deaths ---------
    _sc("sharded-rail-clean", seed=81, steps=5, shadow_nodes=3,
        n_leaves=4, cap_bytes=256,
        channel=ChannelSpec(**_SHARD, shadow_rails=2)),
    _sc("sharded-two-groups-clean", seed=82, steps=4, shadow_nodes=3,
        n_leaves=4, cap_bytes=256,
        channel=ChannelSpec(**_SHARD, n_dp_groups=2, ranks_per_group=4)),
    _sc("shadow-death-midstep", seed=83, steps=5, shadow_nodes=3,
        n_leaves=4, cap_bytes=256, resync=False,
        channel=ChannelSpec(**_SHARD),
        schedule=FailureSchedule(shadow_death=(
            ShadowDeath(step=3, node=1, phase="step"),))),
    _sc("shadow-death-consolidate", seed=84, steps=5, shadow_nodes=3,
        n_leaves=4, cap_bytes=256, resync=False,
        channel=ChannelSpec(**_SHARD),
        schedule=FailureSchedule(shadow_death=(
            ShadowDeath(step=3, node=0, phase="consolidate"),))),
    # death at 2, resync heals at 3, then a link + alive-NIC kill burst at
    # 4 desyncs the revived cluster as a whole (alive owners lose spans)
    _sc("shadow-death-link-burst", seed=85, steps=6, shadow_nodes=3,
        n_leaves=4, cap_bytes=256,
        channel=ChannelSpec(**_SHARD),
        schedule=FailureSchedule(
            shadow_death=(ShadowDeath(step=2, node=2, phase="step"),),
            fabric=(FabricFailure(step=4, kind="link",
                                  target=("leaf0", "spine0")),
                    FabricFailure(step=4, kind="shadow_nic",
                                  target="s0")))),
    _sc("shadow-death-resync", seed=86, steps=6, shadow_nodes=3,
        n_leaves=4, cap_bytes=256,
        channel=ChannelSpec(**_SHARD, shadow_rails=3),
        schedule=FailureSchedule(shadow_death=(
            ShadowDeath(step=2, node=0, phase="step"),))),
    _sc("shadow-death-async", seed=87, steps=5, shadow_nodes=3,
        n_leaves=4, cap_bytes=256, shadow_async=True, resync=False,
        channel=ChannelSpec(**_SHARD),
        schedule=FailureSchedule(shadow_death=(
            ShadowDeath(step=2, node=1, phase="step"),
            ShadowDeath(step=4, node=2, phase="consolidate")))),

    # -- durability tiers behind the shadow plane ---------------------------
    _sc("durability-clean", seed=91, steps=5, shadow_nodes=3,
        n_leaves=4, cap_bytes=256,
        channel=ChannelSpec(**_SHARD),
        durability=DurabilitySpec(enabled=True)),
    # kill the ENTIRE shadow plane after step 4; the only way back is
    # restore_from_tiers, and the run must still end bit-identical
    _sc("shadow-plane-loss", seed=92, steps=6, shadow_nodes=3,
        n_leaves=4, cap_bytes=256,
        channel=ChannelSpec(**_SHARD),
        durability=DurabilitySpec(enabled=True),
        schedule=FailureSchedule(plane_loss=(ShadowPlaneLoss(step=4),))),
    # flush cadence 2: the tiers trail the stream by one step when the
    # plane dies at step 5, so recovery rewinds to 4 and replays
    _sc("flush-lag", seed=93, steps=6, shadow_nodes=3,
        n_leaves=4, cap_bytes=256,
        channel=ChannelSpec(**_SHARD),
        durability=DurabilitySpec(enabled=True, every_steps=2),
        schedule=FailureSchedule(plane_loss=(ShadowPlaneLoss(step=5),))),
    # local-disk refuses step 3's records; the object store still holds a
    # complete epoch there and restore serves the newest point ANY tier has
    _sc("tier-failure-fallback", seed=94, steps=5, shadow_nodes=3,
        n_leaves=4, cap_bytes=256,
        channel=ChannelSpec(**_SHARD),
        durability=DurabilitySpec(enabled=True, object_store=True),
        schedule=FailureSchedule(tier_fail=(
            TierFailure(step=3, tier="local-disk"),))),
    # int8 delta flushing (stateless no-EF codec) + async applies; the
    # zero-flush-stall claim must hold on the compressed path too
    _sc("compressed-flush", seed=95, steps=5, shadow_nodes=3,
        n_leaves=4, cap_bytes=256, shadow_async=True,
        channel=ChannelSpec(**_SHARD),
        durability=DurabilitySpec(enabled=True, compress=True,
                                  rebase_every=2)),

    # -- elastic shrink: train ranks die with NO hot spare ------------------
    # half the world dies after step 3; the run replans DP 8 -> 4,
    # rebuilds channel + shadow plane, and resumes bit-identically
    _sc("elastic-dp8-to-4", seed=101, steps=6,
        channel=ChannelSpec(**_RAIL, n_dp_groups=2, ranks_per_group=4),
        schedule=FailureSchedule(train_node_loss=(
            TrainNodeLoss(step=3, ranks=(4, 5, 6, 7)),))),
    # a non-power-of-two world: 8 -> 6 survivors regroup as 2 groups of 3
    _sc("elastic-dp8-to-6", seed=102, steps=6,
        channel=ChannelSpec(**_RAIL, n_dp_groups=2, ranks_per_group=4),
        schedule=FailureSchedule(train_node_loss=(
            TrainNodeLoss(step=3, ranks=(3, 6)),))),
    # full level: the restore lands on an FSDP-flipped ShardingRules — the
    # one layout change the 1-device smoke mesh can express
    _sc("elastic-fsdp-flip", level="full", seed=103, steps=6,
        channel=ChannelSpec(**_RAIL),
        schedule=FailureSchedule(train_node_loss=(
            TrainNodeLoss(step=3),))),
    # shrink at 3, then the WHOLE rebuilt shadow plane dies at 5: recovery
    # restores the post-shrink epoch from the durability tiers onto the
    # shrunken layout
    _sc("elastic-shrink-then-plane-loss", seed=104, steps=6,
        shadow_nodes=3, n_leaves=4, cap_bytes=256,
        channel=ChannelSpec(**_SHARD, n_dp_groups=2, ranks_per_group=4),
        durability=DurabilitySpec(enabled=True),
        schedule=FailureSchedule(
            train_node_loss=(TrainNodeLoss(step=3, ranks=(5, 7)),),
            plane_loss=(ShadowPlaneLoss(step=5),))),
    # shrink under a compressed channel: the rebuilt stream restarts its
    # error-feedback from the synced resume point, so the sharp EF bound
    # must hold over the post-shrink steps alone
    _sc("elastic-compressed-shrink", seed=105, steps=5, optimizer="sgd",
        momentum=0.0, lr=0.1,
        channel=ChannelSpec(kind="compressed", inner="packetized",
                            topology="rail-optimized",
                            n_dp_groups=2, ranks_per_group=4),
        schedule=FailureSchedule(train_node_loss=(
            TrainNodeLoss(step=3, ranks=(0, 1, 2, 3)),))),
    # two shrinks in one run: 8 -> 6 -> 4, ranks named in ORIGINAL ids
    _sc("elastic-double-shrink", seed=106, steps=6,
        channel=ChannelSpec(**_RAIL, n_dp_groups=2, ranks_per_group=4),
        schedule=FailureSchedule(train_node_loss=(
            TrainNodeLoss(step=2, ranks=(6, 7)),
            TrainNodeLoss(step=4, ranks=(4, 5))))),

    # -- consolidation under a wedged worker --------------------------------
    _sc("wedge-consolidate", seed=61, steps=4, shadow_async=True,
        shadow_nodes=2,
        schedule=FailureSchedule(wedge_node=0, wedge_release_s=1.5)),

    # -- bounded multi-step lag under a throttled applier --------------------
    # every apply is deliberately slow, so the trainer outruns the shadow,
    # hits the max_lag_steps bound (booked as the apply-lag stall stage),
    # and the workers catch up with batched K-step replays; the fast fabric
    # engine rides along so the lagged path is exercised on it too
    _sc("slow-apply-clean", seed=111, steps=8, shadow_async=True,
        shadow_nodes=2, max_lag_steps=3, apply_delay_s=0.03,
        channel=ChannelSpec(**_RAIL, fast=True)),
    # a mid-run link cut desyncs the stream while the applier is lagging:
    # the resync's full-state copy must supersede the queued backlog
    _sc("slow-apply-with-link-burst", seed=112, steps=10, shadow_async=True,
        shadow_nodes=2, max_lag_steps=3, apply_delay_s=0.03,
        channel=ChannelSpec(**_RAIL),
        schedule=FailureSchedule(fabric=(
            FabricFailure(step=3, kind="link", target=("leaf0", "spine0")),
            FabricFailure(step=3, kind="shadow_nic", target="s0")))),
    # sharded owners, each lagging independently: the final consolidate is
    # a distributed gather across backlogged nodes and must still land
    # bit-identical at the trainer's step
    _sc("slow-apply-consolidate", seed=113, steps=8, shadow_nodes=3,
        n_leaves=4, cap_bytes=256, shadow_async=True,
        max_lag_steps=3, apply_delay_s=0.04,
        channel=ChannelSpec(**_SHARD)),

    # -- full-stack: the real training loop ---------------------------------
    _sc("full-inprocess-recovery", level="full", seed=71, steps=8,
        schedule=FailureSchedule(train_fail_steps=(3, 6))),
    _sc("full-packetized-gated-recovery", level="full", seed=72, steps=6,
        channel=ChannelSpec(**_RAIL, n_dp_groups=2, ranks_per_group=4),
        schedule=FailureSchedule(
            train_fail_steps=(5,),
            fabric=(FabricFailure(step=4, kind="capture"),))),
    _sc("full-sync-repeated-work", level="full", seed=73, steps=6,
        checkpointer="sync", ckpt_freq=3,
        schedule=FailureSchedule(train_fail_steps=(5,))),
    _sc("full-packetized-rail-clean", level="full", seed=74, steps=5,
        channel=ChannelSpec(**_RAIL)),
]}
