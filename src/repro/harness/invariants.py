"""System-wide invariants the chaos harness checks after every step.

Each `Invariant` inspects the run's `Trace` (see `repro.harness.runner`)
— per-step records of sends, polls, stalls, checkpointer counters, the
consolidated shadow state, and the trainer/reference state — and yields
`Violation`s. ``applies()`` scopes an invariant to the scenarios where
its claim holds (e.g. the sharp error-feedback bound needs momentum-free
SGD); a scenario can force a specific set by name instead
(`Scenario.invariants`), which is how the violation-bundle machinery is
demonstrated against a knowingly-inapplicable check.

The registry is open: ``@register`` a new `Invariant` subclass and every
scenario (golden corpus, random sweeps, refactored failure drills) checks
it for free.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional

import numpy as np

REGISTRY: dict[str, type] = {}


def register(cls):
    REGISTRY[cls.name] = cls
    return cls


@dataclass(frozen=True)
class Violation:
    """One invariant breach: the minimal fact a repro bundle must replay."""
    invariant: str
    step: Optional[int]
    message: str

    def to_dict(self) -> dict:
        return {"invariant": self.invariant, "step": self.step,
                "message": self.message}


class Invariant:
    """One checkable claim about a run. Instances are per-run (they may
    carry state across ``check_step`` calls, e.g. the contiguity model)."""
    name = "base"

    def applies(self, trace) -> bool:
        return True

    def check_step(self, trace, rec) -> Iterable[Violation]:
        return ()

    def check_end(self, trace) -> Iterable[Violation]:
        return ()

    def _v(self, step, message) -> Violation:
        return Violation(self.name, step, message)


def tree_mismatch(a: dict, b: dict, parts=("params", "mu", "nu")
                  ) -> Optional[str]:
    """First bitwise mismatch between two checkpoint trees, or None."""
    for part in parts:
        pa, pb = a[part], b[part]
        if set(pa) != set(pb):
            return f"{part} leaf sets differ"
        for k in sorted(pa):
            x, y = np.asarray(pa[k]), np.asarray(pb[k])
            if not np.array_equal(x, y):
                d = float(np.max(np.abs(x.astype(np.float64)
                                        - y.astype(np.float64))))
                return f"{part}[{k}] differs (max|delta|={d:.3e})"
    return None


@register
class ExactlyOnceDelivery(Invariant):
    """Every complete capture was reassembled exactly-once on the fabric
    (no missing replica spans, no duplicate mirror bytes on clean steps,
    no drops/retransmits without an injected failure), and the gating
    verdict the channel reports agrees with the fabric's own account."""
    name = "exactly-once"

    def applies(self, trace) -> bool:
        return trace.scenario.channel.has_fabric

    def check_step(self, trace, rec):
        clean = rec.step not in trace.fabric_steps
        for p in rec.polls:
            f = p.fabric
            if f is None:
                continue
            if p.complete != f.reassembled_ok:
                yield self._v(p.step, f"delivery complete={p.complete} but "
                                      f"fabric reassembled_ok="
                                      f"{f.reassembled_ok}")
            if p.complete and f.missing_captures:
                yield self._v(p.step, f"complete delivery with "
                                      f"{f.missing_captures} missing "
                                      f"capture spans")
            if not p.complete and not (f.missing_captures
                                       or not f.ring_completed):
                yield self._v(p.step, "gated delivery but the fabric "
                                      "reports a full capture")
            if clean:
                for attr in ("duplicate_mirror_bytes", "mirror_lost_frames",
                             "drops", "retransmits"):
                    n = getattr(f, attr)
                    if n:
                        yield self._v(p.step, f"clean step (no injected "
                                              f"failure) but {attr}={n}")
                if not f.reassembled_ok:
                    yield self._v(p.step, "clean step but capture not "
                                          "reassembled exactly-once")


@register
class ZeroOverheadAccounting(Invariant):
    """The packetized transport's sender-visible stall is exactly 0.0:
    the event-loop wall time (host CPU *simulating* the fabric) is never
    booked on the training critical path (§4 zero-overhead claim)."""
    name = "zero-overhead"

    def applies(self, trace) -> bool:
        return trace.scenario.channel.kind == "packetized"

    def check_step(self, trace, rec):
        # NOTE: messages stay free of wall-clock values — replay_bundle
        # verifies reproduction by exact message equality
        for s in rec.sends:
            if s.reported != 0.0:
                stages = sorted(k for k, v in s.parts.items() if v != 0.0)
                extra = f" [stages: {', '.join(stages)}]" if stages else ""
                yield self._v(s.step, "packetized send reported nonzero "
                                      "stall (the simulator's event-loop "
                                      "wall time must not be booked)"
                                      + extra)


@register
class StallAccounting(Invariant):
    """Gated/frozen steps book zero stall and no checkpoint; every
    consumed event is either a checkpoint, a skipped capture, or a
    resync-counted checkpoint — nothing double-counts."""
    name = "stall-accounting"

    def applies(self, trace) -> bool:
        return trace.scenario.checkpointer == "checkmate"

    def check_step(self, trace, rec):
        if rec.gated and not rec.applied and not rec.resync:
            if rec.stall != 0.0:
                # no wall-clock value in the message: bundles must replay
                # bit-identically
                yield self._v(rec.step, "gated step booked nonzero stall")

    def check_end(self, trace):
        ck = trace.checkpointer
        n_events = len(trace.records)
        if ck.n_checkpoints + ck.skipped_captures != n_events:
            yield self._v(None, f"accounting leak: n_checkpoints="
                                f"{ck.n_checkpoints} + skipped_captures="
                                f"{ck.skipped_captures} != {n_events} "
                                f"consumed events")
        if len(ck.skipped_steps) != ck.skipped_captures:
            yield self._v(None, f"skipped_steps={ck.skipped_steps} vs "
                                f"skipped_captures={ck.skipped_captures}")


@register
class StallAttribution(Invariant):
    """Every booked stall second is attributed to a known stage, and the
    attribution is bit-exact: each send's per-stage parts sum in order to
    exactly the stall the channel reported, a packetized channel's "send"
    component is exactly 0.0, and the checkpointer's stage ledger sums in
    order to exactly ``stall_total`` (repro.obs.stalls)."""
    name = "stall-attribution"

    KNOWN = frozenset(("send", "quantize", "inline-apply", "apply-lag",
                       "resync", "consolidate-wait", "copy-persist",
                       "elastic-reshard"))

    def applies(self, trace) -> bool:
        return trace.scenario.checkpointer == "checkmate"

    def check_step(self, trace, rec):
        # messages carry stage NAMES only, never wall-clock values —
        # replay_bundle compares them bit-identically
        for s in rec.sends:
            if not s.parts:
                yield self._v(s.step, "channel send set no stall "
                                      "decomposition (last_send_parts)")
                continue
            total = 0.0
            for sec in s.parts.values():
                total += sec
            if total != s.reported:
                yield self._v(s.step, f"send parts "
                                      f"{sorted(s.parts)} do not sum "
                                      f"bit-exactly to the reported stall")
            unknown = sorted(set(s.parts) - self.KNOWN)
            if unknown:
                yield self._v(s.step, f"unknown stall stages {unknown}")
        if trace.scenario.channel.kind == "packetized":
            for s in rec.sends:
                if s.parts.get("send", 0.0) != 0.0:
                    yield self._v(s.step, "packetized send booked a nonzero "
                                          "'send' stage")

    def check_end(self, trace):
        ck = trace.checkpointer
        stages = getattr(ck, "stall_stages", None)
        if stages is None:
            return
        total = 0.0
        for sec in stages.values():
            total += sec
        if total != ck.stall_total:
            yield self._v(None, f"stage ledger {sorted(stages)} does not "
                                f"sum bit-exactly to stall_total")
        unknown = sorted(set(stages) - self.KNOWN)
        if unknown:
            yield self._v(None, f"unknown ledger stages {unknown}")


@register
class CheckpointContiguity(Invariant):
    """The shadow replays a contiguous gradient stream: its consolidated
    step only ever advances one applied step at a time, never across a
    gated gap, and only jumps at an explicit resync or a recovery rewind.
    While desynced it stays frozen at the last fully-captured step. A
    sharded partial apply (survivors replaying past dead owners) advances
    the stream the same single step — every serving node moves in
    lockstep, so the consolidated tree is never torn across steps."""
    name = "contiguity"

    def __init__(self):
        self.expected: Optional[int] = None

    def applies(self, trace) -> bool:
        return trace.scenario.checkpointer == "checkmate"

    def check_step(self, trace, rec):
        if self.expected is None:
            self.expected = trace.bootstrap_step
        if rec.restored_step is not None:
            if rec.plane_restore:
                # a total plane loss rewinds BOTH planes onto the tiers'
                # newest durable step — at or behind the live stream by
                # exactly the flush lag, never ahead of it
                if rec.restored_step > self.expected:
                    yield self._v(rec.step,
                                  f"tier restore landed at "
                                  f"{rec.restored_step}, AHEAD of the "
                                  f"stream at {self.expected}")
                self.expected = rec.restored_step
            elif rec.restored_step != self.expected:
                yield self._v(rec.step, f"restore() returned step "
                                        f"{rec.restored_step}, shadow "
                                        f"should be at {self.expected}")
                self.expected = rec.restored_step
        if rec.resync:
            self.expected = rec.step
        elif rec.applied or rec.partial_applied:
            if rec.step != self.expected + 1:
                yield self._v(rec.step, f"applied step {rec.step} onto a "
                                        f"shadow at {self.expected} — the "
                                        f"stream skipped a gap")
            self.expected = rec.step
        if rec.shadow_step is not None and rec.shadow_step != self.expected:
            yield self._v(rec.step, f"shadow consolidated at "
                                    f"{rec.shadow_step}, contiguous stream "
                                    f"ends at {self.expected}")


@register
class ShadowTrainerBitIdentity(Invariant):
    """At every sync point the shadow's consolidated params/mu/nu are
    bit-identical to the trainer's state at the shadow's step — the
    functional-optimizer replay claim (§4.2.4)."""
    name = "shadow-bit-identity"

    def applies(self, trace) -> bool:
        return (trace.scenario.checkpointer == "checkmate"
                and trace.scenario.channel.kind != "compressed")

    def check_step(self, trace, rec):
        if rec.shadow_ckpt is None or rec.shadow_step is None:
            return
        if rec.shadow_missing:               # partial tree (dead owners):
            return                           # shadow-node-death checks it
        ref = trace.states.get(rec.shadow_step)
        if ref is None:                      # e.g. the bootstrap step
            return
        bad = tree_mismatch(rec.shadow_ckpt, ref)
        if bad:
            yield self._v(rec.step, f"shadow@{rec.shadow_step} != "
                                    f"trainer@{rec.shadow_step}: {bad}")


@register
class ApplyLagBound(Invariant):
    """A bounded-lag shadow honors its contract end to end: the applier
    never trails the trainer by more than ``max_lag_steps`` queued
    deliveries (sampled right after every ingest), the trainer's wait on a
    backlogged applier is booked as the named ``apply-lag`` stage, and a
    throttled applier actually exercises the machinery — the bound blocks
    at least once and (for bounds >= 3) a multi-step batched catch-up
    replay runs. Bit-identity of lagged applies is not re-proved here: the
    batched replay feeds the same consolidated tree `shadow-bit-identity`
    checks at every consolidation point."""
    name = "apply-lag-bound"

    def applies(self, trace) -> bool:
        return (trace.scenario.checkpointer == "checkmate"
                and trace.scenario.max_lag_steps is not None)

    def check_step(self, trace, rec):
        k = trace.scenario.max_lag_steps
        if rec.shadow_lag is not None and rec.shadow_lag > k:
            yield self._v(rec.step, f"shadow lag {rec.shadow_lag} exceeds "
                                    f"max_lag_steps={k}")

    def check_end(self, trace):
        st = trace.shadow_stats
        if st is None:
            return                  # full level: no cluster stats recorded
        sc = trace.scenario
        k = sc.max_lag_steps
        if st.max_queue_depth > k:
            yield self._v(None, f"delivery queue reached depth "
                                f"{st.max_queue_depth}, past the lag bound "
                                f"{k}")
        if st.max_batch > max(k, 1):
            yield self._v(None, f"a worker drained {st.max_batch} steps in "
                                f"one batch, past the lag bound {k}")
        stages = dict(getattr(trace.checkpointer, "stall_stages", {}) or {})
        if st.lag_waits > 0 and "apply-lag" not in stages:
            yield self._v(None, "trainer waited on a backlogged applier "
                                "but no 'apply-lag' stage was booked")
        if not (sc.apply_delay_s > 0 and sc.steps > k):
            return                  # bound never provably under pressure
        if sc.schedule.fabric or sc.schedule.train_fail_steps:
            return                  # resync / restore settles the backlog
            #                         mid-run, so pressure isn't guaranteed
        if st.lag_waits == 0:
            yield self._v(None, "throttled applier never backlogged to the "
                                "bound — the apply-lag machinery was not "
                                "exercised")
        if k >= 3 and st.max_batch < 2:
            # the gate admits at most k pending (one in flight + k-1
            # queued), so a wake can only see a multi-item backlog for
            # bounds >= 3
            yield self._v(None, f"lag bound {k} under a throttled applier "
                                f"but no multi-step batched apply ran")


@register
class ShadowNodeDeath(Invariant):
    """Killing a sharded shadow owner loses exactly that owner's shard and
    nothing else: consolidation raises `ShadowNodeLoss` naming precisely
    the dead owners and their buckets, the dead owners' leaves are absent
    from the partial checkpoint, every surviving owner's leaf stays
    bit-identical to the trainer at the consolidated step, and a resync
    (full-state copy onto replacement hardware) makes the cluster whole
    again. Stateful: models the dead set across steps, honoring the
    kill phase ("step" kills land before that step's capture,
    "consolidate" kills after its apply) and resync revivals."""
    name = "shadow-node-death"

    def __init__(self):
        self.dead: set[int] = set()

    def applies(self, trace) -> bool:
        return bool(trace.scenario.schedule.shadow_death)

    def check_step(self, trace, rec):
        deaths = [d for d in trace.scenario.schedule.shadow_death
                  if d.step == rec.step]
        for d in deaths:
            if d.phase == "step":
                self.dead.add(d.node)
        if rec.resync:          # replacement racked + full-state copy
            self.dead.clear()
        for d in deaths:
            if d.phase == "consolidate":
                self.dead.add(d.node)
        part = trace.shadow_partition or {}
        expected = {n: tuple(part[n]["buckets"]) for n in sorted(self.dead)}
        got = {int(n): tuple(b)
               for n, b in (rec.shadow_missing or {}).items()}
        if got != expected:
            yield self._v(rec.step,
                          f"consolidate reported missing buckets {got} but "
                          f"dead owners {sorted(self.dead)} own {expected}")
        if tuple(rec.dead_nodes or ()) != tuple(sorted(self.dead)):
            yield self._v(rec.step,
                          f"consolidate named dead nodes "
                          f"{list(rec.dead_nodes)}, killed: "
                          f"{sorted(self.dead)}")
        if rec.shadow_ckpt is None or rec.shadow_step is None:
            return
        dead_leaves = {lf for n in self.dead for lf in part[n]["leaves"]}
        still_there = sorted(dead_leaves & set(rec.shadow_ckpt["params"]))
        if still_there:
            yield self._v(rec.step,
                          f"dead owners' leaves {still_there} still served "
                          f"by the consolidated tree")
        ref = trace.states.get(rec.shadow_step)
        if ref is None:                      # e.g. the bootstrap step
            return
        for part_name in ("params", "mu", "nu"):
            for k in sorted(rec.shadow_ckpt[part_name]):
                a = np.asarray(rec.shadow_ckpt[part_name][k])
                if not np.array_equal(a, np.asarray(ref[part_name][k])):
                    yield self._v(rec.step,
                                  f"surviving shard leaf {part_name}[{k}] "
                                  f"diverged from trainer@{rec.shadow_step}")


@register
class ElasticResume(Invariant):
    """An elastic shrink is invisible in the training trajectory: every
    scheduled `TrainNodeLoss` actually reconfigured the run onto the
    survivors, the reconfiguration is booked as the named
    ``elastic-reshard`` stall stage, the rebuilt shadow plane re-attaches
    bit-identical to the trainer at the resumed step and keeps advancing
    on the shrunken layout, and the drill's world accounting is exact —
    the new world is the old world minus the killed ranks and the
    replanned DP width spans exactly the survivors. (Post-shrink steps
    stay covered by replay-determinism / resume-bit-identity, whose
    reference targets are DP-width-independent by construction.)"""
    name = "elastic-resume"

    def applies(self, trace) -> bool:
        return bool(trace.scenario.schedule.train_node_loss)

    def check_step(self, trace, rec):
        if not rec.elastic:
            return
        if rec.restored_step is None:
            yield self._v(rec.step, "elastic resume recorded without a "
                                    "restore() having run")
        if trace.scenario.channel.kind == "compressed":
            return      # the shadow stream is intentionally lossy there
        if rec.shadow_ckpt is None or rec.shadow_step is None:
            return
        ref = trace.states.get(rec.shadow_step)
        if ref is None:
            return
        bad = tree_mismatch(rec.shadow_ckpt, ref)
        if bad:
            yield self._v(rec.step,
                          f"re-attached shadow@{rec.shadow_step} != "
                          f"trainer@{rec.shadow_step}: {bad}")

    def check_end(self, trace):
        sched = trace.scenario.schedule.train_node_loss
        evs = trace.elastic_events
        if len(evs) != len(sched):
            yield self._v(None, f"{len(sched)} shrink(s) scheduled but "
                                f"{len(evs)} reconfiguration(s) ran")
            return
        stages = getattr(trace.checkpointer, "stall_stages", None) or {}
        if "elastic-reshard" not in stages:
            yield self._v(None, "reconfiguration ran but no "
                                "'elastic-reshard' stage was booked in the "
                                "checkpointer's stall ledger")
        for tl, ev in zip(sched, evs):
            if ev["step"] != tl.step:
                yield self._v(tl.step,
                              f"shrink scheduled after step {tl.step} but "
                              f"the drill ran at {ev['step']}")
            if sorted(ev["killed"]) != sorted(tl.ranks):
                yield self._v(tl.step,
                              f"drill killed ranks {sorted(ev['killed'])}, "
                              f"schedule names {sorted(tl.ranks)}")
            if ev["resumed_step"] > ev["step"]:
                yield self._v(tl.step,
                              f"resume landed at {ev['resumed_step']}, "
                              f"AHEAD of the shrink at {ev['step']}")
            if trace.scenario.level != "channel":
                continue            # full level: no modeled rank world
            if ev["new_world"] != ev["old_world"] - len(ev["killed"]):
                yield self._v(tl.step,
                              f"world went {ev['old_world']} -> "
                              f"{ev['new_world']} after killing "
                              f"{len(ev['killed'])} rank(s)")
            if ev["dp"] != ev["new_world"]:
                yield self._v(tl.step,
                              f"replanned dp={ev['dp']} does not span the "
                              f"{ev['new_world']} survivors")
            dead = set(ev["killed"]) & set(ev["survivors"])
            if dead:
                yield self._v(tl.step, f"killed ranks {sorted(dead)} "
                                       f"listed as survivors")
        last = evs[-1]
        if (last["resumed_step"] < trace.scenario.steps
                and not any(f > last["resumed_step"]
                            for f in trace.scenario.schedule.fabric_steps)):
            post = [r for r in trace.records
                    if r.step > last["resumed_step"]
                    and (r.applied or r.partial_applied or r.resync)]
            if not post:
                yield self._v(None, "no shadow apply ever landed on the "
                                    "shrunken layout after the last shrink")


@register
class ReplayDeterminism(Invariant):
    """Re-executed iterations (after a recovery rewind) reproduce the
    original trainer state and loss bit-identically — the PRNG-counter
    data pipeline plus deterministic step make resume exact (Fig 9)."""
    name = "replay-determinism"

    def applies(self, trace) -> bool:
        # recovery onto a compressed shadow stream rewinds the trainer
        # onto a state its original trajectory never visited, so replays
        # legitimately diverge (same scope as resume-bit-identity)
        return not (trace.scenario.channel.kind == "compressed"
                    and trace.scenario.schedule.train_fail_steps)

    def check_step(self, trace, rec):
        if rec.state is None:
            return
        if not rec.first_seen:       # a replay: the runner kept the original
            bad = tree_mismatch(rec.state, trace.states[rec.step])
            if bad:
                yield self._v(rec.step, f"replayed step diverged from its "
                                        f"original execution: {bad}")
        if rec.loss is not None and trace.ref_losses is not None:
            ref = trace.ref_losses[rec.step - 1]
            if rec.loss != ref:
                yield self._v(rec.step, f"loss {rec.loss!r} != reference "
                                        f"run's {ref!r}")


@register
class BitIdenticalResume(Invariant):
    """The chaos run's final trainer state equals the uninterrupted
    reference run's, bit for bit — failures + recovery are invisible in
    the training trajectory (§6.5 / Fig 9)."""
    name = "resume-bit-identity"

    def applies(self, trace) -> bool:
        # a compressed shadow stream intentionally diverges from raw
        # training, so a recovery onto it rewrites the trajectory
        return (trace.ref_final is not None
                and not (trace.scenario.channel.kind == "compressed"
                         and trace.scenario.schedule.train_fail_steps))

    def check_end(self, trace):
        if trace.final is None:
            return
        bad = tree_mismatch(trace.final, trace.ref_final)
        if bad:
            yield self._v(None, f"final state != uninterrupted reference: "
                                f"{bad}")


@register
class CompressedDivergenceBound(Invariant):
    """Error-feedback invariant, sharp in the momentum-free SGD regime:
    the shadow (which consumed the compressed stream) diverges from the
    raw-gradient trainer by exactly lr * residual — bounded by one
    quantization step, not by the number of iterations."""
    name = "compressed-ef-bound"
    ATOL = 5e-6

    def applies(self, trace) -> bool:
        sc = trace.scenario
        return (sc.channel.kind == "compressed" and sc.optimizer == "sgd"
                and sc.momentum == 0.0 and not sc.schedule.fabric
                and not sc.schedule.train_fail_steps
                and trace.compressor is not None
                and trace.final_shadow is not None)

    def check_end(self, trace):
        ef = trace.compressor.ef
        lr = trace.scenario.lr
        shadow, ref = trace.final_shadow, trace.final
        for k in sorted(shadow["params"]):
            div = (np.asarray(shadow["params"][k], np.float64)
                   - np.asarray(ref["params"][k], np.float64))
            res = lr * np.asarray(ef[k], np.float64)
            if not np.allclose(div, res, atol=self.ATOL):
                yield self._v(None, f"params[{k}]: shadow-ref divergence "
                                    f"is not lr*residual (max|delta|="
                                    f"{float(np.max(np.abs(div - res))):.3e})")
            bound = lr * float(np.max(np.abs(np.asarray(ef[k])))) + self.ATOL
            if float(np.max(np.abs(div))) > bound:
                yield self._v(None, f"params[{k}]: divergence "
                                    f"{float(np.max(np.abs(div))):.3e} "
                                    f"exceeds the EF bound {bound:.3e}")


@register
class ConsolidateTimeout(Invariant):
    """A wedged shadow worker cannot hang recovery: consolidation honors
    its deadline, names exactly the lagging node, and a retry after the
    wedge releases completes at the true step."""
    name = "consolidate-timeout"

    def applies(self, trace) -> bool:
        return trace.scenario.schedule.wedge_node is not None

    def check_end(self, trace):
        w = trace.wedge
        sc = trace.scenario
        if w is None:
            yield self._v(None, "wedge scheduled but the runner recorded "
                                "no consolidation attempt")
            return
        if not w["raised"]:
            yield self._v(None, "consolidate() returned despite a wedged "
                                "worker inside the deadline")
            return
        if w["lagging"] != [sc.schedule.wedge_node]:
            yield self._v(None, f"lagging nodes {w['lagging']} != "
                                f"[{sc.schedule.wedge_node}]")
        if w["partial_step"] >= w["final_step"]:
            yield self._v(None, f"partial checkpoint at {w['partial_step']} "
                                f"not older than the completed one at "
                                f"{w['final_step']}")


@register
class ZeroFlushStall(Invariant):
    """Durability flushing adds ZERO training stall: the flush plane runs
    entirely on background worker threads, so no flush-named stage ever
    appears in a send's stall decomposition or in the checkpointer's
    stage ledger — the paper's zero-overhead claim extended through the
    durability tiers (`repro.durability.flush`)."""
    name = "zero-flush-stall"

    FORBIDDEN = ("flush", "durability", "tier")

    def applies(self, trace) -> bool:
        return trace.scenario.durability.enabled

    def _bad(self, names) -> list:
        return sorted(n for n in names
                      if any(f in n for f in self.FORBIDDEN))

    def check_step(self, trace, rec):
        for s in rec.sends:
            bad = self._bad(s.parts)
            if bad:
                yield self._v(s.step, f"flush stage(s) {bad} booked on the "
                                      f"training critical path")

    def check_end(self, trace):
        stages = getattr(trace.checkpointer, "stall_stages", None) or {}
        bad = self._bad(stages)
        if bad:
            yield self._v(None, f"flush stage(s) {bad} in the "
                                f"checkpointer's stall ledger")
        dur = trace.durability
        if dur is None or dur.epochs_started == 0:
            yield self._v(None, "durability enabled but no flush epoch "
                                "ever started — the claim was never "
                                "exercised")


@register
class TierRestore(Invariant):
    """Every durability tier rebuilds a full checkpoint bit-identical to
    the trainer at that tier's newest complete epoch (its recorded lag),
    and a total plane loss recovers through the tiers to the newest
    flushed step, with `ShadowNodeLoss` naming the serving tier."""
    name = "tier-restore"

    def applies(self, trace) -> bool:
        sc = trace.scenario
        # compressed flush (or a compressed channel stream) restores are
        # intentionally approximate — bit-identity is out of scope there
        return (sc.durability.enabled and not sc.durability.compress
                and sc.channel.kind != "compressed")

    def check_end(self, trace):
        from repro.durability.restore import (TierRestoreError,
                                              restore_from_tiers)
        dur = trace.durability
        if dur is None:
            yield self._v(None, "durability enabled but the runner "
                                "attached no DurableShadow")
            return
        n_nodes = trace.scenario.shadow_nodes
        for tier in trace.tiers:
            want = dur.last_complete_step(tier.name)
            try:
                ckpt = restore_from_tiers([tier], trace.layout,
                                          n_nodes=n_nodes)
            except TierRestoreError:
                if want is None:
                    continue       # tier never completed an epoch: fine
                yield self._v(None, f"tier '{tier.name}' books a complete "
                                    f"epoch at step {want} but restore "
                                    f"found no usable point")
                continue
            got = int(ckpt["step"])
            if got != want:
                yield self._v(None, f"tier '{tier.name}' restored step "
                                    f"{got}, its newest complete epoch "
                                    f"is at step {want}")
            ref = trace.states.get(got)
            if ref is None:
                yield self._v(None, f"tier '{tier.name}' restored step "
                                    f"{got}, a step the trainer never "
                                    f"executed")
                continue
            bad = tree_mismatch(ckpt, ref)
            if bad:
                yield self._v(None, f"tier '{tier.name}' restore@{got} != "
                                    f"trainer@{got}: {bad}")
        ev = trace.scenario.durability.every_steps
        for pl in trace.plane_losses:
            if not pl["total"]:
                yield self._v(pl["step"], "whole-plane kill did not "
                                          "surface as a total "
                                          "ShadowNodeLoss")
            hint = pl["durable_hint"]
            if hint is None:
                yield self._v(pl["step"], "total loss carried no durable "
                                          "hint despite attached tiers")
            elif pl["restored_step"] != hint[1]:
                yield self._v(pl["step"],
                              f"restore landed at {pl['restored_step']} "
                              f"but the loss named tier '{hint[0]}' at "
                              f"step {hint[1]}")
            # the drill drains flushes before the kill, so the durable
            # point trails the kill step by exactly the cadence remainder
            if pl["restored_step"] != (pl["step"] // ev) * ev:
                yield self._v(pl["step"],
                              f"restored step {pl['restored_step']} != "
                              f"newest flushed step "
                              f"{(pl['step'] // ev) * ev} "
                              f"(cadence every_steps={ev})")


class _TornTier:
    """Read-through tier proxy serving ONE record as a torn write (its
    byte stream cut mid-payload) — the torn-delta invariant's probe."""

    def __init__(self, inner, torn_key: str):
        self.inner = inner
        self.torn_key = torn_key
        self.name = inner.name

    def entries(self):
        return self.inner.entries()

    def read(self, entry):
        from repro.durability.record import FlushRecord
        rec = self.inner.read(entry)
        if entry.key != self.torn_key:
            return rec
        raw = rec.to_bytes()
        return FlushRecord.from_bytes(raw[:len(raw) // 2])  # raises Torn...


@register
class TornDeltaDetection(Invariant):
    """A flush record cut anywhere mid-write is rejected by its checksum
    — never silently half-applied — and restore falls back past it to an
    older complete epoch that is still bit-identical to the trainer."""
    name = "torn-delta"

    def applies(self, trace) -> bool:
        sc = trace.scenario
        return (sc.durability.enabled and not sc.durability.compress
                and sc.channel.kind != "compressed")

    def check_end(self, trace):
        from repro.durability.record import FlushRecord, TornRecordError
        from repro.durability.restore import (TierRestoreError,
                                              restore_from_tiers)
        dur = trace.durability
        if dur is None or not trace.tiers:
            return
        tier = trace.tiers[0]            # the local-disk tier
        target = None                    # newest payload-carrying record
        for e in sorted(tier.entries(), key=lambda e: (e.epoch, e.node)):
            if e.kind in ("base", "delta"):
                target = e
        if target is None:
            return
        raw = tier.read(target).to_bytes()
        try:
            FlushRecord.from_bytes(raw[:len(raw) // 2])
            yield self._v(None, f"record {target.key} truncated to half "
                                f"parsed cleanly — torn write undetected")
            return
        except TornRecordError:
            pass
        try:
            ckpt = restore_from_tiers([_TornTier(tier, target.key)],
                                      trace.layout,
                                      n_nodes=trace.scenario.shadow_nodes)
        except TierRestoreError:
            if target.kind == "delta":
                # a torn DELTA must only cost its own epoch — an older
                # complete one (the base, at minimum) must still serve
                yield self._v(None, f"torn delta {target.key} made the "
                                    f"whole tier unrestorable instead of "
                                    f"falling back one epoch")
            return
        got = int(ckpt["step"])
        ref = trace.states.get(got)
        if ref is None:
            yield self._v(None, f"fallback restore past torn "
                                f"{target.key} landed at step {got}, a "
                                f"step the trainer never executed")
            return
        bad = tree_mismatch(ckpt, ref)
        if bad:
            yield self._v(None, f"fallback restore past torn "
                                f"{target.key} diverged: {bad}")


def select(trace) -> list[Invariant]:
    """Instantiate the invariants for one run: the scenario's forced list,
    or every registered invariant (``applies()`` scopes them per check)."""
    names = trace.scenario.invariants or tuple(sorted(REGISTRY))
    unknown = [n for n in names if n not in REGISTRY]
    if unknown:
        raise KeyError(f"unknown invariants {unknown}; "
                       f"registered: {sorted(REGISTRY)}")
    return [REGISTRY[n]() for n in names]
