"""Deterministic chaos co-simulation runner.

`run_scenario` drives one declarative `Scenario` through the real stack —
checkpointer -> GradientChannel -> fabric simulator -> shadow plane ->
recovery — while a reference trainer runs beside it, and evaluates the
invariant registry (`repro.harness.invariants`) after every step. Two
stack depths:

* channel level — a synthetic gradient stream (pure function of the
  scenario seed) through a `CheckmateCheckpointer`, with the reference
  trainer applying the same functional optimizer to the raw gradients;
  training-node failures rewind the reference onto ``restore()``.
* full level — the real `repro.train.loop.train` loop on a reduced model
  config, observed through its ``step_hook``; an uninterrupted reference
  run provides the bit-identity targets.

On violation the runner emits a minimal repro bundle — scenario JSON +
seed + failing step — that `replay_bundle` re-runs and compares
bit-identically (tests/test_harness.py replays bundles as pytest cases).
"""
from __future__ import annotations

import dataclasses
import json
import tempfile
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Optional

import numpy as np

from repro.harness import invariants as inv
from repro.harness.scenario import Scenario

WEDGE_TIMEOUT_S = 0.25      # the deadline the wedged consolidate must honor
WEDGE_RETRY_S = 30.0        # post-release retry budget


@dataclass
class SendRecord:
    """One ``channel.send``: the stall it reported vs the wall it took,
    plus the channel's per-stage decomposition of the reported value
    (``last_send_parts`` — its in-order sum must equal ``reported``
    bit-exactly, checked by the stall-attribution invariant)."""
    step: int
    reported: float
    wall_s: float
    parts: dict = field(default_factory=dict)


@dataclass
class PollRecord:
    """One delivery as the shadow side saw it."""
    step: int
    complete: bool
    missing_captures: int
    fabric: object          # FabricResult for packetized transports
    node_complete: Optional[dict] = None   # sharded: per-owner verdicts


class InstrumentedChannel:
    """Transparent `GradientChannel` wrapper recording every send/poll —
    the harness's observation point on the delivery edge."""

    def __init__(self, inner):
        self.inner = inner
        self.name = getattr(inner, "name", "channel")
        self._sends: list[SendRecord] = []
        self._polls: list[PollRecord] = []

    def open(self, layout, multicast_groups=None):
        self.inner.open(layout, multicast_groups)

    def send(self, event) -> float:
        t0 = time.perf_counter()
        reported = self.inner.send(event)
        self._sends.append(SendRecord(
            event.step, float(reported or 0.0), time.perf_counter() - t0,
            parts=dict(getattr(self.inner, "last_send_parts", None) or {})))
        return reported

    @property
    def last_send_parts(self) -> dict:
        """Forward the inner channel's stall decomposition so the
        checkpointer's attribution sees through the wrapper."""
        return getattr(self.inner, "last_send_parts", {})

    def poll(self):
        out = self.inner.poll()
        self._polls.extend(
            PollRecord(d.step, d.complete, d.missing_captures,
                       getattr(d, "fabric", None),
                       getattr(d, "node_complete", None)) for d in out)
        return out

    def kill_shadow_node(self, node_id: int):
        self.inner.kill_shadow_node(node_id)

    def revive_all(self):
        fn = getattr(self.inner, "revive_all", None)
        if fn is not None:
            fn()

    def close(self):
        self.inner.close()

    def take_sends(self) -> list[SendRecord]:
        out, self._sends = self._sends, []
        return out

    def take_polls(self) -> list[PollRecord]:
        out, self._polls = self._polls, []
        return out


@dataclass
class StepRecord:
    """Everything the invariants see about one executed iteration."""
    step: int
    stall: float = 0.0
    loss: Optional[float] = None
    shadow_step: Optional[int] = None    # consolidated shadow step after
    gated: bool = False                  # skipped_steps grew this on_step
    applied: bool = False                # a delivery advanced the shadow
    partial_applied: bool = False        # sharded: survivors-only apply
    shadow_missing: Optional[dict] = None  # node -> buckets lost with it
    dead_nodes: tuple = ()               # dead owners at this consolidate
    resync: bool = False                 # healed via full-state copy
    shadow_lag: Optional[int] = None     # async applier backlog after ingest
    restored_step: Optional[int] = None  # a restore() ran just before this
    plane_restore: bool = False          # ...and it came from the tiers
    elastic: bool = False                # ...and it landed on a shrunken mesh
    first_seen: bool = True              # False = replay after a recovery
    sends: list = field(default_factory=list)
    polls: list = field(default_factory=list)
    state: Optional[dict] = None         # trainer checkpoint after this step
    shadow_ckpt: Optional[dict] = None   # cleared after per-step checks


class Trace:
    """The run's observable history, shared with every invariant."""

    def __init__(self, scenario: Scenario):
        self.scenario = scenario
        self.records: list[StepRecord] = []
        self.states: dict[int, dict] = {}    # step -> first-seen trainer ckpt
        self.ref_losses: Optional[list] = None
        self.ref_final: Optional[dict] = None
        self.final: Optional[dict] = None
        self.final_shadow: Optional[dict] = None
        self.bootstrap_step = 0
        self.checkpointer = None
        self.channel: Optional[InstrumentedChannel] = None
        self.compressor = None
        self.wedge: Optional[dict] = None
        self.shadow_partition: Optional[dict] = None  # node -> buckets/leaves
        self.layout = None                   # the run's BucketLayout
        self.durability = None               # DurableShadow when enabled
        self.tiers: list = []                # its Tier objects
        self.plane_losses: list[dict] = []   # total-loss drills, as observed
        self.elastic_events: list[dict] = []  # shrink drills, as observed
        self.shadow_stats = None             # final ShadowStats (channel lvl)
        self.dur_tmpdir = None               # local-disk tier root; cleaned
        #                                      by run_scenario AFTER end-of-
        #                                      run invariants read the tier
        self.stats = None
        self.violations: list[inv.Violation] = []
        # steps where injected failures make fabric-level loss legitimate.
        # A shadow-node death keeps losing that owner's mirrors on every
        # later send, so every step from the death onward counts (an
        # over-approximation once a resync revives the transport — the
        # death invariant checks those steps precisely).
        fs = set(scenario.schedule.fabric_steps)
        for d in scenario.schedule.shadow_death:
            first = d.step if d.phase == "step" else d.step + 1
            fs.update(range(first, scenario.steps + 1))
        self.fabric_steps = frozenset(fs)


class _Engine:
    """Evaluates the selected invariants per step and at the end. A forced
    selection (``Scenario.invariants``) bypasses ``applies()`` — that is
    how an inapplicable check demonstrates the violation-bundle path."""

    def __init__(self, trace: Trace):
        self.trace = trace
        self.forced = bool(trace.scenario.invariants)
        self.invariants = inv.select(trace)

    def _active(self, i) -> bool:
        return self.forced or i.applies(self.trace)

    def step(self, rec: StepRecord):
        for i in self.invariants:
            if self._active(i):
                self.trace.violations.extend(i.check_step(self.trace, rec))

    def end(self):
        for i in self.invariants:
            if self._active(i):
                self.trace.violations.extend(i.check_end(self.trace))


@dataclass
class ScenarioResult:
    scenario: Scenario
    violations: tuple[inv.Violation, ...]
    trace: Trace
    bundle_path: Optional[Path] = None
    # Chrome trace_event JSON of the run's trailing trace window (the
    # runner's ring tracer); NOT part of bundle() — bundles must compare
    # bit-identically across replays, and trace timings are wall clock
    trace_export: Optional[dict] = None

    @property
    def passed(self) -> bool:
        return not self.violations

    @property
    def failing_step(self) -> Optional[int]:
        steps = [v.step for v in self.violations if v.step is not None]
        return min(steps) if steps else None

    def bundle(self) -> dict:
        """The minimal replayable repro: seed + scenario + failing step."""
        return {"seed": self.scenario.seed,
                "scenario": self.scenario.to_dict(),
                "failing_step": self.failing_step,
                "violations": [v.to_dict() for v in self.violations]}

    def describe(self) -> str:
        sc = self.scenario
        tag = "PASS" if self.passed else f"FAIL@{self.failing_step}"
        extra = ""
        if self.violations:
            v = self.violations[0]
            extra = f"  [{v.invariant}] {v.message}"
        return (f"{tag:<8} {sc.name:<34} {sc.level:<7} "
                f"{sc.channel.kind:<11} steps={sc.steps}{extra}")


# -- bundles ------------------------------------------------------------------

TRACE_TAIL_EVENTS = 64          # trailing trace window embedded in bundles


def write_bundle(result: ScenarioResult, bundle_dir) -> Path:
    """Write the repro bundle to disk. The on-disk JSON adds the trailing
    trace window (``trace_tail``) for triage — ``bundle()`` itself stays
    wall-clock-free so replays compare bit-identically — and the full
    trace export lands beside it as ``<name>.trace.json``."""
    bundle_dir = Path(bundle_dir)
    bundle_dir.mkdir(parents=True, exist_ok=True)
    path = bundle_dir / f"{result.scenario.name}.json"
    d = result.bundle()
    if result.trace_export is not None:
        events = result.trace_export.get("traceEvents", [])
        d["trace_tail"] = events[-TRACE_TAIL_EVENTS:]
        (bundle_dir / f"{result.scenario.name}.trace.json").write_text(
            json.dumps(result.trace_export, indent=1, sort_keys=True))
    path.write_text(json.dumps(d, indent=2, sort_keys=True))
    return path


def replay_bundle(path) -> tuple[ScenarioResult, bool]:
    """Re-run a violation bundle's scenario; True iff the violations
    reproduce bit-identically (same invariants, steps, and messages)."""
    stored = json.loads(Path(path).read_text())
    result = run_scenario(Scenario.from_dict(stored["scenario"]))
    fresh = result.bundle()
    identical = (fresh["violations"] == stored["violations"]
                 and fresh["failing_step"] == stored["failing_step"])
    return result, identical


# -- channel-level co-simulation ----------------------------------------------

def _grads_at(sc: Scenario, params: dict, step: int) -> dict:
    """The synthetic gradient stream: a pure function of (seed, step), so
    recovery replays the identical stream (mirrors repro.data.synthetic)."""
    rng = np.random.default_rng((sc.seed + 1) * 1_000_003 + step)
    return {k: (rng.standard_normal(v.shape) * 0.01).astype(np.float32)
            for k, v in params.items()}


def _install_wedge(shadow, node_id: int, release_s: float):
    node = shadow.nodes[node_id]
    original = node.apply
    release = time.time() + release_s

    def wedged(*a, **kw):
        while time.time() < release:
            time.sleep(0.01)
        return original(*a, **kw)

    node.apply = wedged


def _install_throttle(shadow, delay_s: float):
    """Make every shadow apply deliberately slow (the slow-apply drills).
    Wraps ``_apply`` (not ``apply``) so both the single and the batched
    (`apply_batch`) paths pay the delay per replayed step."""
    for node in shadow.nodes:
        original = node._apply

        def slowed(*a, _orig=original, **kw):
            time.sleep(delay_s)
            return _orig(*a, **kw)

        node._apply = slowed


def _run_channel(sc: Scenario, trace: Trace, engine: _Engine):
    import jax.numpy as jnp

    import jax
    from repro.core.buckets import layout_for_tree
    from repro.core.channel import StepEvent
    from repro.core.checkpoint import CheckmateCheckpointer
    from repro.core.costmodel import ElasticMeshBudget, plan_elastic_mesh
    from repro.core.elastic import rebuild_shadow
    from repro.core.shadow import (ConsolidationTimeout, ShadowCluster,
                                   ShadowNodeLoss)
    from repro.optim.functional import TrainState, apply_updates

    rng = np.random.default_rng(np.uint64(sc.seed))
    params = {f"leaf{k}": rng.standard_normal(
                  (6 + 2 * k, sc.leaf_cols)).astype(np.float32)
              for k in range(sc.n_leaves)}
    layout = layout_for_tree(params, cap_bytes=sc.cap_bytes)
    opt = sc.opt_config()
    zeros = {k: np.zeros_like(v) for k, v in params.items()}

    shadow = ShadowCluster(layout, opt, n_nodes=sc.shadow_nodes,
                           async_mode=sc.shadow_async,
                           max_lag_steps=sc.max_lag_steps)
    if sc.apply_delay_s:
        _install_throttle(shadow, sc.apply_delay_s)
    trace.layout = layout
    dur = None
    if sc.durability.enabled:
        from repro.durability import (DurableShadow, FlushPolicy,
                                      LocalDiskTier, ObjectStoreTier)

        # attach BEFORE bootstrap so the seed replica gets its base epoch
        trace.dur_tmpdir = tempfile.TemporaryDirectory(prefix="repro-dur-")
        tiers = [LocalDiskTier(trace.dur_tmpdir.name)]
        if sc.durability.object_store:
            tiers.append(ObjectStoreTier(
                latency_s=sc.durability.object_latency_s))
        for tf in sc.schedule.tier_fail:
            for t in tiers:
                if t.name == tf.tier:
                    t.fail_steps.add(tf.step)
        dur = DurableShadow(tiers, FlushPolicy(
            every_steps=sc.durability.every_steps,
            compress=sc.durability.compress,
            rebase_every=sc.durability.rebase_every)).attach(shadow)
        trace.durability, trace.tiers = dur, tiers
    shadow.bootstrap(params, zeros, zeros, 0)
    # the seed replica is a state too: a tier restore may land on it
    trace.states.setdefault(
        0, {"params": params, "mu": zeros, "nu": zeros, "step": 0})
    trace.shadow_partition = {
        n.node_id: {"buckets": list(n.bucket_ids),
                    "leaves": list(n._leaves)} for n in shadow.nodes}
    chan = InstrumentedChannel(sc.channel.build(
        sc.schedule.failures_at(), n_shadow_nodes=sc.shadow_nodes))
    ck = CheckmateCheckpointer(shadow, channel=chan)
    trace.checkpointer, trace.channel = ck, chan
    trace.compressor = getattr(chan.inner, "compressor", None)

    # the reference trainer: same functional optimizer over the RAW stream
    def as_state(p, m, v, step):
        return TrainState(
            params={k: jnp.asarray(np.asarray(x)) for k, x in p.items()},
            mu={k: jnp.asarray(np.asarray(x)) for k, x in m.items()},
            nu={k: jnp.asarray(np.asarray(x)) for k, x in v.items()},
            step=jnp.asarray(step, jnp.int32))

    state = as_state(params, zeros, zeros, 0)
    apply_fn = jax.jit(lambda s, g: apply_updates(s, g, opt, sc.lr))
    pending_restore: Optional[int] = None
    pending_plane = False
    pending_elastic = False
    fails = set(sc.schedule.train_fail_steps)
    planes = {p.step for p in sc.schedule.plane_loss}
    shrinks = {t.step: t for t in sc.schedule.train_node_loss}
    # the train-side world the channel models; shrink drills cut it down
    world_ranks = list(range(sc.channel.n_dp_groups
                             * sc.channel.ranks_per_group))
    last_ckpt = None
    step, executed = 0, 0
    try:
        while step < sc.steps:
            executed += 1
            if executed > 6 * sc.steps + 12:
                raise RuntimeError(f"{sc.name}: runaway recovery loop")
            nxt = step + 1
            if nxt in fails:                 # training node dies mid-step
                fails.discard(nxt)
                restored = ck.restore()
                state = as_state(restored["params"], restored["mu"],
                                 restored["nu"], restored["step"])
                pending_restore = int(restored["step"])
                step = int(restored["step"])
                continue
            deaths = [d for d in sc.schedule.shadow_death if d.step == nxt]
            for d in deaths:            # phase "step": dies before the send
                if d.phase == "step":
                    chan.kill_shadow_node(d.node)
                    shadow.kill_node(d.node)
            grads = _grads_at(sc, params, nxt)
            state = apply_fn(state, grads)
            ckpt = {"params": {k: np.asarray(v)
                               for k, v in state.params.items()},
                    "mu": {k: np.asarray(v) for k, v in state.mu.items()},
                    "nu": {k: np.asarray(v) for k, v in state.nu.items()},
                    "step": nxt}
            wedged = (sc.schedule.wedge_node is not None and nxt == sc.steps)
            if wedged:
                _install_wedge(shadow, sc.schedule.wedge_node,
                               sc.schedule.wedge_release_s)
            before = (ck.n_checkpoints, len(ck.skipped_steps),
                      len(ck.resyncs), len(ck.partial_steps))
            stall = ck.on_step(StepEvent(
                step=nxt, grads=grads, lr=sc.lr,
                state_fn=(lambda c=ckpt: c) if sc.resync else None))
            if dur is not None:
                # settle this step's flush epoch (harness time, never the
                # trainer's) so the invariants see the tiers as of step nxt
                dur.drain()

            rec = StepRecord(step=nxt, stall=stall)
            if sc.shadow_async:
                # backlog sample point: right after ingest, before any
                # consolidation settles it — the apply-lag-bound invariant
                # checks this never exceeds max_lag_steps
                rec.shadow_lag = int(shadow.stats().lag)
            rec.resync = len(ck.resyncs) > before[2]
            rec.gated = len(ck.skipped_steps) > before[1]
            rec.applied = ck.n_checkpoints > before[0] and not rec.resync
            rec.partial_applied = len(ck.partial_steps) > before[3]
            rec.restored_step, pending_restore = pending_restore, None
            rec.plane_restore, pending_plane = pending_plane, False
            rec.elastic, pending_elastic = pending_elastic, False
            rec.sends, rec.polls = chan.take_sends(), chan.take_polls()
            for d in deaths:            # phase "consolidate": dies between
                if d.phase == "consolidate":    # the apply and the gather
                    chan.kill_shadow_node(d.node)
                    shadow.kill_node(d.node)
            if wedged:
                # the deadline drill replaces this step's consolidate
                try:
                    shadow.consolidate(timeout=WEDGE_TIMEOUT_S)
                    raised, lagging, partial = False, [], -1
                except ConsolidationTimeout as e:
                    raised, lagging = True, list(e.lagging_nodes)
                    partial = int(e.partial["step"])
                shadow_ck = shadow.consolidate(timeout=WEDGE_RETRY_S)
                trace.wedge = {"raised": raised, "lagging": lagging,
                               "partial_step": partial,
                               "final_step": int(shadow_ck["step"])}
            elif sc.max_lag_steps is not None and nxt < sc.steps:
                # bounded-lag drill: consolidating every step would drain
                # the very backlog the bound exists to absorb — settle only
                # at the final step (bit-identity is still checked there,
                # and the per-step lag bound via rec.shadow_lag)
                shadow_ck = None
            else:
                try:
                    shadow_ck = shadow.consolidate()
                except ShadowNodeLoss as e:
                    # dead owners: the gather serves the survivors' shards
                    # and names exactly the dead buckets as missing
                    shadow_ck = e.partial
                    rec.shadow_missing = {
                        int(n): tuple(int(b) for b in bids)
                        for n, bids in e.missing_buckets.items()}
                    rec.dead_nodes = tuple(sorted(e.dead_nodes))
            if shadow_ck is not None:
                rec.shadow_step = int(shadow_ck["step"])
                rec.shadow_ckpt = shadow_ck
                trace.final_shadow = shadow_ck
            rec.state = ckpt
            rec.first_seen = nxt not in trace.states
            if rec.first_seen:
                trace.states[nxt] = ckpt
            trace.records.append(rec)
            engine.step(rec)
            rec.shadow_ckpt = None          # free the per-step tree
            if not rec.first_seen:          # replays: first-seen copy is
                rec.state = None            # already kept in trace.states
            last_ckpt = ckpt
            step = nxt
            if nxt in shrinks:      # train ranks die AFTER the step: shrink
                tl = shrinks.pop(nxt)
                if dur is not None:
                    dur.drain()     # settle in-flight epochs pre-migration
                restored = ck.restore()          # books consolidate-wait
                survivors = [r for r in world_ranks
                             if r not in set(tl.ranks)]
                plan = plan_elastic_mesh(survivors, ElasticMeshBudget())
                old_world, new_world = len(world_ranks), plan.n_ranks
                world_ranks = list(plan.survivors)
                # the shrunken channel geometry: keep the group size if the
                # new world still fills whole groups, else keep the group
                # count, else collapse to one group of survivors
                if new_world % sc.channel.ranks_per_group == 0:
                    geo = (new_world // sc.channel.ranks_per_group,
                           sc.channel.ranks_per_group)
                elif new_world % sc.channel.n_dp_groups == 0:
                    geo = (sc.channel.n_dp_groups,
                           new_world // sc.channel.n_dp_groups)
                else:
                    geo = (1, new_world)
                remaining = {s: f for s, f
                             in sc.schedule.failures_at().items() if s > nxt}
                spec = dataclasses.replace(
                    sc.channel, n_dp_groups=geo[0], ranks_per_group=geo[1],
                    ranks_per_leaf=min(sc.channel.ranks_per_leaf, geo[1]))
                new_chan = InstrumentedChannel(
                    spec.build(remaining, n_shadow_nodes=sc.shadow_nodes))
                # the bucket layout + ownership map are re-derived for the
                # new world; durability migrates (reattach) and the rebuilt
                # plane cuts a fresh base at the resume step
                shadow = rebuild_shadow(shadow, restored,
                                        n_nodes=sc.shadow_nodes,
                                        cap_bytes=sc.cap_bytes)
                layout = shadow.layout
                ck.reconfigure(shadow, channel=new_chan)  # elastic-reshard
                chan = new_chan
                trace.channel, trace.layout = chan, layout
                trace.compressor = getattr(chan.inner, "compressor", None)
                trace.shadow_partition = {
                    n.node_id: {"buckets": list(n.bucket_ids),
                                "leaves": list(n._leaves)}
                    for n in shadow.nodes}
                trace.elastic_events.append({
                    "step": nxt, "killed": sorted(tl.ranks),
                    "old_world": old_world, "new_world": new_world,
                    "dp": plan.dp, "fsdp": plan.fsdp,
                    "survivors": list(plan.survivors),
                    "geometry": list(geo),
                    "resumed_step": int(restored["step"])})
                state = as_state(restored["params"], restored["mu"],
                                 restored["nu"], restored["step"])
                pending_restore = int(restored["step"])
                pending_elastic = True
                step = int(restored["step"])
            if nxt in planes:       # total shadow-plane loss AFTER the step
                planes.discard(nxt)
                from repro.durability.restore import restore_from_tiers
                dur.drain()         # everything notified so far is durable
                for n in shadow.nodes:
                    chan.kill_shadow_node(n.node_id)
                    shadow.kill_node(n.node_id)
                try:
                    shadow.consolidate()
                    raise RuntimeError(f"{sc.name}: the whole plane is dead "
                                       f"but consolidate served a checkpoint")
                except ShadowNodeLoss as e:
                    trace.plane_losses.append({
                        "step": nxt, "total": bool(e.total),
                        "durable_hint": e.durable_hint,
                        "dead_nodes": sorted(e.dead_nodes)})
                restored = restore_from_tiers(dur.tiers, layout,
                                              n_nodes=sc.shadow_nodes)
                trace.plane_losses[-1]["restored_step"] = int(restored["step"])
                # both planes rewind to the newest durable step: the trainer
                # resumes there and the shadow re-seeds from the same state
                # (bootstrap revives the dead nodes and cuts a fresh base)
                state = as_state(restored["params"], restored["mu"],
                                 restored["nu"], restored["step"])
                shadow.bootstrap(restored["params"], restored["mu"],
                                 restored["nu"], int(restored["step"]))
                chan.revive_all()
                ck._desynced = ck._dead_desynced = False
                pending_restore = int(restored["step"])
                pending_plane = True
                step = int(restored["step"])
        trace.final = last_ckpt
    finally:
        trace.shadow_stats = shadow.stats()
        chan.close()
        if dur is not None:
            dur.drain()
            dur.close()             # idempotent vs shutdown()'s own close
        if sc.shadow_async:
            shadow.shutdown()


# -- full-stack co-simulation -------------------------------------------------

def _run_full(sc: Scenario, trace: Trace, engine: _Engine):
    import jax

    import repro.configs as C
    from repro.core.buckets import layout_for_tree
    from repro.core.checkpoint import (CheckmateCheckpointer, NoCheckpointer,
                                       SyncCheckpointer)
    from repro.core.recovery import FailurePlan, checkpoint_from_state
    from repro.core.shadow import ShadowCluster
    from repro.dist.sharding import ShardingRules, make_smoke_mesh
    from repro.train.loop import train
    from repro.train.step import make_train_state

    cfg = C.get(sc.arch).reduced()
    rules = ShardingRules(make_smoke_mesh())
    opt = sc.opt_config()

    def lr_fn(_):
        return sc.lr

    # uninterrupted reference: the bit-identity target
    ref_state, ref_stats = train(cfg, rules, steps=sc.steps, batch=sc.batch,
                                 seq=sc.seq, opt=opt, lr_fn=lr_fn,
                                 seed=sc.seed)
    trace.ref_losses = list(ref_stats.losses)
    trace.ref_final = checkpoint_from_state(ref_state)

    s0 = make_train_state(jax.random.PRNGKey(sc.seed), cfg, rules)
    shadow = None
    if sc.checkpointer == "checkmate":
        shadow = ShadowCluster(layout_for_tree(s0.params), opt,
                               n_nodes=sc.shadow_nodes,
                               async_mode=sc.shadow_async,
                               max_lag_steps=sc.max_lag_steps)
        shadow.bootstrap(s0.params, s0.mu, s0.nu, 0)
        chan = InstrumentedChannel(sc.channel.build(
            sc.schedule.failures_at(), n_shadow_nodes=sc.shadow_nodes))
        ck = CheckmateCheckpointer(shadow, channel=chan)
        trace.channel = chan
        trace.compressor = getattr(chan.inner, "compressor", None)
    elif sc.checkpointer == "sync":
        ck = SyncCheckpointer(freq=sc.ckpt_freq)
    else:
        ck = NoCheckpointer()
    trace.checkpointer = ck

    # elastic shrink at full level: the drill restores onto an FSDP-flipped
    # ShardingRules — the one layout change the 1-device smoke mesh can
    # express. The TrainNodeLoss fires as an injected failure on the step
    # AFTER tl.step ("ranks die after step"), and the loop's elastic path
    # (train(..., elastic_rules=...)) does the reconfiguration.
    fail_steps = tuple(sc.schedule.train_fail_steps)
    elastic_rules = None
    elastic_recovery = None
    if sc.schedule.train_node_loss:
        tl = sc.schedule.train_node_loss[0]
        fail_steps = tuple(sorted(set(fail_steps) | {tl.step + 1}))
        elastic_rules = ShardingRules(make_smoke_mesh(), fsdp=not rules.fsdp)
        elastic_recovery = fail_steps.index(tl.step + 1) + 1

    seen = {"ncp": 0, "skip": 0, "resync": 0, "recov": 0}

    def hook(step, state, stats):
        rec = StepRecord(step=step, stall=stats.stall_times[-1],
                         loss=stats.losses[-1])
        if stats.recoveries > seen["recov"]:
            seen["recov"] = stats.recoveries
            rec.restored_step = stats.recovered_at[-1]
            if (elastic_recovery is not None
                    and stats.recoveries >= elastic_recovery
                    and not trace.elastic_events):
                rec.elastic = True
                trace.elastic_events.append({
                    "step": tl.step, "killed": sorted(tl.ranks),
                    "fsdp": True,
                    "resumed_step": int(rec.restored_step)})
        if shadow is not None:
            rec.resync = len(ck.resyncs) > seen["resync"]
            rec.gated = len(ck.skipped_steps) > seen["skip"]
            rec.applied = ck.n_checkpoints > seen["ncp"] and not rec.resync
            seen.update(ncp=ck.n_checkpoints, skip=len(ck.skipped_steps),
                        resync=len(ck.resyncs))
            # consolidate the checkpointer's CURRENT plane — an elastic
            # reconfiguration swaps the cluster object mid-run
            shadow_ck = ck.shadow.consolidate()
            rec.shadow_step = int(shadow_ck["step"])
            rec.shadow_ckpt = shadow_ck
            trace.final_shadow = shadow_ck
        if trace.channel is not None:
            rec.sends = trace.channel.take_sends()
            rec.polls = trace.channel.take_polls()
        rec.state = checkpoint_from_state(state)
        rec.first_seen = step not in trace.states
        if rec.first_seen:
            trace.states[step] = rec.state
        trace.records.append(rec)
        engine.step(rec)
        rec.shadow_ckpt = None
        if not rec.first_seen:              # replays: first-seen copy is
            rec.state = None                # already kept in trace.states

    state, stats = train(
        cfg, rules, steps=sc.steps, batch=sc.batch, seq=sc.seq, opt=opt,
        lr_fn=lr_fn, seed=sc.seed, state=s0, checkpointer=ck,
        failure_plan=FailurePlan(fail_steps),
        step_hook=hook, elastic_rules=elastic_rules)
    trace.stats = stats
    trace.final = checkpoint_from_state(state)
    if shadow is not None and sc.shadow_async:
        ck.shadow.shutdown()


def run_scenario(scenario: Scenario, *, bundle_dir=None) -> ScenarioResult:
    """Run one scenario end to end and evaluate its invariants.

    With ``bundle_dir``, any violation writes a minimal repro bundle
    (seed + scenario JSON + failing step) that `replay_bundle` re-runs
    bit-identically; the bundle JSON embeds the trailing trace window and
    the full Chrome trace lands beside it.

    Unless an observability session is already active (``repro.obs
    .enabled_session`` — e.g. the ``repro.obs`` CLI), the runner installs
    its own ring-buffer tracer (metrics stay disabled) so every result
    carries the trailing trace window in ``trace_export``.
    """
    from repro import obs as _obs
    from repro.obs import Observability
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.trace import Tracer

    scenario.validate()
    own_session = not _obs.get().tracer.enabled
    prev = None
    if own_session:
        prev = _obs.install(Observability(
            MetricsRegistry(enabled=False),
            Tracer(maxlen=512)))
    trace = Trace(scenario)
    try:
        engine = _Engine(trace)
        if scenario.level == "channel":
            _run_channel(scenario, trace, engine)
        else:
            _run_full(scenario, trace, engine)
        engine.end()
        result = ScenarioResult(scenario=scenario,
                                violations=tuple(trace.violations),
                                trace=trace)
        result.trace_export = _obs.get().tracer.export()
    finally:
        # the end-of-run invariants read the disk tier — drop it only now
        if trace.dur_tmpdir is not None:
            trace.dur_tmpdir.cleanup()
            trace.dur_tmpdir = None
        if own_session:
            _obs.install(prev)
    if bundle_dir is not None and result.violations:
        result.bundle_path = write_bundle(result, bundle_dir)
    return result
