"""Chaos harness CLI (docs/harness.md).

    python -m repro.harness run --corpus golden [--bundle-dir DIR]
    python -m repro.harness run --scenario gated-then-recovery
    python -m repro.harness run --seed 1234 [--level channel|full]
    python -m repro.harness sweep --n 8 [--seed BASE] [--bundle-dir DIR]
    python -m repro.harness replay --seed 1234
    python -m repro.harness replay --bundle chaos-bundles/foo.json

``run`` / ``sweep`` exit nonzero if any invariant is violated, writing a
minimal repro bundle per violating scenario when --bundle-dir is given.
``replay`` re-runs a bundle (or a sampled seed, twice) and exits zero iff
the outcome reproduces bit-identically — which is what makes every CI
chaos failure a one-integer local repro.

``--time-budget PATH`` additionally times every scenario and fails the
run if the total wall clock exceeds ``tolerance`` x the committed
baseline (``benchmarks/golden_budget.json``) — the guard that keeps the
golden corpus from quietly doubling as scenarios accrete.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.harness.corpus import GOLDEN
from repro.harness.runner import replay_bundle, run_scenario
from repro.harness.scenario import repro_seed, sample_scenario


def _check_time_budget(timings: dict, budget_path: str) -> int:
    """Compare measured wall clock against the committed baseline.

    The budget file maps scenario name -> baseline seconds plus a
    ``tolerance`` multiplier; the check fails only on the TOTAL (single
    scenarios jitter on shared CI runners), but prints any scenario
    individually past tolerance so the offender is named. Scenarios
    without a committed baseline are reported and excluded — add them to
    the budget file when they land.
    """
    with open(budget_path) as f:
        budget = json.load(f)
    tol = float(budget.get("tolerance", 2.0))
    baselines = budget["scenarios"]
    unbudgeted = sorted(set(timings) - set(baselines))
    if unbudgeted:
        print(f"# time-budget: no baseline for {', '.join(unbudgeted)} "
              f"(excluded — add to {budget_path})")
    covered = {n: t for n, t in timings.items() if n in baselines}
    for name, t in sorted(covered.items()):
        if t > tol * baselines[name]:
            print(f"# time-budget: {name} took {t:.1f}s "
                  f"(baseline {baselines[name]:.1f}s, x{tol:g} allowed)")
    total = sum(covered.values())
    allowed = tol * sum(baselines[n] for n in covered)
    verdict = "OK" if total <= allowed else "EXCEEDED"
    print(f"# time-budget: total {total:.1f}s / allowed {allowed:.1f}s "
          f"({len(covered)} budgeted scenario(s)) -> {verdict}")
    return 0 if total <= allowed else 1


def _run_many(scenarios, bundle_dir, budget_path=None) -> int:
    failed = 0
    timings: dict = {}
    for sc in scenarios:
        t0 = time.monotonic()
        result = run_scenario(sc, bundle_dir=bundle_dir)
        timings[sc.name] = time.monotonic() - t0
        print(f"{result.describe()}  [{timings[sc.name]:.1f}s]")
        if not result.passed:
            failed += 1
            if result.bundle_path:
                print(f"         repro bundle -> {result.bundle_path}")
    n = len(scenarios)
    print(f"# {n - failed}/{n} scenarios passed"
          + (f", {failed} FAILED" if failed else ""))
    over = _check_time_budget(timings, budget_path) if budget_path else 0
    return 1 if (failed or over) else 0


def _cmd_run(args) -> int:
    if args.scenario:
        if args.scenario not in GOLDEN:
            print(f"run: unknown scenario {args.scenario!r}; golden "
                  f"scenarios: {', '.join(sorted(GOLDEN))}", file=sys.stderr)
            return 2
        scenarios = [GOLDEN[args.scenario]]
    elif args.corpus:
        scenarios = list(GOLDEN.values())
    elif args.seed is not None:
        scenarios = [sample_scenario(args.seed, level=args.level)]
    else:
        print("run: pass --corpus golden, --scenario NAME, or --seed N",
              file=sys.stderr)
        return 2
    return _run_many(scenarios, args.bundle_dir,
                     budget_path=args.time_budget)


def _cmd_sweep(args) -> int:
    base = repro_seed() if args.seed is None else args.seed
    print(f"# sweep: {args.n} scenarios from base seed {base} "
          f"(replay any with: python -m repro.harness replay --seed S"
          + (f" --level {args.level}" if args.level else "") + ")")
    scenarios = [sample_scenario(base + i, level=args.level)
                 for i in range(args.n)]
    return _run_many(scenarios, args.bundle_dir)


def _cmd_replay(args) -> int:
    if args.bundle:
        result, identical = replay_bundle(args.bundle)
        print(result.describe())
        verdict = ("reproduced bit-identically" if identical
                   else "DID NOT reproduce")
        print(f"# bundle {verdict}: {args.bundle}")
        return 0 if identical else 1
    if args.seed is None:
        print("replay: pass --bundle PATH or --seed N", file=sys.stderr)
        return 2
    sc = sample_scenario(args.seed, level=args.level)
    a = run_scenario(sc).bundle()
    b = run_scenario(sample_scenario(args.seed, level=args.level)).bundle()
    identical = a == b
    print(f"seed {args.seed} -> {sc.name}: "
          f"{len(a['violations'])} violation(s), replay "
          f"{'bit-identical' if identical else 'DIVERGED'}")
    return 0 if identical else 1


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.harness",
        description="Deterministic chaos co-simulation harness "
                    "(docs/harness.md)")
    sub = p.add_subparsers(dest="cmd", required=True)

    run = sub.add_parser("run", help="run golden corpus / named / sampled "
                                     "scenarios")
    run.add_argument("--corpus", choices=["golden"])
    run.add_argument("--scenario", help="golden scenario name")
    run.add_argument("--seed", type=int,
                     help="sample one random scenario from this seed")
    run.add_argument("--level", choices=["channel", "full"])
    run.add_argument("--bundle-dir",
                     help="write violation repro bundles here")
    run.add_argument("--time-budget", metavar="PATH",
                     help="committed wall-clock baseline JSON "
                          "(benchmarks/golden_budget.json); fail if the "
                          "total exceeds tolerance x baseline")
    run.set_defaults(fn=_cmd_run)

    sweep = sub.add_parser("sweep", help="run N seeded random scenarios")
    sweep.add_argument("--n", type=int, default=8)
    sweep.add_argument("--seed", type=int,
                       help="base seed (default: REPRO_SEED env var or 0)")
    sweep.add_argument("--level", choices=["channel", "full"])
    sweep.add_argument("--bundle-dir")
    sweep.set_defaults(fn=_cmd_sweep)

    rep = sub.add_parser("replay", help="re-run a violation bundle or a "
                                        "sampled seed bit-identically")
    rep.add_argument("--bundle", help="path to a repro bundle JSON")
    rep.add_argument("--seed", type=int)
    rep.add_argument("--level", choices=["channel", "full"])
    rep.set_defaults(fn=_cmd_replay)

    args = p.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
