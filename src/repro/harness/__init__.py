"""Deterministic chaos co-simulation harness (docs/harness.md).

Declarative `Scenario` specs drive the full stack — train loop ->
GradientChannel -> fabric simulator -> shadow plane -> recovery — under a
seeded `FailureSchedule`, with a registry of system-wide `Invariant`
checkers evaluated after every step. Violations emit minimal repro
bundles (seed + scenario JSON + failing step) that replay bit-identically.

    from repro.harness import GOLDEN, run_scenario, sample_scenario
    result = run_scenario(GOLDEN["gated-then-recovery"])
    assert result.passed, result.violations

CLI: ``python -m repro.harness {run,sweep,replay}``.
"""
from repro.harness.corpus import GOLDEN                          # noqa: F401
from repro.harness.invariants import (REGISTRY, Invariant,       # noqa: F401
                                      Violation, register)
from repro.harness.runner import (InstrumentedChannel,           # noqa: F401
                                  ScenarioResult, StepRecord, Trace,
                                  replay_bundle, run_scenario, write_bundle)
from repro.harness.scenario import (ChannelSpec, DurabilitySpec,  # noqa: F401
                                    FabricFailure, FailureSchedule,
                                    Scenario, ShadowDeath,
                                    ShadowPlaneLoss, TierFailure,
                                    repro_seed, sample_scenario,
                                    scenario_strategy)
