"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax

from repro.dist import compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 1
    for s in shape:
        ndev *= s
    devices = jax.devices()[:ndev]
    return compat.make_mesh(shape, axes, devices=devices,
                            axis_types=(compat.AxisType.Auto,) * len(axes))
