"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell against the production mesh, with 512 placeholder host devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod    # 2x16x16
    PYTHONPATH=src python -m repro.launch.dryrun --out results.json

Results (memory_analysis, cost_analysis, HLO-walk roofline terms, collective
breakdown) are appended incrementally to the JSON so interrupted runs resume.
"""
# The VERY FIRST lines — before ANY other import — so jax sees 512 devices.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.configs.base import SHAPES, shape_applicable
from repro.dist.sharding import ShardingRules
from repro.launch.hlo_analysis import analyze_compiled
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import Roofline, model_flops_for
from repro.models import registry
from repro.optim import OptimizerConfig
from repro.train.step import (abstract_train_state, build_decode_step,
                              build_prefill_step, build_train_step)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               microbatches=None, save_hlo: str | None = None):
    """Lower+compile one cell; returns the result record."""
    cfg = C.get(arch)
    if microbatches is not None:
        from dataclasses import replace
        cfg = replace(cfg, microbatches=microbatches)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rules = ShardingRules(mesh, fsdp=cfg.fsdp)
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            step = build_train_step(cfg, mesh, rules, OptimizerConfig(),
                                    lambda s: 1e-3)
            state = abstract_train_state(cfg, rules)
            inputs = registry.input_specs(cfg, shape, rules)
            lowered = jax.jit(step, donate_argnums=(0,)).lower(state, inputs)
        elif shape.kind == "prefill":
            step = build_prefill_step(cfg, shape, rules)
            params = registry.abstract_params(cfg, rules)
            inputs = registry.input_specs(cfg, shape, rules)
            lowered = jax.jit(step).lower(params, inputs)
        else:  # decode
            step = build_decode_step(cfg, rules)
            params = registry.abstract_params(cfg, rules)
            cache = registry.abstract_cache(cfg, rules, shape.global_batch,
                                            shape.seq_len)
            inputs = registry.input_specs(cfg, shape, rules)
            lowered = jax.jit(step, donate_argnums=(1,)).lower(
                params, cache, inputs["token"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(compiled.as_text())

    summary = analyze_compiled(compiled)
    rf = Roofline(
        arch=arch, shape=shape_name,
        mesh="multi" if multi_pod else "single", chips=chips,
        flops_per_device=summary["flops_per_device"],
        bytes_per_device=summary["bytes_per_device"],
        collective_bytes_per_device=summary["collective_bytes_per_device"],
        model_flops=model_flops_for(cfg, shape),
        per_collective=summary["per_collective"])

    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single",
           "status": "ok", "chips": chips,
           "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
           "memory": summary["memory"],
           "bytes_per_device_hbm": summary["memory"]["argument_bytes"]
           + summary["memory"]["temp_bytes"],
           **{k: v for k, v in rf.row().items()
              if k not in ("arch", "shape", "mesh", "chips")}}
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else C.all_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for multi in meshes:
        mesh_name = "multi" if multi else "single"
        for arch in archs:
            for shape in shapes:
                key = (arch, shape, mesh_name)
                if key in done:
                    continue
                print(f"=== {arch} x {shape} x {mesh_name} ===", flush=True)
                try:
                    rec = lower_cell(arch, shape, multi,
                                     microbatches=args.microbatches,
                                     save_hlo=args.save_hlo)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1, default=str)
                if rec["status"] == "ok":
                    print(f"  ok: compile={rec['compile_s']}s "
                          f"bound={rec['bound']} "
                          f"compute={rec['compute_s']*1e3:.1f}ms "
                          f"memory={rec['memory_s']*1e3:.1f}ms "
                          f"coll={rec['collective_s']*1e3:.1f}ms "
                          f"useful={rec['useful_flops_ratio']:.2f}", flush=True)
                else:
                    print(f"  {rec['status']}: {rec.get('reason', rec.get('error'))}",
                          flush=True)


if __name__ == "__main__":
    main()
