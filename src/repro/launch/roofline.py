"""Three-term roofline from the compiled dry-run artifact.

Target hardware: TPU v5e —
  peak_bf16   = 197 TFLOP/s per chip
  hbm_bw      = 819 GB/s per chip
  ici_bw      = ~50 GB/s per link (we charge all collective bytes against
                one link's bandwidth per chip, a conservative serialization
                assumption; see EXPERIMENTS.md §Roofline)

  compute term    = HLO_FLOPs / (chips x peak)
  memory term     = HLO_bytes / (chips x hbm_bw)
  collective term = collective_bytes / (chips x link_bw)

HLO_FLOPs/bytes come from repro.launch.hlo_analysis (while-loop trip counts
accounted). All values from the analyzer are per-device (SPMD module), so
the per-chip terms divide by peak only.
"""
from __future__ import annotations

from dataclasses import dataclass

PEAK_BF16 = 197e12          # FLOP/s per chip
HBM_BW = 819e9              # B/s per chip
ICI_BW = 50e9               # B/s per link


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops: float            # analytic 6*N*D (train) / 2*N*D (serve)
    per_collective: dict

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs — remat/redundancy waste detector."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        denom = self.step_time_s * self.chips * PEAK_BF16
        return self.model_flops / denom if denom else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bound": self.bound,
            "step_time_s": self.step_time_s,
            "model_flops": self.model_flops,
            "hlo_flops_total": self.flops_per_device * self.chips,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_at_roofline": self.mfu,
            "per_collective": self.per_collective,
        }


def model_flops_for(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train (N = active params for MoE),
    2*N*D forward-only for prefill/decode (D = tokens processed)."""
    n = cfg.active_param_count() if cfg.num_experts else cfg.param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    d = shape.global_batch * 1          # decode: one token per sequence
    return 2.0 * n * d
