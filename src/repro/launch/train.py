"""End-to-end training driver with Checkmate per-iteration checkpointing.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 50 --batch 8 --seq 64 --shadow-nodes 2 \
        --checkpointer checkmate --fail-at 20,35

On this CPU container use --reduced (tiny same-family config). On a real
pod, drop --reduced and pass --mesh single|multi.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--checkpointer", default="checkmate",
                    choices=["checkmate", "none", "sync", "async",
                             "torch_dcp", "gemini", "checkfreq"])
    ap.add_argument("--freq", type=int, default=1)
    ap.add_argument("--channel", default="inprocess",
                    choices=["inprocess", "packetized"],
                    help="gradient delivery transport for checkmate "
                         "(packetized = buckets -> frames -> fabric)")
    ap.add_argument("--topology", default="rail-optimized",
                    choices=["rail-optimized", "leaf-spine", "single"],
                    help="fabric topology for --channel packetized")
    ap.add_argument("--shadow-nodes", type=int, default=2)
    ap.add_argument("--shadow-async", action="store_true")
    ap.add_argument("--fail-at", default="",
                    help="comma-separated steps to inject failures at")
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient compression with error feedback")
    ap.add_argument("--mesh", default="smoke", choices=["smoke", "single", "multi"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome/Perfetto trace of the run "
                         "(enables the tracing session)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the end-of-run metrics snapshot JSON")
    args = ap.parse_args()

    import jax
    import repro.configs as C
    from repro.core.buckets import layout_for_tree
    from repro.core.channel import (CompressedChannel, InProcessChannel,
                                    PacketizedChannel)
    from repro.core.checkpoint import (AsyncCheckpointer, CheckFreqCheckpointer,
                                       CheckmateCheckpointer,
                                       GeminiLikeCheckpointer, NoCheckpointer,
                                       ShardedAsyncCheckpointer,
                                       SyncCheckpointer)
    from repro.core.recovery import FailurePlan
    from repro.core.shadow import ShadowCluster
    from repro.dist.sharding import ShardingRules, make_smoke_mesh
    from repro.launch.mesh import make_production_mesh
    from repro.optim import OptimizerConfig
    from repro.optim.schedules import cosine_schedule
    from repro.train.loop import train
    from repro.train.step import make_train_state
    from repro import obs
    from repro.obs.publish import collect_run, render_digest

    cfg = C.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.mesh == "smoke":
        mesh = make_smoke_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    rules = ShardingRules(mesh, fsdp=cfg.fsdp)
    opt = OptimizerConfig(name=args.optimizer, lr=args.lr)
    lr_fn = cosine_schedule(args.lr, warmup=5, total=args.steps)

    state0 = make_train_state(jax.random.PRNGKey(args.seed), cfg, rules)

    shadow = None
    if args.checkpointer == "checkmate":
        layout = layout_for_tree(state0.params)
        shadow = ShadowCluster(layout, opt, n_nodes=args.shadow_nodes,
                               async_mode=args.shadow_async)
        shadow.bootstrap(state0.params, state0.mu, state0.nu, 0)
        if args.channel == "packetized":
            channel = PacketizedChannel(topology=args.topology,
                                        n_shadow_nodes=args.shadow_nodes)
        else:
            channel = InProcessChannel()
        if args.compress:
            channel = CompressedChannel(channel)
        ck = CheckmateCheckpointer(shadow, channel=channel)
    else:
        ck = {
            "none": NoCheckpointer(),
            "sync": SyncCheckpointer(args.freq),
            "async": AsyncCheckpointer(args.freq),
            "torch_dcp": ShardedAsyncCheckpointer(args.freq),
            "gemini": GeminiLikeCheckpointer(args.freq),
            "checkfreq": CheckFreqCheckpointer(),
        }[args.checkpointer]

    plan = FailurePlan(tuple(int(x) for x in args.fail_at.split(",") if x))
    # --trace-out/--metrics-out turn the run's instrumentation on; the
    # digest below works either way (a fresh registry publishes from the
    # subsystems' native counters at end of run)
    session = (obs.enabled_session() if args.trace_out or args.metrics_out
               else None)
    ob = session.__enter__() if session is not None else None
    t0 = time.time()
    try:
        state, stats = train(cfg, rules, steps=args.steps, batch=args.batch,
                             seq=args.seq, opt=opt, lr_fn=lr_fn,
                             checkpointer=ck, failure_plan=plan,
                             seed=args.seed, state=state0)
        wall = time.time() - t0
        reg = ob.metrics if ob is not None else obs.MetricsRegistry()
        digest_snap = collect_run(reg, checkpointer=ck)
        if args.trace_out:
            ob.tracer.write(args.trace_out)
        if args.metrics_out:
            reg.write_json(args.metrics_out)
    finally:
        if session is not None:
            session.__exit__(None, None, None)

    report = {
        "arch": cfg.name, "steps": stats.steps,
        "final_loss": stats.losses[-1] if stats.losses else None,
        "throughput_it_s": round(stats.throughput, 3),
        "mean_iter_s": round(stats.mean_iter, 4),
        "checkpoints": ck.n_checkpoints,
        "stall_total_s": round(ck.stall_total, 4),
        "failures": stats.failures, "recoveries": stats.recoveries,
        "wall_s": round(wall, 2),
    }
    if shadow is not None:
        report["channel"] = ck.channel.name
        if ck.skipped_steps:
            report["gated_steps"] = ck.skipped_steps
        s = shadow.stats()
        report["shadow"] = {
            "nodes": args.shadow_nodes, "lag": s.lag,
            "mean_apply_s": round(s.mean_apply_s, 4),
            "max_queue_depth": s.max_queue_depth,
        }
        shadow.shutdown()
    print(json.dumps(report, indent=2))
    # satellite: one-screen end-of-run digest sourced from the metrics
    # registry (same numbers `python -m repro.obs summary` reports)
    print(render_digest(digest_snap, ck=ck))


if __name__ == "__main__":
    main()
