"""Optimized-HLO cost extraction for the roofline analysis.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, not times its trip
count — useless for scan-over-layers models. This module walks the optimized
HLO text instead:

  * computations are parsed into op lists,
  * ``while`` ops carry ``backend_config={"known_trip_count":{"n":...}}`` —
    body + condition costs are multiplied by it,
  * ``fusion``/``call``/``conditional`` recurse into their subcomputations
    for FLOPs; fusion byte traffic is the fusion's own operands + outputs
    (internal traffic stays in registers/VMEM),
  * ``dot`` FLOPs = 2 x prod(output shape) x prod(lhs contracting dims),
  * collective bytes = sum of operand bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (+ ``-start`` forms),
    scaled per §Roofline conventions.

Validated against exact matmul/scan cases in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0, "s2": 1, "u2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all", "collective-broadcast")


@dataclass
class OpCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: dict = field(default_factory=dict)

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        self.collective_bytes += o.collective_bytes
        for k, v in o.per_collective.items():
            self.per_collective[k] = self.per_collective.get(k, 0.0) + v
        return self

    def scaled(self, n: float) -> "OpCost":
        return OpCost(self.flops * n, self.bytes * n,
                      self.collective_bytes * n,
                      {k: v * n for k, v in self.per_collective.items()})


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _type_bytes_elems(type_str: str) -> tuple[float, float]:
    """Total (bytes, elements) for a type string (handles tuples)."""
    total_b = total_e = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1.0
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total_b += elems * _DTYPE_BYTES[dt]
        total_e += elems
    return total_b, total_e


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"([\w\-]+)\(([^)]*)\)(.*)$")

_CALL_RE = re.compile(r"(?:calls|to_apply|condition|body|true_computation|"
                      r"false_computation|branch_computations)="
                      r"(?:\{([^}]*)\}|%?([\w.\-]+))")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _split_operands(s: str) -> list[str]:
    """Split an operand list on top-level commas only (shape dims and layout
    braces contain commas: ``f32[1,32,64]{2,1,0} %name``)."""
    parts, cur, depth = [], [], 0
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def _operand_name(tok: str) -> str:
    """Operand name from either ``%name`` or ``type %name`` spellings."""
    for t in reversed(tok.split()):
        if t.startswith("%"):
            return t.lstrip("%")
    return tok.strip().lstrip("%").split(" ")[0]


@dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    operands: list
    rest: str


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[_Op]] = {}
        self.entry: str | None = None
        self._parse(text)

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            s = line.rstrip()
            stripped = s.strip()
            if (not s.startswith((" ", "\t")) and stripped.endswith("{")
                    and "->" in stripped and "=" not in stripped.split("(")[0]):
                is_entry = stripped.startswith("ENTRY")
                head = stripped[len("ENTRY"):].strip() if is_entry else stripped
                name = re.split(r"[\s(]", head.lstrip("%"), maxsplit=1)[0]
                self.computations[name] = []
                cur = name
                if is_entry:
                    self.entry = name
                continue
            if s.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _OP_RE.match(s)
            if not m:
                continue
            name, type_str, opcode, operands, rest = m.groups()
            ops = [_operand_name(o)
                   for o in _split_operands(operands) if o.strip()]
            self.computations[cur].append(
                _Op(name, type_str, opcode, ops, rest))

    # -- cost walk -----------------------------------------------------------
    def cost(self) -> OpCost:
        if self.entry is None:
            # fall back: largest computation
            self.entry = max(self.computations, key=lambda k: len(self.computations[k]))
        self._memo: dict[tuple[str, bool], OpCost] = {}
        return self._comp_cost(self.entry, top=True)

    def _comp_cost(self, comp: str, top: bool) -> OpCost:
        key = (comp, top)
        if key in self._memo:
            return self._memo[key]
        total = OpCost()
        symtab = {op.name: op for op in self.computations.get(comp, [])}
        for op in self.computations.get(comp, []):
            total += self._op_cost(op, symtab, top)
        self._memo[key] = total
        return total

    def _called(self, op: _Op) -> list[str]:
        names = []
        for m in _CALL_RE.finditer(op.rest):
            blob = m.group(1) or m.group(2) or ""
            for nm in blob.split(","):
                nm = nm.strip().lstrip("%")
                if nm in self.computations:
                    names.append(nm)
        return names

    def _op_cost(self, op: _Op, symtab: dict, top: bool) -> OpCost:
        oc = op.opcode
        out_bytes, out_elems = _type_bytes_elems(op.type_str)

        if oc == "while":
            trips = 1
            m = _TRIP_RE.search(op.rest)
            if m:
                trips = int(m.group(1))
            inner = OpCost()
            for c in self._called(op):
                inner += self._comp_cost(c, top=False)
            return inner.scaled(trips)

        if oc == "fusion":
            inner = OpCost()
            called = self._called(op)
            for c in called:
                inner += self._comp_cost(c, top=False)
            # bytes at the fusion boundary, ALIAS/SLICE-AWARE: an operand
            # consumed only through dynamic-slice reads is charged at the
            # slice bytes (XLA reads just the window); an operand that is
            # in-place dynamic-update-slice'd (same type as the output) is
            # charged at 2x the update bytes (read+write of the window) —
            # XLA's buffer assignment aliases the rest.
            in_bytes = self._fusion_operand_bytes(op, symtab, called)
            out = out_bytes
            dus_update = self._fusion_dus_update_bytes(op, called)
            if dus_update is not None:
                out = dus_update
            return OpCost(flops=inner.flops,
                          bytes=in_bytes + out,
                          collective_bytes=inner.collective_bytes,
                          per_collective=inner.per_collective)

        if oc in ("call", "conditional", "async-start"):
            inner = OpCost()
            for c in self._called(op):
                inner += self._comp_cost(c, top=False)
            inner.bytes += out_bytes
            return inner

        base = oc.replace("-start", "") if oc.endswith("-start") else oc
        if base in COLLECTIVES:
            in_bytes = self._operand_bytes(op, symtab)
            # comm bytes on the wire: use operand bytes (spec convention)
            return OpCost(bytes=in_bytes + out_bytes,
                          collective_bytes=in_bytes,
                          per_collective={base: in_bytes})

        if oc == "dot":
            in_bytes = self._operand_bytes(op, symtab)
            k = self._contracting_elems(op, symtab)
            return OpCost(flops=2.0 * out_elems * k, bytes=in_bytes + out_bytes)

        if oc == "convolution":
            in_bytes = self._operand_bytes(op, symtab)
            # rough: 2 * out_elems * prod(kernel spatial+input feature)
            kshape = self._operand_shape(op, symtab, 1)
            k = float(np.prod(kshape)) if kshape else 1.0
            return OpCost(flops=2.0 * out_elems * max(k, 1.0) /
                          max(self._out_feature(op), 1.0),
                          bytes=in_bytes + out_bytes)

        if oc in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all", "partition-id", "replica-id"):
            return OpCost()

        if oc in ("slice", "dynamic-slice"):
            # reads only the window, not the whole operand
            return OpCost(bytes=2.0 * out_bytes)

        if oc == "dynamic-update-slice":
            # in-place window write: read+write the update, alias the rest
            upd = self._operand_shape_bytes(op, symtab, 1)
            return OpCost(bytes=2.0 * upd if upd else out_bytes)

        if oc in ("copy", "copy-start", "copy-done", "transpose", "reshape",
                  "broadcast", "concatenate", "pad", "reverse", "gather",
                  "scatter", "iota", "convert", "reduce", "select", "compare",
                  "rng", "rng-bit-generator", "sort", "all-reduce-done",
                  "all-gather-done", "collective-permute-done", "custom-call",
                  "optimization-barrier"):
            in_bytes = self._operand_bytes(op, symtab)
            flops = out_elems if oc in ("reduce", "sort") else 0.0
            return OpCost(flops=flops, bytes=in_bytes + out_bytes)

        # elementwise & everything else: 1 flop/elem, boundary bytes
        in_bytes = self._operand_bytes(op, symtab)
        return OpCost(flops=out_elems, bytes=in_bytes + out_bytes)

    # -- helpers ---------------------------------------------------------------
    _PARAM_RE = re.compile(r"^param_(\d+)")

    def _fusion_param_uses(self, called: list[str]) -> dict[int, list]:
        """param index -> [(consumer opcode, consumer out bytes)]."""
        uses: dict[int, list] = {}
        for c in called:
            for op in self.computations.get(c, []):
                ob, _ = _type_bytes_elems(op.type_str)
                for o in op.operands:
                    m = self._PARAM_RE.match(o)
                    if m:
                        uses.setdefault(int(m.group(1)), []).append(
                            (op.opcode, ob))
        return uses

    def _fusion_operand_bytes(self, op: _Op, symtab: dict,
                              called: list[str]) -> float:
        uses = self._fusion_param_uses(called)
        total = 0.0
        for i, o in enumerate(op.operands):
            src = symtab.get(o)
            if src is None:
                continue
            full, _ = _type_bytes_elems(src.type_str)
            u = uses.get(i)
            if u and all(c in ("dynamic-slice", "slice") for c, _ in u):
                total += min(full, sum(b for _, b in u))
            elif u and all(c == "dynamic-update-slice" for c, _ in u):
                total += 0.0          # aliased in-place destination
            else:
                total += full
        return total

    def _fusion_dus_update_bytes(self, op: _Op, called: list[str]):
        """If the fusion's root is an in-place dynamic-update-slice of an
        operand with the fusion's own output type, charge 2x update bytes."""
        for c in called:
            ops = self.computations.get(c, [])
            if not ops:
                continue
            root = ops[-1]
            if root.opcode == "dynamic-update-slice" and \
                    root.type_str.split("{")[0] == op.type_str.split("{")[0]:
                # update operand is index 1; look it up in the inner comp
                inner_tab = {o2.name: o2 for o2 in ops}
                upd = inner_tab.get(root.operands[1]) if len(root.operands) > 1 else None
                if upd is not None:
                    b, _ = _type_bytes_elems(upd.type_str)
                    return 2.0 * b
        return None

    def _operand_shape_bytes(self, op: _Op, symtab: dict, idx: int) -> float:
        if idx >= len(op.operands):
            return 0.0
        src = symtab.get(op.operands[idx])
        if src is None:
            return 0.0
        b, _ = _type_bytes_elems(src.type_str)
        return b

    def _operand_bytes(self, op: _Op, symtab: dict) -> float:
        total = 0.0
        for o in op.operands:
            src = symtab.get(o)
            if src is not None:
                b, _ = _type_bytes_elems(src.type_str)
                total += b
        return total

    def _operand_shape(self, op: _Op, symtab: dict, idx: int):
        if idx >= len(op.operands):
            return None
        src = symtab.get(op.operands[idx])
        if src is None:
            return None
        m = _SHAPE_RE.search(src.type_str)
        if not m:
            return None
        dims = m.group(2)
        return [int(d) for d in dims.split(",")] if dims else []

    def _out_feature(self, op: _Op) -> float:
        m = _SHAPE_RE.search(op.type_str)
        if not m or not m.group(2):
            return 1.0
        return float(m.group(2).split(",")[-1])

    def _contracting_elems(self, op: _Op, symtab: dict) -> float:
        """prod of lhs contracting dim sizes for a dot."""
        lhs_shape = self._operand_shape(op, symtab, 0)
        if lhs_shape is None:
            return 1.0
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
        if not m:
            return 1.0
        k = 1.0
        for d in m.group(1).split(","):
            if d:
                k *= lhs_shape[int(d)]
        return k


def analyze_hlo_text(text: str) -> OpCost:
    return HloModule(text).cost()


def analyze_compiled(compiled) -> dict:
    """Cost summary dict for a jax.stages.Compiled (per-device numbers)."""
    cost = analyze_hlo_text(compiled.as_text())
    xla = compiled.cost_analysis() or {}
    if isinstance(xla, (list, tuple)):        # jax 0.4.x: list of one dict
        xla = xla[0] if xla else {}
    mem = compiled.memory_analysis()
    return {
        "flops_per_device": cost.flops,
        "bytes_per_device": cost.bytes,
        "collective_bytes_per_device": cost.collective_bytes,
        "per_collective": cost.per_collective,
        "xla_flops_unscaled": float(xla.get("flops", 0.0)),
        "xla_bytes_unscaled": float(xla.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
    }
