"""Batched serving driver: prefill + greedy decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="smoke", choices=["smoke", "single", "multi"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    import repro.configs as C
    from repro.dist.sharding import ShardingRules, make_smoke_mesh
    from repro.launch.mesh import make_production_mesh
    from repro.models import registry
    from repro.train.step import build_decode_step

    cfg = C.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = (make_smoke_mesh() if args.mesh == "smoke"
            else make_production_mesh(multi_pod=args.mesh == "multi"))
    rules = ShardingRules(mesh)

    rng = np.random.default_rng(args.seed)
    params = registry.init_params(jax.random.PRNGKey(args.seed), cfg, rules)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (args.batch, args.prompt_len)), jnp.int32)
    max_seq = args.prompt_len + args.gen

    extra = {}
    if cfg.family == "audio":
        extra["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.encoder_seq, cfg.d_model)),
            jnp.bfloat16) * 0.02
    if cfg.family == "vlm":
        extra["patch_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.num_patches, cfg.d_model)),
            jnp.bfloat16) * 0.02

    t0 = time.time()
    cache, logits = registry.prefill(params, cfg, rules, tokens,
                                     max_seq=max_seq, **extra)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    decode = jax.jit(build_decode_step(cfg, rules), donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    generated = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.gen - 1):
        tok, cache = decode(params, cache, tok)
        generated.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    out = np.concatenate(generated, axis=1)
    print(json.dumps({
        "arch": cfg.name, "batch": args.batch,
        "prompt_len": args.prompt_len, "generated": args.gen,
        "prefill_s": round(t_prefill, 3),
        "decode_s": round(t_decode, 3),
        "decode_tok_per_s": round(args.batch * (args.gen - 1)
                                  / max(t_decode, 1e-9), 1),
        "sample_tokens": out[0][:8].tolist(),
    }, indent=2))


if __name__ == "__main__":
    main()
