"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step), so a restarted run replays the
identical stream from any step — the property recovery tests rely on (and
what real pipelines achieve with checkpointable readers). Host sharding:
each data-parallel host materializes only its slice (here we materialize the
global batch on the single CPU host and device_put against the mesh).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import ShardingRules


@dataclass
class SyntheticStream:
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0
    step: int = 0

    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, step)."""
        rng = np.random.default_rng((self.seed << 32) ^ step)
        b, s, cfg = self.batch, self.seq, self.cfg
        if cfg.family == "vit":
            return {
                "patch_embeds": rng.standard_normal(
                    (b, cfg.num_patches, cfg.d_model)).astype(np.float32) * 0.02,
                "labels": rng.integers(0, cfg.vocab_size, (b, 1)).astype(np.int32),
            }
        toks = rng.integers(0, cfg.vocab_size, (b, s + 1)).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.family == "audio":
            out["frames"] = rng.standard_normal(
                (b, cfg.encoder_seq, cfg.d_model)).astype(np.float32) * 0.02
        if cfg.family == "vlm":
            out["patch_embeds"] = rng.standard_normal(
                (b, cfg.num_patches, cfg.d_model)).astype(np.float32) * 0.02
        return out

    def __next__(self) -> dict:
        batch = self.batch_at(self.step)
        self.step += 1
        return batch

    def __iter__(self):
        return self

    def seek(self, step: int):
        """Exact resume for recovery."""
        self.step = step
        return self


def device_batch(batch: dict, rules: ShardingRules) -> dict:
    out = {}
    for k, v in batch.items():
        logical = ("batch",) + (None,) * (v.ndim - 1)
        out[k] = jax.device_put(v, rules.sharding(*logical, dims=v.shape))
    return out
