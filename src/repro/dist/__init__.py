"""Distributed-training substrate: sharding, collectives, compression, PP.

The data-parallel gradient reduce-scatter this package expresses (via
GSPMD constraints in :mod:`repro.dist.sharding` / :mod:`repro.optim.sharded`
and explicitly in :mod:`repro.dist.collectives`) is Checkmate's capture
point: each device owns a disjoint slice of the fully-reduced gradients, so
the network already carries everything a checkpoint needs.
"""
from repro.dist import compat  # noqa: F401  (jax 0.4.x mesh API shims)
