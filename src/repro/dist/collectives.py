"""Explicit ring collectives (paper §2.2; docs/ARCHITECTURE.md "capture point").

Checkmate's capture point exists because a ring AllReduce *is* a
ReduceScatter followed by an AllGather: after the RS phase each device owns
a disjoint, fully-reduced chunk of the gradient — all information needed for
a checkpoint already sits in the network. GSPMD normally emits these
collectives implicitly from sharding constraints (repro.optim.sharded); this
module implements the ring schedule explicitly with ``shard_map`` +
``ppermute`` so tests can assert the exactly-once coverage invariant on the
actual dataflow rather than on compiler output.

Both phases run the classic n-1-step ring: at RS step ``s`` device ``i``
sends chunk ``(i - s - 1) mod n`` and accumulates into ``(i - s - 2) mod n``,
ending with device ``i`` owning fully-reduced chunk ``i``; the AG phase
circulates the owned chunks until everyone holds the full result. Per-chunk
accumulation order is a pure function of ring position, so the reduction is
bitwise deterministic across runs — the property the shadow replay relies on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map


def ring_all_reduce_rs_ag(x, mesh, axis: str):
    """Ring AllReduce decomposed as ReduceScatter -> AllGather.

    Each device contributes its local value of ``x`` (replicated input =>
    result is ``n * x``). Returns ``(all_reduced, rs_shards)``:

    * ``all_reduced`` — the full reduction, replicated (the AG output),
    * ``rs_shards``   — the same values laid out as the RS phase left them:
      a global array of ``x``'s shape sharded over ``axis``, device ``i``
      owning chunk ``i``. Concatenating the shards IS the AllReduce result —
      the exactly-once gradient coverage Checkmate captures.
    """
    n = mesh.shape[axis]
    if n == 1:
        return x, x

    flat = x.reshape(-1)
    pad = (-flat.size) % n
    padded = jnp.pad(flat, (0, pad)) if pad else flat

    def ring(v):
        idx = jax.lax.axis_index(axis)
        acc = v.reshape(n, -1)
        fwd = [(i, (i + 1) % n) for i in range(n)]

        # -- reduce-scatter: after n-1 steps device i owns reduced chunk i --
        for s in range(n - 1):
            send = jnp.take(acc, (idx - s - 1) % n, axis=0)
            recv = jax.lax.ppermute(send, axis, fwd)
            acc = acc.at[(idx - s - 2) % n].add(recv)
        owned = jnp.take(acc, idx, axis=0)

        # -- all-gather: circulate the reduced chunks around the ring -------
        for s in range(n - 1):
            send = jnp.take(acc, (idx - s) % n, axis=0)
            recv = jax.lax.ppermute(send, axis, fwd)
            acc = acc.at[(idx - s - 1) % n].set(recv)

        return acc.reshape(-1), owned

    full, shards = shard_map(
        ring, mesh=mesh,
        in_specs=P(),                    # every device holds its local copy
        out_specs=(P(), P(axis)),        # replicated result, sharded chunks
        check_rep=False,
    )(padded)

    if pad:
        full = full[:flat.size]
        shards = shards[:flat.size]
    return full.reshape(x.shape), shards.reshape(x.shape)
