"""GPipe pipeline parallelism over a ("stage", "data") mesh.

``pipeline_apply`` runs the classic fill/steady/drain schedule with
``shard_map``: stage weights live sharded over the "stage" axis, microbatch
activations move stage-to-stage with ``ppermute``. With M microbatches and S
stages the schedule takes M + S - 1 ticks, so utilization is M / (M + S - 1)
— ``gpipe_utilization`` is that closed form (the bubble the paper's §2.1
training-stack background assumes).

The schedule computes on every stage every tick (idle ticks produce garbage
that is never routed to the output), trading a few wasted FLOPs for a
branch-free SPMD program — the standard trick for static pipeline schedules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import compat
from repro.dist.compat import shard_map


def make_pp_mesh(n_stages: int, n_data: int):
    """("stage", "data") mesh over the first n_stages * n_data devices."""
    return compat.make_mesh(
        (n_stages, n_data), ("stage", "data"),
        devices=jax.devices()[:n_stages * n_data],
        axis_types=(compat.AxisType.Auto,) * 2)


def pipeline_apply(fn, stage_weights, microbatches, mesh):
    """Apply ``fn(stage_weight, x)`` through all stages, GPipe-scheduled.

    ``stage_weights``: (S, ...) — leading dim sharded over "stage".
    ``microbatches``:  (M, mb, ...) — replicated; stage 0 feeds microbatch
    ``t`` at tick ``t``, the last stage emits microbatch ``t - S + 1``.
    Returns the (M, mb, ...) outputs, replicated (equal to applying the
    stages sequentially).
    """
    S = mesh.shape["stage"]
    M = microbatches.shape[0]

    def run(ws, xs):
        w = ws[0]                                 # this stage's weights
        stage = jax.lax.axis_index("stage")
        fwd = [(i, i + 1) for i in range(S - 1)]
        recv = jnp.zeros(xs.shape[1:], xs.dtype)
        outs = jnp.zeros_like(xs)
        for t in range(M + S - 1):
            # stage 0 injects fresh microbatches; later stages consume what
            # the previous stage produced last tick.
            inp = jnp.where(stage == 0, xs[min(t, M - 1)], recv)
            out = fn(w, inp)
            if t >= S - 1:
                outs = outs.at[t - S + 1].set(out)
            if S > 1:
                recv = jax.lax.ppermute(out, "stage", fwd)
        # only the last stage's collected outputs are the real results
        outs = jnp.where(stage == S - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, "stage")

    return shard_map(
        run, mesh=mesh,
        in_specs=(P("stage"), P()),
        out_specs=P(),
        check_rep=False,
    )(stage_weights, microbatches)


def gpipe_utilization(n_micro: int, n_stages: int) -> float:
    """Fraction of stage-ticks doing useful work: M / (M + S - 1)."""
    return n_micro / (n_micro + n_stages - 1)
