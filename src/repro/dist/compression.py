"""int8 gradient compression with error feedback (EF-SGD style).

Reproduces the low-cost differential/compressed-stream direction (arXiv
2509.04084) on top of Checkmate: the multicast payload shrinks ~4x while the
shadow replay stays bit-identical to training, because BOTH sides consume
the same dequantized gradients (tests/test_compression_shadow.py).

Per-leaf scheme:

* add the carried error-feedback residual to the raw gradient,
* symmetric linear quantization to int8 with a per-leaf f32 scale
  (``scale = max|g + ef| / 127``), so per-element error <= scale/2,
* the new residual is exactly the quantization error — repeated
  quantization of a constant gradient averages to the true value
  (the EF convergence property).

Wire format per leaf: the int8 payload + one f32 scale.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

_QMAX = 127.0


def quantize_leaf(g, ef):
    """Quantize one gradient leaf with error feedback.

    Returns ``(q, scale, new_ef)``: int8 payload, f32 scalar scale, and the
    residual to carry into the next iteration
    (``dequantize_leaf(q, scale) + new_ef == g + ef`` exactly in f32).
    """
    g = jnp.asarray(g, jnp.float32)
    target = g + jnp.asarray(ef, jnp.float32)
    scale = jnp.max(jnp.abs(target)) / _QMAX
    safe = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(target / safe), -_QMAX, _QMAX).astype(jnp.int8)
    deq = q.astype(jnp.float32) * safe
    return q, safe, target - deq


def dequantize_leaf(q, scale):
    return q.astype(jnp.float32) * scale


def quantize_flat_stateless(bucket, flat):
    """Stateless (no-error-feedback) int8 quantization of one wire-layout
    flat buffer (`repro.core.buckets`).

    Per-slot symmetric quantization with the SAME rounding semantics as
    the EF path (`quantize_leaf` with a zero residual), but no residual
    is produced or carried: callers that quantize out-of-band copies —
    `repro.durability`'s delta flush — must never perturb a channel
    `Compressor`'s EF state, or the shadow/trainer bit-identity the EF
    invariant proves would silently drift. Returns ``(q, scales)``:
    int8 payload the length of the bucket and one f32 scale per slot.
    """
    src = np.asarray(flat, dtype=np.float32)
    q = np.empty(bucket.size, np.int8)
    scales = np.empty(len(bucket.slots), np.float32)
    for i, s in enumerate(bucket.slots):
        sl = slice(s.offset, s.offset + s.size)
        qi, safe, _ = quantize_leaf(src[sl], 0.0)
        q[sl] = np.asarray(qi)
        scales[i] = float(safe)
    return q, scales


def dequantize_flat_stateless(bucket, q, scales):
    """Inverse of `quantize_flat_stateless`: f32 flat buffer."""
    out = np.empty(bucket.size, np.float32)
    for i, s in enumerate(bucket.slots):
        sl = slice(s.offset, s.offset + s.size)
        out[sl] = np.asarray(dequantize_leaf(
            jnp.asarray(q[sl]), jnp.float32(scales[i])))
    return out


def init_error_feedback(tree):
    """Zero residuals matching the gradient tree."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)


def compress_tree(tree, ef):
    """Quantize a gradient tree; returns ``(deq, new_ef, wire_bytes)``.

    ``deq`` is what training applies AND what the shadow receives — running
    the optimizer on the dequantized gradients on both sides is what keeps
    the replica bit-identical under lossy compression. ``wire_bytes`` is the
    multicast payload size (int8 payload + one f32 scale per leaf).
    """
    leaves, treedef = jax.tree.flatten(tree)
    ef_leaves = treedef.flatten_up_to(ef)
    deq, residuals, wire = [], [], 0
    for g, e in zip(leaves, ef_leaves):
        q, scale, r = quantize_leaf(g, e)
        deq.append(dequantize_leaf(q, scale))
        residuals.append(r)
        wire += q.size * 1 + 4
    return (jax.tree.unflatten(treedef, deq),
            jax.tree.unflatten(treedef, residuals), wire)


class Compressor:
    """Stateful int8+EF compressor for a gradient stream.

    Owns the error-feedback residuals across calls, so a gradient channel
    (`repro.core.channel.CompressedChannel`) can compress successive
    iterations without threading ``ef`` through its callers. Residuals are
    keyed lazily off the first tree's structure.
    """

    def __init__(self):
        self._ef = None
        self._ef_flat = None           # bucket_id -> flat f32 residual buffer
        self._layout = None
        self.wire_bytes_total = 0
        self.raw_bytes_total = 0

    def compress(self, tree):
        """Quantize one iteration's gradients; returns the dequantized tree
        (what the wire delivers) and accumulates wire/raw byte totals."""
        assert self._ef_flat is None, \
            "this Compressor already carries flat (wire-layout) residuals"
        if self._ef is None:
            self._ef = init_error_feedback(tree)
        deq, self._ef, wire = compress_tree(tree, self._ef)
        self.wire_bytes_total += wire
        self.raw_bytes_total += sum(
            leaf.size * jnp.dtype(leaf.dtype).itemsize
            for leaf in jax.tree.leaves(tree))
        return deq

    def compress_flats(self, layout, flats):
        """Quantize one iteration already in wire layout (bucket_id -> flat
        buffer, `repro.core.buckets`); returns the dequantized flat buffers.

        One pass over the bucket bytes: each leaf's contiguous slice is
        quantized in place of tree churn, with the SAME per-leaf scale (and
        therefore bit-identical dequantized values and residuals) as the
        leaf-tree `compress` path — quantization is element-wise and the
        scale is a per-leaf max, which the slice preserves. Residuals are
        carried as per-bucket flat f32 buffers in the same layout.
        """
        assert self._ef is None, \
            "this Compressor already carries leaf-tree residuals"
        if self._ef_flat is None:
            self._layout = layout
            self._ef_flat = {b.bucket_id: np.zeros(b.size, np.float32)
                             for b in layout.buckets}
        from repro.core.buckets import alloc_flat
        deq, wire, raw = {}, 0, 0
        for b in layout.buckets:
            src = np.asarray(flats[b.bucket_id])
            out = alloc_flat(b.size, np.float32)
            ef = self._ef_flat[b.bucket_id]
            for s in b.slots:
                sl = slice(s.offset, s.offset + s.size)
                q, scale, r = quantize_leaf(src[sl], ef[sl])
                out[sl] = np.asarray(dequantize_leaf(q, scale))
                ef[sl] = np.asarray(r)
                wire += s.size + 4
            raw += src.nbytes
            deq[b.bucket_id] = out
        self.wire_bytes_total += wire
        self.raw_bytes_total += raw
        return deq

    # Stateless no-EF codec entry points: same rounding, NO residual
    # read/write — safe for out-of-band consumers (durability flush)
    # while this instance carries a live channel's EF state.
    quantize_flat_stateless = staticmethod(quantize_flat_stateless)
    dequantize_flat_stateless = staticmethod(dequantize_flat_stateless)

    @property
    def ef(self):
        """Current error-feedback residual tree (None before first call) —
        exactly the gradient mass not yet delivered to the stream. When the
        compressor runs the flat (wire-layout) path, this is a zero-copy
        leaf view over the per-bucket residual buffers."""
        if self._ef_flat is not None:
            from repro.core.buckets import FlatTreeView
            return FlatTreeView(self._layout, self._ef_flat)
        return self._ef

    @property
    def ratio(self) -> float:
        return (self.raw_bytes_total / self.wire_bytes_total
                if self.wire_bytes_total else 0.0)


def compression_ratio(tree) -> float:
    """Uncompressed bytes / wire bytes for a gradient tree (~4x for f32)."""
    leaves = jax.tree.leaves(tree)
    raw = sum(leaf.size * jnp.dtype(leaf.dtype).itemsize for leaf in leaves)
    wire = sum(leaf.size * 1 + 4 for leaf in leaves)
    return raw / wire
