"""jax version shims.

The repo targets the jax>=0.5 mesh API (``jax.make_mesh(..., axis_types=...)``
with ``jax.sharding.AxisType``); some deployment containers pin jax 0.4.x,
where meshes have no axis types (every axis behaves like ``Auto`` under
GSPMD, which is exactly how this codebase uses them). Importing this module
installs forward-compatible aliases so the same call sites run on both:

* ``jax.sharding.AxisType`` — a placeholder enum when missing,
* ``jax.make_mesh`` — wrapped to accept and drop ``axis_types`` when the
  installed signature lacks it.

On jax>=0.5 both shims are no-ops. ``repro.dist`` imports this at package
import, so any code that imports the distributed substrate gets the
compatible API.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax

if not hasattr(jax.sharding, "AxisType"):
    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
    _orig_make_mesh = jax.make_mesh

    @functools.wraps(_orig_make_mesh)
    def _make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        del axis_types                  # jax 0.4.x: all axes are GSPMD-auto
        return _orig_make_mesh(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = _make_mesh


try:                                    # moved out of experimental in jax 0.6
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map

make_mesh = jax.make_mesh
AxisType = jax.sharding.AxisType
