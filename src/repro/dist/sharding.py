"""Logical-axis sharding rules (GSPMD).

Model code never names mesh axes. Parameter specs and activation constraints
use *logical* axis names ("batch", "heads", "act_ff", ...) and this module
resolves them against the physical mesh:

=================  ==========================  ============================
logical axes       physical axes               used by
=================  ==========================  ============================
batch              data axes (pod, data)       activations / inputs
vocab, heads,      model                       tensor-parallel weight dims
kv_heads, ff,
ssm_inner, expert
act_heads, act_ff,  model                      tensor-parallel activations
act_vocab,
act_expert, kv_seq
wemb               fsdp ? data axes : none     the d_model weight dim
everything else    none (replicated)           norms, layers, seq, emb, ...
=================  ==========================  ============================

``fsdp=True`` flips the ``wemb`` weight dim to dp-sharded, which turns every
weight use into an all-gather (ZeRO-3 style) while keeping the same logical
specs — the elastic tests restore one layout onto the other.

A logical dim only shards when its size divides the mapped axes' extent
(GSPMD requires even chunks); otherwise it falls back to replicated, which
is what lets the same model code run on the 1-device smoke mesh and the
16x16 production mesh.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import compat

# Logical names that map to the tensor-parallel ("model") axis. Weight dims
# and activation dims are listed separately only for documentation — they
# resolve identically.
_MODEL_AXES = frozenset({
    "vocab", "heads", "kv_heads", "ff", "ssm_inner", "expert",       # weights
    "act_vocab", "act_heads", "act_ff", "act_expert", "kv_seq",      # acts
})

# Logical names that map to the data-parallel axes.
_DATA_AXES = frozenset({"batch"})

# Weight dims that become dp-sharded under FSDP (replicated otherwise).
_FSDP_AXES = frozenset({"wemb"})

# Mesh axes that are NOT data-parallel (everything else contributes to DP).
_NON_DP_MESH_AXES = ("model", "stage")


def dp_axes(mesh) -> tuple[str, ...]:
    """The mesh axes gradients are reduced over (in mesh order)."""
    return tuple(a for a in mesh.axis_names if a not in _NON_DP_MESH_AXES)


def dp_size(mesh) -> int:
    """Total data-parallel extent (the gradient-averaging world size)."""
    return math.prod(mesh.shape[a] for a in dp_axes(mesh))


class ShardingRules:
    """Resolve logical axis names to NamedShardings on a concrete mesh."""

    def __init__(self, mesh, fsdp: bool = False):
        self.mesh = mesh
        self.fsdp = fsdp

    # -- resolution ----------------------------------------------------------
    def physical_axes(self, logical) -> tuple[str, ...]:
        """Mesh axes a logical name maps to (may be empty)."""
        if logical in _DATA_AXES:
            return dp_axes(self.mesh)
        if logical in _MODEL_AXES and "model" in self.mesh.axis_names:
            return ("model",)
        if logical in _FSDP_AXES and self.fsdp:
            return dp_axes(self.mesh)
        return ()

    def axis_size(self, logical) -> int:
        """Extent of the mesh axes behind a logical name (1 if unmapped)."""
        return math.prod(
            (self.mesh.shape[a] for a in self.physical_axes(logical)), start=1)

    def spec(self, *logical, dims=None) -> P:
        """PartitionSpec for one array's logical axes.

        ``dims`` (the array shape) enables the divisibility fallback and the
        one-physical-axis-per-spec guarantee GSPMD requires.
        """
        parts: list = []
        used: set[str] = set()
        for i, name in enumerate(logical):
            axes = self.physical_axes(name) if name is not None else ()
            if any(a in used for a in axes):
                axes = ()               # a physical axis may appear only once
            if axes and dims is not None:
                extent = math.prod(self.mesh.shape[a] for a in axes)
                if dims[i] % extent:
                    axes = ()           # uneven chunks: replicate this dim
            if axes:
                used.update(axes)
                parts.append(axes if len(axes) > 1 else axes[0])
            else:
                parts.append(None)
        while parts and parts[-1] is None:
            parts.pop()                 # trailing Nones are implicit
        return P(*parts)

    def sharding(self, *logical, dims=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical, dims=dims))

    def shard(self, x, *logical):
        """with_sharding_constraint against the resolved logical sharding."""
        return jax.lax.with_sharding_constraint(
            x, self.sharding(*logical, dims=x.shape))


def make_smoke_mesh():
    """Single-host ("data", "model") mesh that works on 1 CPU device.

    Smoke tests run the full GSPMD code path (constraints, logical
    resolution, ZeRO-1 specs) with every axis extent 1, so the lowered
    program is collective-free but structurally identical to a pod run.
    """
    return compat.make_mesh(
        (1, 1), ("data", "model"), devices=jax.devices()[:1],
        axis_types=(compat.AxisType.Auto,) * 2)
