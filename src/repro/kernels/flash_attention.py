"""Flash attention (online softmax) Pallas kernel — the training-side
compute hot spot.

Grid: (batch*heads, q_blocks); the kernel loops kv blocks with running
(max, sum, acc) f32 scratch in VMEM, never materializing the (s, s) score
matrix. Causal masking prunes fully-masked kv blocks via the loop bound
(exact-flops causality, unlike the masked-dense jnp path). GQA is handled
by the wrapper (kv heads expanded view, zero-copy broadcast on TPU).

Block sizes default to (512, 512): at head_dim 128 / bf16 that is
q 128 KB + k/v tiles 128 KB each + f32 acc 256 KB — well inside VMEM, and
all matmul dims are multiples of the 128x128 MXU tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_blocks, block_q, block_k,
                  causal, sm_scale):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * sm_scale          # (block_q, d)

    def body(j, carry):
        acc, m_i, l_i = carry
        k = k_ref[0, pl.ds(j * block_k, block_k)].astype(jnp.float32)
        v = v_ref[0, pl.ds(j * block_k, block_k)].astype(jnp.float32)
        s = q @ k.T                                      # (block_q, block_k)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_i - m_new)
        l_new = l_i * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, q.shape[-1]), jnp.float32)
    m0 = jnp.full((block_q, 1), NEG, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    if causal:
        # kv blocks at or before this q block's diagonal
        upper = jnp.minimum(kv_blocks, (qi * block_q) // block_k + block_q // block_k + 1)
    else:
        upper = kv_blocks
    acc, m_i, l_i = jax.lax.fori_loop(0, upper, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l_i, 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool = True):
    """q, k, v: (b, s, h, d) with kv already expanded to h heads."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)
    sm_scale = 1.0 / (d ** 0.5)

    # (b*h, s, d) layout: one (batch, head) pair per grid row
    qh = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, d)
    kh = jnp.moveaxis(k, 2, 1).reshape(b * h, skv, d)
    vh = jnp.moveaxis(v, 2, 1).reshape(b * h, skv, d)

    grid = (b * h, sq // block_q)
    kernel = functools.partial(
        _flash_kernel, kv_blocks=skv // block_k, block_q=block_q,
        block_k=block_k, causal=causal, sm_scale=sm_scale)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, skv, d), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((1, skv, d), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        interpret=interpret,
    )(qh, kh, vh)
    return jnp.moveaxis(out.reshape(b, h, sq, d), 1, 2)
