"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_ref(p, g, m, v, step, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.1):
    """Reference fused AdamW update. All f32; returns (p', m', v')."""
    g = g.astype(jnp.float32)
    p32 = p.astype(jnp.float32)
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * g * g
    bc1 = 1.0 - b1 ** step
    bc2 = 1.0 - b2 ** step
    upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + wd * p32
    return (p32 - lr * upd).astype(p.dtype), m, v


def flash_attention_ref(q, k, v, *, causal=True):
    """Naive softmax attention. q,k,v: (b, s, h, d) with kv already expanded."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def bucket_pack_ref(leaves: list, total: int):
    """Concatenate raveled leaves into one flat f32 buffer of size total."""
    flat = jnp.concatenate([jnp.ravel(x) for x in leaves])
    assert flat.size == total
    return flat


def bucket_unpack_ref(flat, shapes: list):
    out, off = [], 0
    for shp in shapes:
        n = 1
        for s in shp:
            n *= s
        out.append(flat[off:off + n].reshape(shp))
        off += n
    return out
