"""Bucket pack/unpack Pallas kernel — the gradient-bucket <-> leaf copy.

The TPU analogue of the paper's AVX-512 streaming-memcpy optimization (§5,
8x over Rust memcpy): bucket assembly is pure data movement, so the kernel's
job is to keep it at HBM streaming bandwidth with (rows, 128)-tiled copies
through VMEM and no intermediate materialization.

Leaves are staged as one concatenated source (the XLA concatenate feeding
the kernel fuses away on TPU); the kernel is a tiled identity copy whose
value is (a) explicit VMEM tiling and (b) serving as the DMA skeleton that a
multi-buffer (double-buffered) emitter would use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 2048


def _copy_kernel(src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def packed_copy(flat, block_rows: int = BLOCK_ROWS, interpret: bool = True):
    """Tiled streaming copy of a flat buffer (multiple of 128 elements)."""
    n = flat.size
    rows = n // LANES
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0, (rows, block_rows)
    src = flat.reshape(rows, LANES)
    out = pl.pallas_call(
        _copy_kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), flat.dtype),
        interpret=interpret,
    )(src)
    return out.reshape(n)


def pack_leaves(leaves, total: int, interpret: bool = True):
    """Pack raveled leaves into one flat bucket buffer via the copy kernel.

    ``total`` must be the padded size (multiple of 128*block size handled by
    ops.pack_bucket_kernel).
    """
    flat = jnp.concatenate([jnp.ravel(x) for x in leaves])
    pad = total - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return packed_copy(flat, interpret=interpret)
