"""Fused AdamW Pallas kernel — the shadow/optimizer hot loop on TPU.

AdamW is deeply memory-bound: ~15 flops against 28 B/param moved
(read p,m,v,g = 16 B; write p,m,v = 12 B at f32). Unfused jnp materializes
the m/v intermediates and roughly doubles HBM traffic; this kernel performs
the whole read-modify-write in ONE pass through VMEM tiles.

The parameter tree is flattened to a 1-D buffer (bucket layout — see
repro.core.buckets), viewed as (rows, 128) lanes, and the grid walks row
blocks of 1024 x 128 (2 MB/operand tiles in f32: p,m,v,g in + p,m,v out
= ~14 MB VMEM working set, inside the ~16 MB v5e VMEM budget).

This mirrors the paper's shadow-node optimization story (§5: AVX-512
streaming memcpy, 8x) translated to the TPU memory hierarchy: the win is
touching HBM exactly once per state element.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_BLOCK_ROWS = 1024


def _adamw_kernel(p_ref, g_ref, m_ref, v_ref, step_ref, hyp_ref,
                  po_ref, mo_ref, vo_ref):
    """One (block_rows, 128) tile: fully element-wise in VMEM."""
    lr = hyp_ref[0]
    b1 = hyp_ref[1]
    b2 = hyp_ref[2]
    eps = hyp_ref[3]
    wd = hyp_ref[4]
    step = step_ref[0]

    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...]
    v = v_ref[...]

    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    bc1 = 1.0 - b1 ** step
    bc2 = 1.0 - b2 ** step
    upd = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps) + wd * p
    po_ref[...] = (p - lr * upd).astype(po_ref.dtype)
    mo_ref[...] = m_new
    vo_ref[...] = v_new


def fused_adamw_flat(p, g, m, v, step, lr, b1=0.9, b2=0.95, eps=1e-8,
                     wd=0.1, block_rows: int = DEFAULT_BLOCK_ROWS,
                     interpret: bool = True):
    """p,g,m,v: flat f32 arrays whose size is a multiple of 128*block_rows
    after padding (handled by ops.fused_adamw)."""
    n = p.size
    rows = n // LANES
    block_rows = min(block_rows, rows)
    grid = (rows // block_rows,)

    shape2d = (rows, LANES)
    p2, g2 = p.reshape(shape2d), g.reshape(shape2d)
    m2, v2 = m.reshape(shape2d), v.reshape(shape2d)
    hyp = jnp.array([lr, b1, b2, eps, wd], jnp.float32)
    step_arr = jnp.asarray(step, jnp.float32).reshape(1)

    tile = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    hspec = pl.BlockSpec((5,), lambda i: (0,))

    po, mo, vo = pl.pallas_call(
        _adamw_kernel,
        grid=grid,
        in_specs=[tile, tile, tile, tile, scalar, hspec],
        out_specs=[tile, tile, tile],
        out_shape=[
            jax.ShapeDtypeStruct(shape2d, p.dtype),
            jax.ShapeDtypeStruct(shape2d, jnp.float32),
            jax.ShapeDtypeStruct(shape2d, jnp.float32),
        ],
        interpret=interpret,
    )(p2, g2, m2, v2, step_arr, hyp)
    return po.reshape(n), mo.reshape(n), vo.reshape(n)
