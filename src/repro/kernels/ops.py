"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels run in interpret mode — the kernel body
executes in Python per grid cell, which is what the correctness sweeps
exercise. On TPU, ``interpret=False`` compiles to Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import bucket_pack as _bp
from repro.kernels import flash_attention as _fa
from repro.kernels import fused_adamw as _fw
from repro.kernels import ref as _ref

_ON_TPU = jax.default_backend() == "tpu"
INTERPRET = not _ON_TPU

LANES = 128


def _pad_to(x, mult):
    pad = (-x.size) % mult
    if pad:
        x = jnp.concatenate([jnp.ravel(x), jnp.zeros((pad,), x.dtype)])
    return jnp.ravel(x), pad


@partial(jax.jit, static_argnames=("b1", "b2", "eps", "wd", "block_rows"))
def fused_adamw(p, g, m, v, step, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.1,
                block_rows=256):
    """Fused AdamW on arbitrary-shaped leaves (flattened + padded)."""
    shape = p.shape
    n = p.size
    mult = LANES * block_rows
    pf, _ = _pad_to(p, mult)
    gf, _ = _pad_to(g, mult)
    mf, _ = _pad_to(m, mult)
    vf, _ = _pad_to(v, mult)
    po, mo, vo = _fw.fused_adamw_flat(pf, gf, mf, vf, step, lr, b1, b2, eps,
                                      wd, block_rows=block_rows,
                                      interpret=INTERPRET)
    return (po[:n].reshape(shape), mo[:n].reshape(shape),
            vo[:n].reshape(shape))


@partial(jax.jit, static_argnames=("b1", "b2", "eps", "wd", "block_rows"))
def fused_adamw_flat(p, g, m, v, step, lr, scale=1.0, b1=0.9, b2=0.95,
                     eps=1e-8, wd=0.1, block_rows=1024):
    """Fused AdamW over one contiguous flat bucket buffer — the shadow hot
    loop (`repro.core.shadow`), one pass per state element.

    On TPU this lowers to the Mosaic kernel (`fused_adamw.fused_adamw_flat`,
    2 MB/operand VMEM tiles). On CPU, Pallas interpret mode executes the
    kernel body in Python per grid cell — orders of magnitude too slow for
    the hot loop — so the fallback is the pure-jnp oracle (`ref.adamw_ref`),
    which XLA fuses into a single elementwise pass over the buffer; the
    interpret-mode kernel stays the correctness oracle in
    tests/test_kernels.py. ``scale`` (the global-norm clip factor computed
    on the training side) is folded into the same pass.
    """
    gs = g.astype(jnp.float32) * scale
    if INTERPRET:
        return _ref.adamw_ref(p, gs, m, v, step, lr, b1=b1, b2=b2, eps=eps,
                              wd=wd)
    n = p.size
    mult = LANES * block_rows
    pf, _ = _pad_to(p, mult)
    gf, _ = _pad_to(gs, mult)
    mf, _ = _pad_to(m, mult)
    vf, _ = _pad_to(v, mult)
    po, mo, vo = _fw.fused_adamw_flat(pf, gf, mf, vf, step, lr, b1, b2, eps,
                                      wd, block_rows=block_rows,
                                      interpret=False)
    return po[:n], mo[:n], vo[:n]


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, causal=True, block_q=512, block_k=512):
    """(b, s, h, d) attention; kv heads must already be expanded to h."""
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=INTERPRET)


@jax.jit
def packed_copy(flat):
    n = flat.size
    mult = LANES
    f, pad = _pad_to(flat, mult)
    rows = f.size // LANES
    # choose the largest block that divides rows
    block = rows
    for cand in (4096, 2048, 1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if rows % cand == 0:
            block = cand
            break
    out = _bp.packed_copy(f, block_rows=block, interpret=INTERPRET)
    return out[:n]
