"""Training loop with Checkmate integration, failure injection, recovery,
and straggler observability.

The loop is the paper's Listing 1 with the Checkmate hook: the train step
already returns the reduce-scattered gradients (the multicast payload), the
loop wraps each iteration in a `repro.core.channel.StepEvent`, and the
checkpointer's ``on_step(event)`` pushes it into a `GradientChannel` toward
the shadow plane — the channel packs the capture into bucket wire layout
once, and the shadow applies the flat buffers with one fused optimizer pass
per bucket (docs/channels.md). Baseline checkpointers ignore grads and do
copy-persist on the *state* instead, which is what stalls them.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs as _obs
from repro.configs.base import ModelConfig
from repro.core.buckets import layout_for_tree
from repro.core.channel import GradientChannel, StepEvent
from repro.core.checkpoint import (BaseCheckpointer, CheckmateCheckpointer,
                                   NoCheckpointer)
from repro.core.recovery import (FailurePlan, checkpoint_from_state,
                                 state_from_checkpoint)
from repro.core.shadow import ShadowCluster
from repro.data.synthetic import SyntheticStream, device_batch
from repro.dist.sharding import ShardingRules
from repro.optim import OptimizerConfig, TrainState
from repro.train.step import build_train_step, make_train_state


class TrainingFailure(RuntimeError):
    pass


@dataclass
class LoopStats:
    steps: int = 0
    losses: list = field(default_factory=list)
    iter_times: list = field(default_factory=list)
    stall_times: list = field(default_factory=list)
    failures: int = 0
    recoveries: int = 0
    recovered_at: list = field(default_factory=list)
    straggler_flags: list = field(default_factory=list)
    checkpointer: Optional[BaseCheckpointer] = None

    @property
    def throughput(self) -> float:
        total = sum(self.iter_times) + sum(self.stall_times)
        return self.steps / total if total else 0.0

    @property
    def mean_iter(self) -> float:
        return float(np.mean(self.iter_times)) if self.iter_times else 0.0

    @property
    def steady_iter(self) -> float:
        """Median iteration time excluding the first (compile-heavy) step."""
        xs = self.iter_times[1:] if len(self.iter_times) > 1 else self.iter_times
        return float(np.median(xs)) if xs else 0.0


def train(cfg: ModelConfig, rules: ShardingRules, *,
          steps: int,
          batch: int,
          seq: int,
          opt: OptimizerConfig = OptimizerConfig(),
          lr_fn: Callable = lambda s: 1e-3,
          checkpointer: Optional[BaseCheckpointer] = None,
          channel: Optional[GradientChannel] = None,
          shadow_nodes: int = 2,
          failure_plan: Optional[FailurePlan] = None,
          seed: int = 0,
          straggler_ema: float = 0.9,
          straggler_factor: float = 2.0,
          state: Optional[TrainState] = None,
          step_hook: Optional[Callable] = None,
          elastic_rules=None) -> tuple[TrainState, LoopStats]:
    """Run ``steps`` iterations; on injected failure, restore from the
    checkpointer (Checkmate: shadow consolidation) and continue.

    ``channel`` is the one-argument spelling of the full paper dataflow:
    ``train(..., channel=PacketizedChannel(topology="rail-optimized"))``
    builds a bootstrapped `ShadowCluster` (``shadow_nodes`` CPU nodes) and a
    `CheckmateCheckpointer` wired through that channel. The built
    checkpointer is exposed as ``stats.checkpointer`` (its ``.shadow`` holds
    the cluster). Mutually exclusive with ``checkpointer``.

    ``step_hook(step, state, stats)`` is called after every completed
    iteration (post checkpointer accounting; replayed iterations after a
    recovery call it again with the replayed step number) — the observation
    point `repro.harness` evaluates its per-step invariants from.

    ``elastic_rules`` is the elastic-restart path (`repro.core.elastic`):
    a `ShardingRules` for the post-failure mesh, or a callable
    ``(failed_step) -> Optional[ShardingRules]`` (None = keep the current
    layout). On recovery the loop re-partitions the restored checkpoint
    onto those rules, recompiles the train step for the new mesh, rebuilds
    the shadow plane + channel against the re-derived bucket layout
    (`CheckmateCheckpointer.reconfigure`, booked as the
    ``elastic-reshard`` stall stage), and resumes. The data stream needs
    no rebuild: ``SyntheticStream.batch_at`` materializes the GLOBAL
    batch and ``device_batch`` re-splits it per the new rules, so global
    batch order is preserved across the shrink by construction.
    """
    mesh = rules.mesh
    failure_plan = failure_plan or FailurePlan()
    stream = SyntheticStream(cfg, batch, seq, seed=seed)
    if state is None:
        state = make_train_state(jax.random.PRNGKey(seed), cfg, rules)
    if channel is not None:
        if checkpointer is not None:
            raise ValueError("pass either checkpointer= or channel=, not both")
        shadow = ShadowCluster(layout_for_tree(state.params), opt,
                               n_nodes=shadow_nodes)
        shadow.bootstrap(state.params, state.mu, state.nu, int(state.step))
        checkpointer = CheckmateCheckpointer(shadow, channel=channel)
    checkpointer = checkpointer or NoCheckpointer()

    step_fn = jax.jit(build_train_step(cfg, mesh, rules, opt, lr_fn),
                      donate_argnums=(0,))
    stats = LoopStats(checkpointer=checkpointer)
    ema_iter = None
    step = int(state.step)

    ob = _obs.get()
    while step < steps:
        batch_np = stream.batch_at(step)
        dbatch = device_batch(batch_np, rules)
        t0 = time.perf_counter()
        try:
            if failure_plan.should_fail(step + 1):
                # fail mid-iteration: device state for this step is lost
                stats.failures += 1
                raise TrainingFailure(f"injected failure at step {step + 1}")
            with ob.tracer.span("step.compute", args={"step": step + 1}):
                state, metrics, grads = step_fn(state, dbatch)
                jax.block_until_ready(metrics["loss"])
        except TrainingFailure:
            with ob.tracer.span("recovery.restore", track="recovery",
                                args={"failed_step": step + 1}):
                restored = checkpointer.restore()
            if restored is None:
                raise
            nr = (elastic_rules(step + 1) if callable(elastic_rules)
                  else elastic_rules)
            if nr is not None and nr is not rules:
                # elastic restart: land the consolidated checkpoint on the
                # reconfigured mesh and rebuild everything the old layout
                # derived (step function, bucket layout, shadow plane,
                # channel geometry)
                rules, mesh = nr, nr.mesh
                step_fn = jax.jit(
                    build_train_step(cfg, mesh, rules, opt, lr_fn),
                    donate_argnums=(0,))
                if isinstance(checkpointer, CheckmateCheckpointer):
                    from repro.core.elastic import rebuild_shadow
                    checkpointer.reconfigure(
                        rebuild_shadow(checkpointer.shadow, restored))
                elastic_rules = None       # the switch fires once
            state = state_from_checkpoint(restored, cfg, rules)
            step = int(restored["step"])
            stats.recoveries += 1
            stats.recovered_at.append(step)
            ob.tracer.instant("recovery.resume", track="recovery",
                              args={"resumed_step": step})
            ob.metrics.counter("train_recoveries_total",
                               "Recoveries from injected failures").inc(1)
            continue
        iter_time = time.perf_counter() - t0
        step += 1
        stats.steps += 1
        stats.iter_times.append(iter_time)
        stats.losses.append(float(metrics["loss"]))

        # straggler observability: EMA-based slow-iteration flag
        if ema_iter is None:
            ema_iter = iter_time
        else:
            if iter_time > straggler_factor * ema_iter:
                stats.straggler_flags.append(step)
            ema_iter = straggler_ema * ema_iter + (1 - straggler_ema) * iter_time

        lr = float(metrics["lr"])
        scale = 1.0
        if opt.grad_clip:
            gn = float(metrics["grad_norm"])
            scale = min(1.0, opt.grad_clip / (gn + 1e-9))
        host_grads = None
        if isinstance(grads, dict) and getattr(checkpointer,
                                               "consumes_grads", False):
            # the capture's device->host DMA; the channel packs these host
            # leaves straight into the wire buffer (one further pass).
            # Copy-persist baselines never read grads, so they don't pay it.
            with ob.tracer.span("capture.d2h", args={"step": step}):
                host_grads = {k: np.asarray(v) for k, v in grads.items()}
        stall = checkpointer.on_step(StepEvent(
            step=step, grads=host_grads, lr=lr, grad_scale=scale,
            iter_time=iter_time,
            state_fn=lambda: checkpoint_from_state(state)))
        stats.stall_times.append(stall)
        ob.metrics.counter("train_steps_total", "Completed iterations").inc(1)
        if step_hook is not None:
            step_hook(step, state, stats)

    checkpointer.finalize()
    return state, stats
