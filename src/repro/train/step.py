"""Train / prefill / decode step builders.

``build_train_step`` produces the jit-able step with:
  * microbatched gradient accumulation (lax.scan),
  * ZeRO-1 gradient reduce-scatter + sharded optimizer update + param
    all-gather (GSPMD, via sharding constraints),
  * the reduce-scattered gradient tree returned as an output — Checkmate's
    exactly-once capture point (each device owns a disjoint grad slice).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro import obs as _obs
from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import ShardingRules
from repro.models import registry
from repro.optim import OptimizerConfig, TrainState, apply_updates, init_state
from repro.optim.functional import global_norm
from repro.optim.sharded import zero1_shardings


def state_shardings(cfg: ModelConfig, rules: ShardingRules):
    """(params, mu, nu, step) shardings; mu/nu are ZeRO-1 sharded."""
    aspecs = registry.abstract_params(cfg, rules)
    pshard = jax.tree.map(lambda a: a.sharding, aspecs)
    zshard = (zero1_shardings(aspecs, rules.mesh) if cfg.zero1 else pshard)
    return TrainState(params=pshard, mu=zshard, nu=zshard,
                      step=NamedSharding(rules.mesh, jax.sharding.PartitionSpec()))


def build_train_step(cfg: ModelConfig, mesh, rules: ShardingRules,
                     opt: OptimizerConfig, lr_fn: Callable,
                     return_grads: bool = True):
    """Returns train_step(state, batch) -> (state, metrics[, grads])."""
    aspecs = registry.abstract_params(cfg, rules)
    pshard = jax.tree.map(lambda a: a.sharding, aspecs)
    zshard = (zero1_shardings(aspecs, mesh) if cfg.zero1 else pshard)

    cd = jnp.dtype(cfg.compute_dtype)

    def loss_fn(params, microbatch):
        # PERF (EXPERIMENTS.md §Perf iter 1): cast the whole tree to the
        # compute dtype BEFORE the layer scan, keeping the param shardings —
        # FSDP all-gathers and weight reads then move bf16, not f32.
        params_c = jax.tree.map(
            lambda p, s: jax.lax.with_sharding_constraint(p.astype(cd), s),
            params, pshard)
        return registry.loss_fn(params_c, cfg, rules, microbatch)

    def train_step(state: TrainState, batch):
        mb = cfg.microbatches

        if mb <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            def reshape(x):
                b = x.shape[0]
                return x.reshape((mb, b // mb) + x.shape[1:])
            mbatch = jax.tree.map(reshape, batch)

            def micro(carry, one):
                gacc, lacc = carry
                l, g = jax.value_and_grad(loss_fn)(state.params, one)
                # PERF (§Perf iter 2): reduce-scatter each microbatch's
                # grads to the ZeRO-1 layout inside the scan; the carry is
                # dp-sharded, so GSPMD emits RS (half an all-reduce's bytes).
                g = jax.tree.map(
                    lambda t, s: jax.lax.with_sharding_constraint(t, s),
                    g, zshard)
                return (jax.tree.map(jnp.add, gacc, g), lacc + l), None

            zeros = jax.tree.map(
                lambda a, s: jax.lax.with_sharding_constraint(
                    jnp.zeros(a.shape, jnp.float32), s),
                state.params, zshard)
            (grads, loss), _ = jax.lax.scan(micro, (zeros, 0.0), mbatch)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss / mb

        # --- Checkmate capture point: reduce-scattered final gradients ------
        grads = jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, zshard)

        lr = lr_fn(state.step)
        new_state = apply_updates(state, grads, opt, lr)
        # ZeRO-1: moments stay dp-sharded; params all-gather back.
        new_state = TrainState(
            params=jax.tree.map(
                lambda p, s: jax.lax.with_sharding_constraint(p, s),
                new_state.params, pshard),
            mu=jax.tree.map(
                lambda m, s: jax.lax.with_sharding_constraint(m, s),
                new_state.mu, zshard),
            nu=jax.tree.map(
                lambda v, s: jax.lax.with_sharding_constraint(v, s),
                new_state.nu, zshard),
            step=new_state.step)

        metrics = {"loss": loss, "grad_norm": global_norm(grads), "lr": lr}
        if return_grads:
            return new_state, metrics, grads
        return new_state, metrics

    ob = _obs.get()
    if ob.enabled:
        # the capture payload: f32 reduced gradients, one per param leaf
        nbytes = sum(4 * math.prod(a.shape)
                     for a in jax.tree.leaves(aspecs))
        ob.metrics.gauge("capture_bytes",
                         "Per-step reduced-gradient capture size").set(
            nbytes, arch=cfg.name)
        ob.tracer.instant("train_step.build",
                          args={"arch": cfg.name,
                                "microbatches": cfg.microbatches,
                                "return_grads": return_grads})
    return train_step


def make_train_state(rng, cfg: ModelConfig, rules: ShardingRules) -> TrainState:
    params = registry.init_params(rng, cfg, rules)
    state = init_state(params)
    sh = state_shardings(cfg, rules)
    mu = jax.tree.map(jax.device_put, state.mu, sh.mu)
    nu = jax.tree.map(jax.device_put, state.nu, sh.nu)
    return TrainState(params=params, mu=mu, nu=nu, step=state.step)


def abstract_train_state(cfg: ModelConfig, rules: ShardingRules) -> TrainState:
    aspecs = registry.abstract_params(cfg, rules)
    sh = state_shardings(cfg, rules)
    mu = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, jnp.float32, sharding=s),
        aspecs, sh.mu)
    nu = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, jnp.float32, sharding=s),
        aspecs, sh.nu)
    return TrainState(params=aspecs, mu=mu, nu=nu,
                      step=jax.ShapeDtypeStruct((), jnp.int32))


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig,
                       rules: ShardingRules):
    def prefill_step(params, inputs):
        extra = {k: v for k, v in inputs.items() if k != "tokens"}
        if cfg.family in ("audio", "vlm"):
            cache, logits = registry.prefill(
                params, cfg, rules, inputs["tokens"], shape.seq_len, **extra)
        else:
            cache, logits = registry.prefill(
                params, cfg, rules, inputs["tokens"], shape.seq_len)
        return cache, logits
    return prefill_step


def build_decode_step(cfg: ModelConfig, rules: ShardingRules,
                      greedy: bool = True):
    def serve_step(params, cache, token):
        logits, cache = registry.decode_step(params, cfg, rules, cache, token)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token[:, None], cache
    return serve_step
