"""Fig 6: throughput x checkpoint count per system across the paper's model
families (vision=ViT, GPT LMs, hybrid-parallel LLaMA stand-in).

The paper's claims checked here (as ratios on this host):
  * Checkmate checkpoints EVERY iteration with ~zero stall;
  * per-iteration copy-persist systems stall (1.3-6.5x at per-iteration);
  * CheckFreq checkpoints 5-34.5x less frequently than Checkmate.
"""
from __future__ import annotations

import jax

from benchmarks.common import bench_config, csv_row, smoke_env
from repro.core.buckets import layout_for_tree
from repro.core.checkpoint import (AsyncCheckpointer, CheckFreqCheckpointer,
                                   CheckmateCheckpointer,
                                   GeminiLikeCheckpointer, NoCheckpointer,
                                   SyncCheckpointer)
from repro.core.shadow import ShadowCluster
from repro.optim import OptimizerConfig
from repro.train.loop import train
from repro.train.step import make_train_state

STEPS = 8
MODELS = [("vit-h-14", 8, 0), ("gpt2-1.5b", 4, 128), ("gpt3-xl", 4, 128),
          ("llama2-7b", 4, 128)]


def run():
    mesh, rules = smoke_env()
    opt = OptimizerConfig(lr=1e-3)
    for arch, batch, seq in MODELS:
        cfg = bench_config(arch)
        seq = seq or 128
        for name in ("no_checkpoint", "checkmate", "async", "gemini",
                     "checkfreq"):
            s0 = make_train_state(jax.random.PRNGKey(0), cfg, rules)
            if name == "checkmate":
                shadow = ShadowCluster(layout_for_tree(s0.params), opt,
                                       n_nodes=2, async_mode=True)
                shadow.bootstrap(s0.params, s0.mu, s0.nu, 0)
                ck = CheckmateCheckpointer(shadow)
            else:
                ck = {"no_checkpoint": NoCheckpointer(),
                      "async": AsyncCheckpointer(1),
                      "gemini": GeminiLikeCheckpointer(1),
                      "checkfreq": CheckFreqCheckpointer()}[name]
            _, stats = train(cfg, rules, steps=STEPS, batch=batch, seq=seq,
                             opt=opt, checkpointer=ck, state=s0)
            steady = stats.iter_times[1:] or stats.iter_times
            tput = len(steady) / (sum(steady) + sum(stats.stall_times[1:]))
            csv_row(f"fig6.{cfg.name}.{name}",
                    1e6 / max(tput, 1e-9),
                    f"tput={tput:.2f}it/s ckpts={ck.n_checkpoints} "
                    f"stall={ck.stall_total*1e3:.0f}ms")
            if hasattr(ck, "shadow"):
                ck.shadow.shutdown()


if __name__ == "__main__":
    run()
