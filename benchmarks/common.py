"""Shared helpers for the benchmark harness.

Benchmark configs are small-but-not-tiny (state ~100 MB class) so
copy-persist costs are measurable against iteration time on this CPU host.
Absolute times are container-specific; the *ratios* reproduce the paper's
relative claims (noted per benchmark).
"""
from __future__ import annotations

import time
from dataclasses import replace

import numpy as np

import jax

import repro.configs as C
from repro.dist.sharding import ShardingRules, make_smoke_mesh


def bench_config(arch: str, **over):
    cfg = C.get(arch)
    kw = dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
              head_dim=64, d_ff=1024, vocab_size=8192, microbatches=1,
              attn_q_chunk=64, attn_kv_chunk=128)
    if cfg.num_experts:
        kw.update(num_experts=4, top_k=2, moe_d_ff=512)
    if cfg.ssm_state:
        kw.update(ssm_state=32, ssm_head_dim=32, ssm_chunk=32)
    if cfg.attn_every:
        kw.update(attn_every=2)
    if cfg.encoder_layers:
        kw.update(encoder_layers=2, encoder_seq=64)
    if cfg.num_patches:
        kw.update(num_patches=16)
    kw.update(over)
    return replace(cfg, name=cfg.name + "-bench", **kw)


def smoke_env():
    mesh = make_smoke_mesh()
    return mesh, ShardingRules(mesh)


def timeit(fn, *args, reps=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
