"""Fabric-level sweeps on the event-driven simulator (docs/netsim.md):

* Fig 10 at scale — replication factor vs TX/RX ratio and bus bandwidth at
  512 ranks across 2 DP groups on the rail fabric,
* topology comparison — rail-optimized vs strided leaf/spine vs the
  single-switch idealization for the same workload,
* failure drills — spine kill (reroute) and shadow-NIC kill (capture loss)
  mid-iteration.

``--json`` mode benchmarks the calendar-queue fast path
(`simulate_fabric(fast=True)`) against the per-frame oracle on the Fig 10
512-rank sweep and writes ``BENCH_fabric.json``: min-of-N wall clock per
replication factor, plus a full `FabricResult` equality check per row (the
fast path is only admissible while it is bit-identical).  Exits nonzero if
the aggregate speedup is below 3x or any row's results diverge — the CI
gate for the fast engine.  Timing on shared CPU hosts is noisy (+-30%
burst throttling), hence min-of-N, never means.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

from benchmarks.common import csv_row
from repro.net.simulator import (FailureSpec, simulate_fabric,
                                 sweep_replication, sweep_topology)

SCALE = dict(n_dp_groups=2, ranks_per_group=256,
             grad_bytes_per_group=256 * 2048, topology="rail",
             n_shadow_nodes=2, ranks_per_leaf=32)


def run():
    for r in sweep_replication((1, 2, 4, 8), **SCALE):
        csv_row(f"fabric.fig10.rf{r.replication_factor}",
                r.duration_s * 1e6,
                f"tx_over_rx={r.tx_over_rx:.4f} "
                f"busbw={r.bus_bandwidth_gbps:.1f}Gbps "
                f"ok={r.reassembled_ok} drops={r.drops} "
                f"events={r.events}")

    work = dict(n_dp_groups=2, ranks_per_group=64,
                grad_bytes_per_group=64 * 16384, n_shadow_nodes=2,
                ranks_per_leaf=16)
    for name, r in sweep_topology(("single", "rail", "leaf-spine"),
                                  **work).items():
        csv_row(f"fabric.topology.{name}", r.duration_s * 1e6,
                f"busbw={r.bus_bandwidth_gbps:.1f}Gbps "
                f"pauses={r.pfc_pauses} ok={r.reassembled_ok}")

    base = simulate_fabric(**work)
    mid = base.duration_s / 2
    spine = simulate_fabric(**work,
                            failures=[FailureSpec(mid, "switch", "spine0")])
    csv_row("fabric.fail.spine_kill", spine.duration_s * 1e6,
            f"rerouted={spine.rerouted} retx={spine.retransmits} "
            f"ok={spine.reassembled_ok}")
    snic = simulate_fabric(**work,
                           failures=[FailureSpec(mid, "shadow_nic", "s0")])
    csv_row("fabric.fail.shadow_nic", snic.duration_s * 1e6,
            f"missing={snic.missing_captures} "
            f"ring_ok={snic.ring_completed} ok={snic.reassembled_ok}")


def _min_time(fn, reps: int):
    """(best wall-clock seconds, last result) over ``reps`` runs."""
    best, result = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best, result


def run_json(out_path: str = "BENCH_fabric.json", reps: int = 3,
             min_speedup: float = 3.0) -> int:
    fails, rows = [], []
    for rf in (1, 2, 4, 8):
        cfg = dict(SCALE, replication_factor=rf)
        t_oracle, oracle = _min_time(
            lambda: simulate_fabric(fast=False, **cfg), reps)
        t_fast, fast = _min_time(
            lambda: simulate_fabric(fast=True, **cfg), reps)
        identical = (dataclasses.asdict(oracle) == dataclasses.asdict(fast))
        rows.append({
            "replication_factor": rf,
            "events": oracle.events,
            "tx_over_rx": oracle.tx_over_rx,
            "per_frame_s": t_oracle,
            "fast_s": t_fast,
            "speedup": t_oracle / t_fast,
            "identical": identical,
        })
        if not identical:
            diffs = [k for k, v in dataclasses.asdict(oracle).items()
                     if v != getattr(fast, k)]
            fails.append(f"rf={rf}: fast result diverges from the "
                         f"per-frame oracle on {diffs}")
    per_frame_total = sum(r["per_frame_s"] for r in rows)
    fast_total = sum(r["fast_s"] for r in rows)
    report = {
        "workload": "Fig 10 rail sweep: 512 ranks / 2 DP groups, rf 1-8",
        "scale": {k: (v if not isinstance(v, str) else v)
                  for k, v in SCALE.items()},
        "reps": reps,
        "timing": "min-of-N per engine (shared-CPU noise is one-sided)",
        "rows": rows,
        "per_frame_total_s": per_frame_total,
        "fast_total_s": fast_total,
        "speedup": per_frame_total / fast_total,
        "min_speedup_gate": min_speedup,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))
    if report["speedup"] < min_speedup:
        fails.append(f"fast-path speedup {report['speedup']:.2f}x is below "
                     f"the {min_speedup:.0f}x gate")
    for msg in fails:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if fails else 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="fast-vs-oracle Fig 10 benchmark; write "
                         "BENCH_fabric.json and gate on >= 3x + identity")
    ap.add_argument("--out", default="BENCH_fabric.json")
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()
    if args.json:
        sys.exit(run_json(args.out, reps=args.reps))
    run()
