"""Fabric-level sweeps on the event-driven simulator (docs/netsim.md):

* Fig 10 at scale — replication factor vs TX/RX ratio and bus bandwidth at
  512 ranks across 2 DP groups on the rail fabric,
* topology comparison — rail-optimized vs strided leaf/spine vs the
  single-switch idealization for the same workload,
* failure drills — spine kill (reroute) and shadow-NIC kill (capture loss)
  mid-iteration.
"""
from __future__ import annotations

from benchmarks.common import csv_row
from repro.net.simulator import (FailureSpec, simulate_fabric,
                                 sweep_replication, sweep_topology)

SCALE = dict(n_dp_groups=2, ranks_per_group=256,
             grad_bytes_per_group=256 * 2048, topology="rail",
             n_shadow_nodes=2, ranks_per_leaf=32)


def run():
    for r in sweep_replication((1, 2, 4, 8), **SCALE):
        csv_row(f"fabric.fig10.rf{r.replication_factor}",
                r.duration_s * 1e6,
                f"tx_over_rx={r.tx_over_rx:.4f} "
                f"busbw={r.bus_bandwidth_gbps:.1f}Gbps "
                f"ok={r.reassembled_ok} drops={r.drops} "
                f"events={r.events}")

    work = dict(n_dp_groups=2, ranks_per_group=64,
                grad_bytes_per_group=64 * 16384, n_shadow_nodes=2,
                ranks_per_leaf=16)
    for name, r in sweep_topology(("single", "rail", "leaf-spine"),
                                  **work).items():
        csv_row(f"fabric.topology.{name}", r.duration_s * 1e6,
                f"busbw={r.bus_bandwidth_gbps:.1f}Gbps "
                f"pauses={r.pfc_pauses} ok={r.reassembled_ok}")

    base = simulate_fabric(**work)
    mid = base.duration_s / 2
    spine = simulate_fabric(**work,
                            failures=[FailureSpec(mid, "switch", "spine0")])
    csv_row("fabric.fail.spine_kill", spine.duration_s * 1e6,
            f"rerouted={spine.rerouted} retx={spine.retransmits} "
            f"ok={spine.reassembled_ok}")
    snic = simulate_fabric(**work,
                           failures=[FailureSpec(mid, "shadow_nic", "s0")])
    csv_row("fabric.fail.shadow_nic", snic.duration_s * 1e6,
            f"missing={snic.missing_captures} "
            f"ring_ok={snic.ring_completed} ok={snic.reassembled_ok}")


if __name__ == "__main__":
    run()
