"""Format dryrun_results.json into the §Roofline markdown/CSV table."""
from __future__ import annotations

import json
import sys


def run(path="dryrun_results.json", mesh="single"):
    rows = [r for r in json.load(open(path))
            if r.get("mesh") == mesh]
    print(f"# §Roofline table ({mesh}-pod) — seconds per step")
    print("arch,shape,status,compute_s,memory_s,collective_s,bound,"
          "useful_flops_ratio,mfu_at_roofline,hbm_bytes_per_dev_GB")
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']},{r['shape']},{r['status']},,,,,,,")
            continue
        hbm = r.get("bytes_per_device_hbm", 0) / 1e9
        print(f"{r['arch']},{r['shape']},ok,"
              f"{r['compute_s']:.3f},{r['memory_s']:.3f},"
              f"{r['collective_s']:.3f},{r['bound']},"
              f"{r['useful_flops_ratio']:.2f},{r['mfu_at_roofline']:.4f},"
              f"{hbm:.1f}")


if __name__ == "__main__":
    run(*sys.argv[1:])
