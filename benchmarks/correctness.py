"""Fig 9: recovered-from-shadow training converges identically to an
uninterrupted run (loss curves overlap; states bit-equal)."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import bench_config, csv_row, smoke_env
from repro.core.buckets import layout_for_tree
from repro.core.checkpoint import CheckmateCheckpointer
from repro.core.recovery import FailurePlan
from repro.core.shadow import ShadowCluster
from repro.optim import OptimizerConfig
from repro.train.loop import train
from repro.train.step import make_train_state

STEPS, BATCH, SEQ, SEED = 10, 8, 64, 11


def run():
    mesh, rules = smoke_env()
    cfg = bench_config("vit-h-14")          # the paper uses a vision model
    opt = OptimizerConfig(lr=1e-3)

    state_a, stats_a = train(cfg, rules, steps=STEPS, batch=BATCH, seq=SEQ,
                             opt=opt, seed=SEED)
    s0 = make_train_state(jax.random.PRNGKey(SEED), cfg, rules)
    shadow = ShadowCluster(layout_for_tree(s0.params), opt, n_nodes=2)
    shadow.bootstrap(s0.params, s0.mu, s0.nu, 0)
    state_b, stats_b = train(cfg, rules, steps=STEPS, batch=BATCH, seq=SEQ,
                             opt=opt, seed=SEED, state=s0,
                             checkpointer=CheckmateCheckpointer(shadow),
                             failure_plan=FailurePlan((3, 5, 8)))

    max_loss_diff = max(abs(a - b)
                        for a, b in zip(stats_a.losses, stats_b.losses))
    identical = all(np.array_equal(np.asarray(state_a.params[k]),
                                   np.asarray(state_b.params[k]))
                    for k in state_a.params)
    csv_row("fig9.loss_curve_max_diff", 0.0, f"{max_loss_diff:.2e}")
    csv_row("fig9.recoveries", 0.0, f"{stats_b.recoveries}")
    csv_row("fig9.states_bit_identical", 0.0, str(identical))


if __name__ == "__main__":
    run()
