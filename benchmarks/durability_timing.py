"""Durability plane cost: delta bytes and trainer stall at gpt2-1.5b.

The tiered persistence layer (`repro.durability`, docs/durability.md)
claims two numbers and this benchmark gates both:

* **delta bytes << full-state bytes** — an int8 compressed flush epoch
  moves a fraction of the f32 base sweep (the paper-scale argument for
  flushing every step instead of snapshotting);
* **flush adds 0.0 trainer stall** — flushing runs entirely on the
  per-node `FlushWorker` threads, so the checkpointer's stall ledger
  (`stall_stages`) contains no flush/durability/tier stage and the
  per-step stall with flushing attached matches the vocabulary of the
  run without it.

``--json`` writes ``BENCH_durability.json`` and exits nonzero if a gate
fails; the default mode prints the harness CSV rows. A raw-policy
restore is also checked bit-identical against ``consolidate()`` — a
benchmark that persists the wrong bytes fast would gate green otherwise.

The workload is the same dimension-scaled GPT-2 1.5B per-layer leaf
tree the shadow benchmark uses (580 leaves, default DDP 25 MB cap).
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import csv_row
from benchmarks.shadow_timing import gpt2_1_5b_leaf_tree
from repro.core.buckets import layout_for_tree
from repro.core.checkpoint import CheckmateCheckpointer
from repro.core.channel import StepEvent
from repro.core.shadow import ShadowCluster
from repro.durability import (DurableShadow, FlushPolicy, LocalDiskTier,
                              restore_from_tiers)
from repro.obs.stalls import KNOWN_STAGES
from repro.optim import OptimizerConfig

FLUSH_STAGE_WORDS = ("flush", "durability", "tier")


def _drive(params, layout, grad_steps, opt, policy, root, n_nodes=2):
    """One checkpointered run with a durability plane attached.

    Returns (stall_stages, tier, dur, consolidated, flush_wall_s)."""
    shadow = ShadowCluster(layout, opt, n_nodes=n_nodes)
    tier = LocalDiskTier(root)
    dur = DurableShadow([tier], policy).attach(shadow)
    zeros = {k: np.zeros_like(v) for k, v in params.items()}
    shadow.bootstrap(params, zeros, zeros, 0)
    ck = CheckmateCheckpointer(shadow, durability=dur)
    t_flush = 0.0
    for step, grads in enumerate(grad_steps, start=1):
        ck.on_step(StepEvent(step=step, grads=grads, lr=1e-3))
        t0 = time.perf_counter()
        dur.drain()                      # background worker time, measured
        t_flush += time.perf_counter() - t0
    ckpt = shadow.consolidate(timeout=120)
    stages = dict(ck.stall_stages)
    ck.finalize()
    shadow.shutdown()
    return stages, tier, dur, ckpt, t_flush


def run_json(out_path: str = "BENCH_durability.json", steps: int = 6) -> int:
    opt = OptimizerConfig(lr=1e-3)
    params = gpt2_1_5b_leaf_tree()
    layout = layout_for_tree(params)         # default DDP 25 MB cap
    state_bytes = 3 * layout.total_bytes     # params + mu + nu, f32
    rng = np.random.default_rng(7)
    grad_steps = [{k: rng.standard_normal(v.shape).astype(np.float32) * 0.01
                   for k, v in params.items()} for _ in range(steps)]
    fails: list[str] = []

    with tempfile.TemporaryDirectory(prefix="bench-dur-raw-") as root:
        raw_stages, raw_tier, raw_dur, ckpt, raw_flush_s = _drive(
            params, layout, grad_steps, opt, FlushPolicy(), root)
        raw_epoch_bytes = raw_tier.put_bytes_total / max(
            1, raw_dur.epochs_started)
        restored = restore_from_tiers([raw_tier], layout, n_nodes=2)
        if restored["step"] != steps:
            fails.append(f"raw restore landed at {restored['step']}, "
                         f"trainer is at {steps}")
        for part in ("params", "mu", "nu"):
            for k in ckpt[part]:
                if not np.array_equal(restored[part][k], ckpt[part][k]):
                    fails.append(f"raw restore differs from consolidate "
                                 f"at {part}[{k}]")
                    break

    with tempfile.TemporaryDirectory(prefix="bench-dur-q-") as root:
        # one f32 base epoch, then int8 diff deltas all the way
        q_stages, q_tier, q_dur, _, q_flush_s = _drive(
            params, layout, grad_steps, opt,
            FlushPolicy(compress=True, rebase_every=steps + 1), root)
        ents = q_tier.entries()
        base_bytes = sum(e.nbytes for e in ents if e.kind == "base")
        delta_epochs = sorted({e.epoch for e in ents if e.kind == "delta"})
        epoch_delta = [sum(e.nbytes for e in ents
                           if e.kind == "delta" and e.epoch == ep)
                       for ep in delta_epochs]
        delta_mean = float(np.mean(epoch_delta)) if epoch_delta else 0.0

    # -- gates ---------------------------------------------------------------
    if not delta_epochs:
        fails.append("compressed run produced no delta epochs")
    if delta_mean >= state_bytes / 3:
        fails.append(f"compressed delta epoch moves {delta_mean / 1e6:.2f} "
                     f"MB, not << the {state_bytes / 1e6:.2f} MB full "
                     "state (int8 diffs should be ~4x smaller)")
    for label, stages in (("raw", raw_stages), ("compressed", q_stages)):
        flushy = [s for s in stages
                  if any(w in s.lower() for w in FLUSH_STAGE_WORDS)]
        if flushy:
            fails.append(f"{label} run booked trainer stall on flush "
                         f"stages {flushy}: flushing must be free")
        unknown = [s for s in stages if s not in KNOWN_STAGES]
        if unknown:
            fails.append(f"{label} run booked stall on stages {unknown} "
                         f"outside the ledger vocabulary {KNOWN_STAGES}")

    report = {
        "arch": "gpt2-1.5b (per-layer leaf structure, dim-scaled)",
        "steps": steps,
        "n_buckets": len(layout.buckets),
        "state_bytes": state_bytes,
        "raw": {
            "epoch_bytes_mean": raw_epoch_bytes,
            "flush_wall_s_total": raw_flush_s,
            "stall_stages": raw_stages,
        },
        "compressed": {
            "base_bytes": base_bytes,
            "delta_epoch_bytes_mean": delta_mean,
            "delta_vs_state": delta_mean / state_bytes,
            "flush_wall_s_total": q_flush_s,
            "stall_stages": q_stages,
        },
        "flush_stall_s": 0.0 if not fails else None,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))
    for msg in fails:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if fails else 0


def run():
    """CSV rows for the benchmark harness (model-free, seconds-scale)."""
    opt = OptimizerConfig(lr=1e-3)
    params = gpt2_1_5b_leaf_tree(n_layers=8)     # trimmed for the sweep
    layout = layout_for_tree(params)
    state_bytes = 3 * layout.total_bytes
    rng = np.random.default_rng(7)
    grad_steps = [{k: rng.standard_normal(v.shape).astype(np.float32) * 0.01
                   for k, v in params.items()} for _ in range(4)]
    for label, policy in (("raw", FlushPolicy()),
                          ("int8", FlushPolicy(compress=True,
                                               rebase_every=5))):
        with tempfile.TemporaryDirectory(prefix="bench-dur-") as root:
            stages, tier, dur, _, flush_s = _drive(
                params, layout, grad_steps, opt, policy, root)
            epoch_bytes = tier.put_bytes_total / max(1, dur.epochs_started)
            csv_row(f"durability.{label}", flush_s / len(grad_steps) * 1e6,
                    f"epoch_bytes={epoch_bytes:.0f} "
                    f"state_bytes={state_bytes} "
                    f"stall_stages={sorted(stages)}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="delta-size + zero-flush-stall gates; write "
                         "BENCH_durability.json")
    ap.add_argument("--out", default="BENCH_durability.json")
    ap.add_argument("--steps", type=int, default=6)
    args = ap.parse_args()
    if args.json:
        sys.exit(run_json(args.out, steps=args.steps))
    run()
