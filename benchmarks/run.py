"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,fig6,...] [--smoke]

``--smoke`` runs the fast, model-free subset (savings, multicast_overhead
+ channel send overhead) — CI runs it with the repo's own deprecation
messages promoted to errors (scoped ``PYTHONWARNINGS`` filters) to prove
the in-repo benchmark callers are migrated off deprecated APIs.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract.
Mapping to the paper:
  savings            -> Fig 1, Fig 11, §6.7, App. A/B anchors
  stalls             -> Fig 2 (per-iteration stalls per system)
  throughput         -> Fig 6 (throughput x checkpoint count, 4 model fams)
  shadow_timing      -> Fig 7 (shadow keeps up; min CPU nodes)
  durability_timing  -> tiered flush cost: delta bytes + zero trainer stall
  optimizer_scaling  -> Fig 8 (opt-step scaling across shadow partitions)
  correctness        -> Fig 9 (recovered == uninterrupted)
  multicast_overhead -> Fig 10 (replication factor sweep)
  fabric_sweep       -> Fig 10 at 512 ranks + topology/failure sweeps on
                        the event-driven fabric simulator (docs/netsim.md)
  kernels            -> Pallas kernels vs jnp refs
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

MODULES = [
    ("savings", "benchmarks.savings"),
    ("multicast_overhead", "benchmarks.multicast_overhead"),
    ("fabric_sweep", "benchmarks.fabric_sweep"),
    ("optimizer_scaling", "benchmarks.optimizer_scaling"),
    ("kernels", "benchmarks.kernels"),
    ("stalls", "benchmarks.stalls"),
    ("shadow_timing", "benchmarks.shadow_timing"),
    ("durability_timing", "benchmarks.durability_timing"),
    ("correctness", "benchmarks.correctness"),
    ("throughput", "benchmarks.throughput"),
]


SMOKE = {"savings", "multicast_overhead"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true",
                    help=f"fast model-free subset: {sorted(SMOKE)}")
    ap.add_argument("--metrics-out", default=None,
                    help="write a repro.obs metrics snapshot of the run "
                         "(default BENCH_metrics.json under --smoke)")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    if args.smoke:
        only = SMOKE if not only else (only & SMOKE)
        if not only:
            ap.error(f"--only selects no smoke module; smoke set: "
                     f"{sorted(SMOKE)}")
        if args.metrics_out is None:
            args.metrics_out = "BENCH_metrics.json"

    registry = None
    if args.metrics_out:
        from repro.obs import MetricsRegistry
        registry = MetricsRegistry()

    print("name,us_per_call,derived")
    failures = []
    for name, mod in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            fn = __import__(mod, fromlist=["run"]).run
            # benchmarks that accept a registry publish their channel /
            # stall accounting into the run-wide metrics snapshot
            if registry is not None and "registry" in (
                    inspect.signature(fn).parameters):
                fn(registry=registry)
            else:
                fn()
        except Exception as e:                      # keep the harness going
            traceback.print_exc()
            failures.append(name)
            print(f"{name}.FAILED,0,{type(e).__name__}")
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if registry is not None:
        registry.write_json(args.metrics_out)
        print(f"# metrics snapshot -> {args.metrics_out}")
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
