"""Fig 2: iteration time + checkpoint stalls per system when checkpointing
EVERY iteration (GPT-class bench model, real wall-clock on this host).

Paper claims to reproduce (relative): sync stalls worst (9.5x there);
async still stalls (same volume); sharding reduces it; Checkmate ~ no-ckpt.
"""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import bench_config, csv_row, smoke_env
from repro.core.buckets import layout_for_tree
from repro.core.checkpoint import (AsyncCheckpointer, CheckmateCheckpointer,
                                   GeminiLikeCheckpointer, NoCheckpointer,
                                   ShardedAsyncCheckpointer, SyncCheckpointer)
from repro.core.shadow import ShadowCluster
from repro.optim import OptimizerConfig
from repro.train.loop import train
from repro.train.step import make_train_state

STEPS, BATCH, SEQ = 6, 8, 128


def run():
    mesh, rules = smoke_env()
    cfg = bench_config("gpt3-xl")
    opt = OptimizerConfig(lr=1e-3)

    def make_ck(name):
        if name == "checkmate":
            s0 = make_train_state(jax.random.PRNGKey(0), cfg, rules)
            shadow = ShadowCluster(layout_for_tree(s0.params), opt,
                                   n_nodes=2, async_mode=True)
            shadow.bootstrap(s0.params, s0.mu, s0.nu, 0)
            return CheckmateCheckpointer(shadow), s0
        s0 = make_train_state(jax.random.PRNGKey(0), cfg, rules)
        return {
            "no_checkpoint": NoCheckpointer(),
            "sync": SyncCheckpointer(1),
            "async": AsyncCheckpointer(1),
            "torch_dcp": ShardedAsyncCheckpointer(1, n_shards=4),
            "gemini": GeminiLikeCheckpointer(1),
        }[name], s0

    base_iter = None
    for name in ("no_checkpoint", "checkmate", "sync", "async", "torch_dcp",
                 "gemini"):
        ck, s0 = make_ck(name)
        _, stats = train(cfg, rules, steps=STEPS, batch=BATCH, seq=SEQ,
                         opt=opt, checkpointer=ck, state=s0)
        it = stats.steady_iter
        stall = ck.stall_total / max(ck.n_checkpoints, 1)
        if name == "no_checkpoint":
            base_iter = it
        slowdown = (it + stall) / base_iter
        csv_row(f"fig2.{name}", (it + stall) * 1e6,
                f"iter={it*1e3:.0f}ms stall={stall*1e3:.0f}ms "
                f"slowdown={slowdown:.2f}x")
        if hasattr(ck, "shadow"):
            ck.shadow.shutdown()


if __name__ == "__main__":
    run()
