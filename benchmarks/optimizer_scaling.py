"""Fig 8: optimizer-step time scales ~linearly as the shadow partition count
grows (paper: cores/nodes; here: per-node partitions on one host, with
per-partition time measured independently as if parallel)."""
from __future__ import annotations

import time

import numpy as np

import jax

from benchmarks.common import bench_config, csv_row, smoke_env
from repro.core.buckets import layout_for_tree
from repro.core.channel import InProcessChannel, StepEvent
from repro.core.shadow import ShadowCluster
from repro.optim import OptimizerConfig
from repro.train.step import make_train_state


def run():
    mesh, rules = smoke_env()
    opt = OptimizerConfig(lr=1e-3)
    cfg = bench_config("gpt3-6.7b", num_layers=6, d_model=512, d_ff=2048,
                       vocab_size=16384)
    s0 = make_train_state(jax.random.PRNGKey(0), cfg, rules)
    params = {k: np.asarray(v) for k, v in s0.params.items()}
    zeros = {k: np.zeros_like(v) for k, v in params.items()}
    grads = {k: np.ones_like(v) for k, v in params.items()}
    layout = layout_for_tree(s0.params)
    base = None
    for nodes in (1, 2, 4, 8):
        shadow = ShadowCluster(layout, opt, n_nodes=nodes)
        shadow.bootstrap(params, zeros, zeros, 0)
        chan = InProcessChannel()
        chan.open(layout)
        chan.send(StepEvent(step=1, grads=grads, lr=1e-3))  # warmup (jit)
        for d in chan.poll():
            shadow.on_delivery(d)
        # measure each node's apply independently; the cluster-parallel time
        # is the max over nodes (they run on separate machines in the paper)
        flats = {b.bucket_id: np.ones(b.size, np.float32)
                 for b in layout.buckets}
        per_node = []
        for node in shadow.nodes:
            sub = {bid: flats[bid] for bid in node.bucket_ids}
            node.apply(2, 1e-3, sub)                 # per-node jit warmup
            reps = []
            for r in range(3):
                t0 = time.perf_counter()
                node.apply(3 + r, 1e-3, sub)
                reps.append(time.perf_counter() - t0)
            per_node.append(min(reps))
        t = max(per_node) if per_node else 0.0
        base = base or t
        csv_row(f"fig8.nodes{nodes}", t * 1e6,
                f"opt_step={t*1e3:.1f}ms speedup={base/max(t,1e-9):.2f}x")


if __name__ == "__main__":
    run()
