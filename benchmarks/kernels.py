"""Kernel microbenchmarks: fused AdamW / flash attention / packed copy vs
their jnp references (interpret mode on CPU — correctness-scale timings; on
TPU the same entry points compile to Mosaic)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, timeit
from repro.kernels import ops, ref


def run():
    rng = np.random.default_rng(0)

    n = 128 * 512
    p = jnp.asarray(rng.standard_normal(n), jnp.float32)
    g = jnp.asarray(rng.standard_normal(n), jnp.float32)
    m = jnp.zeros(n, jnp.float32)
    v = jnp.zeros(n, jnp.float32)
    t_ref = timeit(jax.jit(lambda *a: ref.adamw_ref(*a, 1e-3)), p, g, m, v,
                   jnp.float32(1.0))
    t_k = timeit(lambda *a: ops.fused_adamw(*a, 1.0, 1e-3), p, g, m, v)
    csv_row("kernel.fused_adamw", t_k * 1e6,
            f"ref_us={t_ref*1e6:.0f} n={n} interpret=True")

    b, s, h, d = 1, 256, 4, 64
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32) * 0.3
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32) * 0.3
    vv = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    t_ref = timeit(jax.jit(lambda *a: ref.flash_attention_ref(*a)), q, k, vv)
    t_k = timeit(lambda *a: ops.flash_attention(*a, causal=True,
                                                block_q=128, block_k=128),
                 q, k, vv)
    err = float(jnp.max(jnp.abs(
        ops.flash_attention(q, k, vv, causal=True, block_q=128, block_k=128)
        - ref.flash_attention_ref(q, k, vv))))
    csv_row("kernel.flash_attention", t_k * 1e6,
            f"ref_us={t_ref*1e6:.0f} max_err={err:.1e} interpret=True")

    x = jnp.asarray(rng.standard_normal(1 << 20), jnp.float32)
    t_k = timeit(ops.packed_copy, x)
    csv_row("kernel.packed_copy", t_k * 1e6, f"bytes={x.nbytes}")


if __name__ == "__main__":
    run()
