"""Fig 7: can the shadow keep up? Batch-size sweep — iteration time vs
shadow pull+optimizer time, and the min shadow-node count (§6.3).

``--json`` mode benchmarks the flat wire-layout apply (one fused optimizer
pass per bucket, `ShadowCluster(flat=True)`) against the legacy per-leaf
path at the gpt2_1_5b layout and writes ``BENCH_shadow.json`` with
mean/max apply seconds for both. Exits nonzero if the flat path is not
faster — the CI smoke gate for the shadow hot loop.

``--json`` additionally plans and times the bucket-sharded frontier
fleet: `repro.core.costmodel.shadow_plan_for_config` sizes arctic_480b
(metadata only — nothing model-sized allocates) and must come back with a
genuinely sharded fleet (>= 8 nodes) whose per-node resident state (the
peak-RSS proxy) fits the budget; a dimension-scaled timing run then
shards the gpt2 leaf tree across that many simulated shadow nodes and
gates on the sharded critical path (slowest node's per-step apply) beating
the single-node apply — the whole point of sharding the shadow plane.

The json benchmark uses the paper's *per-layer* leaf structure for GPT-2
1.5B (48 layers x 12 tensors + embeddings = 580 leaves, the shape a DDP
bucketer actually sees on the capture side), dimension-scaled to fit a CPU
container, bucketed at the default DDP 25 MB cap. The repo's jax models
scan-stack layer weights into ~12 mega-leaves, which hides exactly the
per-leaf dispatch cost the flat path deletes.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

import jax

from benchmarks.common import bench_config, csv_row, smoke_env
from repro.core.buckets import layout_for_tree
from repro.core.channel import InProcessChannel, StepEvent
from repro.core.shadow import ShadowCluster, plan_shadow_nodes
from repro.optim import OptimizerConfig
from repro.train.loop import train
from repro.train.step import make_train_state


def run():
    mesh, rules = smoke_env()
    opt = OptimizerConfig(lr=1e-3)
    for arch in ("gpt2-1.5b", "vit-h-14"):
        cfg = bench_config(arch)
        for batch in (2, 8, 16):
            s0 = make_train_state(jax.random.PRNGKey(0), cfg, rules)
            layout = layout_for_tree(s0.params)
            shadow = ShadowCluster(layout, opt, n_nodes=1)
            shadow.bootstrap(s0.params, s0.mu, s0.nu, 0)
            from repro.core.checkpoint import CheckmateCheckpointer
            _, stats = train(cfg, rules, steps=5, batch=batch, seq=64,
                             opt=opt, state=s0,
                             checkpointer=CheckmateCheckpointer(shadow))
            st = shadow.stats()
            tree = {k: np.asarray(v) for k, v in s0.params.items()}
            n_min, t_apply = plan_shadow_nodes(layout, opt, stats.steady_iter,
                                               tree)
            keeps_up = st.mean_apply_s < stats.steady_iter
            csv_row(f"fig7.{cfg.name}.b{batch}", stats.steady_iter * 1e6,
                    f"iter={stats.steady_iter*1e3:.0f}ms "
                    f"opt_step={st.mean_apply_s*1e3:.1f}ms "
                    f"min_nodes={n_min} keeps_up={keeps_up}")


def gpt2_1_5b_leaf_tree(d: int = 128, vocab: int = 6272, pos: int = 128,
                        n_layers: int = 48) -> dict[str, np.ndarray]:
    """GPT-2 1.5B's per-layer leaf structure (the DDP capture-side view),
    dimension-scaled (default ~12.5x down from d=1600) for a CPU host."""
    rng = np.random.default_rng(0)

    def t(shape):
        return rng.standard_normal(shape).astype(np.float32) * 0.02

    tree = {"wte.w": t((vocab, d)), "wpe.w": t((pos, d))}
    for i in range(n_layers):
        pre = f"h{i}."
        tree.update({
            pre + "ln1.w": t((d,)), pre + "ln1.b": t((d,)),
            pre + "attn.qkv.w": t((d, 3 * d)),
            pre + "attn.qkv.b": t((3 * d,)),
            pre + "attn.proj.w": t((d, d)), pre + "attn.proj.b": t((d,)),
            pre + "ln2.w": t((d,)), pre + "ln2.b": t((d,)),
            pre + "mlp.fc.w": t((d, 4 * d)), pre + "mlp.fc.b": t((4 * d,)),
            pre + "mlp.proj.w": t((4 * d, d)), pre + "mlp.proj.b": t((d,)),
        })
    tree.update({"lnf.w": t((d,)), "lnf.b": t((d,))})
    return tree


def _time_paths(layout, params, grad_steps, opt: OptimizerConfig):
    """Per-step apply seconds through the channel->shadow hot path for the
    flat and the legacy cluster, INTERLEAVED step by step so both paths see
    the same machine conditions (shared CPU containers throttle in bursts);
    the first (compile-heavy) apply is excluded."""
    zeros = {k: np.zeros_like(v) for k, v in params.items()}
    shadows, chans = {}, {}
    for mode, flat in (("flat", True), ("legacy", False)):
        # window sized to the run so the compile-heavy first apply is
        # still in apply_times when we slice it off below
        shadows[mode] = ShadowCluster(layout, opt, n_nodes=1, flat=flat,
                                      apply_times_maxlen=len(grad_steps) + 1)
        shadows[mode].bootstrap(params, zeros, zeros, 0)
        chans[mode] = InProcessChannel()
        chans[mode].open(layout)
    for step, grads in enumerate(grad_steps, start=1):
        for mode in ("flat", "legacy"):
            chans[mode].send(StepEvent(step=step, grads=grads, lr=1e-3))
            for d in chans[mode].poll():
                shadows[mode].on_delivery(d)
    out = {}
    for mode in ("flat", "legacy"):
        chans[mode].close()
        times = list(shadows[mode].nodes[0].apply_times)[1:]
        out[mode] = {"mean_apply_s": float(np.mean(times)),
                     "max_apply_s": float(np.max(times)),
                     "steps": len(times)}
    return out


def _sharded_entry(params, grad_steps,
                   opt: OptimizerConfig) -> tuple[dict, list[str]]:
    """Plan the arctic_480b shadow fleet (metadata only) and time a
    dimension-scaled stand-in sharded across that many nodes.

    The sharded figure of merit is the CRITICAL PATH: nodes apply their
    partitions concurrently in production, so a step costs the slowest
    node's apply, not the sum. The timing layout is rebucketed at a 1 MB
    cap so every node in the fleet actually owns shards (the stand-in is
    ~12.5x dimension-scaled; arctic's real layout has 13k+ buckets), and
    the single-node baseline runs on the SAME layout so per-bucket
    overheads cancel. Returns the report entry plus gate failures (empty
    == all gates pass)."""
    import repro.configs as C
    from repro.core.costmodel import ShadowBudget, shadow_plan_for_config

    budget = ShadowBudget()
    plan = shadow_plan_for_config(C.get("arctic-480b"), budget=budget)

    layout = layout_for_tree(params, cap_bytes=1 << 20)
    zeros = {k: np.zeros_like(v) for k, v in params.items()}
    clusters = {
        "single": ShadowCluster(layout, opt, n_nodes=1,
                                apply_times_maxlen=len(grad_steps) + 1),
        "sharded": ShadowCluster(layout, opt, n_nodes=plan.n_nodes,
                                 apply_times_maxlen=len(grad_steps) + 1),
    }
    chan = InProcessChannel()
    chan.open(layout)
    for c in clusters.values():
        c.bootstrap(params, zeros, zeros, 0)
    for step, grads in enumerate(grad_steps, start=1):
        chan.send(StepEvent(step=step, grads=grads, lr=1e-3))
        for d in chan.poll():
            for c in clusters.values():
                c.on_delivery(d)
    chan.close()
    # slowest owner per step == the distributed fleet's step time; the
    # first (compile-heavy) apply is excluded, empty owners apply in ~0
    per_node = [list(n.apply_times)[1:] for n in clusters["sharded"].nodes
                if n.apply_times]
    n_steps = min(len(t) for t in per_node)
    critical = [max(t[s] for t in per_node) for s in range(n_steps)]
    single_mean_s = float(np.mean(
        list(clusters["single"].nodes[0].apply_times)[1:]))

    entry = {
        "arch": "arctic-480b",
        "plan": {"n_nodes": plan.n_nodes, "ram_bound": plan.ram_bound,
                 "nic_bound": plan.nic_bound, "n_buckets": plan.n_buckets,
                 "grad_bytes": plan.grad_bytes,
                 "state_bytes": plan.state_bytes,
                 "bytes_per_node_max": plan.bytes_per_node_max,
                 "gbps_per_node_max": plan.gbps_per_node_max,
                 "usable_ram_per_node": budget.usable_ram},
        "timing": {"workload": "gpt2-1.5b leaf tree (dim-scaled, "
                               "1 MB buckets)",
                   "n_nodes": plan.n_nodes,
                   "n_timing_buckets": len(layout.buckets),
                   "owners_with_shards": len(per_node),
                   "critical_path_mean_s": float(np.mean(critical)),
                   "critical_path_max_s": float(np.max(critical)),
                   "single_node_mean_s": single_mean_s,
                   "speedup_vs_single": single_mean_s
                   / float(np.mean(critical)),
                   "steps": n_steps},
    }
    fails = []
    if plan.n_nodes < 8:
        fails.append(f"arctic-480b plan is {plan.n_nodes} nodes; the "
                     "frontier fleet must be genuinely sharded (>= 8)")
    if plan.bytes_per_node_max > budget.usable_ram:
        fails.append("per-node peak RSS proxy "
                     f"({plan.bytes_per_node_max / 1e9:.1f} GB) exceeds "
                     f"usable RAM ({budget.usable_ram / 1e9:.1f} GB)")
    if float(np.mean(critical)) >= single_mean_s:
        fails.append("sharded critical path "
                     f"({np.mean(critical) * 1e3:.2f} ms) is not faster "
                     f"than the single-node apply "
                     f"({single_mean_s * 1e3:.2f} ms)")
    return entry, fails


def _overlapped_entry(opt: OptimizerConfig, steps: int = 10,
                      delay_s: float = 0.01,
                      max_lag: int = 3) -> tuple[dict, list[str]]:
    """Overlapped multi-step apply under a throttled applier: the
    bounded-lag cluster (batched K-step catch-up drains) vs the legacy
    unbounded one-delivery-per-wakeup path.

    Both appliers are throttled identically, so the figure of merit is
    backlog shape, not apply speed: the bounded cluster must hold its
    queue at the lag bound and drain in O(K) applies at consolidate, where
    the sequential path backlogs O(steps) and pays for every one of them
    after the last send. Gates on exactly that separation."""
    import time

    def drive(max_lag_steps):
        tree = gpt2_1_5b_leaf_tree(n_layers=4)
        layout = layout_for_tree(tree, cap_bytes=1 << 20)
        shadow = ShadowCluster(layout, opt, n_nodes=2, async_mode=True,
                               max_lag_steps=max_lag_steps)
        for node in shadow.nodes:       # throttle the fused apply itself so
            orig = node._apply          # batched replays pay it per step
            node._apply = (lambda *a, _o=orig:
                           (time.sleep(delay_s), _o(*a))[1])
        zeros = {k: np.zeros_like(v) for k, v in tree.items()}
        shadow.bootstrap(tree, zeros, zeros, 0)
        rng = np.random.default_rng(3)
        grads = {k: rng.standard_normal(v.shape).astype(np.float32) * 0.01
                 for k, v in tree.items()}
        chan = InProcessChannel()
        chan.open(layout)
        for step in range(1, steps + 1):
            chan.send(StepEvent(step=step, grads=grads, lr=1e-3))
            for d in chan.poll():
                shadow.on_delivery(d)
        chan.close()
        t0 = time.perf_counter()
        ck = shadow.consolidate(timeout=120)
        drain_s = time.perf_counter() - t0
        st = shadow.stats()
        shadow.shutdown()
        assert ck["step"] == steps
        return {"max_queue_depth": st.max_queue_depth,
                "batched_applies": st.batched_applies,
                "max_batch": st.max_batch,
                "lag_waits": st.lag_waits,
                "lag_wait_s": st.lag_wait_s,
                "drain_s": drain_s}

    bounded = drive(max_lag)
    unbounded = drive(None)
    entry = {
        "workload": f"async shadow, throttled applier "
                    f"({delay_s * 1e3:.0f} ms/apply), {steps} steps",
        "max_lag_steps": max_lag,
        "bounded": bounded,
        "unbounded": unbounded,
    }
    fails = []
    if bounded["max_queue_depth"] > max_lag:
        fails.append(f"bounded-lag queue reached "
                     f"{bounded['max_queue_depth']}, past the bound "
                     f"{max_lag}")
    if unbounded["max_queue_depth"] <= max_lag:
        fails.append("the throttled sequential path never backlogged past "
                     "the bound — the comparison is vacuous")
    if bounded["batched_applies"] < 1:
        fails.append("no multi-step batched catch-up replay ran on the "
                     "bounded-lag path")
    if bounded["drain_s"] >= unbounded["drain_s"]:
        fails.append(f"bounded-lag drain ({bounded['drain_s']:.3f}s) is "
                     f"not faster than the sequential backlog drain "
                     f"({unbounded['drain_s']:.3f}s)")
    return entry, fails


def run_json(out_path: str = "BENCH_shadow.json", steps: int = 8) -> int:
    opt = OptimizerConfig(lr=1e-3)
    params = gpt2_1_5b_leaf_tree()
    layout = layout_for_tree(params)          # default DDP 25 MB cap
    rng = np.random.default_rng(7)
    grad_steps = [{k: rng.standard_normal(v.shape).astype(np.float32) * 0.01
                   for k, v in params.items()} for _ in range(steps + 1)]

    timed = _time_paths(layout, params, grad_steps, opt)
    flat, legacy = timed["flat"], timed["legacy"]
    speedup = legacy["mean_apply_s"] / flat["mean_apply_s"]
    sharded, shard_fails = _sharded_entry(params, grad_steps, opt)
    overlapped, overlap_fails = _overlapped_entry(opt)
    report = {
        "arch": "gpt2-1.5b (per-layer leaf structure, dim-scaled)",
        "n_buckets": len(layout.buckets),
        "n_leaves": sum(len(b.slots) for b in layout.buckets),
        "state_bytes": layout.total_bytes,
        "flat": flat,
        "legacy": legacy,
        "speedup": speedup,
        "sharded": sharded,
        "overlapped": overlapped,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))
    fails = list(shard_fails) + list(overlap_fails)
    if flat["mean_apply_s"] >= legacy["mean_apply_s"]:
        fails.append("flat apply is not faster than the legacy per-leaf "
                     "path")
    for msg in fails:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if fails else 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="flat-vs-legacy apply benchmark; write "
                         "BENCH_shadow.json and gate on flat being faster")
    ap.add_argument("--out", default="BENCH_shadow.json")
    ap.add_argument("--steps", type=int, default=8)
    args = ap.parse_args()
    if args.json:
        sys.exit(run_json(args.out, steps=args.steps))
    run()
