"""Fig 7: can the shadow keep up? Batch-size sweep — iteration time vs
shadow pull+optimizer time, and the min shadow-node count (§6.3)."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import bench_config, csv_row, smoke_env
from repro.core.buckets import layout_for_tree
from repro.core.shadow import ShadowCluster, plan_shadow_nodes
from repro.optim import OptimizerConfig
from repro.train.loop import train
from repro.train.step import make_train_state


def run():
    mesh, rules = smoke_env()
    opt = OptimizerConfig(lr=1e-3)
    for arch in ("gpt2-1.5b", "vit-h-14"):
        cfg = bench_config(arch)
        for batch in (2, 8, 16):
            s0 = make_train_state(jax.random.PRNGKey(0), cfg, rules)
            layout = layout_for_tree(s0.params)
            shadow = ShadowCluster(layout, opt, n_nodes=1)
            shadow.bootstrap(s0.params, s0.mu, s0.nu, 0)
            from repro.core.checkpoint import CheckmateCheckpointer
            _, stats = train(cfg, rules, steps=5, batch=batch, seq=64,
                             opt=opt, state=s0,
                             checkpointer=CheckmateCheckpointer(shadow))
            st = shadow.stats()
            tree = {k: np.asarray(v) for k, v in s0.params.items()}
            n_min, t_apply = plan_shadow_nodes(layout, opt, stats.steady_iter,
                                               tree)
            keeps_up = st.mean_apply_s < stats.steady_iter
            csv_row(f"fig7.{cfg.name}.b{batch}", stats.steady_iter * 1e6,
                    f"iter={stats.steady_iter*1e3:.0f}ms "
                    f"opt_step={st.mean_apply_s*1e3:.1f}ms "
                    f"min_nodes={n_min} keeps_up={keeps_up}")


if __name__ == "__main__":
    run()
