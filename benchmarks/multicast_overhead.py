"""Fig 10: replication-factor sweep on the packet simulator — AllReduce bus
bandwidth and switch TX/RX frame counts (only tagged packets replicate)."""
from __future__ import annotations

from benchmarks.common import csv_row
from repro.net.simulator import simulate_allgather_replication


def run():
    base = None
    for rf in (1, 2, 4, 8, 16):
        r = simulate_allgather_replication(
            4, 1 << 30, link_gbps=100.0, replication_factor=rf,
            # Fig 10 attaches one dedicated port per replica: drain scales
            shadow_drain_gbps=100.0 * 2 * rf)
        base = base or r.bus_bandwidth_gbps
        csv_row(f"fig10.rf{rf}", r.duration_s * 1e6,
                f"busbw={r.bus_bandwidth_gbps:.1f}Gbps "
                f"tx_over_rx={r.tx_over_rx:.2f} ok={r.reassembled_ok} "
                f"drops={r.drops}")
    csv_row("fig10.busbw_constant", 0.0,
            f"{abs(base - r.bus_bandwidth_gbps) < 1e-6}")


if __name__ == "__main__":
    run()
