"""Fig 10: replication-factor sweep on the packet simulator — AllReduce bus
bandwidth and switch TX/RX frame counts (only tagged packets replicate) —
plus per-channel send-side overhead (in-process vs packetized vs
compressed) on the `GradientChannel` delivery API."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row
from repro.core.buckets import layout_for_tree
from repro.core.channel import (CompressedChannel, InProcessChannel,
                                PacketizedChannel, StepEvent)
from repro.net.simulator import simulate_allgather_replication


def run():
    base = None
    for rf in (1, 2, 4, 8, 16):
        r = simulate_allgather_replication(
            4, 1 << 30, link_gbps=100.0, replication_factor=rf,
            # Fig 10 attaches one dedicated port per replica: drain scales
            shadow_drain_gbps=100.0 * 2 * rf)
        base = base or r.bus_bandwidth_gbps
        csv_row(f"fig10.rf{rf}", r.duration_s * 1e6,
                f"busbw={r.bus_bandwidth_gbps:.1f}Gbps "
                f"tx_over_rx={r.tx_over_rx:.2f} ok={r.reassembled_ok} "
                f"drops={r.drops}")
    csv_row("fig10.busbw_constant", 0.0,
            f"{abs(base - r.bus_bandwidth_gbps) < 1e-6}")

    # -- per-channel send-side overhead (capture critical path) --------------
    rng = np.random.default_rng(0)
    tree = {f"layer{i}.w": rng.standard_normal((256, 512)).astype(np.float32)
            for i in range(8)}
    layout = layout_for_tree(tree, cap_bytes=1 << 20)
    channels = [
        ("inprocess", InProcessChannel()),
        ("packetized", PacketizedChannel(topology="rail-optimized",
                                         n_dp_groups=2, ranks_per_group=4)),
        ("compressed", CompressedChannel(InProcessChannel())),
    ]
    for name, chan in channels:
        chan.open(layout)
        chan.send(StepEvent(step=1, grads=tree, lr=1e-3))    # warmup
        chan.poll()
        reps = []
        for r_i in range(3):
            t0 = time.perf_counter()
            chan.send(StepEvent(step=2 + r_i, grads=tree, lr=1e-3))
            reps.append(time.perf_counter() - t0)
        ds = chan.poll()
        ok = all(d.complete for d in ds)
        wire = ds[-1].wire_bytes
        chan.close()
        csv_row(f"channel_send.{name}", min(reps) * 1e6,
                f"wire_bytes={wire} complete={ok}")


if __name__ == "__main__":
    run()
