"""Fig 10: replication-factor sweep on the packet simulator — AllReduce bus
bandwidth and switch TX/RX frame counts (only tagged packets replicate) —
plus per-channel send-side overhead (in-process vs packetized vs
compressed) measured from the checkpointer's stall-attribution ledger
(`repro.obs.stalls`), so the number reported here is the same decomposition
the observability plane books at run time."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.core.buckets import layout_for_tree
from repro.core.channel import (CompressedChannel, InProcessChannel,
                                PacketizedChannel, StepEvent)
from repro.core.checkpoint import CheckmateCheckpointer
from repro.core.shadow import ShadowCluster
from repro.net.simulator import simulate_allgather_replication
from repro.optim import OptimizerConfig

SEND_STAGES = ("send", "quantize")       # the channel-attributed stall


def run(registry=None):
    base = None
    for rf in (1, 2, 4, 8, 16):
        r = simulate_allgather_replication(
            4, 1 << 30, link_gbps=100.0, replication_factor=rf,
            # Fig 10 attaches one dedicated port per replica: drain scales
            shadow_drain_gbps=100.0 * 2 * rf)
        base = base or r.bus_bandwidth_gbps
        csv_row(f"fig10.rf{rf}", r.duration_s * 1e6,
                f"busbw={r.bus_bandwidth_gbps:.1f}Gbps "
                f"tx_over_rx={r.tx_over_rx:.2f} ok={r.reassembled_ok} "
                f"drops={r.drops}")
    csv_row("fig10.busbw_constant", 0.0,
            f"{abs(base - r.bus_bandwidth_gbps) < 1e-6}")

    # -- per-channel send-side overhead (capture critical path) --------------
    # Measured from the stall-attribution ledger: drive the real
    # CheckmateCheckpointer over each channel and report the channel-
    # attributed stages (send + quantize) per step — the same decomposition
    # `repro.obs summary` prints, rather than a one-off wall timing around
    # send() (which would also charge the fabric event loop / inline apply).
    rng = np.random.default_rng(0)
    tree = {f"layer{i}.w": rng.standard_normal((256, 512)).astype(np.float32)
            for i in range(8)}
    layout = layout_for_tree(tree, cap_bytes=1 << 20)
    zeros = {k: np.zeros_like(v) for k, v in tree.items()}
    opt = OptimizerConfig(name="sgd", lr=1e-3)
    channels = [
        ("inprocess", lambda: InProcessChannel()),
        ("packetized", lambda: PacketizedChannel(topology="rail-optimized",
                                                 n_dp_groups=2,
                                                 ranks_per_group=4)),
        ("compressed", lambda: CompressedChannel(InProcessChannel())),
    ]
    n_reps = 3
    for name, make in channels:
        chan = make()
        shadow = ShadowCluster(layout, opt, n_nodes=2)
        shadow.bootstrap(tree, zeros, zeros, 0)
        ck = CheckmateCheckpointer(shadow, channel=chan)
        ck.on_step(StepEvent(step=1, grads=tree, lr=1e-3))      # warmup
        base = dict(ck.stall_stages)
        for r_i in range(n_reps):
            ck.on_step(StepEvent(step=2 + r_i, grads=tree, lr=1e-3))
        delta = {k: v - base.get(k, 0.0)
                 for k, v in ck.stall_stages.items()}
        send_s = sum(delta.get(s, 0.0) for s in SEND_STAGES)
        breakdown = " ".join(f"{k}={v / n_reps * 1e6:.1f}us"
                             for k, v in sorted(delta.items()))
        chan.close()
        csv_row(f"channel_send.{name}", send_s / n_reps * 1e6, breakdown)
        if registry is not None:
            from repro.obs.publish import publish_channel
            from repro.obs.stalls import publish_stalls
            publish_stalls(registry, ck, labels={"bench": name})
            publish_channel(registry, chan)


if __name__ == "__main__":
    run()
