"""Fig 1 + Fig 11 + §6.7: cost-model curves (wasted GPU-hours vs frequency,
savings vs scale/failure-rate/overhead)."""
from __future__ import annotations

import time

from benchmarks.common import csv_row
from repro.core import costmodel as cm


def run():
    p = cm.CostParams()
    t0 = time.perf_counter()

    # -- Fig 1: wasted GPU-hours vs checkpoint frequency ----------------------
    freqs = [1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024]
    curve = cm.sweep_frequencies(p, freqs)
    best_f, best_w = min(curve, key=lambda kv: kv[1])
    ck = cm.wasted_gpu_hours_checkmate(p)
    csv_row("fig1.best_frequency", 0.0, f"f*={best_f}")
    csv_row("fig1.sota_min_gpu_hours", 0.0, f"{best_w:.0f}")
    csv_row("fig1.checkmate_gpu_hours", 0.0,
            f"{ck:.0f} (paper: 4367; cut={1 - ck / best_w:.1%})")
    f30 = 30 * 60 / p.iter_time_s
    csv_row("fig1.30min_interval_gpu_hours", 0.0,
            f"{cm.wasted_gpu_hours_sota(f30, p):.0f} (paper: ~1.7M)")

    # -- Fig 11: savings sweeps ------------------------------------------------
    for rate, tag in [(2.0e-5, "meta_rate"), (1.0e-6, "low_rate")]:
        q = cm.CostParams(failure_rate=rate)
        sw = cm.sweep_overhead(q, [0.01, 1.2], [4096, 16384])
        for n, rows in sw.items():
            for w, saved in rows:
                csv_row(f"fig11.{tag}.N{n}.omega{w}", 0.0,
                        f"saved_gpu_h_per_day={saved:.0f}")
    lo = cm.gpu_hours_saved_per_day(cm.CostParams(failure_rate=1e-6)) * 54
    csv_row("fig11.54day_low_rate_total", 0.0,
            f"{lo:.0f} (paper: ~70000)")

    # -- validation anchors (Appendix A) --------------------------------------
    csv_row("appA.iter_time_s", 0.0,
            f"{cm.iteration_time(cm.LLAMA3_405B, 400e12, 16384):.2f} (paper 4.58)")
    csv_row("appA.ckpt_time_s", 0.0,
            f"{cm.checkpoint_time(405e9):.2f} (paper 1.2)")
    csv_row("appB.cpu_node_hours", 0.0,
            f"{cm.cpu_node_hours(p):.0f} (paper 166K)")
    csv_row("savings.total_usd", (time.perf_counter() - t0) * 1e6,
            f"{cm.savings_usd(p):.0f} (paper ~2.6M)")


if __name__ == "__main__":
    run()
