"""Quickstart: train a small LM with Checkmate per-iteration checkpointing.

Runs on CPU in ~a minute. Shows the three-plane wiring:
  training plane  -> train_step returns reduce-scattered gradients,
  network plane   -> a PacketizedChannel packs buckets into MTU frames and
                     routes them through the simulated multicast fabric,
  shadow plane    -> CPU nodes replay the functional optimizer per iteration.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

import repro.configs as C
from repro.core.buckets import layout_for_tree
from repro.core.channel import PacketizedChannel
from repro.core.checkpoint import CheckmateCheckpointer
from repro.core.shadow import ShadowCluster
from repro.dist.sharding import ShardingRules, make_smoke_mesh
from repro.optim import OptimizerConfig
from repro.train.loop import train
from repro.train.step import make_train_state


def main():
    cfg = C.get("tinyllama-1.1b").reduced()     # tiny same-family config
    mesh = make_smoke_mesh()
    rules = ShardingRules(mesh)
    opt = OptimizerConfig(lr=1e-3)

    # Bootstrap the shadow cluster with the initial replica.
    state0 = make_train_state(jax.random.PRNGKey(0), cfg, rules)
    layout = layout_for_tree(state0.params)
    shadow = ShadowCluster(layout, opt, n_nodes=2, async_mode=True)
    shadow.bootstrap(state0.params, state0.mu, state0.nu, 0)

    # Every gradient reaches the shadow plane through ONE channel: here the
    # full paper dataflow (buckets -> frames -> fabric -> reassembly).
    channel = PacketizedChannel(topology="rail-optimized",
                                n_dp_groups=2, ranks_per_group=4)
    state, stats = train(
        cfg, rules, steps=20, batch=8, seq=64, opt=opt,
        checkpointer=CheckmateCheckpointer(shadow, channel=channel),
        state=state0)

    ckpt = shadow.consolidate()
    s = shadow.stats()
    exact = all(np.array_equal(np.asarray(state.params[k]), ckpt["params"][k])
                for k in state.params)
    print(f"steps={stats.steps} loss {stats.losses[0]:.3f} -> {stats.losses[-1]:.3f}")
    print(f"checkpoints (per-iteration): {ckpt['step']}")
    print(f"shadow lag={s.lag} mean_apply={s.mean_apply_s*1e3:.1f}ms "
          f"(iter={stats.mean_iter*1e3:.1f}ms) -> keeps up: "
          f"{s.mean_apply_s < stats.mean_iter}")
    print(f"shadow checkpoint bit-identical to training state: {exact}")
    shadow.shutdown()
    assert exact


if __name__ == "__main__":
    main()
