"""End-to-end driver at the ~100M-parameter scale: a GPT-class model trained
with Checkmate per-iteration checkpointing + a mid-run injected failure,
recovered from the shadow cluster.

    PYTHONPATH=src python examples/train_100m.py [--steps 120]

(~112M params; on this single CPU core a step is a few seconds — scale
--steps to taste. On a pod, use repro.launch.train with a full config.)
"""
import argparse
import json
import time
from dataclasses import replace

import numpy as np
import jax

import repro.configs as C
from repro.core.buckets import layout_for_tree
from repro.core.checkpoint import CheckmateCheckpointer
from repro.core.recovery import FailurePlan
from repro.core.shadow import ShadowCluster
from repro.dist.sharding import ShardingRules, make_smoke_mesh
from repro.optim import OptimizerConfig
from repro.optim.schedules import cosine_schedule
from repro.train.loop import train
from repro.train.step import make_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--fail-at", type=int, default=60)
    args = ap.parse_args()

    cfg = replace(C.get("gpt2-1.5b"),
                  name="gpt2-100m", num_layers=12, d_model=768, num_heads=12,
                  num_kv_heads=12, head_dim=64, d_ff=3072, vocab_size=16384,
                  microbatches=1, attn_q_chunk=128)
    n = cfg.param_count()
    print(f"model: {cfg.name} — {n/1e6:.1f}M params")

    mesh = make_smoke_mesh()
    rules = ShardingRules(mesh)
    opt = OptimizerConfig(lr=3e-4, weight_decay=0.1)
    lr_fn = cosine_schedule(3e-4, warmup=10, total=args.steps)

    state0 = make_train_state(jax.random.PRNGKey(0), cfg, rules)
    shadow = ShadowCluster(layout_for_tree(state0.params), opt, n_nodes=2,
                           async_mode=True)
    shadow.bootstrap(state0.params, state0.mu, state0.nu, 0)

    t0 = time.time()
    state, stats = train(
        cfg, rules, steps=args.steps, batch=args.batch, seq=args.seq,
        opt=opt, lr_fn=lr_fn, state=state0,
        checkpointer=CheckmateCheckpointer(shadow),
        failure_plan=FailurePlan((args.fail_at,)))
    wall = time.time() - t0

    ckpt = shadow.consolidate()
    s = shadow.stats()
    exact = all(np.array_equal(np.asarray(state.params[k]), ckpt["params"][k])
                for k in state.params)
    print(json.dumps({
        "params_M": round(n / 1e6, 1),
        "steps": stats.steps,
        "loss_first": round(stats.losses[0], 3),
        "loss_last": round(float(np.mean(stats.losses[-5:])), 3),
        "steady_iter_s": round(stats.steady_iter, 2),
        "recoveries": stats.recoveries,
        "checkpoints": ckpt["step"],
        "shadow_mean_apply_s": round(s.mean_apply_s, 3),
        "shadow_keeps_up": s.mean_apply_s < stats.steady_iter,
        "shadow_bit_identical": exact,
        "wall_s": round(wall, 1),
    }, indent=2))
    shadow.shutdown()
    assert exact and stats.losses[-1] < stats.losses[0]


if __name__ == "__main__":
    main()
