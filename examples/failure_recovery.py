"""Failure recovery (paper Fig 9): a run with injected failures, recovered
from the per-iteration shadow checkpoint, converges IDENTICALLY to an
uninterrupted run — bit-for-bit.

All gradients flow through a `PacketizedChannel` (buckets -> frames ->
fabric -> reassembly). The second failure is compounded: the fabric loses
step 11's capture mid-iteration (shadow-NIC cut), the channel reports a
gated delivery, and when training fails at step 12 recovery lands on the
last FULLY captured step (10) — no manual lost-step bookkeeping anywhere.

    PYTHONPATH=src python examples/failure_recovery.py
"""
import numpy as np
import jax

import repro.configs as C
from repro.core.buckets import layout_for_tree
from repro.core.channel import PacketizedChannel
from repro.core.checkpoint import CheckmateCheckpointer
from repro.core.recovery import FailurePlan
from repro.core.shadow import ShadowCluster
from repro.dist.sharding import ShardingRules, make_smoke_mesh
from repro.optim import OptimizerConfig
from repro.train.loop import train
from repro.train.step import make_train_state


def main():
    cfg = C.get("llama3.2-3b").reduced()
    mesh = make_smoke_mesh()
    rules = ShardingRules(mesh)
    opt = OptimizerConfig(lr=1e-3)
    steps, batch, seq, seed = 16, 8, 64, 7

    # Run A: uninterrupted.
    state_a, stats_a = train(cfg, rules, steps=steps, batch=batch, seq=seq,
                             opt=opt, seed=seed)

    # Run B: training failures at steps 6 and 12; the fabric additionally
    # loses step 11's capture, gating that delivery.
    s0 = make_train_state(jax.random.PRNGKey(seed), cfg, rules)
    shadow = ShadowCluster(layout_for_tree(s0.params), opt, n_nodes=2)
    shadow.bootstrap(s0.params, s0.mu, s0.nu, 0)
    channel = PacketizedChannel(topology="rail-optimized",
                                n_dp_groups=2, ranks_per_group=4,
                                failures_at={11: "capture"})
    ck = CheckmateCheckpointer(shadow, channel=channel)
    state_b, stats_b = train(cfg, rules, steps=steps, batch=batch, seq=seq,
                             opt=opt, seed=seed, state=s0, checkpointer=ck,
                             failure_plan=FailurePlan((6, 12)))

    same = all(np.array_equal(np.asarray(state_a.params[k]),
                              np.asarray(state_b.params[k]))
               for k in state_a.params)
    print(f"run A losses: {[f'{l:.4f}' for l in stats_a.losses[-4:]]}")
    print(f"run B losses: {[f'{l:.4f}' for l in stats_b.losses[-4:]]}")
    print(f"failures={stats_b.failures} recoveries={stats_b.recoveries} "
          f"recovered_at={stats_b.recovered_at} "
          f"gated_captures={ck.skipped_steps}")
    print(f"final states identical: {same}")
    assert same and stats_b.recoveries == 2
    # fully-per-iteration recovery at 5; capture-gated recovery at 10
    assert stats_b.recovered_at == [5, 10]
    assert ck.skipped_steps == [11]


if __name__ == "__main__":
    main()
