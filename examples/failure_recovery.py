"""Failure recovery (paper Fig 9): a run with injected failures, recovered
from the per-iteration shadow checkpoint, converges IDENTICALLY to an
uninterrupted run — bit-for-bit.

Failure injection goes through the chaos harness (`repro.harness`,
docs/harness.md) — the one blessed path: a declarative Scenario drives
train loop -> PacketizedChannel (buckets -> frames -> fabric ->
reassembly) -> shadow plane -> recovery, and the invariant registry
(resume-bit-identity, replay-determinism, contiguity, exactly-once,
zero-overhead accounting) checks every step. The second failure is
compounded: the fabric loses step 11's capture mid-iteration (shadow-NIC
cut), the channel reports a gated delivery, and when training fails at
step 12 recovery lands on the last FULLY captured step (10) — no manual
lost-step bookkeeping anywhere.

    PYTHONPATH=src python examples/failure_recovery.py
"""
import numpy as np

from repro.harness import (ChannelSpec, FabricFailure, FailureSchedule,
                           Scenario, run_scenario)


def main():
    scenario = Scenario(
        name="failure-recovery-example", level="full",
        arch="llama3.2-3b", steps=16, batch=8, seq=64, seed=7,
        channel=ChannelSpec(kind="packetized", topology="rail-optimized",
                            n_dp_groups=2, ranks_per_group=4),
        schedule=FailureSchedule(
            train_fail_steps=(6, 12),
            fabric=(FabricFailure(step=11, kind="capture"),)))

    result = run_scenario(scenario)
    trace = result.trace
    stats, ck = trace.stats, trace.checkpointer

    same = all(np.array_equal(trace.final["params"][k],
                              trace.ref_final["params"][k])
               for k in trace.ref_final["params"])
    print(f"run A losses: {[f'{l:.4f}' for l in trace.ref_losses[-4:]]}")
    print(f"run B losses: {[f'{l:.4f}' for l in stats.losses[-4:]]}")
    print(f"failures={stats.failures} recoveries={stats.recoveries} "
          f"recovered_at={stats.recovered_at} "
          f"gated_captures={ck.skipped_steps}")
    print(f"final states identical: {same}")
    print(f"invariants: {'all passed' if result.passed else result.violations}")
    assert result.passed and same and stats.recoveries == 2
    # fully-per-iteration recovery at 5; capture-gated recovery at 10
    assert stats.recovered_at == [5, 10]
    assert ck.skipped_steps == [11]


if __name__ == "__main__":
    main()
