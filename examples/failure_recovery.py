"""Failure recovery (paper Fig 9): a run with injected failures, recovered
from the per-iteration shadow checkpoint, converges IDENTICALLY to an
uninterrupted run — bit-for-bit.

    PYTHONPATH=src python examples/failure_recovery.py
"""
import numpy as np
import jax

import repro.configs as C
from repro.core.buckets import layout_for_tree
from repro.core.checkpoint import CheckmateCheckpointer
from repro.core.recovery import FailurePlan
from repro.core.shadow import ShadowCluster
from repro.dist.sharding import ShardingRules, make_smoke_mesh
from repro.optim import OptimizerConfig
from repro.train.loop import train
from repro.train.step import make_train_state


def main():
    cfg = C.get("llama3.2-3b").reduced()
    mesh = make_smoke_mesh()
    rules = ShardingRules(mesh)
    opt = OptimizerConfig(lr=1e-3)
    steps, batch, seq, seed = 16, 8, 64, 7

    # Run A: uninterrupted.
    state_a, stats_a = train(cfg, rules, steps=steps, batch=batch, seq=seq,
                             opt=opt, seed=seed)

    # Run B: failures at steps 6 and 12, recovery from shadow.
    s0 = make_train_state(jax.random.PRNGKey(seed), cfg, rules)
    shadow = ShadowCluster(layout_for_tree(s0.params), opt, n_nodes=2)
    shadow.bootstrap(s0.params, s0.mu, s0.nu, 0)
    state_b, stats_b = train(cfg, rules, steps=steps, batch=batch, seq=seq,
                             opt=opt, seed=seed, state=s0,
                             checkpointer=CheckmateCheckpointer(shadow),
                             failure_plan=FailurePlan((6, 12)))

    same = all(np.array_equal(np.asarray(state_a.params[k]),
                              np.asarray(state_b.params[k]))
               for k in state_a.params)
    print(f"run A losses: {[f'{l:.4f}' for l in stats_a.losses[-4:]]}")
    print(f"run B losses: {[f'{l:.4f}' for l in stats_b.losses[-4:]]}")
    print(f"failures={stats_b.failures} recoveries={stats_b.recoveries} "
          f"recovered_at={stats_b.recovered_at}")
    print(f"final states identical: {same}")
    assert same and stats_b.recoveries == 2


if __name__ == "__main__":
    main()
